"""DMA-hazard detector for double-buffered copy schedules.

The manual ``make_async_copy`` pipelines in ``kernels/conv2d.py`` and
``kernels/matmul.py`` follow one shape: per reduction step ``ci`` warm up
slot 0 on the first step, prefetch step ``ci+1`` into the other slot, wait
on ``ci``'s slot, then read it. :func:`double_buffered_schedule` emits that
event stream; :func:`check_schedule` simulates it and reports every hazard:

  H1 read-before-wait      a step reads slot data it never waited for
  H2 double-start          two in-flight copies target one slot
  H3 reuse-distance        a slot is refilled < n_slots steps after its
                           previous fill (the prefetch would race the
                           compute still consuming it)
  H4 inflight-read         a copy is in flight into a slot the current
                           grid step reads
  H5 dangling-start        an in-flight copy is never waited before the
                           schedule ends
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

START, WAIT, READ = "start", "wait", "read"


@dataclasses.dataclass(frozen=True)
class DmaEvent:
    kind: str  # start | wait | read
    slot: int
    step: int  # reduction-step payload the event moves/consumes


@dataclasses.dataclass(frozen=True)
class DmaSchedule:
    """Event stream for one double-buffered operand stream."""

    n_slots: int
    n_steps: int
    events: Tuple[DmaEvent, ...]
    name: str = "stream"


@dataclasses.dataclass(frozen=True)
class Hazard:
    code: str  # H1..H5
    event_index: int  # -1 for end-of-schedule hazards
    message: str

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.code}@{self.event_index}: {self.message}"


def double_buffered_schedule(n_steps: int, n_slots: int = 2,
                             name: str = "stream") -> DmaSchedule:
    """The schedule the PR-4 kernels issue across the reduction axis."""
    ev: List[DmaEvent] = []
    for ci in range(n_steps):
        slot = ci % n_slots
        if ci == 0:
            ev.append(DmaEvent(START, 0, 0))
        if ci + 1 < n_steps:
            ev.append(DmaEvent(START, (ci + 1) % n_slots, ci + 1))
        ev.append(DmaEvent(WAIT, slot, ci))
        ev.append(DmaEvent(READ, slot, ci))
    return DmaSchedule(n_slots=n_slots, n_steps=n_steps, events=tuple(ev),
                       name=name)


def check_schedule(sched: DmaSchedule) -> List[Hazard]:
    """Simulate the event stream; return every hazard found (empty = clean)."""
    n = sched.n_slots
    inflight: List[Optional[int]] = [None] * n  # step being copied into slot
    ready: List[Optional[int]] = [None] * n  # step landed in slot
    unread: List[bool] = [False] * n  # landed but not yet consumed
    last_fill: List[Optional[int]] = [None] * n  # step of previous fill
    hazards: List[Hazard] = []

    def bad(code: str, i: int, msg: str) -> None:
        hazards.append(Hazard(code, i, f"[{sched.name}] {msg}"))

    for i, ev in enumerate(sched.events):
        if ev.slot < 0 or ev.slot >= n:
            bad("H2", i, f"event targets slot {ev.slot} outside 0..{n - 1}")
            continue
        if ev.kind == START:
            if inflight[ev.slot] is not None:
                bad("H2", i, f"start(step {ev.step}) while step "
                             f"{inflight[ev.slot]} is still in flight into "
                             f"slot {ev.slot}")
            if unread[ev.slot]:
                bad("H3", i, f"start(step {ev.step}) overwrites slot "
                             f"{ev.slot} before step {ready[ev.slot]} was "
                             f"read")
            if (last_fill[ev.slot] is not None
                    and ev.step - last_fill[ev.slot] < n):
                bad("H3", i, f"slot {ev.slot} reused after "
                             f"{ev.step - last_fill[ev.slot]} steps "
                             f"(< {n} buffers)")
            inflight[ev.slot] = ev.step
            last_fill[ev.slot] = ev.step
        elif ev.kind == WAIT:
            if inflight[ev.slot] != ev.step:
                bad("H1", i, f"wait(step {ev.step}, slot {ev.slot}) without "
                             f"a matching start (in flight: "
                             f"{inflight[ev.slot]})")
            else:
                inflight[ev.slot] = None
                ready[ev.slot] = ev.step
                unread[ev.slot] = True
        elif ev.kind == READ:
            if inflight[ev.slot] is not None:
                bad("H4", i, f"step {ev.step} reads slot {ev.slot} while "
                             f"step {inflight[ev.slot]} is being copied "
                             f"into it")
            if ready[ev.slot] != ev.step:
                bad("H1", i, f"step {ev.step} reads slot {ev.slot} but the "
                             f"slot holds "
                             f"{'nothing' if ready[ev.slot] is None else f'step {ready[ev.slot]}'}"
                             f" (missing wait)")
            unread[ev.slot] = False
        else:  # pragma: no cover - malformed schedule
            bad("H1", i, f"unknown event kind {ev.kind!r}")
    for slot, step in enumerate(inflight):
        if step is not None:
            hazards.append(Hazard(
                "H5", -1, f"[{sched.name}] copy of step {step} into slot "
                          f"{slot} never waited before schedule end"))
    return hazards
