"""``repro.verify.lint`` — structural invariants over the source tree and
the op registry. Stdlib-only (``ast`` + ``inspect``); run as

    PYTHONPATH=src python -m repro.verify.lint [root ...]

and exits 1 on any violation. CI runs it alongside ruff.

Source rules (AST, so prose in comments/docstrings never trips them):

  VRF001  ``pl.pallas_call`` outside ``kernels/`` — every launch lives in the
          kernel layer, where it carries a words_fn + access plan.
  VRF002  ``make_async_copy`` outside ``kernels/`` — manual DMA without an
          auditable schedule.
  VRF003  ``jnp.repeat`` on a KV-named tensor outside ``kernels/`` — the old
          GQA wrapper materialized repeated K/V in HBM (g x the traffic);
          the dispatch layer keeps heads factored. (``kernels/ref.py``'s
          repeat is the XLA reference semantics, hence the kernels/ scope.)
  VRF013  (kernels/ only) ``<acc...>.astype(<narrow dtype>)`` — casting an
          accumulator below float32 silently trades the quantization
          error model (int8 storage, exact f32 accumulation) for a lossy
          one. Casting the *final store* to the output dtype is fine; the
          rule only fires when the cast target is a narrow dtype literal
          (bfloat16/float16/int8/fp8), not e.g. ``o_ref.dtype``.
  VRF014  (repro/{ops,serving,distributed}/ only) ``raise RuntimeError`` —
          runtime layers raise the ``repro.resilience.errors`` taxonomy
          (transient vs fatal, diagnostics attached) so handlers can route
          on recoverability; a bare RuntimeError is unclassifiable.
          Re-raises (``raise`` with no exception) and other exception
          types are untouched.
  VRF015  legacy kernel kwargs outside ``kernels/`` — a call to a public
          kernel entry point (conv2d, matmul, conv2d_q, matmul_q,
          conv2d_shard, conv2d_im2col) passing ``plan=``, ``target=`` or
          ``tiles=`` keywords. Execution policy rides one
          ``ctx=ExecutionContext(...)`` since the planning-API redesign;
          the old kwargs survive as one-release DeprecationWarning shims,
          and this rule keeps new in-repo uses from creeping back in.
          The dispatch adapters in ``ops/registry.py`` import kernels
          under private aliases (``_conv2d_pallas`` …) for their
          explicit-plan handoff, so the terminal-name match exempts them
          by construction.

Registry rules (imported live, so they track what's actually registered):

  VRF010  every op entry of an instrumented backend (one with a fallback,
          i.e. not the terminal xla tier that delegates data movement to the
          compiler) declares a ``words_fn``.
  VRF011  every ``words_fn`` entry also declares an ``access_plan_fn`` so
          the static auditor can cross-check it — except the ``*_dist`` ops,
          whose execution is a shard_map program, not one Pallas launch.
  VRF012  declared capability flags match the entry fn's signature (e.g. a
          ``per_row_q_offset`` flag on an fn with no ``q_offset`` parameter
          would dispatch calls the kernel cannot honor).
  VRF013  every entry whose declared dtypes include a sub-byte-word storage
          format (int8 / fp8) also declares ``caps.accum_dtype`` at f32 or
          wider — quantized storage without a stated accumulation contract
          is unauditable.
"""

from __future__ import annotations

import ast
import dataclasses
import inspect
import sys
from pathlib import Path
from typing import List, Optional, Sequence

# tensors whose repeat re-materializes a KV cache (VRF003)
_KV_NAMES = frozenset({
    "k", "v", "kp", "vp", "kk", "vv", "key", "value", "keys", "values",
    "k_cache", "v_cache", "k_pool", "v_pool", "k_pages", "v_pages",
})

# capability flag -> parameter the entry fn must accept (VRF012)
_FLAG_PARAMS = {
    "dynamic_q_offset": "q_offset",
    "per_row_q_offset": "q_offset",
    "key_mask": "key_mask",
}

# storage dtypes that demand a declared accumulation dtype (VRF013)
_QUANT_DTYPES = frozenset({
    "int8", "uint8", "float8_e4m3fn", "float8_e5m2", "fp8", "int4",
})
# dtype literals an accumulator must never be cast down to (VRF013)
_NARROW_DTYPES = frozenset({
    "bfloat16", "float16", "int8", "uint8", "float8_e4m3fn", "float8_e5m2",
})
# accumulation dtypes wide enough to satisfy VRF013
_WIDE_ACCUM = frozenset({"float32", "float64", "int32", "int64"})

# public kernel entry points whose legacy kwargs VRF015 polices
_KERNEL_ENTRY_POINTS = frozenset({
    "conv2d", "matmul", "conv2d_q", "matmul_q", "conv2d_shard",
    "conv2d_im2col",
})
# the retired per-call kwargs (now carried by ExecutionContext)
_LEGACY_KERNEL_KWARGS = frozenset({"plan", "target", "tiles"})


@dataclasses.dataclass(frozen=True)
class Violation:
    code: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _terminal_name(node: ast.AST) -> Optional[str]:
    """`x` -> "x", `a.b.kv` -> "kv"; None for anything else."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _base_name(node: ast.AST) -> Optional[str]:
    """Like :func:`_terminal_name` but seeing through subscripts and calls,
    so ``acc_ref[...]`` and ``acc.sum()`` both resolve to their base name."""
    while isinstance(node, (ast.Subscript, ast.Call)):
        node = node.value if isinstance(node, ast.Subscript) else node.func
    return _terminal_name(node)


def _narrow_dtype_literal(node: ast.AST) -> Optional[str]:
    """The narrow-dtype name if ``node`` is a literal like ``jnp.bfloat16``
    or ``"int8"``; None for dynamic expressions such as ``o_ref.dtype``."""
    name = _terminal_name(node)
    if name is None and isinstance(node, ast.Constant) \
            and isinstance(node.value, str):
        name = node.value
    return name if name in _NARROW_DTYPES else None


class _Checker(ast.NodeVisitor):
    def __init__(self, path: Path, rel: str, in_kernels: bool,
                 in_runtime: bool = False):
        self.rel = rel
        self.in_kernels = in_kernels
        self.in_runtime = in_runtime
        self.found: List[Violation] = []

    def visit_Raise(self, node: ast.Raise) -> None:
        if self.in_runtime and node.exc is not None:
            raised = node.exc
            if isinstance(raised, ast.Call):
                raised = raised.func
            if _terminal_name(raised) == "RuntimeError":
                self.found.append(Violation(
                    "VRF014", self.rel, node.lineno,
                    "bare RuntimeError in a runtime layer — raise a "
                    "repro.resilience.errors fault (transient/fatal "
                    "classified, diagnostics attached)"))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        callee = _terminal_name(node.func)
        if self.in_kernels:
            if (callee == "astype" and isinstance(node.func, ast.Attribute)
                    and node.args):
                base = _base_name(node.func.value)
                narrow = _narrow_dtype_literal(node.args[0])
                if base is not None and "acc" in base and narrow is not None:
                    self.found.append(Violation(
                        "VRF013", self.rel, node.lineno,
                        f"accumulator {base!r} cast down to {narrow} — "
                        "accumulate in f32, cast only the final store"))
        if not self.in_kernels:
            if callee == "pallas_call":
                self.found.append(Violation(
                    "VRF001", self.rel, node.lineno,
                    "pl.pallas_call outside kernels/ (uninstrumented launch)"))
            elif callee == "make_async_copy":
                self.found.append(Violation(
                    "VRF002", self.rel, node.lineno,
                    "make_async_copy outside kernels/ (unaudited manual DMA)"))
            elif callee == "repeat" and node.args:
                arg = _terminal_name(node.args[0])
                if arg in _KV_NAMES:
                    self.found.append(Violation(
                        "VRF003", self.rel, node.lineno,
                        f"jnp.repeat on KV tensor {arg!r} re-materializes "
                        "the cache (keep GQA heads factored)"))
            elif callee in _KERNEL_ENTRY_POINTS:
                legacy = sorted(
                    kw.arg for kw in node.keywords
                    if kw.arg in _LEGACY_KERNEL_KWARGS)
                if legacy:
                    self.found.append(Violation(
                        "VRF015", self.rel, node.lineno,
                        f"legacy kernel kwargs {legacy} on {callee}() — "
                        "pass ctx=ExecutionContext(...) instead"))
        self.generic_visit(node)


def lint_file(path: Path, repo_root: Path) -> List[Violation]:
    rel = str(path.relative_to(repo_root)) if path.is_relative_to(repo_root) \
        else str(path)
    try:
        tree = ast.parse(path.read_text(), filename=rel)
    except SyntaxError as e:  # pragma: no cover - broken file
        return [Violation("VRF000", rel, e.lineno or 0, f"syntax error: {e.msg}")]
    parts = set(path.parts)
    checker = _Checker(
        path, rel, in_kernels="kernels" in parts,
        in_runtime="repro" in parts
        and bool(parts & {"ops", "serving", "distributed"}))
    checker.visit(tree)
    return checker.found


def lint_sources(roots: Sequence[Path], repo_root: Path) -> List[Violation]:
    out: List[Violation] = []
    for root in roots:
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            out.extend(lint_file(f, repo_root))
    return out


def _accepts(fn, param: str) -> bool:
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):  # pragma: no cover - builtins
        return True
    if param in sig.parameters:
        return True
    return any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in sig.parameters.values())


def lint_registry() -> List[Violation]:
    """Live checks over the imported op registry (VRF010-VRF012)."""
    from repro.ops import registry

    out: List[Violation] = []
    for bname in registry.backends():
        backend = registry.get_backend(bname)
        instrumented_tier = backend.fallback is not None
        for op, entry in sorted(backend.ops.items()):
            where = f"{bname}.{op}"
            if instrumented_tier and entry.words_fn is None:
                out.append(Violation(
                    "VRF010", "repro/ops/registry.py", 0,
                    f"{where}: instrumented backend entry has no words_fn"))
            if (entry.words_fn is not None and entry.access_plan_fn is None
                    and not op.endswith("_dist")):
                out.append(Violation(
                    "VRF011", "repro/ops/registry.py", 0,
                    f"{where}: words_fn without access_plan_fn — the static "
                    "auditor cannot cross-check it"))
            for flag in sorted(entry.caps.flags):
                param = _FLAG_PARAMS.get(flag)
                if param and not _accepts(entry.fn, param):
                    out.append(Violation(
                        "VRF012", "repro/ops/registry.py", 0,
                        f"{where}: declares capability {flag!r} but its fn "
                        f"accepts no {param!r} parameter"))
            quant = sorted(set(entry.caps.dtypes) & _QUANT_DTYPES)
            if quant:
                acc = entry.caps.accum_dtype
                if acc is None:
                    out.append(Violation(
                        "VRF013", "repro/ops/registry.py", 0,
                        f"{where}: declares quantized dtypes {quant} but no "
                        "accum_dtype (accumulation contract unstated)"))
                elif acc not in _WIDE_ACCUM:
                    out.append(Violation(
                        "VRF013", "repro/ops/registry.py", 0,
                        f"{where}: accum_dtype {acc!r} is narrower than "
                        "float32 for quantized storage dtypes"))
    return out


def default_roots(repo_root: Path) -> List[Path]:
    return [p for p in (repo_root / "src" / "repro", repo_root / "scripts")
            if p.exists()]


def run_lint(roots: Optional[Sequence[Path]] = None,
             repo_root: Optional[Path] = None) -> List[Violation]:
    repo_root = repo_root or Path(__file__).resolve().parents[3]
    roots = list(roots) if roots else default_roots(repo_root)
    return lint_sources(roots, repo_root) + lint_registry()


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    repo_root = Path(__file__).resolve().parents[3]
    roots = [Path(a).resolve() for a in argv] or None
    found = run_lint(roots, repo_root)
    for viol in found:
        print(viol)
    if found:
        print(f"repro.verify.lint: {len(found)} violation(s)")
        return 1
    print("repro.verify.lint: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
