"""Structured kernel-launch metadata: what each Pallas launch moves and when.

Every registered Pallas kernel exposes a ``<kernel>_access_plan`` builder
returning a :class:`KernelAccessPlan` — the grid, the per-operand HBM access
pattern (BlockSpec ``index_map``s for pipelined operands, explicit halo
windows for manual-DMA operands, flat word counts for scalar prefetch), the
VMEM scratch allocations, and the double-buffered DMA schedule. The plan is
pure data built from the same geometry helpers the kernel lowering uses, so
``repro.verify.audit`` can abstractly interpret it — walk the grid, count
exact HBM words, check bounds/coverage — without touching a device.

Word unit everywhere: 32-bit words (``itemsize / 4`` per element), matching
the ``*_hbm_words`` counters and the Thm 2.1 bounds.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple, Union


@dataclasses.dataclass(frozen=True)
class BlockAccess:
    """A pipelined ``pl.BlockSpec`` operand.

    ``index_map`` is the spec's index map, vectorizable over numpy arrays:
    called with one array per grid axis (all the same flat length) it must
    return one block-index array/scalar per array dimension. Pallas only
    re-fetches (re-stores) a block when the mapped index changes between
    consecutive grid steps, so audited words = index-transition count x
    block words.
    """

    name: str
    kind: str  # "load" | "store"
    block_shape: Tuple[int, ...]  # elements moved per (re)visit
    array_shape: Tuple[int, ...]  # padded element extent in HBM
    index_map: Callable  # (*grid_axes) -> per-dim block indices
    word_size: float  # 32-bit words per element
    counted: bool = True  # charged by the op's words_fn
    note: str = ""


@dataclasses.dataclass(frozen=True)
class WindowAccess:
    """A manual ``make_async_copy`` operand (the halo-window streams).

    ``window`` maps grid indices to one ``(start, size)`` pair per array
    dimension (vectorizable; ``size`` is static per plan). The copy issues
    every grid step — no revisit elision — so words = n_steps x window
    words. ``requires`` independently derives the element range op
    semantics need at that step; the auditor checks requires ⊆ window,
    which is what catches an off-by-one halo index map even when the word
    *totals* stay unchanged.
    """

    name: str
    kind: str  # "load" | "store"
    window: Callable  # (*grid_axes) -> ((start, size), ...) per dim
    array_shape: Tuple[int, ...]
    word_size: float
    requires: Optional[Callable] = None  # (*grid_axes) -> ((lo, hi), ...)
    counted: bool = True
    note: str = ""


@dataclasses.dataclass(frozen=True)
class FlatAccess:
    """Traffic with no per-step structure: scalar-prefetch operands and
    one-shot materializations (the im2col patch expansion). ``counted``
    mirrors whether the op's ``words_fn`` charges it."""

    name: str
    kind: str  # "load" | "store"
    words: float
    counted: bool = True
    note: str = ""


Access = Union[BlockAccess, WindowAccess, FlatAccess]


@dataclasses.dataclass(frozen=True)
class ScratchAlloc:
    """One VMEM scratch buffer (words, 32-bit)."""

    name: str
    words: float


@dataclasses.dataclass(frozen=True)
class KernelAccessPlan:
    """Everything one Pallas launch does to memory, as pure data."""

    op: str
    grid: Tuple[int, ...]
    accesses: Tuple[Access, ...]
    scratch: Tuple[ScratchAlloc, ...] = ()
    # DMA schedule over the innermost (reduction) grid axis, None when the
    # kernel has no manual double buffering. Built by
    # hazards.double_buffered_schedule to mirror the kernel's issue order.
    dma: Optional["object"] = None  # hazards.DmaSchedule
    note: str = ""

    @property
    def n_steps(self) -> int:
        n = 1
        for g in self.grid:
            n *= int(g)
        return n

    def scratch_words(self) -> float:
        return float(sum(s.words for s in self.scratch))
