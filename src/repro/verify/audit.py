"""Abstract interpretation of :class:`KernelAccessPlan`s — the static auditor.

``audit_access_plan`` walks a kernel's launch grid with numpy (row-major, the
Pallas iteration order: last axis innermost), evaluates every operand's
index map / halo window over all steps at once, and computes the exact HBM
words the launch moves:

  * BlockSpec operands move their block only when the mapped index *changes*
    between consecutive steps (the Pallas revisit elision), so words =
    transition count x block words.
  * Manual-DMA window operands copy every step: words = n_steps x window
    words. Their windows are bounds-checked against the padded array extent
    and checked to *cover* the independently-derived ``requires`` region —
    the check with teeth against off-by-one halo index maps, whose word
    totals are unchanged.
  * Flat (scalar-prefetch / one-shot) operands contribute their words as-is.

``audit_decision`` then holds a ``DispatchDecision`` to account: the counted
words must equal the op's ``words_fn`` result exactly, scratch must fit the
target's VMEM, conv tiles must fit the plan's ``kernel_footprints`` budget,
the audited bound ratio must not exceed the recorded one, and the DMA
schedule must simulate hazard-free (``repro.verify.hazards``).

The ResNet-50 grids are 500–6400 steps, serving decode smaller still, so the
exhaustive walk costs milliseconds.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import hazards as hz
from .access import (BlockAccess, FlatAccess, KernelAccessPlan, WindowAccess)

# Counted-vs-words_fn slack: pure float-association noise. Word *models*
# drifting from the kernel show up orders of magnitude above this.
REL_TOL = 1e-6


class AuditError(RuntimeError):
    """A kernel's static audit found mismatches, violations, or hazards."""

    def __init__(self, report: "AuditReport"):
        self.report = report
        lines = [f"static audit failed for {report.op}:"]
        lines += [f"  - {p}" for p in report.problems]
        lines += [f"  - hazard {h}" for h in report.hazards]
        super().__init__("\n".join(lines))


@dataclasses.dataclass
class AuditReport:
    op: str
    grid: Tuple[int, ...]
    n_steps: int
    loaded_words: float  # all load traffic, counted or not
    stored_words: float
    counted_words: float  # what the op's words_fn should report
    per_access: Dict[str, float]
    problems: List[str]
    hazards: List[hz.Hazard]
    measured_words: Optional[float] = None

    @property
    def ok(self) -> bool:
        return not self.problems and not self.hazards


def _prod(xs: Sequence[int]) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _grid_axes(grid: Tuple[int, ...]) -> Tuple[List[np.ndarray], int]:
    """One int64 array per grid axis, flattened row-major (last axis
    fastest) — the order Pallas iterates the grid."""
    if not grid:
        return [], 1
    idx = np.indices(tuple(int(g) for g in grid), dtype=np.int64)
    idx = idx.reshape(len(grid), -1)
    return [idx[i] for i in range(len(grid))], idx.shape[1]


def _as_steps(x, n_steps: int) -> np.ndarray:
    return np.broadcast_to(np.asarray(x, dtype=np.int64), (n_steps,))


def _audit_block(acc: BlockAccess, axes: List[np.ndarray], n_steps: int,
                 problems: List[str]) -> float:
    cols = [_as_steps(c, n_steps) for c in acc.index_map(*axes)]
    if len(cols) != len(acc.array_shape):
        problems.append(f"{acc.name}: index_map yields {len(cols)} dims for a "
                        f"{len(acc.array_shape)}-d array")
        return 0.0
    for d, (c, b, ext) in enumerate(zip(cols, acc.block_shape,
                                        acc.array_shape)):
        if int(c.min()) < 0:
            problems.append(f"{acc.name}: dim {d} block index "
                            f"{int(c.min())} < 0")
        if (int(c.max()) + 1) * int(b) > int(ext):
            problems.append(
                f"{acc.name}: dim {d} block {int(c.max())} x {b} spans past "
                f"the padded extent {ext}")
    mat = np.stack(cols)
    changed = np.ones(n_steps, dtype=bool)
    if n_steps > 1:
        changed[1:] = (mat[:, 1:] != mat[:, :-1]).any(axis=0)
    return float(changed.sum()) * _prod(acc.block_shape) * acc.word_size


def _audit_window(acc: WindowAccess, axes: List[np.ndarray], n_steps: int,
                  problems: List[str]) -> float:
    win = acc.window(*axes)
    if len(win) != len(acc.array_shape):
        problems.append(f"{acc.name}: window yields {len(win)} dims for a "
                        f"{len(acc.array_shape)}-d array")
        return 0.0
    starts, sizes = [], []
    for d, ((start, size), ext) in enumerate(zip(win, acc.array_shape)):
        start, size = _as_steps(start, n_steps), int(size)
        starts.append(start)
        sizes.append(size)
        if int(start.min()) < 0:
            problems.append(f"{acc.name}: dim {d} window start "
                            f"{int(start.min())} < 0")
        if int(start.max()) + size > int(ext):
            problems.append(
                f"{acc.name}: dim {d} window [{int(start.max())}, "
                f"{int(start.max()) + size}) exceeds the padded extent {ext}")
    if acc.requires is not None:
        req = acc.requires(*axes)
        for d, ((lo, hi), start, size) in enumerate(zip(req, starts, sizes)):
            lo, hi = _as_steps(lo, n_steps), _as_steps(hi, n_steps)
            miss_lo = lo < start
            miss_hi = hi > start + size
            if bool(miss_lo.any()) or bool(miss_hi.any()):
                i = int(np.argmax(miss_lo | miss_hi))
                problems.append(
                    f"{acc.name}: dim {d} window [{int(starts[d][i])}, "
                    f"{int(starts[d][i]) + size}) at step {i} misses the "
                    f"required elements [{int(lo[i])}, {int(hi[i])})")
    return float(n_steps) * _prod(sizes) * acc.word_size


def audit_access_plan(ap: KernelAccessPlan) -> AuditReport:
    """Walk the grid; count exact words; bounds/coverage-check every operand;
    simulate the DMA schedule."""
    axes, n_steps = _grid_axes(ap.grid)
    problems: List[str] = []
    per_access: Dict[str, float] = {}
    loaded = stored = counted = 0.0
    for acc in ap.accesses:
        if isinstance(acc, BlockAccess):
            words = _audit_block(acc, axes, n_steps, problems)
        elif isinstance(acc, WindowAccess):
            words = _audit_window(acc, axes, n_steps, problems)
        elif isinstance(acc, FlatAccess):
            words = float(acc.words)
        else:  # pragma: no cover - plan construction bug
            problems.append(f"unknown access type {type(acc).__name__}")
            continue
        per_access[acc.name] = words
        if acc.kind == "store":
            stored += words
        else:
            loaded += words
        if acc.counted:
            counted += words
    found = hz.check_schedule(ap.dma) if ap.dma is not None else []
    return AuditReport(op=ap.op, grid=ap.grid, n_steps=n_steps,
                       loaded_words=loaded, stored_words=stored,
                       counted_words=counted, per_access=per_access,
                       problems=problems, hazards=found)


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= REL_TOL * max(abs(a), abs(b), 1.0)


def audit_decision(ap: KernelAccessPlan, decision, target=None
                   ) -> AuditReport:
    """Audit one dispatch: the access plan's counted words must reproduce
    ``decision.measured_words`` exactly, scratch must fit VMEM, conv tiles
    must fit the ``kernel_footprints`` budget, and the audited bound ratio
    must not exceed the recorded one."""
    from repro.core.tiling import conv_kernel_tiles_fit
    from repro.plan.ops import ConvSpec

    report = audit_access_plan(ap)
    report.measured_words = decision.measured_words
    if decision.measured_words is None:
        report.problems.append(
            f"{ap.op}: dispatch carries no measured_words (missing words_fn "
            "or spec args) — nothing to audit against")
        return report
    if not _close(report.counted_words, float(decision.measured_words)):
        report.problems.append(
            f"{ap.op}: audited words {report.counted_words:.6f} != words_fn "
            f"{float(decision.measured_words):.6f} "
            f"(delta {report.counted_words - float(decision.measured_words):+.6f})")
    tgt = target if target is not None else (
        decision.plan.target if decision.plan is not None else None)
    if tgt is not None and ap.scratch:
        if ap.scratch_words() > float(tgt.vmem_words) + 1e-9:
            report.problems.append(
                f"{ap.op}: VMEM scratch {ap.scratch_words():.0f} words "
                f"exceeds the target's {tgt.vmem_words:.0f}")
    plan = decision.plan
    if plan is not None and isinstance(plan.op, ConvSpec) and tgt is not None:
        if not conv_kernel_tiles_fit(plan.to_shape(), plan.tiles,
                                     tgt.memory_model()):
            report.problems.append(
                f"{ap.op}: plan tiles {plan.tiles} overflow the "
                "kernel_footprints budget (conv_kernel_tiles_fit)")
    lb = decision.lower_bound
    ratio = decision.bound_ratio
    if lb is not None and ratio is not None:
        audited_ratio = report.counted_words / max(float(lb), 1.0)
        if audited_ratio > float(ratio) * (1.0 + REL_TOL):
            report.problems.append(
                f"{ap.op}: audited bound ratio {audited_ratio:.4f} exceeds "
                f"the recorded {float(ratio):.4f}")
    return report


# ---------------------------------------------------------------------------
# ExecutionPlan construction audit (the repro.plan hook).
# ---------------------------------------------------------------------------

def validate_execution_plan(ep) -> List[str]:
    """Structural checks on a freshly built plan: the launch grid must cover
    the op, conv tiles must fit the exact halo-window VMEM budget, and the
    recorded efficiency must be consistent."""
    from repro.core.tiling import conv_kernel_tiles_fit
    from repro.plan.ops import AttentionSpec, ConvSpec, MatmulSpec

    problems: List[str] = []
    op, tiles, grid = ep.op, ep.tiles, ep.grid

    def cover(axis: str, n_blocks: int, block: int, extent: int) -> None:
        if n_blocks * block < extent:
            problems.append(f"grid does not cover {axis}: {n_blocks} x "
                            f"{block} < {extent}")

    if isinstance(op, ConvSpec):
        if len(tiles) != 5 or len(grid) != 5:
            problems.append(f"conv plan must carry 5 tiles/5 grid axes, got "
                            f"{tiles}/{grid}")
        else:
            cover("N", grid[0], tiles[0], op.N)
            cover("cO", grid[1], tiles[2], op.c_O)
            cover("hO", grid[2], tiles[3], op.h_O)
            cover("wO", grid[3], tiles[4], op.w_O)
            cover("cI", grid[4], tiles[1], op.c_I)
            if not conv_kernel_tiles_fit(ep.to_shape(), tiles,
                                         ep.target.memory_model()):
                problems.append(f"conv tiles {tiles} overflow the exact "
                                "halo-window VMEM budget")
    elif isinstance(op, MatmulSpec):
        if len(tiles) != 3 or len(grid) != 3:
            problems.append(f"matmul plan must carry 3 tiles/3 grid axes, "
                            f"got {tiles}/{grid}")
        else:
            cover("m", grid[0], tiles[0], op.m)
            cover("n", grid[1], tiles[1], op.n)
            cover("k", grid[2], tiles[2], op.k)
    elif isinstance(op, AttentionSpec):
        g = max(1, op.H // max(op.KV, 1))
        if len(tiles) != 2 or len(grid) != 3:
            problems.append(f"attention plan must carry 2 tiles/3 grid axes, "
                            f"got {tiles}/{grid}")
        else:
            if grid[0] != op.B * op.KV:
                problems.append(f"attention grid rows {grid[0]} != B*KV "
                                f"{op.B * op.KV}")
            cover("folded Lq", grid[1], tiles[0], g * op.Lq)
            cover("Lk", grid[2], tiles[1], op.Lk)
    if ep.lower_bound > 0 and not _close(
            ep.efficiency, ep.comm_volume / max(ep.lower_bound, 1.0)):
        problems.append("efficiency is not comm_volume / lower_bound")
    return problems


class PlanAuditError(RuntimeError):
    pass


def _plan_hook(ep) -> None:
    problems = validate_execution_plan(ep)
    if problems:
        raise PlanAuditError(
            "plan audit failed for " + repr(ep.op) + ":\n" +
            "\n".join(f"  - {p}" for p in problems))


def install_plan_audit() -> None:
    """Register the structural plan validator on ``repro.plan``'s
    construction hook (idempotent). Every plan built afterwards is checked
    before it enters the cache."""
    from repro.plan import planner

    planner.register_plan_audit_hook(_plan_hook)
