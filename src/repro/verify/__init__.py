"""``repro.verify`` — static communication auditor, DMA-hazard detector, lint.

No device required: the auditor abstractly interprets each registered Pallas
kernel's launch (grid + BlockSpec index maps + manual-DMA halo windows) and
computes the exact HBM words it moves, which must reproduce the op's
``words_fn`` to the last word; the hazard detector simulates double-buffered
copy schedules against wait/reuse/overlap rules; the lint walks the source
tree for structural invariants (``python -m repro.verify.lint``).

Entry points:

    from repro import verify
    report = verify.audit_decision(access_plan, decision)   # one dispatch
    verify.install_plan_audit()       # validate every freshly built plan
    scripts/verify.py                 # the full registered-op sweep + mutants
"""

from .access import (  # noqa: F401
    BlockAccess,
    FlatAccess,
    KernelAccessPlan,
    ScratchAlloc,
    WindowAccess,
)
from .audit import (  # noqa: F401
    AuditError,
    AuditReport,
    PlanAuditError,
    audit_access_plan,
    audit_decision,
    install_plan_audit,
    validate_execution_plan,
)
from .hazards import (  # noqa: F401
    DmaEvent,
    DmaSchedule,
    Hazard,
    check_schedule,
    double_buffered_schedule,
)
