"""Seeded mutants — the auditor's own regression harness.

Each mutant injects a realistic kernel bug into otherwise-correct launch
metadata and asserts the static checks catch it:

  * ``halo_off_by_one``   shifts the conv2d input halo window's h start by
    one row — the classic stride/halo index-map bug. The word *totals* are
    unchanged, so only the ``requires``-coverage check can see it.
  * ``dropped_dma_wait``  removes the WAIT events from the double-buffered
    schedule — the kernel would read stale VMEM (H1).
  * ``same_slot_prefetch`` prefetches step ci+1 into the slot step ci is
    about to consume — the overlap bug double buffering exists to prevent
    (H2/H3).
  * ``scale_applied_twice`` makes the quantized conv's folded scale vector
    re-fetch on every reduction step instead of once — the classic
    dequantize-in-the-loop bug. Totals move (counted > words_fn), so the
    counted-vs-measured exactness check must flag it.
  * ``fault_swallowed``     a handler catches an injected NumericFault and
    silently eats it — no retry, no row failure, no record. The campaign's
    resolution accounting (``FaultCampaign.unresolved`` /
    ``verify_accounted``) must flag the swallowed injection.

``run_seeded_mutants()`` returns ``(name, caught, detail)`` triples;
``scripts/verify.py --mutants`` (and the CI verify job) fail unless every
mutant is caught.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from . import audit as _audit
from . import hazards as hz
from .access import BlockAccess, KernelAccessPlan, WindowAccess


def _conv2d_plan() -> KernelAccessPlan:
    """A representative strided conv2d access plan (ResNet conv3_1-like)."""
    from repro.kernels.conv2d import conv2d_access_plan

    x = jax.ShapeDtypeStruct((8, 64, 56, 56), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((128, 64, 3, 3), jnp.bfloat16)
    return conv2d_access_plan(x, w, stride=(2, 2))


def halo_off_by_one() -> Tuple[bool, str]:
    """Shift the input halo window one row down; words stay identical."""
    ap = _conv2d_plan()
    mutated = []
    for acc in ap.accesses:
        if isinstance(acc, WindowAccess) and acc.name == "input":
            orig = acc.window

            def shifted(*axes, _orig=orig):
                win = list(_orig(*axes))
                (h0, hs) = win[2]
                win[2] = (h0 + 1, hs)  # off-by-one h start
                return tuple(win)

            acc = dataclasses.replace(acc, window=shifted)
        mutated.append(acc)
    report = _audit.audit_access_plan(
        dataclasses.replace(ap, accesses=tuple(mutated)))
    caught = any("misses the required" in p or "exceeds the padded extent" in p
                 for p in report.problems)
    return caught, "; ".join(report.problems[:2]) or "not detected"


def dropped_dma_wait() -> Tuple[bool, str]:
    """Strip the WAIT events: compute reads data the DMA never landed."""
    sched = hz.double_buffered_schedule(6, name="mutant:no-wait")
    mutated = dataclasses.replace(
        sched, events=tuple(e for e in sched.events if e.kind != hz.WAIT))
    found = hz.check_schedule(mutated)
    caught = any(h.code == "H1" for h in found)
    return caught, "; ".join(str(h) for h in found[:2]) or "not detected"


def same_slot_prefetch() -> Tuple[bool, str]:
    """Prefetch ci+1 into the slot step ci still consumes (n_slots=1 bug)."""
    sched = hz.double_buffered_schedule(6, name="mutant:same-slot")
    mutated = dataclasses.replace(
        sched, events=tuple(
            dataclasses.replace(e, slot=0) if e.kind == hz.START else e
            for e in sched.events))
    found = hz.check_schedule(mutated)
    caught = any(h.code in ("H2", "H3") for h in found)
    return caught, "; ".join(str(h) for h in found[:2]) or "not detected"


def scale_applied_twice() -> Tuple[bool, str]:
    """The dequantize-at-every-application bug: the kernel fetches the
    folded scale vector once per application site (per-tap AND at the final
    store) instead of holding it resident, doubling the scale stream. The
    words_fn charges the vector exactly once, so the counted-vs-measured
    exactness check in ``audit_decision`` must fire."""
    from repro import ops
    from repro.kernels.quant import conv2d_q_access_plan
    from repro.plan import TPU_V5E

    x = jax.ShapeDtypeStruct((8, 64, 56, 56), jnp.int8)
    w = jax.ShapeDtypeStruct((128, 64, 3, 3), jnp.int8)
    s = jax.ShapeDtypeStruct((1, 128), jnp.float32)
    ctx = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
    decision = ops.explain("conv2d_q", ctx=ctx, dtype="int8",
                           spec_args=(x, w, s), spec_kw={"stride": (2, 2)})
    ap = conv2d_q_access_plan(x, w, s, stride=(2, 2), plan=decision.plan)

    extra = tuple(
        dataclasses.replace(acc, name="scale(second application)")
        for acc in ap.accesses
        if isinstance(acc, BlockAccess) and acc.name == "scale")
    assert extra, "conv2d_q access plan no longer carries a scale operand"
    report = _audit.audit_decision(
        dataclasses.replace(ap, accesses=ap.accesses + extra), decision)
    caught = any("!= words_fn" in p for p in report.problems)
    return caught, "; ".join(report.problems[:2]) or "not detected"


def fault_swallowed() -> Tuple[bool, str]:
    """A fault handler that catches an injected NumericFault and silently
    swallows it — the recovery bug the resolution accounting exists for.
    Every legitimate handler stamps ``Injection.resolution`` (retried /
    row_failed / degraded / ...); this one stamps nothing, so the campaign
    must report the injection as unresolved."""
    from repro.resilience.errors import NumericFault
    from repro.resilience.faults import FaultCampaign

    camp = FaultCampaign(seed=0, rate=1.0, kinds=("numeric",), max_faults=1)
    inj = camp.draw("dispatch/conv2d", op="conv2d")
    assert inj is not None, "rate-1.0 campaign failed to inject"
    try:
        raise camp.fault_for(inj, op="conv2d", backend="pallas")
    except NumericFault:
        pass  # the mutant: no resolve(), no retry, no row failure
    leaks = camp.unresolved()
    caught = bool(leaks)
    return caught, (f"{len(leaks)} unresolved injection(s): "
                    f"{leaks[0].kind} at {leaks[0].site}" if caught
                    else "not detected")


MUTANTS: Tuple[Tuple[str, Callable[[], Tuple[bool, str]]], ...] = (
    ("halo_off_by_one", halo_off_by_one),
    ("dropped_dma_wait", dropped_dma_wait),
    ("same_slot_prefetch", same_slot_prefetch),
    ("scale_applied_twice", scale_applied_twice),
    ("fault_swallowed", fault_swallowed),
)


def run_seeded_mutants() -> List[Tuple[str, bool, str]]:
    return [(name, *fn()) for name, fn in MUTANTS]
