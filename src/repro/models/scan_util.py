"""Scan wrapper: rolled (compact HLO) by default, fully unrolled when
REPRO_UNROLL_SCANS=1.

Why: XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so a scan-over-layers model would report 1/R of its true FLOPs/bytes
in the dry-run roofline. The dry-run sets the env var so every scan unrolls
and cost_analysis sees the full program; tests and real training keep the
rolled form (compile time, remat behavior identical either way).
"""

from __future__ import annotations

import os

import jax


def unroll_scans() -> bool:
    return os.environ.get("REPRO_UNROLL_SCANS", "0") == "1"


def scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if unroll_scans() else 1)
