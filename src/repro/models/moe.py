"""Top-k routed mixture-of-experts (GShard-style grouped dispatch).

Tokens are reshaped into G groups (one per device shard in production; the
group axis carries the (data, model) sharding), routed top-k with a capacity
limit per group, and dispatched to experts with one-hot combine einsums — the
formulation GSPMD turns into all-to-alls when the expert axis is sharded on
``model``. Router math is f32; dispatch/combine tensors are compute-dtype.

Capacity per group: C = ceil(k * T_g / E * capacity_factor); overflow tokens
fall through the residual (standard token dropping).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.ops import ExecutionContext

from .layers import truncated_normal

Params = Dict[str, jax.Array]


def init_moe(key, cfg) -> Params:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    std = D ** -0.5
    return {
        "router": truncated_normal(ks[0], (D, E), std, jnp.float32),
        "w_gate": truncated_normal(ks[1], (E, D, F), std, dtype),
        "w_up": truncated_normal(ks[2], (E, D, F), std, dtype),
        "w_down": truncated_normal(ks[3], (E, F, D), F ** -0.5, dtype),
    }


def capacity(cfg, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.experts_per_token * cfg.capacity_factor
            / max(cfg.n_experts, 1))
    return max(4, -(-c // 4) * 4)  # multiple of 4, at least 4


def moe_block(
    p: Params,
    x: jax.Array,  # (B, L, D)
    cfg,
    n_groups: int = 1,
    ctx: Optional[ExecutionContext] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out, aux_loss). aux_loss is the load-balancing loss.

    ``ctx`` is the stack-wide execution policy; the grouped expert einsums
    have no dispatched kernel entry yet, so it is accepted for API
    uniformity with the other blocks."""
    del ctx
    B, L, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    cd = jnp.dtype(cfg.compute_dtype)
    T = B * L
    G = n_groups if T % n_groups == 0 else 1
    Tg = T // G
    C = capacity(cfg, Tg)
    xg = x.reshape(G, Tg, D)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (G,Tg,E)

    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (G,Tg,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, k) slot within its expert's capacity buffer;
    # k=0 assignments get priority over k=1 (GShard ordering)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G,Tg,K,E)
    kt = onehot.transpose(0, 2, 1, 3).reshape(G, K * Tg, E)  # k-major
    pos_kt = jnp.cumsum(kt, axis=1) - kt  # 0-based position per expert
    pos = pos_kt.reshape(G, K, Tg, E).transpose(0, 2, 1, 3)  # (G,Tg,K,E)
    within_cap = (pos < C).astype(jnp.float32) * onehot

    # combine[g,t,e,c] = sum_k gate_k * onehot_e * onehot_c
    pos_idx = jnp.sum(pos * onehot, axis=-1)  # (G,Tg,K) position scalar
    pos_oh = jax.nn.one_hot(pos_idx, C, dtype=jnp.float32)  # (G,Tg,K,C)
    kept = jnp.sum(within_cap, axis=-1)  # (G,Tg,K) in {0,1}
    combine = jnp.einsum("gtk,gtke,gtkc->gtec",
                         gate_vals * kept, onehot, pos_oh).astype(cd)
    dispatch = (combine != 0).astype(cd)

    if cfg.moe_shard_hints:
        # pin the GShard dispatch layout so GSPMD picks all-to-alls on the
        # G<->E reshard instead of replicating the one-hot tensors
        # (requires mesh axes "data"/"model"; launcher-only flag).
        from jax.sharding import PartitionSpec as P

        grp = ("data", "model")
        combine = jax.lax.with_sharding_constraint(
            combine, P(grp, None, None, None))
        dispatch = jax.lax.with_sharding_constraint(
            dispatch, P(grp, None, None, None))

    # dispatch -> (E, G, C, D), expert axis sharded on `model` in production
    ein = jnp.einsum("gtec,gtd->egcd", dispatch, xg.astype(cd))
    if cfg.moe_shard_hints:
        ein = jax.lax.with_sharding_constraint(
            ein, P("model", "data", None, None))
    hg = jnp.einsum("egcd,edf->egcf", ein, p["w_gate"].astype(cd))
    hu = jnp.einsum("egcd,edf->egcf", ein, p["w_up"].astype(cd))
    h = jax.nn.silu(hg.astype(jnp.float32)).astype(cd) * hu
    eout = jnp.einsum("egcf,efd->egcd", h, p["w_down"].astype(cd))
    if cfg.moe_shard_hints:
        eout = jax.lax.with_sharding_constraint(
            eout, P("model", "data", None, None))
    out = jnp.einsum("gtec,egcd->gtd", combine, eout)

    # load-balancing auxiliary loss (Switch/GShard)
    frac_tokens = jnp.mean(onehot[:, :, 0, :], axis=1)  # top-1 assignment share
    frac_probs = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    return out.reshape(B, L, D).astype(x.dtype), aux.astype(jnp.float32)


def moe_block_dense_ref(p: Params, x: jax.Array, cfg) -> jax.Array:
    """Oracle: evaluate every expert on every token, combine by top-k gates
    (no capacity drops) — matches moe_block when capacity_factor is large."""
    B, L, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    logits = jnp.einsum("bld,de->ble", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    gates = jnp.zeros_like(probs)
    for k in range(K):
        gates = gates + gate_vals[..., k:k + 1] * jax.nn.one_hot(
            gate_idx[..., k], E)
    xf = x.astype(jnp.float32)
    hg = jnp.einsum("bld,edf->blef", xf, p["w_gate"].astype(jnp.float32))
    hu = jnp.einsum("bld,edf->blef", xf, p["w_up"].astype(jnp.float32))
    h = jax.nn.silu(hg) * hu
    eout = jnp.einsum("blef,efd->bled", h, p["w_down"].astype(jnp.float32))
    return jnp.einsum("ble,bled->bld", gates, eout).astype(x.dtype)
