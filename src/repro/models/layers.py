"""Shared neural layers: norms, RoPE, SwiGLU, GQA attention with KV cache.

Pure functions over explicit parameter pytrees (no framework). Weights are
kept in cfg.param_dtype and cast to cfg.compute_dtype at use; attention
logits/softmax and all reductions accumulate in f32 (the paper's
mixed-precision discipline: low-precision operands, high-precision
accumulator).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import ops
from repro.ops import ExecutionContext

Params = Dict[str, jax.Array]


def truncated_normal(key, shape, std, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, L, hd); positions: (L,) or (B, L)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * inv[None, :]  # (L, hd/2)
        ang = ang[None, None]  # (1, 1, L, hd/2)
    else:
        ang = positions[:, :, None].astype(jnp.float32) * inv[None, None, :]
        ang = ang[:, None]  # (B, 1, L, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    std = d_model ** -0.5
    return {
        "w_gate": truncated_normal(k1, (d_model, d_ff), std, dtype),
        "w_up": truncated_normal(k2, (d_model, d_ff), std, dtype),
        "w_down": truncated_normal(k3, (d_ff, d_model), d_ff ** -0.5, dtype),
    }


def mlp(p: Params, x: jax.Array, compute_dtype) -> jax.Array:
    xc = x.astype(compute_dtype)
    g = jnp.einsum("...d,df->...f", xc, p["w_gate"].astype(compute_dtype))
    u = jnp.einsum("...d,df->...f", xc, p["w_up"].astype(compute_dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(compute_dtype)).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    std = D ** -0.5
    p = {
        "wq": truncated_normal(ks[0], (D, H * hd), std, dtype),
        "wk": truncated_normal(ks[1], (D, KV * hd), std, dtype),
        "wv": truncated_normal(ks[2], (D, KV * hd), std, dtype),
        "wo": truncated_normal(ks[3], (H * hd, D), (H * hd) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def attention_block(
    p: Params,
    x: jax.Array,  # (B, L, D)
    cfg,
    positions: jax.Array,  # (L,) or (B, L) absolute positions of x
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (B,KV,Lmax,hd) k, v
    cache_index: Optional[jax.Array] = None,  # scalar or (B,): write offset(s)
    ctx: Optional[ExecutionContext] = None,
    attn_mask: Optional[jax.Array] = None,  # (B, L) True = real token
    block_tables: Optional[jax.Array] = None,  # (B, w): paged-pool tables
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Returns (out, updated_cache). With a cache, keys/values are written at
    cache_index and attention runs over the full cache (decode/prefill).

    ``block_tables`` switches the cache to the paged layout: ``cache`` is the
    shared ``(num_blocks, KV, block_size, hd)`` k/v pool, row i's keys live in
    blocks ``block_tables[i]``, and the step is decode-only (L == 1). The new
    K/V land in physical block ``tables[i, pos // bs]`` at offset ``pos % bs``;
    dead rows (tables all zero) write reserved garbage block 0.

    A scalar ``cache_index`` writes all rows at one offset (lockstep prefill /
    wave decode); a ``(B,)`` vector writes row i at ``cache_index[i]`` and
    masks row i's attention to ``kpos <= cache_index[i] + ...`` — the
    continuous-batching decode contract where every slot sits at its own
    depth. ``attn_mask`` marks padding tokens (False) so they are never
    attended to, fixing left-padded batched prefill at the source.

    ``ctx`` picks the attention backend via ``repro.ops`` dispatch: the
    in-cache / masked variants need capabilities (traced or per-row
    ``q_offset``, key masks) only the XLA entry declares, so a pallas
    context falls back there by capability — no per-call-site ifs here."""
    B, L, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)

    q = jnp.einsum("bld,dh->blh", xc, p["wq"].astype(cd))
    k = jnp.einsum("bld,dh->blh", xc, p["wk"].astype(cd))
    v = jnp.einsum("bld,dh->blh", xc, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = q.reshape(B, L, H, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, L, KV, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, L, KV, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    idx = None if cache_index is None else jnp.asarray(cache_index, jnp.int32)
    new_cache = None
    if block_tables is not None:
        if L != 1:
            raise ValueError(f"paged attention is decode-only (L == 1), got L={L}")
        quantized = len(cache) == 4  # (kp, ks, vp, vs): int8 pool + scales
        kp, vp = (cache[0], cache[2]) if quantized else cache
        bs = kp.shape[2]
        pos = jnp.broadcast_to(idx, (B,))  # per-row depth = write position
        blk = jnp.take_along_axis(block_tables, (pos // bs)[:, None],
                                  axis=1)[:, 0]  # (B,) physical block
        off = pos % bs
        if quantized:
            # per-(row, kv_head) symmetric int8 over hd; the scale rides the
            # pool's (num_blocks, KV, bs) companion leaves
            ks, vs = cache[1], cache[3]
            k_new, ks_new = _quantize_kv_row(k[:, :, 0, :])
            v_new, vs_new = _quantize_kv_row(v[:, :, 0, :])
            kp = kp.at[blk, :, off].set(k_new)
            ks = ks.at[blk, :, off].set(ks_new)
            vp = vp.at[blk, :, off].set(v_new)
            vs = vs.at[blk, :, off].set(vs_new)
            o = ops.attention_decode_quant(q.astype(cd), kp, ks, vp, vs,
                                           block_tables, pos + 1, ctx=ctx)
            o = o.transpose(0, 2, 1, 3).reshape(B, L, H * hd)
            out = jnp.einsum("blh,hd->bld", o,
                             p["wo"].astype(cd)).astype(x.dtype)
            return out, (kp, ks, vp, vs)
        kp = kp.at[blk, :, off].set(k[:, :, 0, :].astype(kp.dtype))
        vp = vp.at[blk, :, off].set(v[:, :, 0, :].astype(vp.dtype))
        o = ops.attention_decode(q.astype(cd), kp, vp, block_tables, pos + 1,
                                 ctx=ctx)
        o = o.transpose(0, 2, 1, 3).reshape(B, L, H * hd)
        out = jnp.einsum("blh,hd->bld", o, p["wo"].astype(cd)).astype(x.dtype)
        return out, (kp, vp)
    if cache is not None and len(cache) == 1:
        # fused layout: one (B, KV, L, 2, hd) tensor -> a single
        # dynamic-update-slice per step instead of two (§Perf decode variant)
        ckv = cache[0]
        kv = jnp.stack([k, v], axis=3).astype(ckv.dtype)  # (B,KV,L,2,hd)
        if idx.ndim:  # per-slot write offsets (continuous-batching decode)
            ckv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
                c, u, (0, i, 0, 0)))(ckv, kv, idx)
        else:
            ckv = jax.lax.dynamic_update_slice(ckv, kv, (0, 0, idx, 0, 0))
        new_cache = (ckv,)
        k_att = ckv[:, :, :, 0, :].astype(cd)
        v_att = ckv[:, :, :, 1, :].astype(cd)
        q_offset = idx
    elif cache is not None:
        ck, cv = cache
        if idx.ndim:
            ck = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
                c, u, (0, i, 0)))(ck, k.astype(ck.dtype), idx)
            cv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
                c, u, (0, i, 0)))(cv, v.astype(cv.dtype), idx)
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, idx, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, idx, 0))
        new_cache = (ck, cv)
        k_att, v_att = ck.astype(cd), cv.astype(cd)
        q_offset = idx
    else:
        k_att, v_att = k, v
        q_offset = 0

    key_mask = _expand_key_mask(attn_mask, idx, L, k_att.shape[2],
                                cached=cache is not None)
    o = ops.attention(q, k_att, v_att, causal=cfg.causal,
                      q_offset=q_offset, key_mask=key_mask, ctx=ctx)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, H * hd)
    out = jnp.einsum("blh,hd->bld", o, p["wo"].astype(cd)).astype(x.dtype)
    return out, new_cache


def _quantize_kv_row(r: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization of one decode step's (B, KV, hd) k or v
    row, one scale per (row, kv_head) reduced over hd — the granularity the
    quantized pool's (num_blocks, KV, bs) scale leaves store. All-zero rows
    get scale 1.0 (quantize to 0) so dequantization never divides by zero."""
    qmax = 127.0
    rf = r.astype(jnp.float32)
    amax = jnp.max(jnp.abs(rf), axis=-1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(rf / scale[..., None]), -qmax,
                 qmax).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _expand_key_mask(attn_mask, idx, L: int, Lk: int, cached: bool):
    """(B, L) pad mask -> (B, Lk) key mask over this call's attention keys.

    Without a cache the keys are exactly this call's tokens. With a cache the
    keys span the whole cache; the call's mask lands on the written window
    [idx, idx + L) and everything outside it is presumed valid (unwritten
    tail entries are hidden by the causal mask)."""
    if attn_mask is None:
        return None
    attn_mask = jnp.asarray(attn_mask, bool)
    if not cached:
        return attn_mask
    if idx.ndim:
        raise NotImplementedError(
            "attn_mask with per-row cache_index is unsupported; "
            "continuous-batching decode feeds one real token per row")
    pos = jnp.arange(Lk, dtype=jnp.int32)[None, :]  # (1, Lk)
    col = jnp.clip(pos - idx, 0, L - 1)
    in_window = (pos >= idx) & (pos < idx + L)
    return jnp.where(in_window, jnp.take_along_axis(attn_mask, col, axis=1),
                     True)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return truncated_normal(key, (vocab, d_model), 1.0, dtype)


def embed(table: jax.Array, tokens: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def lm_logits(head: jax.Array, x: jax.Array, compute_dtype) -> jax.Array:
    """(B, L, D) @ (D, V) -> f32 logits."""
    return jnp.einsum("bld,dv->blv", x.astype(compute_dtype),
                      head.astype(compute_dtype)).astype(jnp.float32)
