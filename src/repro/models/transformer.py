"""Config-driven model assembly for all 10 assigned architectures.

Parameters for one *pattern unit* (e.g. 7 mamba blocks + 1 attention block for
jamba) are initialized per repeat and stacked on a leading axis; the forward
pass lax.scans over repeats so the lowered HLO contains a single unit
regardless of depth. KV caches / recurrent states are stacked the same way
and scanned alongside.

Block kinds: attn | mamba | mlstm | slstm. FFN (SwiGLU or MoE per
cfg.n_experts/moe_every) follows attn/mamba/slstm positions when d_ff > 0.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .scan_util import scan as _scan

from repro.ops import ExecutionContext

from . import moe as moe_lib
from . import ssm, xlstm
from .config import ModelConfig
from .layers import (attention_block, embed, init_attention, init_embed,
                     init_mlp, lm_logits, mlp, rms_norm, truncated_normal)

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _has_ffn(cfg: ModelConfig, pos: int) -> bool:
    kind = cfg.pattern[pos]
    return cfg.d_ff > 0 and kind in ("attn", "mamba", "slstm")


def _is_moe(cfg: ModelConfig, pos: int) -> bool:
    return _has_ffn(cfg, pos) and cfg.n_experts > 0 and pos % cfg.moe_every == 0


def init_unit(key, cfg: ModelConfig) -> Dict[str, PyTree]:
    """Parameters for one pattern unit."""
    dtype = jnp.dtype(cfg.param_dtype)
    unit: Dict[str, PyTree] = {}
    keys = jax.random.split(key, 2 * len(cfg.pattern))
    for i, kind in enumerate(cfg.pattern):
        k_core, k_ffn = keys[2 * i], keys[2 * i + 1]
        blk: Dict[str, PyTree] = {"norm1": jnp.ones((cfg.d_model,), dtype)}
        if kind == "attn":
            blk["core"] = init_attention(k_core, cfg)
        elif kind == "mamba":
            blk["core"] = ssm.init_mamba(k_core, cfg)
        elif kind == "mlstm":
            blk["core"] = xlstm.init_mlstm(k_core, cfg)
        elif kind == "slstm":
            blk["core"] = xlstm.init_slstm(k_core, cfg)
        else:
            raise ValueError(f"unknown block kind {kind!r}")
        if _has_ffn(cfg, i):
            blk["norm2"] = jnp.ones((cfg.d_model,), dtype)
            blk["ffn"] = (moe_lib.init_moe(k_ffn, cfg) if _is_moe(cfg, i)
                          else init_mlp(k_ffn, cfg.d_model, cfg.d_ff, dtype))
        unit[f"b{i}"] = blk
    return unit


def init_params(key, cfg: ModelConfig) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_head, k_units = jax.random.split(key, 3)
    params: Dict[str, PyTree] = {}
    if not cfg.inputs_are_embeddings or cfg.family == "vlm":
        params["embed"] = init_embed(k_embed, cfg.padded_vocab, cfg.d_model, dtype)
    params["layers"] = jax.vmap(lambda k: init_unit(k, cfg))(
        jax.random.split(k_units, cfg.repeats))
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    params["head"] = truncated_normal(k_head, (cfg.d_model, cfg.padded_vocab),
                                      cfg.d_model ** -0.5, dtype)
    return params


# ---------------------------------------------------------------------------
# Caches / recurrent state
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> PyTree:
    """Stacked (repeats-leading) cache pytree for decode."""
    def unit_cache():
        c: Dict[str, PyTree] = {}
        for i, kind in enumerate(cfg.pattern):
            if kind == "attn":
                if cfg.fused_kv_cache:
                    shape = (batch, cfg.n_kv_heads, max_len, 2, cfg.hd)
                    c[f"b{i}"] = {"kv": jnp.zeros(shape, dtype)}
                else:
                    shape = (batch, cfg.n_kv_heads, max_len, cfg.hd)
                    c[f"b{i}"] = {"k": jnp.zeros(shape, dtype),
                                  "v": jnp.zeros(shape, dtype)}
            elif kind == "mamba":
                h, tail = ssm.init_mamba_state(cfg, batch, jnp.float32)
                c[f"b{i}"] = {"h": h, "tail": tail}
            elif kind == "mlstm":
                C, n = xlstm.init_mlstm_state(cfg, batch, jnp.float32)
                c[f"b{i}"] = {"C": C, "n": n}
            elif kind == "slstm":
                cc, nn = xlstm.init_slstm_state(cfg, batch, jnp.float32)
                c[f"b{i}"] = {"c": cc, "n": nn}
        return c

    one = unit_cache()
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.repeats,) + a.shape), one)


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16, quantized: bool = False) -> PyTree:
    """Stacked paged KV pool: every attention layer gets a
    ``(num_blocks, KV, block_size, hd)`` key pool and value pool (stacked to
    (repeats, ...) like ``init_cache``). Physical block 0 is the reserved
    garbage block (``serving.kv.GARBAGE_BLOCK``): dead batch rows point their
    tables at it. Attention-only patterns — recurrent blocks carry O(1)
    state and gain nothing from paging.

    ``quantized=True`` stores the pools as int8 plus per-(block, kv_head,
    position) f32 scale leaves ``ks``/``vs`` of shape (num_blocks, KV,
    block_size) — (0.25 + 1/hd) words per cached element instead of bf16's
    0.5, which is what roughly doubles ``serving.kv.plan_pool_blocks``'s
    block capacity from the same HBM budget. Scales initialize to 1.0
    (matching the all-zero-row convention of ``quantize_symmetric``)."""
    if set(cfg.pattern) != {"attn"}:
        raise ValueError(
            f"paged cache requires a pure-attention pattern, got {cfg.pattern}")
    shape = (num_blocks, cfg.n_kv_heads, block_size, cfg.hd)
    if quantized:
        one = {f"b{i}": {"kp": jnp.zeros(shape, jnp.int8),
                         "ks": jnp.ones(shape[:3], jnp.float32),
                         "vp": jnp.zeros(shape, jnp.int8),
                         "vs": jnp.ones(shape[:3], jnp.float32)}
               for i in range(len(cfg.pattern))}
    else:
        one = {f"b{i}": {"kp": jnp.zeros(shape, dtype),
                         "vp": jnp.zeros(shape, dtype)}
               for i in range(len(cfg.pattern))}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.repeats,) + a.shape), one)


def insert_cache_slot(cache: PyTree, row: PyTree, slot) -> PyTree:
    """Write a single-sequence cache (batch size 1) into batch slot ``slot``
    of a pooled cache. Every leaf is stacked (repeats, B, ...), so the batch
    axis is axis 1. This is the continuous-batching admission primitive:
    prefill a request at batch 1, then splice its KV/state row into the
    freed slot while the other slots keep decoding."""
    slot = jnp.asarray(slot, jnp.int32)
    return jax.tree.map(
        lambda p, r: jax.lax.dynamic_update_slice_in_dim(
            p, r.astype(p.dtype), slot, axis=1), cache, row)


def reset_cache_slot(cache: PyTree, slot) -> PyTree:
    """Zero one batch slot of a pooled cache (slot retirement hygiene)."""
    slot = jnp.asarray(slot, jnp.int32)
    return jax.tree.map(
        lambda p: jax.lax.dynamic_update_slice_in_dim(
            p, jnp.zeros_like(p[:, :1]), slot, axis=1), cache)


def cache_footprint_words(cfg: ModelConfig, max_len: int,
                          dtype=jnp.bfloat16,
                          block_size: Optional[int] = None) -> float:
    """Per-sequence decode-cache size in 32-bit words (the paper's unit).

    Computed from ``init_cache`` via eval_shape (no allocation); the serving
    engine divides a HardwareTarget's HBM budget by this to size its slot
    pool. ``block_size`` switches to block-granular accounting: a paged
    sequence occupies whole blocks, so its footprint is ``max_len`` rounded
    up to the block size (the engine's admission math must match actual pool
    occupancy — a shared prefix is then charged once via
    ``BlockAllocator.used_words``, not here)."""
    if block_size is not None:
        max_len = -(-max_len // block_size) * block_size
    shapes = jax.eval_shape(lambda: init_cache(cfg, 1, max_len, dtype))
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(shapes)) / 4.0


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _unit_forward(unit_params, x, cfg: ModelConfig, positions, unit_cache,
                  cache_index, n_groups: int, ctx: Optional[ExecutionContext],
                  decode: bool, attn_mask=None, block_tables=None):
    """One pattern unit; returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, PyTree] = {}
    for i, kind in enumerate(cfg.pattern):
        blk = unit_params[f"b{i}"]
        h = rms_norm(x, blk["norm1"], cfg.norm_eps)
        bc = unit_cache.get(f"b{i}") if unit_cache is not None else None
        if kind == "attn":
            paged = bc is not None and "kp" in bc
            quant_paged = paged and "ks" in bc  # int8 pool + scale leaves
            if bc is None:
                cache = None
            elif quant_paged:
                cache = (bc["kp"], bc["ks"], bc["vp"], bc["vs"])
            elif paged:
                cache = (bc["kp"], bc["vp"])
            elif cfg.fused_kv_cache:
                cache = (bc["kv"],)
            else:
                cache = (bc["k"], bc["v"])
            out, upd = attention_block(blk["core"], h, cfg, positions,
                                       cache=cache, cache_index=cache_index,
                                       ctx=ctx, attn_mask=attn_mask,
                                       block_tables=(block_tables if paged
                                                     else None))
            if upd is not None:
                if quant_paged:
                    new_cache[f"b{i}"] = {"kp": upd[0], "ks": upd[1],
                                          "vp": upd[2], "vs": upd[3]}
                elif paged:
                    new_cache[f"b{i}"] = {"kp": upd[0], "vp": upd[1]}
                else:
                    new_cache[f"b{i}"] = ({"kv": upd[0]} if cfg.fused_kv_cache
                                          else {"k": upd[0], "v": upd[1]})
        elif kind == "mamba":
            state = (bc["h"], bc["tail"]) if bc is not None else None
            if decode:
                out, upd = ssm.mamba_decode_step(blk["core"], h, cfg, state,
                                                 ctx=ctx)
            else:
                out, upd = ssm.mamba_block(blk["core"], h, cfg, state,
                                           ctx=ctx)
            if upd is not None:
                new_cache[f"b{i}"] = {"h": upd[0], "tail": upd[1]}
        elif kind == "mlstm":
            state = (bc["C"], bc["n"]) if bc is not None else None
            if decode:
                out, upd = xlstm.mlstm_decode_step(blk["core"], h, cfg, state,
                                                   ctx=ctx)
            else:
                out, upd = xlstm.mlstm_block(blk["core"], h, cfg, state,
                                             ctx=ctx)
            if upd is not None:
                new_cache[f"b{i}"] = {"C": upd[0], "n": upd[1]}
        elif kind == "slstm":
            state = (bc["c"], bc["n"]) if bc is not None else None
            if decode:
                out, upd = xlstm.slstm_decode_step(blk["core"], h, cfg, state,
                                                   ctx=ctx)
            else:
                out, upd = xlstm.slstm_block(blk["core"], h, cfg, state,
                                             ctx=ctx)
            if upd is not None:
                new_cache[f"b{i}"] = {"c": upd[0], "n": upd[1]}
        x = x + out
        if _has_ffn(cfg, i):
            h = rms_norm(x, blk["norm2"], cfg.norm_eps)
            if _is_moe(cfg, i):
                f, a = moe_lib.moe_block(blk["ffn"], h, cfg,
                                         n_groups=n_groups, ctx=ctx)
                aux = aux + a
            else:
                f = mlp(blk["ffn"], h, jnp.dtype(cfg.compute_dtype))
            x = x + f
    return x, new_cache, aux


def hidden_forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,  # (B, L) int32
    embeds: Optional[jax.Array] = None,  # (B, L, D) stub-frontend outputs
    cache: Optional[PyTree] = None,
    cache_index: Optional[jax.Array] = None,
    n_groups: int = 1,
    ctx: Optional[ExecutionContext] = None,
    remat: bool = False,
    decode: bool = False,
    act_spec=None,  # PartitionSpec for (B, L, D) activations (seq parallel)
    attn_mask: Optional[jax.Array] = None,  # (B, L) True = real token
    positions: Optional[jax.Array] = None,  # (L,) or (B, L) RoPE positions
    block_tables: Optional[jax.Array] = None,  # (B, w) paged-cache tables
) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
    """Backbone only: returns (final-norm hidden states, new_cache, aux).

    A ``block_tables`` array marks ``cache`` as a paged pool
    (``init_paged_cache`` layout): decode-only, one query per row, keys
    gathered through the table by the paged attention kernel.

    ``ctx`` is the execution policy (``repro.ops.ExecutionContext``): which
    backend serves each kernel call, planned against which HardwareTarget,
    at which precision. ``None`` resolves the default (XLA unless
    ``REPRO_BACKEND`` says otherwise).

    ``cache_index`` may be a scalar (all rows at one depth: training, lockstep
    prefill) or a (B,) vector (each row at its own depth: continuous-batching
    decode); positions default to ``arange(L) + cache_index`` per row.
    ``attn_mask`` marks padding (False) so attention never reads pad tokens —
    with explicit ``positions`` this makes left-padded batched prefill exact.
    Recurrent blocks (mamba/xlstm) consume every position in order, so padded
    batches are attention-arch-only; serve ragged recurrent prompts at their
    exact length (the serving engine's prefill-into-slot does)."""
    cd = jnp.dtype(cfg.compute_dtype)
    if embeds is not None:
        x = embeds.astype(cd)
    else:
        x = embed(params["embed"], tokens, cd)
    B, L, _ = x.shape
    if cache_index is None:
        cache_index = jnp.zeros((), jnp.int32)
    cache_index = jnp.asarray(cache_index, jnp.int32)
    if positions is None:
        if cache_index.ndim:  # (B,) per-slot depths -> (B, L) positions
            positions = (jnp.arange(L, dtype=jnp.int32)[None, :]
                         + cache_index[:, None])
        else:
            positions = jnp.arange(L, dtype=jnp.int32) + cache_index

    def constrain(a):
        if act_spec is not None:
            return jax.lax.with_sharding_constraint(a, act_spec)
        return a

    x = constrain(x)
    body_fn = functools.partial(
        _unit_forward, cfg=cfg, positions=positions, cache_index=cache_index,
        n_groups=n_groups, ctx=ctx, decode=decode, attn_mask=attn_mask,
        block_tables=block_tables)

    def scan_body(carry, xs):
        x, aux = carry
        unit_params, unit_cache = xs
        x, new_cache, a = body_fn(unit_params, x, unit_cache=unit_cache)
        return (constrain(x), aux + a), new_cache

    scan_fn = scan_body
    if remat:
        scan_fn = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    (x, aux), new_cache = _scan(
        scan_fn, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], cache) if cache is not None else (params["layers"],
                                                             _none_tree(cfg)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, (new_cache if cache is not None else None), aux


def forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    cache: Optional[PyTree] = None,
    cache_index: Optional[jax.Array] = None,
    n_groups: int = 1,
    ctx: Optional[ExecutionContext] = None,
    remat: bool = False,
    decode: bool = False,
    act_spec=None,
    attn_mask: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
    """Returns (logits, new_cache, aux_loss)."""
    x, new_cache, aux = hidden_forward(
        params, cfg, tokens=tokens, embeds=embeds, cache=cache,
        cache_index=cache_index, n_groups=n_groups, ctx=ctx,
        remat=remat, decode=decode, act_spec=act_spec, attn_mask=attn_mask,
        positions=positions, block_tables=block_tables)
    logits = lm_logits(params["head"], x, jnp.dtype(cfg.compute_dtype))
    return logits, new_cache, aux


def _none_tree(cfg: ModelConfig):
    """Scan requires xs leaves; give each repeat an empty-dict placeholder."""
    return {"__empty__": jnp.zeros((cfg.repeats,), jnp.int8)}


# ---------------------------------------------------------------------------
# Losses / steps (pjit'd by launch/ and train/)
# ---------------------------------------------------------------------------

def next_token_loss(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Causal LM shift-by-one cross entropy, mean over (B, L-1)."""
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    true_logit = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - true_logit)


def classification_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-frame classification (encoder models)."""
    lg = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    true_logit = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - true_logit)


def chunked_next_token_loss(params, cfg: ModelConfig, x: jax.Array,
                            tokens: jax.Array, n_chunks: int) -> jax.Array:
    """Cross entropy without materializing the full (B, L, V) logits: the
    sequence is split into n_chunks, each chunk's logits are computed,
    reduced, and rematerialized in the backward pass. Essential when
    V ~ 150k (2.5 GB/device of f32 logits otherwise)."""
    B, L, D = x.shape
    xs = x[:, :-1]
    tg = tokens[:, 1:]
    Lm = xs.shape[1]
    pad = (-Lm) % n_chunks
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        tg = jnp.pad(tg, ((0, 0), (0, pad)), constant_values=-1)
    c = xs.shape[1] // n_chunks
    xs = xs.reshape(B, n_chunks, c, D).swapaxes(0, 1)
    tg = tg.reshape(B, n_chunks, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(xc, tc):
        lg = lm_logits(params["head"], xc, jnp.dtype(cfg.compute_dtype))
        logz = jax.nn.logsumexp(lg, axis=-1)
        tl = jnp.take_along_axis(lg, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
        valid = (tc >= 0).astype(jnp.float32)
        return jnp.sum((logz - tl) * valid), jnp.sum(valid)

    def body(carry, xs_tc):
        s, n = carry
        ls, ns = chunk_loss(*xs_tc)
        return (s + ls, n + ns), None

    (tot, cnt), _ = _scan(body, (0.0, 0.0), (xs, tg))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            n_groups: int = 1, ctx: Optional[ExecutionContext] = None,
            remat: bool = False, aux_weight: float = 0.01,
            loss_chunks: int = 0, act_spec=None):
    if loss_chunks > 1 and cfg.causal and "tokens" in batch:
        x, _, aux = hidden_forward(
            params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            n_groups=n_groups, ctx=ctx, remat=remat,
            act_spec=act_spec)
        loss = chunked_next_token_loss(params, cfg, x, batch["tokens"],
                                       loss_chunks)
        return loss + aux_weight * aux, (loss, aux)
    logits, _, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        n_groups=n_groups, ctx=ctx, remat=remat,
        act_spec=act_spec)
    if cfg.causal and "tokens" in batch:
        loss = next_token_loss(logits, batch["tokens"])
    else:
        loss = classification_loss(logits, batch["labels"])
    return loss + aux_weight * aux, (loss, aux)
