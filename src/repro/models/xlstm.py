"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

TPU adaptation notes (DESIGN.md §2/§7):
  * mLSTM maps onto the same chunkwise-dual machinery as SSD: matrix state
    C_t = f_t C_{t-1} + i_t v_t k_t^T is the mamba recurrence with S = head
    dim, so the chunked evaluation is two MXU einsums per chunk. The
    exponential input gate is stabilized by clamping its pre-activation
    (exp-gate overflow guard) instead of xLSTM's running-max bookkeeping.
  * sLSTM drops the hidden-to-hidden gate recurrence (input-conditioned gates
    only) so the scalar recurrence becomes associative and runs as a
    log-depth associative scan instead of a 524k-step sequential loop.
Both simplifications are recorded as changed assumptions.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.ops import ExecutionContext

from .layers import truncated_normal
from .scan_util import scan as _scan

Params = Dict[str, jax.Array]

_EXP_CLAMP = 8.0


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg) -> Params:
    D = cfg.d_model
    H = cfg.n_heads
    hd = D // H
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    std = D ** -0.5
    return {
        "wq": truncated_normal(ks[0], (D, D), std, dtype),
        "wk": truncated_normal(ks[1], (D, D), std, dtype),
        "wv": truncated_normal(ks[2], (D, D), std, dtype),
        "w_if": truncated_normal(ks[3], (D, 2 * H), std, dtype),  # i, f gates
        "b_if": jnp.zeros((2 * H,), dtype),
        "wo": truncated_normal(ks[4], (D, D), std, dtype),
    }


def _mlstm_gates(p: Params, x: jax.Array, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    B, L, D = x.shape
    H = cfg.n_heads
    hd = D // H
    xc = x.astype(cd)
    q = jnp.einsum("bld,de->ble", xc, p["wq"].astype(cd)).reshape(B, L, H, hd)
    k = jnp.einsum("bld,de->ble", xc, p["wk"].astype(cd)).reshape(B, L, H, hd)
    v = jnp.einsum("bld,de->ble", xc, p["wv"].astype(cd)).reshape(B, L, H, hd)
    gates = jnp.einsum("bld,dg->blg", xc, p["w_if"].astype(cd)).astype(jnp.float32)
    gates = gates + p["b_if"].astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)  # (B,L,H)
    logi = jnp.clip(ig, -_EXP_CLAMP, _EXP_CLAMP)  # log of exp input gate
    logf = jax.nn.log_sigmoid(fg)  # forget in (0,1)
    scale = hd ** -0.5
    return (q.astype(jnp.float32) * scale, k.astype(jnp.float32),
            v.astype(jnp.float32), logi, logf)


def mlstm_block(
    p: Params,
    x: jax.Array,  # (B, L, D)
    cfg,
    state: Optional[Tuple[jax.Array, jax.Array]] = None,  # C (B,H,hd,hd), n (B,H,hd)
    ctx: Optional[ExecutionContext] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """``ctx`` is the execution policy every block in the stack accepts;
    the chunked-dual einsums currently have no dispatched kernel entry, so
    it is carried for API uniformity (and future backend entries)."""
    del ctx  # no dispatched kernels in the chunked-dual form yet
    B, L, D = x.shape
    H = cfg.n_heads
    hd = D // H
    cd = jnp.dtype(cfg.compute_dtype)
    q, k, v, logi, logf = _mlstm_gates(p, x, cfg)

    c = min(cfg.chunk_size, L)
    pad = (c - L % c) % c
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v, logi, logf = map(zf, (q, k, v, logi, logf))
    nc = q.shape[1] // c

    def chunk(carry, inp):
        C_prev, n_prev = carry  # (B,H,hd,hd), (B,H,hd)
        qc, kc, vc, lic, lfc = inp  # (B,c,H,*)
        Lc = jnp.cumsum(lfc, axis=1)  # cumulative log forget (inclusive)
        # intra: weight for source u at target t: exp(Lc_t - Lc_u + logi_u).
        # Valid (t >= u) entries are <= _EXP_CLAMP; the clamp prevents
        # upper-triangle overflow (inf * 0 = NaN under the causal mask).
        w = jnp.exp(jnp.minimum(
            Lc[:, :, None, :] - Lc[:, None, :, :] + lic[:, None, :, :],
            _EXP_CLAMP))
        # symbolic causal mask (see ssm.py: avoids giant folded constants)
        ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
        ui = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
        tri = (ui <= ti).astype(jnp.float32)[None, :, :, None]
        w = w * tri  # (B,t,u,H)
        scores = jnp.einsum("bthd,buhd->btuh", qc, kc)
        num = jnp.einsum("btuh,btuh,buhd->bthd", scores, w, vc)
        den = jnp.einsum("btuh,btuh,buhd->bthd", scores, w, jnp.ones_like(kc))
        # carry-in contribution
        dstart = jnp.exp(Lc)  # (B,c,H)
        num = num + jnp.einsum("bthd,bhde,bth->bthe", qc, C_prev, dstart)
        den = den + jnp.einsum("bthd,bhd,bth->bth", qc, n_prev, dstart)[..., None]
        h = num / jnp.maximum(jnp.abs(den), 1.0)
        # state update to chunk end
        Lend = Lc[:, -1:, :]
        w_end = jnp.exp(Lend - Lc + lic)  # (B,c,H)
        C_new = (jnp.exp(Lend[:, 0])[:, :, None, None] * C_prev
                 + jnp.einsum("buh,buhd,buhe->bhde", w_end, kc, vc))
        n_new = (jnp.exp(Lend[:, 0])[:, :, None] * n_prev
                 + jnp.einsum("buh,buhd->bhd", w_end, kc))
        return (C_new, n_new), h

    def to_chunks(a):
        return a.reshape(B, nc, c, *a.shape[2:]).swapaxes(0, 1)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
    else:
        C0, n0 = (s.astype(jnp.float32) for s in state)
    (C_last, n_last), hs = _scan(
        chunk, (C0, n0), tuple(map(to_chunks, (q, k, v, logi, logf))))
    h = hs.swapaxes(0, 1).reshape(B, nc * c, H, hd)[:, :L].reshape(B, L, D)
    out = jnp.einsum("ble,ed->bld", h.astype(cd), p["wo"].astype(cd)).astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = (C_last.astype(state[0].dtype), n_last.astype(state[1].dtype))
    return out, new_state


def mlstm_decode_step(p: Params, x: jax.Array, cfg,
                      state: Tuple[jax.Array, jax.Array],
                      ctx: Optional[ExecutionContext] = None):
    del ctx  # see mlstm_block
    B = x.shape[0]
    H = cfg.n_heads
    hd = cfg.d_model // H
    cd = jnp.dtype(cfg.compute_dtype)
    q, k, v, logi, logf = _mlstm_gates(p, x, cfg)  # L=1
    C_prev, n_prev = (s.astype(jnp.float32) for s in state)
    f = jnp.exp(logf[:, 0])  # (B,H)
    i = jnp.exp(logi[:, 0])
    C_new = f[:, :, None, None] * C_prev + i[:, :, None, None] * jnp.einsum(
        "bhd,bhe->bhde", k[:, 0], v[:, 0])
    n_new = f[:, :, None] * n_prev + i[:, :, None] * k[:, 0]
    num = jnp.einsum("bhd,bhde->bhe", q[:, 0], C_new)
    den = jnp.einsum("bhd,bhd->bh", q[:, 0], n_new)[..., None]
    h = (num / jnp.maximum(jnp.abs(den), 1.0)).reshape(B, 1, cfg.d_model)
    out = jnp.einsum("ble,ed->bld", h.astype(cd), p["wo"].astype(cd)).astype(x.dtype)
    return out, (C_new.astype(state[0].dtype), n_new.astype(state[1].dtype))


def init_mlstm_state(cfg, batch: int, dtype=jnp.float32):
    H = cfg.n_heads
    hd = cfg.d_model // H
    return (jnp.zeros((batch, H, hd, hd), dtype), jnp.zeros((batch, H, hd), dtype))


def mlstm_block_ref(p: Params, x: jax.Array, cfg) -> jax.Array:
    """Sequential oracle."""
    B, L, D = x.shape
    H = cfg.n_heads
    hd = D // H
    cd = jnp.dtype(cfg.compute_dtype)
    q, k, v, logi, logf = _mlstm_gates(p, x, cfg)

    def step(carry, inp):
        C, n = carry
        qt, kt, vt, lit, lft = inp
        f = jnp.exp(lft)
        i = jnp.exp(lit)
        C = f[:, :, None, None] * C + i[:, :, None, None] * jnp.einsum(
            "bhd,bhe->bhde", kt, vt)
        n = f[:, :, None] * n + i[:, :, None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.einsum("bhd,bhd->bh", qt, n)[..., None]
        return (C, n), num / jnp.maximum(jnp.abs(den), 1.0)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    _, hs = jax.lax.scan(step, (C0, n0), tuple(
        a.swapaxes(0, 1) for a in (q, k, v, logi, logf)))
    h = hs.swapaxes(0, 1).reshape(B, L, D)
    return jnp.einsum("ble,ed->bld", h.astype(cd), p["wo"].astype(cd)).astype(x.dtype)


# ---------------------------------------------------------------------------
# sLSTM (associative-scan form)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg) -> Params:
    D = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    std = D ** -0.5
    return {
        "w_zifo": truncated_normal(ks[0], (D, 4 * D), std, dtype),
        "b_zifo": jnp.zeros((4 * D,), dtype),
        "wo": truncated_normal(ks[1], (D, D), std, dtype),
    }


def _slstm_gates(p: Params, x: jax.Array, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    pre = jnp.einsum("bld,dg->blg", x.astype(cd), p["w_zifo"].astype(cd))
    pre = pre.astype(jnp.float32) + p["b_zifo"].astype(jnp.float32)
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    return (jnp.tanh(z), jax.nn.sigmoid(i), jax.nn.sigmoid(f),
            jax.nn.sigmoid(o))


def slstm_block(
    p: Params,
    x: jax.Array,
    cfg,
    state: Optional[Tuple[jax.Array, jax.Array]] = None,  # c (B,D), n (B,D)
    ctx: Optional[ExecutionContext] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    del ctx  # associative-scan form has no dispatched kernel entry yet
    B, L, D = x.shape
    cd = jnp.dtype(cfg.compute_dtype)
    z, i, f, o = _slstm_gates(p, x, cfg)

    def combine(a, b):
        (fa, ca, na), (fb, cb, nb) = a, b
        return (fa * fb, fb * ca + cb, fb * na + nb)

    fs_in, cs_in, ns_in = f, i * z, i
    if state is not None:
        # fold the carry in as a virtual step -1 holding (1, c0, n0)
        c0, n0 = (s.astype(jnp.float32) for s in state)
        fs_in = jnp.concatenate([jnp.ones_like(c0)[:, None], fs_in], axis=1)
        cs_in = jnp.concatenate([c0[:, None], cs_in], axis=1)
        ns_in = jnp.concatenate([n0[:, None], ns_in], axis=1)
    fs, cs, ns = jax.lax.associative_scan(combine, (fs_in, cs_in, ns_in), axis=1)
    if state is not None:
        cs, ns = cs[:, 1:], ns[:, 1:]
    h = o * cs / jnp.maximum(jnp.abs(ns), 1.0)
    out = jnp.einsum("ble,ed->bld", h.astype(cd), p["wo"].astype(cd)).astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = (cs[:, -1].astype(state[0].dtype), ns[:, -1].astype(state[1].dtype))
    return out, new_state


def slstm_decode_step(p: Params, x: jax.Array, cfg,
                      state: Tuple[jax.Array, jax.Array],
                      ctx: Optional[ExecutionContext] = None):
    del ctx  # see slstm_block
    z, i, f, o = _slstm_gates(p, x, cfg)  # (B,1,D)
    c_prev, n_prev = (s.astype(jnp.float32) for s in state)
    c = f[:, 0] * c_prev + i[:, 0] * z[:, 0]
    n = f[:, 0] * n_prev + i[:, 0]
    h = (o[:, 0] * c / jnp.maximum(jnp.abs(n), 1.0))[:, None]
    cd = jnp.dtype(cfg.compute_dtype)
    out = jnp.einsum("ble,ed->bld", h.astype(cd), p["wo"].astype(cd)).astype(x.dtype)
    return out, (c.astype(state[0].dtype), n.astype(state[1].dtype))


def init_slstm_state(cfg, batch: int, dtype=jnp.float32):
    return (jnp.zeros((batch, cfg.d_model), dtype),
            jnp.zeros((batch, cfg.d_model), dtype))
