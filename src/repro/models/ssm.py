"""Mamba block in chunkwise state-space-dual (SSD) form.

HARDWARE ADAPTATION (DESIGN.md §2): the original Mamba CUDA kernel fuses a
sequential selective scan; a mechanical port would serialize the TPU. We use
the matmul-rich SSD formulation (Mamba-2 style): the sequence is split into
chunks of ``cfg.chunk_size``; within a chunk the recurrence is evaluated as
two MXU-friendly einsums (an attention-like (c x c) masked product), across
chunks a lax.scan carries the (H, p, S) state. Per-head scalar decay
a_t = exp(-dt_t * A_h), B/C projections shared across heads.

Recurrence (per batch, head):
    h_t = a_t h_{t-1} + (dt_t x_t) outer B_t        h in R^{p x S}
    y_t = h_t C_t + D_h x_t

The short causal conv1d in front is the paper's 7NL conv degenerate and can
run through the Pallas conv1d kernel (kernels/conv1d.py).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import ops
from repro.ops import ExecutionContext
from .layers import truncated_normal
from .scan_util import scan as _scan

Params = Dict[str, jax.Array]


def init_mamba(key, cfg) -> Params:
    D, di, S, K = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim, cfg.conv_kernel
    H = di // cfg.hd if di % cfg.hd == 0 else 1
    p = di // H
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    std = D ** -0.5
    return {
        "w_in": truncated_normal(ks[0], (D, 2 * di), std, dtype),  # x, z
        "conv_w": truncated_normal(ks[1], (K, di), K ** -0.5, dtype),
        "w_dt": truncated_normal(ks[2], (di, H), di ** -0.5, dtype),
        "b_dt": jnp.zeros((H,), dtype),
        "w_B": truncated_normal(ks[3], (di, S), di ** -0.5, dtype),
        "w_C": truncated_normal(ks[4], (di, S), di ** -0.5, dtype),
        "log_A": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),  # (H,)
        "D_skip": jnp.ones((H,), dtype),
        "w_out": truncated_normal(ks[5], (di, D), di ** -0.5, dtype),
    }


def _heads(cfg) -> Tuple[int, int]:
    di = cfg.d_inner
    H = di // cfg.hd if di % cfg.hd == 0 else 1
    return H, di // H


def _ssm_inputs(p: Params, x: jax.Array, cfg, ctx: Optional[ExecutionContext]):
    """Shared front: in-proj, causal conv, gate projections.

    Returns xh (B,L,H,ph), z (B,L,di), loga (B,L,H), dt (B,L,H),
    Bm/Cm (B,L,S)."""
    cd = jnp.dtype(cfg.compute_dtype)
    H, ph = _heads(cfg)
    xz = jnp.einsum("bld,de->ble", x.astype(cd), p["w_in"].astype(cd))
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = ops.conv1d_causal(xi, p["conv_w"].astype(cd), ctx=ctx)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(cd)
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", xi, p["w_dt"].astype(cd)).astype(jnp.float32)
        + p["b_dt"].astype(jnp.float32))  # (B,L,H) f32
    A = jnp.exp(p["log_A"].astype(jnp.float32))  # (H,)
    loga = -dt * A[None, None, :]  # log a_t  (<= 0)
    Bm = jnp.einsum("bld,ds->bls", xi, p["w_B"].astype(cd)).astype(jnp.float32)
    Cm = jnp.einsum("bld,ds->bls", xi, p["w_C"].astype(cd)).astype(jnp.float32)
    B, L, _ = x.shape
    xh = xi.reshape(B, L, H, ph).astype(jnp.float32) * dt[..., None]
    return xh, xi, z, loga, dt, Bm, Cm


def mamba_block(
    p: Params,
    x: jax.Array,  # (B, L, D)
    cfg,
    state: Optional[Tuple[jax.Array, jax.Array]] = None,  # (ssm h, conv tail)
    ctx: Optional[ExecutionContext] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Full-sequence (train/prefill) mamba block; chunked SSD scan."""
    B, L, D = x.shape
    H, ph = _heads(cfg)
    S = cfg.ssm_state_dim
    cd = jnp.dtype(cfg.compute_dtype)
    xh, xi, z, loga, dt, Bm, Cm = _ssm_inputs(p, x, cfg, ctx)

    c = min(cfg.chunk_size, L)
    if L % c != 0:  # pad to a whole number of chunks
        pad = c - L % c
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // c

    def chunk(h_prev, inp):
        xb, la, Bc, Cc = inp  # (B,c,H,ph), (B,c,H), (B,c,S), (B,c,S)
        Lc = jnp.cumsum(la, axis=1)  # inclusive cumulative log-decay
        scores = jnp.einsum("bts,bus->btu", Cc, Bc)  # (B,c,c)
        # valid (t >= u) exponents are <= 0; clamp kills upper-triangle
        # overflow that would otherwise produce inf * 0 = NaN under the mask
        decay = jnp.exp(jnp.minimum(
            Lc[:, :, None, :] - Lc[:, None, :, :], 0.0))  # (B,t,u,H)
        # symbolic causal mask (iota compare): a materialized tril constant
        # at c=4096 is 67MB and stalls XLA constant folding per unrolled body
        ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
        ui = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
        tri = (ui <= ti).astype(jnp.float32)
        y_intra = jnp.einsum("btu,btuh,buhp->bthp",
                             scores * tri[None], decay * tri[None, :, :, None], xb)
        y_inter = jnp.einsum("bts,bhps,bth->bthp", Cc, h_prev, jnp.exp(Lc))
        Lend = Lc[:, -1:, :]  # (B,1,H)
        w_end = jnp.exp(Lend - Lc)  # decay from u to chunk end
        h_new = (jnp.exp(Lend[:, 0, :])[:, :, None, None] * h_prev
                 + jnp.einsum("buh,buhp,bus->bhps", w_end, xb, Bc))
        return h_new, y_intra + y_inter

    def to_chunks(a):
        return a.reshape(a.shape[0], nc, c, *a.shape[2:]).swapaxes(0, 1)

    h0 = (state[0].astype(jnp.float32) if state is not None
          else jnp.zeros((B, H, ph, S), jnp.float32))
    h_last, ys = _scan(
        chunk, h0, (to_chunks(xh), to_chunks(loga), to_chunks(Bm), to_chunks(Cm)))
    y = ys.swapaxes(0, 1).reshape(B, nc * c, H, ph)[:, :L]
    y = y + xi.reshape(B, L, H, ph).astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, L, H * ph)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(cd)
    out = jnp.einsum("ble,ed->bld", y, p["w_out"].astype(cd)).astype(x.dtype)

    new_state = None
    if state is not None:
        K = cfg.conv_kernel
        xz = jnp.einsum("bld,de->ble", x.astype(cd), p["w_in"].astype(cd))
        conv_tail = jnp.split(xz, 2, axis=-1)[0][:, -(K - 1):, :]
        new_state = (h_last.astype(state[0].dtype), conv_tail.astype(state[1].dtype))
    return out, new_state


def mamba_decode_step(
    p: Params,
    x: jax.Array,  # (B, 1, D)
    cfg,
    state: Tuple[jax.Array, jax.Array],  # h (B,H,ph,S), conv tail (B,K-1,di)
    ctx: Optional[ExecutionContext] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    B = x.shape[0]
    H, ph = _heads(cfg)
    cd = jnp.dtype(cfg.compute_dtype)
    h, tail = state
    K = cfg.conv_kernel

    xz = jnp.einsum("bld,de->ble", x.astype(cd), p["w_in"].astype(cd))
    xi_new, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    window = jnp.concatenate([tail.astype(cd), xi_new], axis=1)  # (B,K,di)
    xi = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32))[:, None, :]
    xi = jax.nn.silu(xi).astype(cd)

    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", xi, p["w_dt"].astype(cd)).astype(jnp.float32)
        + p["b_dt"].astype(jnp.float32))[:, 0]  # (B,H)
    A = jnp.exp(p["log_A"].astype(jnp.float32))
    a = jnp.exp(-dt * A[None, :])  # (B,H)
    Bm = jnp.einsum("bld,ds->bls", xi, p["w_B"].astype(cd)).astype(jnp.float32)[:, 0]
    Cm = jnp.einsum("bld,ds->bls", xi, p["w_C"].astype(cd)).astype(jnp.float32)[:, 0]
    xhead = xi.reshape(B, H, ph).astype(jnp.float32) * dt[..., None]

    hf = h.astype(jnp.float32)
    h_new = a[:, :, None, None] * hf + jnp.einsum("bhp,bs->bhps", xhead, Bm)
    y = jnp.einsum("bhps,bs->bhp", h_new, Cm)
    y = y + xi.reshape(B, H, ph).astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, H * ph)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(cd)
    out = jnp.einsum("ble,ed->bld", y, p["w_out"].astype(cd)).astype(x.dtype)
    new_tail = jnp.concatenate([tail[:, 1:], jnp.split(xz, 2, axis=-1)[0]], axis=1) \
        if K > 1 else tail
    return out, (h_new.astype(h.dtype), new_tail.astype(tail.dtype))


def init_mamba_state(cfg, batch: int, dtype=jnp.float32):
    H, ph = _heads(cfg)
    h = jnp.zeros((batch, H, ph, cfg.ssm_state_dim), dtype)
    tail = jnp.zeros((batch, max(cfg.conv_kernel - 1, 1), cfg.d_inner), dtype)
    return (h, tail)


# ---------------------------------------------------------------------------
# Sequential oracle (tests): the literal recurrence.
# ---------------------------------------------------------------------------

def mamba_block_ref(p: Params, x: jax.Array, cfg) -> jax.Array:
    B, L, D = x.shape
    H, ph = _heads(cfg)
    S = cfg.ssm_state_dim
    cd = jnp.dtype(cfg.compute_dtype)
    xh, xi, z, loga, dt, Bm, Cm = _ssm_inputs(
        p, x, cfg, ctx=ops.default_context().with_backend("xla"))

    def step(h, inp):
        xt, lat, Bt, Ct = inp  # (B,H,ph), (B,H), (B,S), (B,S)
        h = jnp.exp(lat)[:, :, None, None] * h + jnp.einsum("bhp,bs->bhps", xt, Bt)
        y = jnp.einsum("bhps,bs->bhp", h, Ct)
        return h, y

    h0 = jnp.zeros((B, H, ph, S), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (xh.swapaxes(0, 1), loga.swapaxes(0, 1),
                                    Bm.swapaxes(0, 1), Cm.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1)  # (B,L,H,ph)
    y = y + xi.reshape(B, L, H, ph).astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, L, H * ph)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(cd)
    return jnp.einsum("ble,ed->bld", y, p["w_out"].astype(cd)).astype(x.dtype)
