"""Model configuration: one dataclass drives every assigned architecture.

A model is a repeated *pattern unit* of blocks (e.g. ("attn",) for dense LMs,
("mamba",)*7 + ("attn",) for jamba's 1:7 interleave, ("mlstm", "slstm") for
xLSTM). Parameters are stacked over pattern repeats and the forward pass is a
lax.scan over repeats — the HLO stays one-unit sized no matter how deep the
model, which keeps 512-device dry-run compiles tractable.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[str, ...] = ("attn",)  # block kinds in one pattern unit
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1  # apply MoE FFN on every k-th pattern position
    # SSM / recurrent
    ssm_state_dim: int = 16
    ssm_expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 256  # chunked-scan chunk length
    # encoder / frontends
    causal: bool = True
    inputs_are_embeddings: bool = False  # audio/vlm stub frontends
    # training
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256  # embed/head padded for TP divisibility
    # distribution hints (set by the launcher; require the named mesh axes)
    moe_shard_hints: bool = False  # constrain MoE dispatch-path shardings
    fused_kv_cache: bool = False  # one (B,KV,L,2,hd) tensor per attn layer

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by pattern "
            f"unit {len(self.pattern)}")
        assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, hd = self.n_heads, self.n_kv_heads, self.hd
        n = V * D  # embed
        if not self.inputs_are_embeddings:
            n += V * D  # lm head (untied)
        per_unit = 0
        for i, kind in enumerate(self.pattern):
            if kind in ("attn", "encoder_attn"):
                per_unit += D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D
                per_unit += self._ffn_params(i)
            elif kind == "mamba":
                di = self.d_inner
                per_unit += 2 * D * di  # in_proj (x, z)
                per_unit += self.conv_kernel * di
                per_unit += di * (2 * self.ssm_state_dim + 1) + di  # B,C,dt,A
                per_unit += di * D  # out proj
                per_unit += self._ffn_params(i)
            elif kind == "mlstm":
                di = self.d_inner
                per_unit += D * (4 * di) + di * D  # qkv+gates, out
            elif kind == "slstm":
                per_unit += D * (4 * D) + D * D
                per_unit += self._ffn_params(i)
        return n + per_unit * self.repeats

    def _ffn_params(self, pos: int) -> int:
        D, F = self.d_model, self.d_ff
        if F == 0:
            return 0
        dense = 3 * D * F  # SwiGLU
        if self.n_experts and pos % self.moe_every == 0:
            return self.n_experts * dense + D * self.n_experts  # + router
        return dense

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        D, F = self.d_model, self.d_ff
        moe_positions = sum(1 for i in range(len(self.pattern))
                            if self.pattern[i] in ("attn", "mamba", "slstm")
                            and i % self.moe_every == 0 and F > 0)
        dense = 3 * D * F
        inactive = (self.n_experts - self.experts_per_token) * dense
        return full - inactive * moe_positions * self.repeats


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell: what gets lowered in the dry-run."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, layers: Optional[int] = None) -> ModelConfig:
    """Smoke-test variant: same family/pattern, tiny dims."""
    unit = len(cfg.pattern)
    n_layers = layers or (2 * unit)
    n_layers = max(unit, (n_layers // unit) * unit)
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv, min(cfg.n_heads, 4))
    heads = (heads // kv) * kv or kv
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=cfg.d_ff and 128,
        vocab_size=min(cfg.vocab_size, 256),
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        ssm_state_dim=8,
        chunk_size=16,
        name=cfg.name + "-smoke",
    )
