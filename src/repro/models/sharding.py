"""Sharding rules: parameter / activation / cache PartitionSpecs.

The axis binding follows the paper's parallel blocking LP (the unified
``repro.plan`` planner emits it — see ``gemm_sharding_plan`` below): for every
GEMM in the stack, rows (tokens) -> the data-like axes, columns
(features/heads/experts/vocab) -> the `model` axis; the reduction axis is
never sharded in the fwd pass (its split is what the LP charges as
output-reduction traffic). The static rule tables below are that LP solution
written out for the transformer stack; ``gemm_sharding_plan`` re-derives it
per-shape when a layer falls outside the tables.

Conventions:
  mesh axes  = ("pod", "data", "model")  (pod optional)
  batch spec = P(("pod", "data")) - the pod axis is an outer pure-DP ring
  params     = stacked over repeats: a leading None is prepended to every spec
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig

PyTree = Any


def mesh_target(mesh: Mesh, base=None):
    """HardwareTarget whose mesh_axes mirror a jax Mesh — the planner input
    for every sharding decision in this module."""
    from repro.plan import HardwareTarget

    return HardwareTarget.from_mesh(mesh, base=base)


def gemm_sharding_plan(m: int, n: int, k: int, mesh: Mesh):
    """LP-derived PartitionSpecs for C[m,n] = A[m,k] B[k,n] on ``mesh``.

    Returns (plan, spec_A, spec_B, spec_C); specs cover the two matrix dims.
    This is the dynamic path behind the static rule tables below."""
    from repro.plan import MatmulSpec, Planner

    ep = Planner(mesh_target(mesh)).plan(MatmulSpec(m, n, k))
    sp = ep.sharding
    return (ep, P(*sp.input_spec[:2]), P(*sp.filter_spec[:2]),
            P(*sp.output_spec[:2]))


def static_rule_gemms(cfg: ModelConfig, tokens: int = 65536):
    """The static rule tables below, re-expressed as the GEMMs they shard.

    Yields ``(name, (m, n, k), weight_spec)`` for every two-axis weight GEMM
    in the transformer stack: ``m`` = tokens, ``(k, n)`` = the weight shape,
    ``weight_spec`` = the hand-written PartitionSpec from the tables. This is
    the contract ``tests/test_sharding_rules.py`` verifies against the
    dynamic LP path (``gemm_sharding_plan``) — if the tables and the LP ever
    diverge, that test fails loudly instead of production silently running a
    non-LP sharding."""
    D = cfg.d_model
    out = []
    for i, kind in enumerate(cfg.pattern):
        if kind == "attn":
            a = _attn_specs(cfg)
            H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
            out += [("attn.wq", (tokens, H * hd, D), a["wq"]),
                    ("attn.wk", (tokens, KV * hd, D), a["wk"]),
                    ("attn.wv", (tokens, KV * hd, D), a["wv"]),
                    ("attn.wo", (tokens, D, H * hd), a["wo"])]
        elif kind == "mamba":
            m = _mamba_specs()
            out += [("mamba.w_in", (tokens, 2 * cfg.d_inner, D), m["w_in"]),
                    ("mamba.w_out", (tokens, D, cfg.d_inner), m["w_out"])]
        elif kind == "mlstm":
            m = _mlstm_specs()
            out += [("mlstm.wq", (tokens, D, D), m["wq"]),
                    ("mlstm.wo", (tokens, D, D), m["wo"])]
        elif kind == "slstm":
            s = _slstm_specs()
            out += [("slstm.w_zifo", (tokens, 4 * D, D), s["w_zifo"]),
                    ("slstm.wo", (tokens, D, D), s["wo"])]
        from .transformer import _has_ffn, _is_moe
        if _has_ffn(cfg, i) and not _is_moe(cfg, i):
            f = _mlp_specs()
            out += [("mlp.w_gate", (tokens, cfg.d_ff, D), f["w_gate"]),
                    ("mlp.w_down", (tokens, D, cfg.d_ff), f["w_down"])]
    out.append(("head", (tokens, cfg.padded_vocab, D),
                param_specs(cfg)["head"]))
    # dedup repeated pattern positions: one check per distinct GEMM
    seen, uniq = set(), []
    for name, mnk, spec in out:
        if (name, mnk) not in seen:
            seen.add((name, mnk))
            uniq.append((name, mnk, spec))
    return uniq


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def batch_axes(mesh: Mesh):
    ax = data_axes(mesh)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


def _attn_specs(cfg: ModelConfig) -> dict:
    s = {
        "wq": P(None, "model"),
        "wk": P(None, "model"),
        "wv": P(None, "model"),
        "wo": P("model", None),
    }
    if cfg.qkv_bias:
        s.update({"bq": P("model"), "bk": P("model"), "bv": P("model")})
    return s


def _mlp_specs() -> dict:
    return {"w_gate": P(None, "model"), "w_up": P(None, "model"),
            "w_down": P("model", None)}


def _moe_specs() -> dict:
    # expert parallelism: experts sharded over `model`
    return {"router": P(None, None),
            "w_gate": P("model", None, None),
            "w_up": P("model", None, None),
            "w_down": P("model", None, None)}


def _mamba_specs() -> dict:
    return {
        "w_in": P(None, "model"),
        "conv_w": P(None, "model"),
        "w_dt": P("model", None),
        "b_dt": P(None),
        "w_B": P("model", None),
        "w_C": P("model", None),
        "log_A": P(None),
        "D_skip": P(None),
        "w_out": P("model", None),
    }


def _mlstm_specs() -> dict:
    return {"wq": P(None, "model"), "wk": P(None, "model"),
            "wv": P(None, "model"), "w_if": P(None, None),
            "b_if": P(None), "wo": P("model", None)}


def _slstm_specs() -> dict:
    return {"w_zifo": P(None, "model"), "b_zifo": P("model"),
            "wo": P("model", None)}


def param_specs(cfg: ModelConfig) -> PyTree:
    """PartitionSpec pytree matching transformer.init_params structure."""
    layers = {}
    for i, kind in enumerate(cfg.pattern):
        blk = {"norm1": P(None)}
        if kind == "attn":
            blk["core"] = _attn_specs(cfg)
        elif kind == "mamba":
            blk["core"] = _mamba_specs()
        elif kind == "mlstm":
            blk["core"] = _mlstm_specs()
        elif kind == "slstm":
            blk["core"] = _slstm_specs()
        from .transformer import _has_ffn, _is_moe
        if _has_ffn(cfg, i):
            blk["norm2"] = P(None)
            blk["ffn"] = _moe_specs() if _is_moe(cfg, i) else _mlp_specs()
        layers[f"b{i}"] = blk
    # prepend the stacked-repeats axis
    layers = jax.tree.map(lambda p: P(None, *p), layers,
                          is_leaf=lambda x: isinstance(x, P))
    specs = {"layers": layers,
             "final_norm": P(None),
             "head": P(None, "model")}
    if not cfg.inputs_are_embeddings or cfg.family == "vlm":
        specs["embed"] = P("model", None)
    return specs


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq_len: int) -> PyTree:
    """Decode cache specs. Attention KV: batch on data axes when it divides,
    sequence on `model` (32k decode) or on every axis (500k, batch 1) — GSPMD
    turns softmax/PV over the sharded length into the flash-decode
    all-reduce pattern."""
    ba = batch_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    shard_batch = ba if batch % max(dsize, 1) == 0 and batch > 1 else None
    if shard_batch is None:
        seq_spec = tuple(mesh.axis_names)  # all axes on sequence (500k cell)
    else:
        seq_spec = "model"

    def unit():
        c = {}
        for i, kind in enumerate(cfg.pattern):
            if kind == "attn":
                if cfg.fused_kv_cache:
                    c[f"b{i}"] = {"kv": P(None, shard_batch, None, seq_spec,
                                          None, None)}
                else:
                    kv = P(None, shard_batch, None, seq_spec, None)
                    c[f"b{i}"] = {"k": kv, "v": kv}
            elif kind == "mamba":
                c[f"b{i}"] = {"h": P(None, shard_batch, "model", None, None),
                              "tail": P(None, shard_batch, None, "model")}
            elif kind == "mlstm":
                c[f"b{i}"] = {"C": P(None, shard_batch, None, "model", None),
                              "n": P(None, shard_batch, None, "model")}
            elif kind == "slstm":
                c[f"b{i}"] = {"c": P(None, shard_batch, "model"),
                              "n": P(None, shard_batch, "model")}
        return c

    return unit()


def batch_specs(cfg: ModelConfig, mesh: Mesh, kind: str) -> PyTree:
    """Input-batch specs for train/prefill/decode steps."""
    ba = batch_axes(mesh)
    specs = {}
    if cfg.inputs_are_embeddings and kind != "decode":
        specs["embeds"] = P(ba, "model", None)  # sequence-sharded activations
        specs["labels"] = P(ba, "model")
    else:
        specs["tokens"] = P(ba, None)
    return specs


def shardings(mesh: Mesh, specs: PyTree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
