"""Model substrate: config-driven assembly of all 10 assigned architectures
(dense GQA / MoE / SSD-mamba / xLSTM / encoder / VLM-stub) with stacked-layer
scan, KV-cache decode, and LP-driven sharding rules."""

from . import moe, sharding, ssm, transformer, xlstm  # noqa: F401
from .config import LM_SHAPES, ModelConfig, ShapeSpec, reduced  # noqa: F401
