"""Distributed training loop: pjit train_step, gradient accumulation,
optional int8 gradient compression, fault-tolerant stepping, checkpointing.

Fault model (1000+ nodes):
  * checkpoint/restart - atomic committed checkpoints (train.checkpoint),
    auto-resume from the newest commit;
  * bad step / bad data (the single-host analogue of a straggling or corrupt
    node): non-finite loss or a raised exception skips the step, keeps the
    previous state, and increments a skip counter instead of killing the job;
  * elastic restart - checkpoints restore onto a different mesh (see
    checkpoint.restore).

Gradient compression (beyond-paper, motivated by the paper's mixed-precision
bounds: shrinking p on the wire shrinks the Thm 2.2 bound proportionally):
int8 quantize/dequantize per leaf with per-tensor scales before the update.
On a real multi-pod mesh this wraps the pod-axis psum inside shard_map; here
it is applied to the gathered gradient so its accuracy cost is measurable.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, make_source
from repro.models import sharding as shd
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.scan_util import scan as _scan
from repro.ops import ExecutionContext
from . import checkpoint as ckpt
from .optimizer import AdamWConfig, AdamWState, apply_updates, init_state

log = logging.getLogger("repro.train")
PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1  # gradient accumulation
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    remat: bool = False
    n_groups: int = 1
    ctx: Optional[ExecutionContext] = None  # execution policy (repro.ops)
    compress_grads: bool = False
    aux_weight: float = 0.01
    seed: int = 0
    loss_chunks: int = 0  # >1: chunked cross-entropy (big-vocab memory)
    act_spec: Any = None  # activation PartitionSpec (sequence parallelism)


def quantize_int8(tree: PyTree) -> PyTree:
    """Simulated wire compression: int8 with per-tensor absmax scale."""
    def q(g):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        return jnp.round(gf / scale).astype(jnp.int8).astype(jnp.float32) * scale
    return jax.tree.map(q, tree)


def make_train_step(
    model_cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    train_cfg: TrainConfig,
) -> Callable:
    """Builds train_step(params, opt_state, batch) -> (params, opt, metrics).
    Microbatching splits the batch leading dim and accumulates grads."""

    def loss_for(params, batch):
        return T.loss_fn(params, model_cfg, batch,
                         n_groups=train_cfg.n_groups,
                         ctx=train_cfg.ctx,
                         remat=train_cfg.remat,
                         aux_weight=train_cfg.aux_weight,
                         loss_chunks=train_cfg.loss_chunks,
                         act_spec=train_cfg.act_spec)

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch):
        mb = train_cfg.microbatches
        if mb > 1:
            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
            batches = jax.tree.map(split, batch)

            def acc_fn(carry, mbatch):
                gsum, lsum = carry
                (l, (_ce, _aux)), g = grad_fn(params, mbatch)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = _scan(acc_fn, (zero, 0.0), batches)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss = lsum / mb
        else:
            (loss, (_ce, _aux)), grads = grad_fn(params, batch)

        if train_cfg.compress_grads:
            grads = quantize_int8(grads)
        new_params, new_opt, om = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return train_step


class Trainer:
    """End-to-end driver. With mesh=None everything runs single-device."""

    def __init__(
        self,
        model_cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        train_cfg: TrainConfig,
        data_cfg: DataConfig,
        mesh=None,
    ):
        self.model_cfg, self.opt_cfg, self.train_cfg = model_cfg, opt_cfg, train_cfg
        self.data_cfg = data_cfg
        self.mesh = mesh
        self.source = make_source(data_cfg)
        self.skipped_steps = 0

        step_fn = make_train_step(model_cfg, opt_cfg, train_cfg)
        if mesh is not None:
            # sharded path: params/opt keep their NamedShardings (set when the
            # state was created/restored with shd.shardings); jit propagates.
            with mesh:
                self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        else:
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    # -- state ---------------------------------------------------------------
    def init(self, seed: int = 0):
        key = jax.random.PRNGKey(seed)
        self.params = T.init_params(key, self.model_cfg)
        self.opt_state = init_state(self.params)
        self.start_step = 0

    def resume_or_init(self):
        tc = self.train_cfg
        self.init(tc.seed)
        if tc.ckpt_dir:
            latest = ckpt.latest_step(tc.ckpt_dir)
            if latest is not None:
                # plain tuple, matching the structure used by save() below
                tree = {"params": self.params, "opt": tuple(self.opt_state)}
                restored, extra = ckpt.restore(tc.ckpt_dir, tree, step=latest)
                self.params = restored["params"]
                self.opt_state = AdamWState(*restored["opt"])
                self.start_step = latest
                log.info("resumed from step %d", latest)

    # -- loop ----------------------------------------------------------------
    def run(self) -> Dict[str, list]:
        tc = self.train_cfg
        if not hasattr(self, "params"):
            self.resume_or_init()
        history = {"loss": [], "step_time": []}
        for step in range(self.start_step, tc.steps):
            batch_np = self.source.batch(step)
            batch = jax.tree.map(jnp.asarray, batch_np)
            t0 = time.time()
            try:
                new_params, new_opt, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                self.params, self.opt_state = new_params, new_opt
            except FloatingPointError as e:  # bad step: skip, keep state
                self.skipped_steps += 1
                log.warning("skipping step %d: %s", step, e)
                continue
            dt = time.time() - t0
            history["loss"].append(loss)
            history["step_time"].append(dt)
            if tc.log_every and step % tc.log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
            if tc.ckpt_dir and tc.ckpt_every and (step + 1) % tc.ckpt_every == 0:
                ckpt.save(tc.ckpt_dir, step + 1,
                          {"params": self.params, "opt": tuple(self.opt_state)},
                          extra={"skipped": self.skipped_steps},
                          keep=tc.keep_ckpts)
        return history
