"""AdamW with cosine schedule, global-norm clipping, and ZeRO-1 style state
sharding (optimizer moments inherit the parameter sharding, optionally with
the largest replicated dim re-sharded over `data`).

Pure-pytree implementation (no optax dependency): state = (step, m, v).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


def init_state(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(
    params: PyTree,
    grads: PyTree,
    state: AdamWState,
    cfg: AdamWConfig,
) -> Tuple[PyTree, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    b1, b2 = cfg.betas
    lr = schedule(cfg, step)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        pf = p.astype(jnp.float32)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0  # no decay on norms/bias
        new_p = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + decay * pf)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics


def opt_state_specs(param_specs: PyTree) -> Any:
    """Optimizer-state PartitionSpecs: moments inherit parameter sharding
    (ZeRO-1: states live sharded; GSPMD keeps the update local)."""
    from jax.sharding import PartitionSpec as P

    return AdamWState(
        step=P(),
        m=jax.tree.map(lambda s: s, param_specs,
                       is_leaf=lambda x: isinstance(x, P)),
        v=jax.tree.map(lambda s: s, param_specs,
                       is_leaf=lambda x: isinstance(x, P)),
    )
