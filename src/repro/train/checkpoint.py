"""Fault-tolerant, mesh-elastic checkpointing.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json      tree structure, shapes, dtypes, step metadata
        arrays.npz         flat leaf arrays (host-gathered)
        .complete          commit marker (atomic rename finishes the write)

Properties needed at 1000+ nodes:
  * atomic commit - a crash mid-write never corrupts the latest checkpoint
    (write to step_X.tmp, fsync, rename);
  * keep-last-k rotation;
  * elastic restore - arrays are saved unsharded (host view) and re-laid-out
    onto *any* mesh via jax.device_put with the target NamedSharding, so a
    restart may change pod count / mesh shape;
  * resumable - latest_step() scans for the newest committed step.

For multi-host production this would write per-host shards; the single-host
container writes the gathered view (same commit protocol).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    # jax.tree.flatten_with_path only exists in newer jax; use tree_util
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save(directory: str, step: int, tree: PyTree, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    keys, leaves, _ = _flatten_with_paths(tree)
    arrays = {}
    true_dtypes = []
    for i, l in enumerate(leaves):
        a = np.asarray(jax.device_get(l))
        true_dtypes.append(str(a.dtype))
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = a.view(np.uint16)  # npz can't hold ml_dtypes; store raw bits
        arrays[f"a{i}"] = a
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": keys,
        "dtypes": true_dtypes,
        "shapes": [list(a.shape) for a in arrays.values()],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, ".complete"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit

    _rotate(directory, keep)
    return final


def _rotate(directory: str, keep: int) -> None:
    steps = sorted(committed_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def committed_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, ".complete")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, template: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, dict]:
    """Restore into the structure of ``template``. If ``shardings`` (a pytree
    of NamedSharding matching template) is given, leaves are placed sharded —
    onto whatever mesh those shardings reference (elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    import ml_dtypes

    keys_t, leaves_t, treedef = _flatten_with_paths(template)
    by_key = {}
    for i, k in enumerate(manifest["keys"]):
        a = data[f"a{i}"]
        dt = manifest["dtypes"][i]
        if dt == "bfloat16":
            a = a.view(ml_dtypes.bfloat16)
        by_key[k] = a
    missing = [k for k in keys_t if k not in by_key]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    arrays = [by_key[k].astype(t.dtype) if hasattr(t, "dtype") else by_key[k]
              for k, t in zip(keys_t, leaves_t)]

    if shardings is not None:
        shard_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "mesh"))
        placed = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    else:
        placed = [jax.numpy.asarray(a) for a in arrays]
    return jax.tree.unflatten(treedef, placed), manifest["extra"]
