"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels are
validated against, shape-for-shape, in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """C = A @ B with f32 accumulation regardless of input dtype."""
    return jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32)
    ).astype(out_dtype)


def conv2d_ref(
    x: jax.Array,  # (N, c_I, H, W)
    w: jax.Array,  # (c_O, c_I, h_F, w_F)
    stride: tuple[int, int] = (1, 1),
    out_dtype=jnp.float32,
) -> jax.Array:
    """Direct 7NL convolution, VALID padding (the paper's §2.1 convention:
    H = sh*h_O + h_F  =>  h_O = (H - h_F) // sh  output rows)."""
    sh, sw = stride
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(sh, sw),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out.astype(out_dtype)


def conv1d_causal_ref(
    x: jax.Array,  # (B, L, D)
    w: jax.Array,  # (K, D) depthwise taps
    out_dtype=None,
) -> jax.Array:
    """Causal depthwise conv: out[b,l,d] = sum_k x[b, l-K+1+k, d] * w[k, d],
    zero-padded on the left (the mamba/xlstm short conv)."""
    K = w.shape[0]
    xf = x.astype(jnp.float32)
    pad = jnp.pad(xf, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xf)
    for k in range(K):
        out = out + pad[:, k : k + x.shape[1], :] * w[k].astype(jnp.float32)
    return out.astype(out_dtype or x.dtype)


def flash_attention_ref(
    q: jax.Array,  # (B, H, Lq, Dh)
    k: jax.Array,  # (B, Hkv, Lk, Dh)
    v: jax.Array,  # (B, Hkv, Lk, Dh)
    causal: bool = True,
    q_offset: int = 0,
) -> jax.Array:
    """GQA attention oracle with f32 softmax. Hkv may divide H (grouped KV).
    ``q_offset`` shifts the causal mask (decode: query position = offset)."""
    B, H, Lq, Dh = q.shape
    Hkv = k.shape[1]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        Lk = k.shape[2]
        qpos = jnp.arange(Lq)[:, None] + q_offset
        kpos = jnp.arange(Lk)[None, :]
        mask = kpos <= qpos
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
