"""Causal depthwise conv1d Pallas kernel (the mamba/xLSTM short convolution).

7NL view: N=B, c_I=c_O=D (depthwise), h=sequence, w_F=K, h_F=1. The blocking
LP degenerates to choosing (b_B, b_D) tiles with the full (short) K window
VMEM-resident; the sequence axis streams whole per tile (K <= 8 in all
assigned archs, L*b_D*2B <= VMEM for every cell incl. 32k prefill at b_D=128).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.conv_model import round_up
from repro.plan import HardwareTarget


def _conv1d_kernel(x_ref, w_ref, o_ref, *, K: int):
    x = x_ref[...].astype(jnp.float32)  # (bB, L, bD)
    w = w_ref[...].astype(jnp.float32)  # (K, bD)
    L = x.shape[1]
    acc = x * w[K - 1][None, None, :]  # tap k = K-1 aligns with current step
    for k in range(K - 1):
        shift = K - 1 - k
        shifted = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :L, :]
        acc = acc + shifted * w[k][None, None, :]
    o_ref[...] = acc.astype(o_ref.dtype)


def conv1d_causal(
    x: jax.Array,  # (B, L, D)
    w: jax.Array,  # (K, D)
    tiles: Tuple[int, int] | None = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``target`` sets the channel-tile lane width and the interpret default;
    the degenerate LP solution is the closed form in the module docstring."""
    B, L, D = x.shape
    K = w.shape[0]
    lane = target.align_lane if target is not None else 128
    sublane = target.align_sublane if target is not None else 8
    bB, bD = tiles or (max(1, min(B, sublane)), max(1, min(D, lane)))
    if interpret is None:
        interpret = target.interpret if target is not None else True
    Bp, Dp = round_up(B, bB), round_up(D, bD)
    if (Bp, Dp) != (B, D):
        x = jnp.pad(x, ((0, Bp - B), (0, 0), (0, Dp - D)))
        w = jnp.pad(w, ((0, 0), (0, Dp - D)))
    out = pl.pallas_call(
        functools.partial(_conv1d_kernel, K=K),
        grid=(Bp // bB, Dp // bD),
        in_specs=[
            pl.BlockSpec((bB, L, bD), lambda i, j: (i, 0, j)),
            pl.BlockSpec((K, bD), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bB, L, bD), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, L, Dp), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:B, :, :D]


def _conv1d_geometry(x, w, tiles: Tuple[int, int] | None,
                     target: Optional[HardwareTarget]):
    """(bB, bD, Bp, Dp, grid) — the launch geometry :func:`conv1d_causal`
    lowers, shared with the words counter and the access plan."""
    B, L, D = x.shape
    lane = target.align_lane if target is not None else 128
    sublane = target.align_sublane if target is not None else 8
    bB, bD = tiles or (max(1, min(B, sublane)), max(1, min(D, lane)))
    Bp, Dp = round_up(B, bB), round_up(D, bD)
    return bB, bD, Bp, Dp, (Bp // bB, Dp // bD)


def conv1d_hbm_words(
    x,  # array or ShapeDtypeStruct, (B, L, D)
    w,  # array or ShapeDtypeStruct, (K, D)
    tiles: Tuple[int, int] | None = None,
    target: Optional[HardwareTarget] = None,
) -> float:
    """Measured HBM words (32-bit) one ``conv1d_causal`` dispatch moves: one
    padded input block in and one output block out per (i, j) grid step, plus
    the (K, bD) filter block — fetched once per step when the channel grid
    has > 1 column (its index map (0, j) changes every step), but only once
    in total when nD == 1 (the index map is then constant and Pallas elides
    the re-fetch). Shapes/dtypes only (``jax.ShapeDtypeStruct`` works)."""
    L = x.shape[1]
    K = w.shape[0]
    bB, bD, Bp, Dp, grid = _conv1d_geometry(x, w, tiles, target)
    nB, nD = grid
    p_x = jnp.dtype(x.dtype).itemsize / 4.0
    p_w = jnp.dtype(w.dtype).itemsize / 4.0
    w_fetches = nB * nD if nD > 1 else 1
    return (nB * nD * bB * L * bD * p_x  # input blocks (out dtype = x dtype)
            + w_fetches * K * bD * p_w  # filter blocks
            + nB * nD * bB * L * bD * p_x)  # output stores


def conv1d_access_plan(
    x,  # array or ShapeDtypeStruct, (B, L, D)
    w,  # array or ShapeDtypeStruct, (K, D)
    tiles: Tuple[int, int] | None = None,
    target: Optional[HardwareTarget] = None,
    op: str = "conv1d_causal",
):
    """The :class:`repro.verify.access.KernelAccessPlan` of one
    ``conv1d_causal`` launch (pure BlockSpec pipeline, no manual DMA)."""
    from repro.verify.access import (BlockAccess, KernelAccessPlan,
                                     ScratchAlloc)

    L = x.shape[1]
    K = w.shape[0]
    bB, bD, Bp, Dp, grid = _conv1d_geometry(x, w, tiles, target)
    p_x = jnp.dtype(x.dtype).itemsize / 4.0
    p_w = jnp.dtype(w.dtype).itemsize / 4.0
    accesses = (
        BlockAccess(name="x", kind="load", block_shape=(bB, L, bD),
                    array_shape=(Bp, L, Dp), word_size=p_x,
                    index_map=lambda i, j: (i, 0, j)),
        BlockAccess(name="w", kind="load", block_shape=(K, bD),
                    array_shape=(K, Dp), word_size=p_w,
                    index_map=lambda i, j: (0, j)),
        BlockAccess(name="out", kind="store", block_shape=(bB, L, bD),
                    array_shape=(Bp, L, Dp), word_size=p_x,
                    index_map=lambda i, j: (i, 0, j)),
    )
    scratch = (
        ScratchAlloc("x_pipeline[2]", 2 * bB * L * bD * p_x),
        ScratchAlloc("w_pipeline[2]", 2 * K * bD * p_w),
        ScratchAlloc("out_pipeline[2]", 2 * bB * L * bD * p_x),
    )
    return KernelAccessPlan(op=op, grid=grid, accesses=accesses,
                            scratch=scratch)
