"""Causal depthwise conv1d Pallas kernel (the mamba/xLSTM short convolution).

7NL view: N=B, c_I=c_O=D (depthwise), h=sequence, w_F=K, h_F=1. The blocking
LP degenerates to choosing (b_B, b_D) tiles with the full (short) K window
VMEM-resident; the sequence axis streams whole per tile (K <= 8 in all
assigned archs, L*b_D*2B <= VMEM for every cell incl. 32k prefill at b_D=128).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.conv_model import round_up
from repro.plan import HardwareTarget


def _conv1d_kernel(x_ref, w_ref, o_ref, *, K: int):
    x = x_ref[...].astype(jnp.float32)  # (bB, L, bD)
    w = w_ref[...].astype(jnp.float32)  # (K, bD)
    L = x.shape[1]
    acc = x * w[K - 1][None, None, :]  # tap k = K-1 aligns with current step
    for k in range(K - 1):
        shift = K - 1 - k
        shifted = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :L, :]
        acc = acc + shifted * w[k][None, None, :]
    o_ref[...] = acc.astype(o_ref.dtype)


def conv1d_causal(
    x: jax.Array,  # (B, L, D)
    w: jax.Array,  # (K, D)
    tiles: Tuple[int, int] | None = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``target`` sets the channel-tile lane width and the interpret default;
    the degenerate LP solution is the closed form in the module docstring."""
    B, L, D = x.shape
    K = w.shape[0]
    lane = target.align_lane if target is not None else 128
    sublane = target.align_sublane if target is not None else 8
    bB, bD = tiles or (max(1, min(B, sublane)), max(1, min(D, lane)))
    if interpret is None:
        interpret = target.interpret if target is not None else True
    Bp, Dp = round_up(B, bB), round_up(D, bD)
    if (Bp, Dp) != (B, D):
        x = jnp.pad(x, ((0, Bp - B), (0, 0), (0, Dp - D)))
        w = jnp.pad(w, ((0, 0), (0, Dp - D)))
    out = pl.pallas_call(
        functools.partial(_conv1d_kernel, K=K),
        grid=(Bp // bB, Dp // bD),
        in_specs=[
            pl.BlockSpec((bB, L, bD), lambda i, j: (i, 0, j)),
            pl.BlockSpec((K, bD), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bB, L, bD), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, L, Dp), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:B, :, :D]
