"""Public jit'd kernel wrappers.

Every op has two execution paths:
  * ``xla``    - pure jnp/lax (used by default in the model stack so the same
                 graph lowers on CPU, the dry-run's 512 fake devices, and real
                 TPU without Pallas);
  * ``pallas`` - the LP-tiled Pallas kernel (TPU target; interpret=True on
                 CPU). Enabled via use_pallas=True or REPRO_USE_PALLAS=1.

The switch is an argument rather than global state so tests can sweep both
paths and assert they agree.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref
from .conv1d import conv1d_causal as _conv1d_pallas
from .conv2d import conv2d as _conv2d_pallas
from .flash_attention import flash_attention as _flash_pallas
from .matmul import matmul as _matmul_pallas


def _default_use_pallas() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


@functools.partial(jax.jit, static_argnames=("use_pallas", "out_dtype"))
def matmul(a, b, use_pallas: bool | None = None, out_dtype=jnp.float32):
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas:
        return _matmul_pallas(a, b, out_dtype=out_dtype)
    return ref.matmul_ref(a, b, out_dtype=out_dtype)


@functools.partial(jax.jit, static_argnames=("stride", "use_pallas", "out_dtype"))
def conv2d(x, w, stride=(1, 1), use_pallas: bool | None = None,
           out_dtype=jnp.float32):
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas:
        return _conv2d_pallas(x, w, stride=stride, out_dtype=out_dtype)
    return ref.conv2d_ref(x, w, stride=stride, out_dtype=out_dtype)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def conv1d_causal(x, w, use_pallas: bool | None = None):
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas:
        return _conv1d_pallas(x, w)
    return ref.conv1d_causal_ref(x, w)


@functools.partial(jax.jit, static_argnames=("causal", "q_offset", "use_pallas"))
def attention(q, k, v, causal: bool = True, q_offset: int = 0,
              use_pallas: bool | None = None):
    """GQA attention, (B, H, L, Dh) layout; Hkv divides H."""
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, q_offset=q_offset)
    B, H, Lq, Dh = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    out = _flash_pallas(
        q.reshape(B * H, Lq, Dh),
        k.reshape(B * H, Lk, Dh),
        v.reshape(B * H, Lk, Dh),
        causal=causal, q_offset=q_offset,
    )
    return out.reshape(B, H, Lq, Dh)
