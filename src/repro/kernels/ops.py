"""DEPRECATED shim — superseded by the ``repro.ops`` dispatch subsystem.

The ``use_pallas: bool`` switch is replaced by capability-based backend
dispatch: build an :class:`repro.ops.ExecutionContext` (HardwareTarget +
precision policy + backend override) and pass ``ctx=`` instead:

    from repro import ops
    ops.matmul(a, b, ctx=ops.ExecutionContext(target=TPU_V5E))

This module forwards the old signatures for one PR and will then be removed.
Passing ``use_pallas=`` emits a ``DeprecationWarning``; ``use_pallas=None``
defers to the new resolution order (``REPRO_BACKEND`` env var, then the
context's target default).
"""

from __future__ import annotations

import warnings

import jax.numpy as jnp


def _ctx(use_pallas):
    from repro import ops as _ops

    if use_pallas is None:
        return None
    warnings.warn(
        "use_pallas= is deprecated; pass ctx=repro.ops.ExecutionContext(...) "
        "(or set REPRO_BACKEND=xla|pallas)", DeprecationWarning, stacklevel=3)
    return _ops.default_context().with_backend(
        "pallas" if use_pallas else "xla")


def matmul(a, b, use_pallas: bool | None = None, out_dtype=jnp.float32):
    from repro import ops as _ops

    return _ops.matmul(a, b, ctx=_ctx(use_pallas), out_dtype=out_dtype)


def conv2d(x, w, stride=(1, 1), use_pallas: bool | None = None,
           out_dtype=jnp.float32):
    from repro import ops as _ops

    return _ops.conv2d(x, w, stride=stride, ctx=_ctx(use_pallas),
                       out_dtype=out_dtype)


def conv1d_causal(x, w, use_pallas: bool | None = None):
    from repro import ops as _ops

    return _ops.conv1d_causal(x, w, ctx=_ctx(use_pallas))


def attention(q, k, v, causal: bool = True, q_offset: int = 0,
              use_pallas: bool | None = None):
    """GQA attention, (B, H, L, Dh) layout; Hkv divides H."""
    from repro import ops as _ops

    return _ops.attention(q, k, v, causal=causal, q_offset=q_offset,
                          ctx=_ctx(use_pallas))
