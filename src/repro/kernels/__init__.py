"""Pallas TPU kernels for the perf-critical compute hot-spots, tiled by the
``repro.plan`` planner (every kernel accepts ``plan=`` / ``target=``).
Validated against the pure-jnp oracles in ref.py with interpret=True on CPU.

``plan_conv_tiles`` / ``plan_tiles`` are deprecated shims over
``repro.plan.plan``; new code should pass an ``ExecutionPlan`` or a
``HardwareTarget`` instead."""

from . import ops, ref  # noqa: F401
from .conv1d import conv1d_causal  # noqa: F401
from .conv2d import conv2d, plan_conv_tiles  # noqa: F401
from .flash_attention import attention_blocks, flash_attention  # noqa: F401
from .matmul import matmul, plan_tiles  # noqa: F401
