"""Pallas TPU kernels for the perf-critical compute hot-spots, tiled by the
``repro.plan`` planner. Every kernel accepts ``plan=`` (an ``ExecutionPlan``
from ``repro.plan.plan``) or ``target=`` (a ``HardwareTarget``); the
pre-redesign per-module planners (``plan_conv_tiles``, ``plan_tiles``) are
retired, and so is the ``use_pallas=`` shim (``kernels/ops.py``) — pick a
backend with ``repro.ops.ExecutionContext``. Validated against the pure-jnp
oracles in ref.py with interpret=True on CPU.

Consumers should not call these modules directly: the ``repro.ops`` dispatch
subsystem (ExecutionContext -> Backend -> kernel) routes each call to the
right backend with capability fallback and attaches measured HBM-word
counters (``conv2d_hbm_words``, ``matmul_hbm_words``, ``im2col_hbm_words``,
``attention_hbm_words``, ``paged_decode_hbm_words``) to every instrumented
dispatch."""

from . import ref  # noqa: F401
from .conv1d import conv1d_causal  # noqa: F401
from .conv2d import conv2d, conv2d_hbm_words  # noqa: F401
from .flash_attention import (  # noqa: F401
    attention_blocks,
    attention_hbm_words,
    flash_attention,
    paged_decode_attention,
    paged_decode_hbm_words,
)
from .im2col import conv2d_im2col, im2col_hbm_words  # noqa: F401
from .matmul import matmul, matmul_hbm_words  # noqa: F401
