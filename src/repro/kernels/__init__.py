"""Pallas TPU kernels for the perf-critical compute hot-spots, tiled by the
``repro.plan`` planner. Every kernel accepts ``plan=`` (an ``ExecutionPlan``
from ``repro.plan.plan``) or ``target=`` (a ``HardwareTarget``); the
pre-redesign per-module planners (``plan_conv_tiles``, ``plan_tiles``) are
retired. Validated against the pure-jnp oracles in ref.py with
interpret=True on CPU.

Consumers should not call these modules directly: the ``repro.ops`` dispatch
subsystem (ExecutionContext -> Backend -> kernel) routes each call to the
right backend with capability fallback. ``kernels/ops.py`` is the deprecated
``use_pallas=`` shim forwarding there for one PR."""

from . import ops, ref  # noqa: F401
from .conv1d import conv1d_causal  # noqa: F401
from .conv2d import conv2d  # noqa: F401
from .flash_attention import attention_blocks, flash_attention  # noqa: F401
from .matmul import matmul  # noqa: F401
