"""LP-tiled Pallas matmul (TPU target, validated with interpret=True on CPU).

Block shapes (bm, bn, bk) come from the paper's blocking LP applied to the
degenerate 7NL CNN (w_F = h_F = w_O = h_O = 1): the same machinery that tiles
convolutions tiles every GEMM in the LM stack. Inputs stream HBM->VMEM in
bf16 (p_I = p_F = 0.5 words); the accumulator tile is f32 (p_O = 1 word) and
stays VMEM-resident across the k reduction — exactly the paper's §5
scratchpad/accumulator discipline.

The A/B streams are double-buffered across the k reduction grid axis (the
same pattern as kernels/conv2d.py): both operands stay in ANY/HBM memory and
the kernel DMAs each (bm, bk)/(bk, bn) block into a two-slot VMEM scratch,
starting step k+1's copies before computing step k's GEMM — this is the
double-buffering the LP's halved capacity (§5) models.

``matmul_hbm_words`` reports the measured HBM words one dispatch moves from
the same launch geometry.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_model import Precision, round_up
from repro.plan import (ExecutionPlan, HardwareTarget, MatmulSpec,
                        resolve_kernel_plan, warn_legacy_kernel_kwargs)


def _matmul_spec(m: int, n: int, k: int, in_bits: int) -> MatmulSpec:
    p_in = in_bits / 32.0
    return MatmulSpec(m=m, n=n, k=k, prec=Precision(p_in, p_in, 1.0))


def _matmul_kernel(a_hbm, b_hbm, o_ref, a_vmem, b_vmem, acc_ref, sems, *,
                   nk: int, bm: int, bn: int, bk: int):
    """Grid = (nm, nn, nk); k innermost so the f32 accumulator tile stays
    resident across the reduction (paper §5 loop-order discipline)."""
    i, j, ki = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    def stream(slot, k_idx):
        return (
            pltpu.make_async_copy(
                a_hbm.at[pl.ds(i * bm, bm), pl.ds(k_idx * bk, bk)],
                a_vmem.at[slot], sems.at[slot, 0]),
            pltpu.make_async_copy(
                b_hbm.at[pl.ds(k_idx * bk, bk), pl.ds(j * bn, bn)],
                b_vmem.at[slot], sems.at[slot, 1]),
        )

    @pl.when(ki == 0)
    def _warmup():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        for cp in stream(0, 0):
            cp.start()

    slot = ki % 2

    @pl.when(ki + 1 < nk)
    def _prefetch():  # overlap the next k step's DMA with this step's GEMM
        for cp in stream(1 - slot, ki + 1):
            cp.start()

    for cp in stream(slot, ki):
        cp.wait()

    acc_ref[...] += jnp.dot(
        a_vmem[slot], b_vmem[slot], preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    a: jax.Array,  # (m, k)
    b: jax.Array,  # (k, n)
    out_dtype=jnp.float32,
    ctx=None,  # ExecutionContext (duck-typed: .target/.interpret/.autotune)
    tiles: Tuple[int, int, int] | None = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """C[m,n] = A @ B with LP-chosen VMEM tiling.

    Execution policy rides ``ctx``. Tiles come from (in priority order) an
    explicit legacy ``tiles`` triple, an explicit ``plan`` (the dispatcher/
    autotuner handoff), or a fresh plan resolved for the context's target
    (tuned winner when one is stored). ``target=``/``tiles=`` are legacy
    (DeprecationWarning; lint VRF015)."""
    warn_legacy_kernel_kwargs("matmul", target=target, tiles=tiles)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    in_bits = jnp.dtype(a.dtype).itemsize * 8
    (bm, bn, bk), interpret = resolve_kernel_plan(
        _matmul_spec(m, n, k, in_bits),
        plan=plan, target=target, tiles=tiles, interpret=interpret, ctx=ctx)

    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    nm, nn, nk = mp // bm, np_ // bn, kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, bm=bm, bn=bn, bk=bk),
        grid=(nm, nn, nk),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((2, bm, bk), a.dtype),  # double-buffered A stream
            pltpu.VMEM((2, bk, bn), b.dtype),  # double-buffered B stream
            pltpu.VMEM((bm, bn), jnp.float32),  # f32 accumulator
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def matmul_access_plan(
    a,  # array or ShapeDtypeStruct, (m, k)
    b,  # array or ShapeDtypeStruct, (k, n)
    tiles: Optional[Tuple[int, int, int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    out_dtype=jnp.float32,
    op: str = "matmul",
):
    """The :class:`repro.verify.access.KernelAccessPlan` of one ``matmul``
    launch: the A/B halo-free DMA windows streamed every (i, j, k) step, the
    blocked output store, the two-slot VMEM scratch, and the double-buffered
    DMA schedule over the k reduction — restated from the same geometry the
    kernel lowers so ``repro.verify.audit`` can cross-check ``words_fn``."""
    from repro.verify.access import (BlockAccess, KernelAccessPlan,
                                     ScratchAlloc, WindowAccess)
    from repro.verify.hazards import double_buffered_schedule

    m, k = a.shape
    n = b.shape[1]
    in_bits = jnp.dtype(a.dtype).itemsize * 8
    (bm, bn, bk), _ = resolve_kernel_plan(
        _matmul_spec(m, n, k, in_bits), plan=plan, target=target, tiles=tiles)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    grid = (mp // bm, np_ // bn, kp // bk)
    p_a = jnp.dtype(a.dtype).itemsize / 4.0
    p_b = jnp.dtype(b.dtype).itemsize / 4.0
    p_out = jnp.dtype(out_dtype).itemsize / 4.0
    accesses = (
        WindowAccess(
            name="a", kind="load", array_shape=(mp, kp), word_size=p_a,
            window=lambda i, j, ki: ((i * bm, bm), (ki * bk, bk)),
            requires=lambda i, j, ki: ((i * bm, (i + 1) * bm),
                                       (ki * bk, (ki + 1) * bk))),
        WindowAccess(
            name="b", kind="load", array_shape=(kp, np_), word_size=p_b,
            window=lambda i, j, ki: ((ki * bk, bk), (j * bn, bn)),
            requires=lambda i, j, ki: ((ki * bk, (ki + 1) * bk),
                                       (j * bn, (j + 1) * bn))),
        BlockAccess(
            name="out", kind="store", block_shape=(bm, bn),
            array_shape=(mp, np_), word_size=p_out,
            index_map=lambda i, j, ki: (i, j)),
    )
    scratch = (
        ScratchAlloc("a_vmem[2]", 2 * bm * bk * p_a),
        ScratchAlloc("b_vmem[2]", 2 * bk * bn * p_b),
        ScratchAlloc("acc_f32", float(bm * bn)),
    )
    return KernelAccessPlan(
        op=op, grid=grid, accesses=accesses, scratch=scratch,
        dma=double_buffered_schedule(grid[2], n_slots=2, name="a/b k-stream"),
        note="DMA schedule repeats identically per (i, j) output tile")


def matmul_hbm_words(
    a,  # array or ShapeDtypeStruct, (m, k)
    b,  # array or ShapeDtypeStruct, (k, n)
    tiles: Optional[Tuple[int, int, int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    out_dtype=jnp.float32,
) -> float:
    """Measured HBM words (32-bit) one ``matmul`` dispatch moves: one A and
    one B block DMA'd per grid step plus the padded output stores. Only
    shapes/dtypes are consulted (``jax.ShapeDtypeStruct`` works)."""
    m, k = a.shape
    n = b.shape[1]
    in_bits = jnp.dtype(a.dtype).itemsize * 8
    (bm, bn, bk), _ = resolve_kernel_plan(
        _matmul_spec(m, n, k, in_bits), plan=plan, target=target, tiles=tiles)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    n_steps = (mp // bm) * (np_ // bn) * (kp // bk)
    p_a = jnp.dtype(a.dtype).itemsize / 4.0
    p_b = jnp.dtype(b.dtype).itemsize / 4.0
    p_out = jnp.dtype(out_dtype).itemsize / 4.0
    return (n_steps * (bm * bk * p_a + bk * bn * p_b) + mp * np_ * p_out)
