"""LP-tiled Pallas matmul (TPU target, validated with interpret=True on CPU).

Block shapes (bm, bn, bk) come from the paper's blocking LP applied to the
degenerate 7NL CNN (w_F = h_F = w_O = h_O = 1): the same machinery that tiles
convolutions tiles every GEMM in the LM stack. Inputs stream HBM->VMEM in
bf16 (p_I = p_F = 0.5 words); the accumulator tile is f32 (p_O = 1 word) and
stays VMEM-resident across the k reduction — exactly the paper's §5
scratchpad/accumulator discipline, with double-buffering halving capacity.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_model import Precision, ceil_div, round_up
from repro.core.tiling import TPU_VMEM_WORDS, matmul_tiles


@functools.lru_cache(maxsize=512)
def plan_tiles(m: int, n: int, k: int, vmem_words: int = TPU_VMEM_WORDS,
               in_bits: int = 16) -> Tuple[int, int, int]:
    """Cache the LP solve per GEMM shape (runs at trace time only)."""
    p_in = in_bits / 32.0
    bm, bn, bk = matmul_tiles(m, n, k, vmem_words=vmem_words,
                              prec=Precision(p_in, p_in, 1.0))
    # clamp to the padded problem so BlockSpecs divide evenly
    bm = min(bm, round_up(m, 8))
    bn = min(bn, round_up(n, 128))
    bk = min(bk, round_up(k, 128))
    return bm, bn, bk


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Grid = (nm, nn, nk); k innermost so the f32 accumulator tile stays
    resident across the reduction (paper §5 loop-order discipline)."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    a: jax.Array,  # (m, k)
    b: jax.Array,  # (k, n)
    out_dtype=jnp.float32,
    tiles: Tuple[int, int, int] | None = None,
    interpret: bool = True,
) -> jax.Array:
    """C[m,n] = A @ B with LP-chosen VMEM tiling."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    in_bits = jnp.dtype(a.dtype).itemsize * 8
    bm, bn, bk = tiles or plan_tiles(m, n, k, in_bits=in_bits)

    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    nm, nn, nk = mp // bm, np_ // bn, kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
