"""LP-tiled Pallas matmul (TPU target, validated with interpret=True on CPU).

Block shapes (bm, bn, bk) come from the paper's blocking LP applied to the
degenerate 7NL CNN (w_F = h_F = w_O = h_O = 1): the same machinery that tiles
convolutions tiles every GEMM in the LM stack. Inputs stream HBM->VMEM in
bf16 (p_I = p_F = 0.5 words); the accumulator tile is f32 (p_O = 1 word) and
stays VMEM-resident across the k reduction — exactly the paper's §5
scratchpad/accumulator discipline, with double-buffering halving capacity.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_model import Precision, round_up
from repro.plan import (ExecutionPlan, HardwareTarget, MatmulSpec,
                        resolve_kernel_plan)


def _matmul_spec(m: int, n: int, k: int, in_bits: int) -> MatmulSpec:
    p_in = in_bits / 32.0
    return MatmulSpec(m=m, n=n, k=k, prec=Precision(p_in, p_in, 1.0))


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Grid = (nm, nn, nk); k innermost so the f32 accumulator tile stays
    resident across the reduction (paper §5 loop-order discipline)."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    a: jax.Array,  # (m, k)
    b: jax.Array,  # (k, n)
    out_dtype=jnp.float32,
    tiles: Tuple[int, int, int] | None = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """C[m,n] = A @ B with LP-chosen VMEM tiling.

    Tiles come from (in priority order) an explicit legacy ``tiles`` triple,
    an ``ExecutionPlan``, or a fresh plan solved for ``target``."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    in_bits = jnp.dtype(a.dtype).itemsize * 8
    (bm, bn, bk), interpret = resolve_kernel_plan(
        _matmul_spec(m, n, k, in_bits),
        plan=plan, target=target, tiles=tiles, interpret=interpret)

    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    nm, nn, nk = mp // bm, np_ // bn, kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
