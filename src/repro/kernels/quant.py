"""Quantized (int8-stream) conv2d / matmul Pallas kernels.

Same LP-tiled launch geometry as ``kernels/conv2d.py`` / ``kernels/matmul.py``
(the geometry helpers are imported, not restated), but the input and filter
stream HBM->VMEM as int8 — a quarter word per element — so the blocking LP,
solving against ``Precision(0.25, 0.25, p_out)``, buys roughly 2x bigger
tiles from the same VMEM and the Thm 2.1 bound itself drops (see
``core.bounds.mixed_precision_bound``). Inside the kernel each MXU tap runs
an int8 x int8 -> int32 dot (``preferred_element_type``, exact for any
b_cI <= 2^14) whose result is widened into the f32 accumulator tile; the
folded per-output-channel scale — one f32 vector, quantization's whole
dequantization state — is applied once at the store:

    out[n, co, h, w] = (sum_taps int8-dot) * scale[co]  ->  out_dtype

``scale`` is ``s_x * s_w[c_O]`` (``repro.quant.quantize_conv_operands``), a
``(1, c_O)`` f32 operand delivered through a constant-index BlockSpec: Pallas
fetches it exactly once per launch, which is also exactly how the words_fn
and the access plan charge it (c_O words, not c_O x n_steps — the seeded
``scale_applied_twice`` mutant flips precisely this and the auditor must
catch it).

Output storage defaults to bf16 (half a word): int8-in/bf16-out is the
policy ``repro.quant.INT8_SPEC`` names, and it is what moves measured conv
words to ~0.5x the bf16-in/f32-out baseline on the ResNet-50 shapes
(gated <= 0.55x in ``benchmarks/quant_bench.py``).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_model import Precision, round_up
from repro.plan import (ConvSpec, ExecutionPlan, HardwareTarget, MatmulSpec,
                        resolve_kernel_plan, warn_legacy_kernel_kwargs)

from .conv2d import _launch_geometry, _normalize_tiles


def _wordwidth(dtype) -> float:
    return jnp.dtype(dtype).itemsize / 4.0


def _conv_spec_q(N: int, c_I: int, c_O: int, h_O: int, w_O: int, h_F: int,
                 w_F: int, sh: int, sw: int, x_dtype, w_dtype,
                 out_dtype) -> ConvSpec:
    """Per-operand mixed-precision ConvSpec: the LP and the Thm 2.1 bound
    both see the stored widths (int8 = 0.25 words), unlike ``_conv_spec``
    which pins p_O to one full word."""
    return ConvSpec(N=N, c_I=c_I, c_O=c_O, w_O=w_O, h_O=h_O, w_F=w_F,
                    h_F=h_F, sw=sw, sh=sh,
                    prec=Precision(_wordwidth(x_dtype), _wordwidth(w_dtype),
                                   _wordwidth(out_dtype)))


def _matmul_spec_q(m: int, n: int, k: int, a_dtype, b_dtype,
                   out_dtype) -> MatmulSpec:
    return MatmulSpec(m=m, n=n, k=k,
                      prec=Precision(_wordwidth(a_dtype),
                                     _wordwidth(b_dtype),
                                     _wordwidth(out_dtype)))


# ---------------------------------------------------------------------------
# conv2d_q
# ---------------------------------------------------------------------------

def _conv_q_kernel(x_hbm, w_hbm, s_ref, o_ref, x_vmem, w_vmem, acc_ref,
                   sems, *, n_ci: int,
                   tiles: Tuple[int, int, int, int, int], h_in: int,
                   w_in: int, h_F: int, w_F: int, sh: int, sw: int):
    bN, b_cI, b_cO, bh, bw = tiles
    n, co, h, wb, ci = (pl.program_id(i) for i in range(5))

    def stream(slot, ci_idx):
        return (
            pltpu.make_async_copy(
                x_hbm.at[pl.ds(n * bN, bN), pl.ds(ci_idx * b_cI, b_cI),
                         pl.ds(h * bh * sh, h_in), pl.ds(wb * bw * sw, w_in)],
                x_vmem.at[slot], sems.at[slot, 0]),
            pltpu.make_async_copy(
                w_hbm.at[pl.ds(co * b_cO, b_cO), pl.ds(ci_idx * b_cI, b_cI)],
                w_vmem.at[slot], sems.at[slot, 1]),
        )

    @pl.when(ci == 0)
    def _warmup():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        for cp in stream(0, 0):
            cp.start()

    slot = ci % 2

    @pl.when(ci + 1 < n_ci)
    def _prefetch():
        for cp in stream(1 - slot, ci + 1):
            cp.start()

    for cp in stream(slot, ci):
        cp.wait()

    x = x_vmem[slot]  # (bN, b_cI, h_in, w_in) int8
    w = w_vmem[slot]  # (b_cO, b_cI, h_F, w_F) int8
    acc = acc_ref[...]
    for hf in range(h_F):
        for wf in range(w_F):
            tap = jax.lax.slice(
                x,
                (0, 0, hf, wf),
                (bN, b_cI, hf + (bh - 1) * sh + 1, wf + (bw - 1) * sw + 1),
                (1, 1, sh, sw),
            )
            lhs = tap.transpose(0, 2, 3, 1).reshape(bN * bh * bw, b_cI)
            rhs = w[:, :, hf, wf].T  # (b_cI, b_cO)
            # exact int8 x int8 -> int32 tap product, widened into the f32
            # accumulator (never narrowed below f32 until the scaled store)
            out = jnp.dot(lhs, rhs, preferred_element_type=jnp.int32)
            acc = acc + out.astype(jnp.float32).reshape(
                bN, bh, bw, b_cO).transpose(0, 3, 1, 2)
    acc_ref[...] = acc

    @pl.when(ci == n_ci - 1)
    def _store():
        s = s_ref[0, pl.ds(co * b_cO, b_cO)]  # folded per-c_O scales
        o_ref[...] = (acc_ref[...] * s[None, :, None, None]).astype(
            o_ref.dtype)


def conv2d_q(
    x: jax.Array,  # (N, c_I, H, W) int8
    w: jax.Array,  # (c_O, c_I, h_F, w_F) int8
    scale: jax.Array,  # (1, c_O) f32: folded s_x * s_w[c_O]
    stride: Tuple[int, int] = (1, 1),
    out_dtype=jnp.bfloat16,
    ctx=None,  # ExecutionContext (duck-typed: .target/.interpret/.autotune)
    tiles: Optional[Sequence[int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Quantized direct convolution (VALID padding): int8 operand streams,
    f32 accumulation, one folded per-output-channel scale applied at the
    store. Operands come from ``repro.quant.quantize_conv_operands``.
    Execution policy rides ``ctx``; ``target=``/``tiles=`` are legacy
    (DeprecationWarning; lint VRF015)."""
    warn_legacy_kernel_kwargs("conv2d_q", target=target, tiles=tiles)
    N, c_I, H, W = x.shape
    c_O, c_I2, h_F, w_F = w.shape
    assert c_I == c_I2
    assert scale.shape == (1, c_O), f"scale must be (1, {c_O}), got {scale.shape}"
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    t, interpret = resolve_kernel_plan(
        _conv_spec_q(N, c_I, c_O, h_O, w_O, h_F, w_F, sh, sw, x.dtype,
                     w.dtype, out_dtype),
        plan=plan, target=target, tiles=tiles, interpret=interpret, ctx=ctx)
    t = _normalize_tiles(t, h_O, w_O)
    bN, b_cI, b_cO, bh, bw = t
    (Np, cIp, cOp, hOp, wOp, Hp, Wp, h_in, w_in,
     grid) = _launch_geometry(N, c_I, c_O, H, W, h_F, w_F, sh, sw, t)

    if (Np, cIp, Hp, Wp) != (N, c_I, H, W):
        x = jnp.pad(x, ((0, Np - N), (0, cIp - c_I), (0, Hp - H),
                        (0, Wp - W)))
    if (cOp, cIp) != (c_O, c_I):
        w = jnp.pad(w, ((0, cOp - c_O), (0, cIp - c_I), (0, 0), (0, 0)))
    if cOp != c_O:
        scale = jnp.pad(scale, ((0, 0), (0, cOp - c_O)))

    out = pl.pallas_call(
        functools.partial(_conv_q_kernel, n_ci=grid[4], tiles=t, h_in=h_in,
                          w_in=w_in, h_F=h_F, w_F=w_F, sh=sh, sw=sw),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            # constant index map: Pallas fetches the scale vector exactly
            # once per launch (c_O words — what words_fn charges)
            pl.BlockSpec((1, cOp), lambda n, co, h, wb, ci: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bN, b_cO, bh, bw),
                               lambda n, co, h, wb, ci: (n, co, h, wb)),
        out_shape=jax.ShapeDtypeStruct((Np, cOp, hOp, wOp), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((2, bN, b_cI, h_in, w_in), x.dtype),  # int8 stream
            pltpu.VMEM((2, b_cO, b_cI, h_F, w_F), w.dtype),  # int8 stream
            pltpu.VMEM((bN, b_cO, bh, bw), jnp.float32),  # f32 accumulator
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
    )(x, w, scale)
    return out[:N, :c_O, :h_O, :w_O]


def conv2d_q_access_plan(
    x,  # array or ShapeDtypeStruct, (N, c_I, H, W) int8
    w,  # array or ShapeDtypeStruct, (c_O, c_I, h_F, w_F) int8
    scale=None,  # array or ShapeDtypeStruct, (1, c_O) f32
    stride: Tuple[int, int] = (1, 1),
    tiles: Optional[Sequence[int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    out_dtype=jnp.bfloat16,
):
    """The :class:`repro.verify.access.KernelAccessPlan` of one ``conv2d_q``
    launch. Identical stream structure to ``conv2d_access_plan`` at int8
    word widths, plus the scale vector as a constant-index BlockAccess —
    the auditor's revisit elision counts its c_O words exactly once."""
    from repro.verify.access import (BlockAccess, KernelAccessPlan,
                                     ScratchAlloc, WindowAccess)
    from repro.verify.hazards import double_buffered_schedule

    N, c_I, H, W = x.shape
    c_O, _, h_F, w_F = w.shape
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    t, _ = resolve_kernel_plan(
        _conv_spec_q(N, c_I, c_O, h_O, w_O, h_F, w_F, sh, sw, x.dtype,
                     w.dtype, out_dtype),
        plan=plan, target=target, tiles=tiles)
    t = _normalize_tiles(t, h_O, w_O)
    bN, b_cI, b_cO, bh, bw = t
    (Np, cIp, cOp, hOp, wOp, Hp, Wp, h_in, w_in,
     grid) = _launch_geometry(N, c_I, c_O, H, W, h_F, w_F, sh, sw, t)
    p_in = _wordwidth(x.dtype)
    p_flt = _wordwidth(w.dtype)
    p_out = _wordwidth(out_dtype)

    def x_requires(n, co, h, wb, ci):
        row_lo, row_hi = h * bh, h * bh + bh - 1
        col_lo, col_hi = wb * bw, wb * bw + bw - 1
        return ((n * bN, (n + 1) * bN),
                (ci * b_cI, (ci + 1) * b_cI),
                (row_lo * sh, row_hi * sh + h_F),
                (col_lo * sw, col_hi * sw + w_F))

    accesses = (
        WindowAccess(
            name="input", kind="load", array_shape=(Np, cIp, Hp, Wp),
            word_size=p_in,
            window=lambda n, co, h, wb, ci: (
                (n * bN, bN), (ci * b_cI, b_cI),
                (h * bh * sh, h_in), (wb * bw * sw, w_in)),
            requires=x_requires),
        WindowAccess(
            name="filter", kind="load", array_shape=(cOp, cIp, h_F, w_F),
            word_size=p_flt,
            window=lambda n, co, h, wb, ci: (
                (co * b_cO, b_cO), (ci * b_cI, b_cI), (0, h_F), (0, w_F)),
            requires=lambda n, co, h, wb, ci: (
                (co * b_cO, (co + 1) * b_cO), (ci * b_cI, (ci + 1) * b_cI),
                (0, h_F), (0, w_F))),
        BlockAccess(
            name="scale", kind="load", block_shape=(1, cOp),
            array_shape=(1, cOp), word_size=1.0,
            index_map=lambda n, co, h, wb, ci: (0, 0),
            note="folded per-c_O dequant scales, fetched once per launch"),
        BlockAccess(
            name="output", kind="store", block_shape=(bN, b_cO, bh, bw),
            array_shape=(Np, cOp, hOp, wOp), word_size=p_out,
            index_map=lambda n, co, h, wb, ci: (n, co, h, wb)),
    )
    scratch = (
        ScratchAlloc("x_vmem[2]", 2 * bN * b_cI * h_in * w_in * p_in),
        ScratchAlloc("w_vmem[2]", 2 * b_cO * b_cI * h_F * w_F * p_flt),
        ScratchAlloc("acc_f32", float(bN * b_cO * bh * bw)),
    )
    return KernelAccessPlan(
        op="conv2d_q", grid=grid, accesses=accesses, scratch=scratch,
        dma=double_buffered_schedule(grid[4], n_slots=2,
                                     name="int8 input/filter c_I stream"),
        note="DMA schedule repeats identically per (n, co, h, w) tile")


def conv2d_q_hbm_words(
    x,  # array or ShapeDtypeStruct, (N, c_I, H, W) int8
    w,  # array or ShapeDtypeStruct, (c_O, c_I, h_F, w_F) int8
    scale=None,  # unused beyond its c_O words; keeps the spec_args signature
    stride: Tuple[int, int] = (1, 1),
    tiles: Optional[Sequence[int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    out_dtype=jnp.bfloat16,
) -> float:
    """Measured HBM words of one ``conv2d_q`` dispatch: int8 input/filter
    windows per grid step, the padded out_dtype stores, plus the scale
    vector exactly once (c_O f32 words)."""
    N, c_I, H, W = x.shape
    c_O, _, h_F, w_F = w.shape
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    t, _ = resolve_kernel_plan(
        _conv_spec_q(N, c_I, c_O, h_O, w_O, h_F, w_F, sh, sw, x.dtype,
                     w.dtype, out_dtype),
        plan=plan, target=target, tiles=tiles)
    t = _normalize_tiles(t, h_O, w_O)
    bN, b_cI, b_cO, bh, bw = t
    (Np, cIp, cOp, hOp, wOp, _, _, h_in, w_in,
     grid) = _launch_geometry(N, c_I, c_O, H, W, h_F, w_F, sh, sw, t)
    n_steps = math.prod(grid)
    p_in = _wordwidth(x.dtype)
    p_flt = _wordwidth(w.dtype)
    p_out = _wordwidth(out_dtype)
    return (n_steps * bN * b_cI * h_in * w_in * p_in
            + n_steps * b_cO * b_cI * h_F * w_F * p_flt
            + Np * cOp * hOp * wOp * p_out
            + cOp * 1.0)


# ---------------------------------------------------------------------------
# matmul_q
# ---------------------------------------------------------------------------

def _matmul_q_kernel(a_hbm, b_hbm, s_ref, o_ref, a_vmem, b_vmem, acc_ref,
                     sems, *, nk: int, bm: int, bn: int, bk: int):
    i, j, ki = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    def stream(slot, k_idx):
        return (
            pltpu.make_async_copy(
                a_hbm.at[pl.ds(i * bm, bm), pl.ds(k_idx * bk, bk)],
                a_vmem.at[slot], sems.at[slot, 0]),
            pltpu.make_async_copy(
                b_hbm.at[pl.ds(k_idx * bk, bk), pl.ds(j * bn, bn)],
                b_vmem.at[slot], sems.at[slot, 1]),
        )

    @pl.when(ki == 0)
    def _warmup():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        for cp in stream(0, 0):
            cp.start()

    slot = ki % 2

    @pl.when(ki + 1 < nk)
    def _prefetch():
        for cp in stream(1 - slot, ki + 1):
            cp.start()

    for cp in stream(slot, ki):
        cp.wait()

    acc_ref[...] += jnp.dot(
        a_vmem[slot], b_vmem[slot], preferred_element_type=jnp.int32
    ).astype(jnp.float32)

    @pl.when(ki == nk - 1)
    def _store():
        s = s_ref[0, pl.ds(j * bn, bn)]  # folded per-column scales
        o_ref[...] = (acc_ref[...] * s[None, :]).astype(o_ref.dtype)


def matmul_q(
    a: jax.Array,  # (m, k) int8
    b: jax.Array,  # (k, n) int8
    scale: jax.Array,  # (1, n) f32: folded s_a * s_b[n]
    out_dtype=jnp.bfloat16,
    ctx=None,  # ExecutionContext (duck-typed: .target/.interpret/.autotune)
    tiles: Optional[Tuple[int, int, int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Quantized GEMM: int8 A/B streams double-buffered over k, f32
    accumulator, folded per-column scale applied at the store. Operands come
    from ``repro.quant.quantize_matmul_operands``. Execution policy rides
    ``ctx``; ``target=``/``tiles=`` are legacy (DeprecationWarning; lint
    VRF015)."""
    warn_legacy_kernel_kwargs("matmul_q", target=target, tiles=tiles)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert scale.shape == (1, n), f"scale must be (1, {n}), got {scale.shape}"
    (bm, bn, bk), interpret = resolve_kernel_plan(
        _matmul_spec_q(m, n, k, a.dtype, b.dtype, out_dtype),
        plan=plan, target=target, tiles=tiles, interpret=interpret, ctx=ctx)

    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    if np_ != n:
        scale = jnp.pad(scale, ((0, 0), (0, np_ - n)))

    nm, nn, nk = mp // bm, np_ // bn, kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_q_kernel, nk=nk, bm=bm, bn=bn, bk=bk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, np_), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((2, bm, bk), a.dtype),  # int8 A stream
            pltpu.VMEM((2, bk, bn), b.dtype),  # int8 B stream
            pltpu.VMEM((bm, bn), jnp.float32),  # f32 accumulator
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
    )(a, b, scale)
    return out[:m, :n]


def matmul_q_access_plan(
    a,  # array or ShapeDtypeStruct, (m, k) int8
    b,  # array or ShapeDtypeStruct, (k, n) int8
    scale=None,
    tiles: Optional[Tuple[int, int, int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    out_dtype=jnp.bfloat16,
):
    """The :class:`repro.verify.access.KernelAccessPlan` of one ``matmul_q``
    launch: ``matmul_access_plan``'s structure at int8 word widths plus the
    constant-index scale BlockAccess (counted once)."""
    from repro.verify.access import (BlockAccess, KernelAccessPlan,
                                     ScratchAlloc, WindowAccess)
    from repro.verify.hazards import double_buffered_schedule

    m, k = a.shape
    n = b.shape[1]
    (bm, bn, bk), _ = resolve_kernel_plan(
        _matmul_spec_q(m, n, k, a.dtype, b.dtype, out_dtype),
        plan=plan, target=target, tiles=tiles)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    grid = (mp // bm, np_ // bn, kp // bk)
    p_a = _wordwidth(a.dtype)
    p_b = _wordwidth(b.dtype)
    p_out = _wordwidth(out_dtype)
    accesses = (
        WindowAccess(
            name="a", kind="load", array_shape=(mp, kp), word_size=p_a,
            window=lambda i, j, ki: ((i * bm, bm), (ki * bk, bk)),
            requires=lambda i, j, ki: ((i * bm, (i + 1) * bm),
                                       (ki * bk, (ki + 1) * bk))),
        WindowAccess(
            name="b", kind="load", array_shape=(kp, np_), word_size=p_b,
            window=lambda i, j, ki: ((ki * bk, bk), (j * bn, bn)),
            requires=lambda i, j, ki: ((ki * bk, (ki + 1) * bk),
                                       (j * bn, (j + 1) * bn))),
        BlockAccess(
            name="scale", kind="load", block_shape=(1, np_),
            array_shape=(1, np_), word_size=1.0,
            index_map=lambda i, j, ki: (0, 0),
            note="folded per-column dequant scales, fetched once per launch"),
        BlockAccess(
            name="out", kind="store", block_shape=(bm, bn),
            array_shape=(mp, np_), word_size=p_out,
            index_map=lambda i, j, ki: (i, j)),
    )
    scratch = (
        ScratchAlloc("a_vmem[2]", 2 * bm * bk * p_a),
        ScratchAlloc("b_vmem[2]", 2 * bk * bn * p_b),
        ScratchAlloc("acc_f32", float(bm * bn)),
    )
    return KernelAccessPlan(
        op="matmul_q", grid=grid, accesses=accesses, scratch=scratch,
        dma=double_buffered_schedule(grid[2], n_slots=2,
                                     name="int8 a/b k-stream"),
        note="DMA schedule repeats identically per (i, j) output tile")


def matmul_q_hbm_words(
    a,  # array or ShapeDtypeStruct, (m, k) int8
    b,  # array or ShapeDtypeStruct, (k, n) int8
    scale=None,
    tiles: Optional[Tuple[int, int, int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    out_dtype=jnp.bfloat16,
) -> float:
    """Measured HBM words of one ``matmul_q`` dispatch (int8 streams +
    out_dtype stores + the scale vector once)."""
    m, k = a.shape
    n = b.shape[1]
    (bm, bn, bk), _ = resolve_kernel_plan(
        _matmul_spec_q(m, n, k, a.dtype, b.dtype, out_dtype),
        plan=plan, target=target, tiles=tiles)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    n_steps = (mp // bm) * (np_ // bn) * (kp // bk)
    p_a = _wordwidth(a.dtype)
    p_b = _wordwidth(b.dtype)
    p_out = _wordwidth(out_dtype)
    return (n_steps * (bm * bk * p_a + bk * bn * p_b)
            + mp * np_ * p_out + np_ * 1.0)
