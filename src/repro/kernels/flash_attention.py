"""Blocked (flash-style) attention Pallas kernel with LP-informed tile sizes.

Attention's two GEMMs (QK^T and PV) are 7NL degenerates; the paper's capacity
argument picks the (block_q, block_k) pair: three f32 VMEM residents
(q tile, o tile, running stats) plus streamed k/v tiles must fit M/2.
block_q = block_k = 512 keeps the working set
  (2*bq*dh*4 + 2*bk*dh*2 + bq*bk*4) < 2 MiB  for dh <= 256,
far under VMEM while saturating the MXU (both >= 128).

Causal masking is done per-tile with absolute positions; GQA is handled by
the wrapper (kv heads are gathered, never materialized repeated in HBM).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_model import round_up
from repro.core.tiling import attention_block_size
from repro.plan import HardwareTarget

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def attention_blocks(dh: int, target: HardwareTarget,
                     kv_word: Optional[float] = None) -> tuple[int, int]:
    """(block_q, block_k) from the target's capacity argument (module
    docstring): f32 q/acc/stats residents + streamed k/v tiles must fit the
    double-buffered budget. Delegates to ``core.tiling.attention_block_size``
    — the same closed form the planner's attention plans use — so kernel
    launch geometry and planned tiles can never drift apart. ``kv_word`` is
    the stream width of the actual k/v arrays (words of 32 bits); defaults to
    the target's policy."""
    m_eff = target.memory_model().M_eff
    p_kv = target.precision.p_I if kv_word is None else kv_word
    b = attention_block_size(dh, m_eff, p_kv=p_kv)
    return b, b


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k: int, q_offset: int, kv_len: int,
                  q_seq_len: Optional[int]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)  # (bk, dh)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if kv_len % block_k != 0:  # padded keys: mask them out unconditionally
        s = jnp.where(kpos < kv_len, s, NEG_INF)
    if causal:
        qidx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        if q_seq_len is not None:
            # GQA group folding: row j of the flattened query axis is query
            # j % q_seq_len of its group, so positions wrap per group.
            qidx = qidx % q_seq_len
        s = jnp.where(kpos <= qidx + q_offset, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _store():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _flash_kernel_dyn(offs_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                      m_ref, l_ref, acc_ref, *,
                      scale: float, causal: bool, block_q: int, block_k: int,
                      n_k: int, q_seq_len: Optional[int]):
    """The dynamic twin of ``_flash_kernel``: per-row q_offset and kv_len
    arrive as scalar-prefetch refs (one int32 per BH row) instead of static
    ints, so one trace serves every (offset, length) combination — the decode
    hot path retraces on shape only, never on position."""
    b = pl.program_id(0)
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)  # (bk, dh)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # the length mask is unconditional: it covers both block padding and
    # per-row cache lengths shorter than the padded key axis
    s = jnp.where(kpos < lens_ref[b], s, NEG_INF)
    if causal:
        qidx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        if q_seq_len is not None:
            qidx = qidx % q_seq_len  # GQA fold: positions wrap per group
        s = jnp.where(kpos <= qidx + offs_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _store():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (BH, Lq, Dh)  - batch*heads flattened by the wrapper
    k: jax.Array,  # (BH, Lk, Dh)
    v: jax.Array,  # (BH, Lk, Dh)
    causal: bool = True,
    q_offset=0,  # int, or int32 array: scalar or per-row (BH,)
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
    q_seq_len: Optional[int] = None,
    kv_lens: Optional[jax.Array] = None,  # int32 (BH,): valid keys per row
) -> jax.Array:
    """``q_seq_len``: set when the query axis folds GQA groups — q rows are g
    groups of ``q_seq_len`` queries stacked, each group restarting at absolute
    position ``q_offset`` (the repeat-free GQA path; K/V stay un-repeated at
    (B*Hkv, Lk, Dh)). None = plain contiguous positions.

    A traced/array ``q_offset`` or a ``kv_lens`` array selects the dynamic
    kernel: offsets and cache lengths ride as scalar-prefetch operands, so the
    serving engine's lockstep decode (every row at a different position)
    compiles once per shape instead of once per step."""
    BH, Lq, Dh = q.shape
    Lk = k.shape[1]
    if block_q is None or block_k is None:
        if target is not None:
            kv_word = jnp.dtype(k.dtype).itemsize / 4.0
            tq, tk = attention_blocks(Dh, target, kv_word=kv_word)
        else:
            tq, tk = DEFAULT_BLOCK_Q, DEFAULT_BLOCK_K
        block_q = block_q if block_q is not None else tq
        block_k = block_k if block_k is not None else tk
    if interpret is None:
        interpret = target.interpret if target is not None else True
    scale = 1.0 / (Dh ** 0.5)
    bq = min(block_q, round_up(Lq, 8))
    bk = min(block_k, round_up(Lk, 8))
    Lqp, Lkp = round_up(Lq, bq), round_up(Lk, bk)
    if Lqp != Lq:
        q = jnp.pad(q, ((0, 0), (0, Lqp - Lq), (0, 0)))
    if Lkp != Lk:
        # padded keys are masked out via kpos > qpos + Lk guard below
        k = jnp.pad(k, ((0, 0), (0, Lkp - Lk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Lkp - Lk), (0, 0)))
    n_q, n_k = Lqp // bq, Lkp // bk

    if q_seq_len is not None and q_seq_len >= Lq:
        q_seq_len = None  # a single group degenerates to plain positions

    scratch = [
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, Dh), jnp.float32),
    ]
    dynamic = kv_lens is not None or not isinstance(q_offset, int)
    if dynamic:
        offs = jnp.broadcast_to(
            jnp.asarray(q_offset, jnp.int32).reshape(-1), (BH,))
        lens = (jnp.full((BH,), Lk, jnp.int32) if kv_lens is None
                else jnp.broadcast_to(
                    jnp.asarray(kv_lens, jnp.int32).reshape(-1), (BH,)))
        kernel = functools.partial(
            _flash_kernel_dyn, scale=scale, causal=causal,
            block_q=bq, block_k=bk, n_k=n_k, q_seq_len=q_seq_len,
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2,
                grid=(BH, n_q, n_k),
                in_specs=[
                    pl.BlockSpec((1, bq, Dh), lambda b, i, j, o, s: (b, i, 0)),
                    pl.BlockSpec((1, bk, Dh), lambda b, i, j, o, s: (b, j, 0)),
                    pl.BlockSpec((1, bk, Dh), lambda b, i, j, o, s: (b, j, 0)),
                ],
                out_specs=pl.BlockSpec(
                    (1, bq, Dh), lambda b, i, j, o, s: (b, i, 0)),
                scratch_shapes=scratch,
            ),
            out_shape=jax.ShapeDtypeStruct((BH, Lqp, Dh), q.dtype),
            interpret=interpret,
        )(offs, lens, q, k, v)
        return out[:, :Lq]

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=bq, block_k=bk, n_k=n_k, q_offset=q_offset, kv_len=Lk,
        q_seq_len=q_seq_len,
    )
    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Lqp, Dh), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
    return out[:, :Lq]


# ---------------------------------------------------------------------------
# Paged decode: block-table-gathering attention over the serving KV pool.
# ---------------------------------------------------------------------------

def _paged_decode_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *,
                         scale: float, block_size: int, n_blk: int):
    """One (batch row, kv head) pair streams its block-table chain: grid step
    j fetches physical block ``tables[b, j]`` straight from the pool via the
    index_map (no gather materialized in HBM), masks positions past the row's
    cache length, and folds into the online softmax. Dead/padded table slots
    point at reserved block 0, whose garbage keys are masked by the length."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (g, hd): this kv head's q group
    k = k_ref[0, 0].astype(jnp.float32)  # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)  # (bs, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (g, bs)
    kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < lens_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_blk - 1)
    def _store():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # rows with length 0 -> zeros
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,        # (B, H, 1, hd) - one new query per sequence
    kp: jax.Array,       # (num_blocks, KV, block_size, hd) - the key pool
    vp: jax.Array,       # (num_blocks, KV, block_size, hd) - the value pool
    tables: jax.Array,   # (B, w) int32 - physical block ids per sequence
    lengths: jax.Array,  # (B,) int32 - valid cache length per sequence
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Decode attention that reads K/V directly out of the paged pool.

    The communication-optimal property the paper's decode bound asks for:
    each sequence moves exactly its own ``w * block_size`` cached keys/values
    once — no repeat-materialized GQA heads, no gather copy of the table into
    a contiguous buffer first. Query heads are grouped per kv head
    (h = kv * g + i, matching the registry's GQA fold), so the q block a grid
    row loads is the (g, hd) group that shares its kv head."""
    B, H, Lq, hd = q.shape
    if Lq != 1:
        raise ValueError(f"paged decode takes one query per row, got Lq={Lq}")
    KV, block_size = kp.shape[1], kp.shape[2]
    w = tables.shape[1]
    g = H // KV
    if interpret is None:
        interpret = target.interpret if target is not None else True
    scale = 1.0 / (hd ** 0.5)
    qf = q.reshape(B, KV, g, hd)
    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, block_size=block_size, n_blk=w)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, w),
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda b, h, j, t, l: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_size, hd),
                             lambda b, h, j, t, l: (t[b, j], h, 0, 0)),
                pl.BlockSpec((1, 1, block_size, hd),
                             lambda b, h, j, t, l: (t[b, j], h, 0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, g, hd), lambda b, h, j, t, l: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), qf, kp, vp)
    return out.reshape(B, H, 1, hd)


# ---------------------------------------------------------------------------
# Measured HBM traffic, in 32-bit words, from launch geometry (shape-only).
# ---------------------------------------------------------------------------

def attention_hbm_words(BH: int, Lq: int, Lk: int, dh: int,
                        block_q: int, block_k: int,
                        p_q: float = 1.0, p_kv: float = 1.0,
                        p_o: float = 1.0) -> float:
    """Words the flash launch moves: q tiles once, k/v streamed once per q
    tile, o stored once — the same accounting ``plan(AttentionSpec)`` models,
    evaluated at the kernel's actual clamped/padded blocks.

    When the whole key stream is a single block (n_k == 1) the k/v index map
    (b, j, 0) is constant across the q-tile axis, so Pallas fetches k/v once
    per batch row, not once per q tile — the static auditor
    (``repro.verify``) counts index-map *transitions* and caught the
    per-q-tile formula overcounting exactly this corner."""
    bq = min(block_q, round_up(Lq, 8))
    bk = min(block_k, round_up(Lk, 8))
    lqp, lkp = round_up(Lq, bq), round_up(Lk, bk)
    n_q, n_k = lqp // bq, lkp // bk
    kv_fetches = n_q if n_k > 1 else 1
    return (p_q * BH * lqp * dh
            + 2.0 * p_kv * BH * kv_fetches * lkp * dh
            + p_o * BH * lqp * dh)


def flash_attention_access_plan(BH: int, Lq: int, Lk: int, dh: int,
                                block_q: int, block_k: int,
                                p_q: float = 1.0, p_kv: float = 1.0,
                                p_o: float = 1.0, dynamic: bool = False,
                                op: str = "attention"):
    """The :class:`repro.verify.access.KernelAccessPlan` of one flash launch
    over the folded (BH, Lq) view (``Lq`` = g * per-head queries after the
    registry's GQA fold). ``dynamic=True`` adds the scalar-prefetched
    q_offset/kv_lens operands of ``_flash_kernel_dyn`` — recorded as
    *uncounted* traffic, mirroring ``attention_hbm_words`` which charges
    only the tensor streams (2 x BH int32 words, O(BH) against O(BH*L*dh))."""
    from repro.verify.access import (BlockAccess, FlatAccess,
                                     KernelAccessPlan, ScratchAlloc)

    bq = min(block_q, round_up(Lq, 8))
    bk = min(block_k, round_up(Lk, 8))
    lqp, lkp = round_up(Lq, bq), round_up(Lk, bk)
    n_q, n_k = lqp // bq, lkp // bk
    accesses = [
        BlockAccess(name="q", kind="load", block_shape=(1, bq, dh),
                    array_shape=(BH, lqp, dh), word_size=p_q,
                    index_map=lambda b, i, j: (b, i, 0)),
        BlockAccess(name="k", kind="load", block_shape=(1, bk, dh),
                    array_shape=(BH, lkp, dh), word_size=p_kv,
                    index_map=lambda b, i, j: (b, j, 0)),
        BlockAccess(name="v", kind="load", block_shape=(1, bk, dh),
                    array_shape=(BH, lkp, dh), word_size=p_kv,
                    index_map=lambda b, i, j: (b, j, 0)),
        BlockAccess(name="out", kind="store", block_shape=(1, bq, dh),
                    array_shape=(BH, lqp, dh), word_size=p_o,
                    index_map=lambda b, i, j: (b, i, 0)),
    ]
    if dynamic:
        accesses += [
            FlatAccess(name="q_offset", kind="load", words=float(BH),
                       counted=False, note="scalar prefetch, uncharged"),
            FlatAccess(name="kv_lens", kind="load", words=float(BH),
                       counted=False, note="scalar prefetch, uncharged"),
        ]
    scratch = (
        ScratchAlloc("m/l/acc_f32", float(bq + bq + bq * dh)),
        ScratchAlloc("q_pipeline[2]", 2 * bq * dh * p_q),
        ScratchAlloc("kv_pipeline[2x2]", 4 * bk * dh * p_kv),
        ScratchAlloc("out_pipeline[2]", 2 * bq * dh * p_o),
    )
    return KernelAccessPlan(op=op, grid=(BH, n_q, n_k),
                            accesses=tuple(accesses), scratch=scratch)


def paged_decode_access_plan(B: int, KV: int, g: int, w: int,
                             block_size: int, hd: int, num_blocks: int,
                             p_q: float = 1.0, p_kv: float = 1.0,
                             p_o: float = 1.0, tables=None,
                             op: str = "attention_decode"):
    """The :class:`repro.verify.access.KernelAccessPlan` of one paged decode
    launch. ``tables`` defaults to a synthetic table with all-distinct
    consecutive physical blocks — the allocator's normal output — which is
    the traffic-maximal case ``paged_decode_hbm_words`` charges (a table
    that happens to repeat a block in consecutive slots would move less:
    the index map (t[b, j], h) elides the re-fetch)."""
    import numpy as np

    from repro.verify.access import (BlockAccess, FlatAccess,
                                     KernelAccessPlan, ScratchAlloc)

    if tables is None:
        tables = (np.arange(B * w, dtype=np.int64).reshape(B, w)
                  % max(num_blocks, 1))
        if num_blocks < 2 and w > 1:
            raise ValueError("paged pool with < 2 blocks cannot have "
                             "all-distinct consecutive table entries")
    t = np.asarray(tables, dtype=np.int64)
    accesses = (
        BlockAccess(name="q", kind="load", block_shape=(1, 1, g, hd),
                    array_shape=(B, KV, g, hd), word_size=p_q,
                    index_map=lambda b, h, j: (b, h, 0, 0)),
        BlockAccess(name="k_pool", kind="load",
                    block_shape=(1, 1, block_size, hd),
                    array_shape=(num_blocks, KV, block_size, hd),
                    word_size=p_kv,
                    index_map=lambda b, h, j: (t[b, j], h, 0, 0)),
        BlockAccess(name="v_pool", kind="load",
                    block_shape=(1, 1, block_size, hd),
                    array_shape=(num_blocks, KV, block_size, hd),
                    word_size=p_kv,
                    index_map=lambda b, h, j: (t[b, j], h, 0, 0)),
        BlockAccess(name="out", kind="store", block_shape=(1, 1, g, hd),
                    array_shape=(B, KV, g, hd), word_size=p_o,
                    index_map=lambda b, h, j: (b, h, 0, 0)),
        FlatAccess(name="tables", kind="load", words=float(B * w),
                   note="int32 scalar prefetch, charged by words_fn"),
        FlatAccess(name="lengths", kind="load", words=float(B),
                   note="int32 scalar prefetch, charged by words_fn"),
    )
    scratch = (
        ScratchAlloc("m/l/acc_f32", float(g + g + g * hd)),
        ScratchAlloc("q_pipeline[2]", 2 * g * hd * p_q),
        ScratchAlloc("kv_pipeline[2x2]", 4 * block_size * hd * p_kv),
        ScratchAlloc("out_pipeline[2]", 2 * g * hd * p_o),
    )
    return KernelAccessPlan(op=op, grid=(B, KV, w), accesses=accesses,
                            scratch=scratch)


def paged_decode_hbm_words(B: int, KV: int, g: int, w: int, block_size: int,
                           hd: int, p_q: float = 1.0, p_kv: float = 1.0,
                           p_o: float = 1.0) -> float:
    """Words one paged decode step moves: each (row, kv head) loads its
    (g, hd) query group, streams w blocks of k and v once, stores the group —
    plus the int32 block tables and lengths (1 word each)."""
    return (p_q * B * KV * g * hd
            + 2.0 * p_kv * B * KV * w * block_size * hd
            + p_o * B * KV * g * hd
            + B * w + B)
