"""Blocked (flash-style) attention Pallas kernel with LP-informed tile sizes.

Attention's two GEMMs (QK^T and PV) are 7NL degenerates; the paper's capacity
argument picks the (block_q, block_k) pair: three f32 VMEM residents
(q tile, o tile, running stats) plus streamed k/v tiles must fit M/2.
block_q = block_k = 512 keeps the working set
  (2*bq*dh*4 + 2*bk*dh*2 + bq*bk*4) < 2 MiB  for dh <= 256,
far under VMEM while saturating the MXU (both >= 128).

Causal masking is done per-tile with absolute positions; GQA is handled by
the wrapper (kv heads are gathered, never materialized repeated in HBM).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_model import round_up
from repro.plan import HardwareTarget

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def attention_blocks(dh: int, target: HardwareTarget,
                     kv_word: Optional[float] = None) -> tuple[int, int]:
    """(block_q, block_k) from the target's capacity argument (module
    docstring): f32 q/acc/stats residents + streamed k/v tiles must fit the
    double-buffered budget. Largest MXU-saturating power of two <= 512 that
    fits; the LP degenerates to this closed form because both attention GEMMs
    share the b_q x b_k footprint term. ``kv_word`` is the stream width of the
    actual k/v arrays (words of 32 bits); defaults to the target's policy."""
    m_eff = target.memory_model().M_eff
    p_kv = target.precision.p_I if kv_word is None else kv_word
    for b in (512, 256, 128, 64, 32, 16, 8):
        words = 2.0 * b * dh + 2.0 * b * dh * p_kv + b * b + 2.0 * b
        if words <= m_eff:
            return b, b
    raise ValueError(
        f"no attention block fits {target.name}: dh={dh} needs more than "
        f"M_eff={m_eff:.0f} words even at block 8")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k: int, q_offset: int, kv_len: int,
                  q_seq_len: Optional[int]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)  # (bk, dh)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if kv_len % block_k != 0:  # padded keys: mask them out unconditionally
        s = jnp.where(kpos < kv_len, s, NEG_INF)
    if causal:
        qidx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        if q_seq_len is not None:
            # GQA group folding: row j of the flattened query axis is query
            # j % q_seq_len of its group, so positions wrap per group.
            qidx = qidx % q_seq_len
        s = jnp.where(kpos <= qidx + q_offset, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _store():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (BH, Lq, Dh)  - batch*heads flattened by the wrapper
    k: jax.Array,  # (BH, Lk, Dh)
    v: jax.Array,  # (BH, Lk, Dh)
    causal: bool = True,
    q_offset: int = 0,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
    q_seq_len: Optional[int] = None,
) -> jax.Array:
    """``q_seq_len``: set when the query axis folds GQA groups — q rows are g
    groups of ``q_seq_len`` queries stacked, each group restarting at absolute
    position ``q_offset`` (the repeat-free GQA path; K/V stay un-repeated at
    (B*Hkv, Lk, Dh)). None = plain contiguous positions."""
    BH, Lq, Dh = q.shape
    Lk = k.shape[1]
    if block_q is None or block_k is None:
        if target is not None:
            kv_word = jnp.dtype(k.dtype).itemsize / 4.0
            tq, tk = attention_blocks(Dh, target, kv_word=kv_word)
        else:
            tq, tk = DEFAULT_BLOCK_Q, DEFAULT_BLOCK_K
        block_q = block_q if block_q is not None else tq
        block_k = block_k if block_k is not None else tk
    if interpret is None:
        interpret = target.interpret if target is not None else True
    scale = 1.0 / (Dh ** 0.5)
    bq = min(block_q, round_up(Lq, 8))
    bk = min(block_k, round_up(Lk, 8))
    Lqp, Lkp = round_up(Lq, bq), round_up(Lk, bk)
    if Lqp != Lq:
        q = jnp.pad(q, ((0, 0), (0, Lqp - Lq), (0, 0)))
    if Lkp != Lk:
        # padded keys are masked out via kpos > qpos + Lk guard below
        k = jnp.pad(k, ((0, 0), (0, Lkp - Lk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Lkp - Lk), (0, 0)))
    n_q, n_k = Lqp // bq, Lkp // bk

    if q_seq_len is not None and q_seq_len >= Lq:
        q_seq_len = None  # a single group degenerates to plain positions
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=bq, block_k=bk, n_k=n_k, q_offset=q_offset, kv_len=Lk,
        q_seq_len=q_seq_len,
    )
    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Lqp, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Lq]
