"""Communication-optimal direct convolution as a Pallas TPU kernel.

This is the paper's §5 tiling, retargeted from GEMMINI to the TPU memory
hierarchy: the blocking LP (core.tiling.optimize_blocking, eq. 6 + the §5
buffer model) picks the channel/batch/spatial tile sizes; the f32 output tile
plays the accumulator (held in VMEM across the c_I reduction, which is the
innermost grid axis); input/filter tiles stream HBM->VMEM in low precision.

Layout: NCHW input, OIHW filter, VALID padding, arbitrary stride — the exact
7NL CNN of §2.1. Inside a tile the (h_F, w_F) loops are fully unrolled and
each tap is one MXU GEMM of shape (bN*b_hO*b_wO, b_cI) x (b_cI, b_cO): the
small-filter lift's q/r axes land in the unroll, channel axes land in the MXU.

Spatial tiling is halo-aware: an output row block [i*bh, (i+1)*bh) needs the
overlapping input window starting at row i*bh*sh of (bh - 1)*sh + h_F rows
(consecutive windows share an h_F - sh row halo), and similarly for columns.
Overlapping windows cannot be expressed with blocked BlockSpecs, so the input
and filter stay in ANY/HBM memory and the kernel streams each window itself
with ``pltpu.make_async_copy`` into a two-slot VMEM scratch, double-buffered
across the c_I reduction grid axis: while the MXU runs the taps of reduction
step ci, the DMAs for step ci + 1 are already in flight (§5's
double-buffering, which is also why the LP halves usable capacity).

``conv2d_hbm_words`` reports the measured HBM words one dispatch moves,
computed from the same launch geometry the kernel lowers (grid x DMA window
sizes + output stores) — the number ``ops.explain`` places next to the
paper's Thm 2.1 lower bound.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_model import Precision, round_up
from repro.plan import (ConvSpec, ExecutionPlan, HardwareTarget,
                        resolve_kernel_plan, warn_legacy_kernel_kwargs)


def _conv_spec(N: int, c_I: int, c_O: int, h_O: int, w_O: int, h_F: int,
               w_F: int, sh: int, sw: int, in_bits: int) -> ConvSpec:
    p_in = in_bits / 32.0
    return ConvSpec(N=N, c_I=c_I, c_O=c_O, w_O=w_O, h_O=h_O, w_F=w_F, h_F=h_F,
                    sw=sw, sh=sh, prec=Precision(p_in, p_in, 1.0))


def _normalize_tiles(tiles: Sequence[int], h_O: int, w_O: int
                     ) -> Tuple[int, int, int, int, int]:
    """Accept the legacy (bN, b_cI, b_cO) triple (spatial kept whole) or the
    full (bN, b_cI, b_cO, b_hO, b_wO) planner tuple."""
    if len(tiles) == 3:
        return (*tiles, h_O, w_O)
    bN, b_cI, b_cO, bh, bw = tiles
    return (bN, b_cI, b_cO, max(1, min(bh, h_O)), max(1, min(bw, w_O)))


def _launch_geometry(N: int, c_I: int, c_O: int, H: int, W: int, h_F: int,
                     w_F: int, sh: int, sw: int,
                     tiles: Tuple[int, int, int, int, int]):
    """Padded dims, halo-window extents, and grid — the single source of
    truth shared by the kernel lowering and the HBM-word counter."""
    bN, b_cI, b_cO, bh, bw = tiles
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    Np, cIp, cOp = round_up(N, bN), round_up(c_I, b_cI), round_up(c_O, b_cO)
    hOp, wOp = round_up(h_O, bh), round_up(w_O, bw)
    # padded input must cover the last block's halo window
    Hp = max(H, (hOp - 1) * sh + h_F)
    Wp = max(W, (wOp - 1) * sw + w_F)
    h_in = (bh - 1) * sh + h_F
    w_in = (bw - 1) * sw + w_F
    grid = (Np // bN, cOp // b_cO, hOp // bh, wOp // bw, cIp // b_cI)
    return Np, cIp, cOp, hOp, wOp, Hp, Wp, h_in, w_in, grid


def _conv_kernel(x_hbm, w_hbm, o_ref, x_vmem, w_vmem, acc_ref, sems, *,
                 n_ci: int, tiles: Tuple[int, int, int, int, int],
                 h_in: int, w_in: int, h_F: int, w_F: int, sh: int, sw: int):
    bN, b_cI, b_cO, bh, bw = tiles
    n, co, h, wb, ci = (pl.program_id(i) for i in range(5))

    def stream(slot, ci_idx):
        """The two HBM->VMEM copies feeding reduction step ci_idx: the halo
        input window of this (n, h, wb) tile and the (co, ci) filter block."""
        return (
            pltpu.make_async_copy(
                x_hbm.at[pl.ds(n * bN, bN), pl.ds(ci_idx * b_cI, b_cI),
                         pl.ds(h * bh * sh, h_in), pl.ds(wb * bw * sw, w_in)],
                x_vmem.at[slot], sems.at[slot, 0]),
            pltpu.make_async_copy(
                w_hbm.at[pl.ds(co * b_cO, b_cO), pl.ds(ci_idx * b_cI, b_cI)],
                w_vmem.at[slot], sems.at[slot, 1]),
        )

    @pl.when(ci == 0)
    def _warmup():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        for cp in stream(0, 0):
            cp.start()

    slot = ci % 2

    @pl.when(ci + 1 < n_ci)
    def _prefetch():  # overlap the next reduction step's DMA with the GEMMs
        for cp in stream(1 - slot, ci + 1):
            cp.start()

    for cp in stream(slot, ci):
        cp.wait()

    x = x_vmem[slot]  # (bN, b_cI, h_in, w_in)
    w = w_vmem[slot]  # (b_cO, b_cI, h_F, w_F)
    acc = acc_ref[...]
    for hf in range(h_F):
        for wf in range(w_F):
            # strided tap window: (bN, b_cI, bh, bw)
            tap = jax.lax.slice(
                x,
                (0, 0, hf, wf),
                (bN, b_cI, hf + (bh - 1) * sh + 1, wf + (bw - 1) * sw + 1),
                (1, 1, sh, sw),
            )
            # MXU GEMM: (bN*bh*bw, b_cI) @ (b_cI, b_cO)
            lhs = tap.transpose(0, 2, 3, 1).reshape(bN * bh * bw, b_cI)
            rhs = w[:, :, hf, wf].T  # (b_cI, b_cO)
            out = jnp.dot(lhs, rhs, preferred_element_type=jnp.float32)
            acc = acc + out.reshape(bN, bh, bw, b_cO).transpose(0, 3, 1, 2)
    acc_ref[...] = acc

    @pl.when(ci == n_ci - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def conv2d(
    x: jax.Array,  # (N, c_I, H, W)
    w: jax.Array,  # (c_O, c_I, h_F, w_F)
    stride: Tuple[int, int] = (1, 1),
    out_dtype=jnp.float32,
    ctx=None,  # ExecutionContext (duck-typed: .target/.interpret/.autotune)
    tiles: Optional[Sequence[int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Direct convolution with paper-LP tiling. VALID padding.

    Execution policy rides ``ctx`` (an ``repro.ops.ExecutionContext``:
    target, interpret override, autotune policy). Tiles come from (in
    priority order) an explicit legacy ``tiles`` tuple — (bN, b_cI, b_cO) or
    (bN, b_cI, b_cO, b_hO, b_wO) — an explicit ``plan``
    (:class:`repro.plan.ExecutionPlan`, the dispatcher/autotuner handoff),
    or a fresh plan resolved for the context's target (default TPU_V5E;
    tuned winner when one is stored). ``target=``/``tiles=`` are legacy
    (DeprecationWarning; lint VRF015); ``interpret`` defaults to the
    target's policy (True everywhere until a real TPU backend is attached).
    """
    warn_legacy_kernel_kwargs("conv2d", target=target, tiles=tiles)
    N, c_I, H, W = x.shape
    c_O, c_I2, h_F, w_F = w.shape
    assert c_I == c_I2
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    in_bits = jnp.dtype(x.dtype).itemsize * 8
    t, interpret = resolve_kernel_plan(
        _conv_spec(N, c_I, c_O, h_O, w_O, h_F, w_F, sh, sw, in_bits),
        plan=plan, target=target, tiles=tiles, interpret=interpret, ctx=ctx)
    t = _normalize_tiles(t, h_O, w_O)
    bN, b_cI, b_cO, bh, bw = t
    (Np, cIp, cOp, hOp, wOp, Hp, Wp, h_in, w_in,
     grid) = _launch_geometry(N, c_I, c_O, H, W, h_F, w_F, sh, sw, t)

    if (Np, cIp, Hp, Wp) != (N, c_I, H, W):
        x = jnp.pad(x, ((0, Np - N), (0, cIp - c_I), (0, Hp - H),
                        (0, Wp - W)))
    if (cOp, cIp) != (c_O, c_I):
        w = jnp.pad(w, ((0, cOp - c_O), (0, cIp - c_I), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_conv_kernel, n_ci=grid[4], tiles=t, h_in=h_in,
                          w_in=w_in, h_F=h_F, w_F=w_F, sh=sh, sw=sw),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((bN, b_cO, bh, bw),
                               lambda n, co, h, wb, ci: (n, co, h, wb)),
        out_shape=jax.ShapeDtypeStruct((Np, cOp, hOp, wOp), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((2, bN, b_cI, h_in, w_in), x.dtype),  # double-buffered
            pltpu.VMEM((2, b_cO, b_cI, h_F, w_F), w.dtype),  # input + filter
            pltpu.VMEM((bN, b_cO, bh, bw), jnp.float32),  # f32 accumulator
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
    )(x, w)
    return out[:N, :c_O, :h_O, :w_O]


def exact_window(H: int, W: int, h_F: int, w_F: int, sh: int, sw: int
                 ) -> bool:
    """True iff an (H, W) input extent is an *exact* halo window — every row
    and column participates in some VALID output ((H - h_F) % sh == 0 and
    likewise for W). Shard-local windows built by ``repro.distributed`` are
    exact by construction; an inexact window there means halo rows were
    mis-exchanged, so the distributed path asserts this before dispatch."""
    return (H - h_F) % sh == 0 and (W - w_F) % sw == 0


def conv2d_shard(
    x: jax.Array,  # (bN, b_cI, (b_hO-1)*sh + h_F, (b_wO-1)*sw + w_F)
    w: jax.Array,  # (c_O, b_cI, h_F, w_F)
    stride: Tuple[int, int] = (1, 1),
    out_dtype=jnp.float32,
    ctx=None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Shard-local entry for ``repro.distributed``: the same LP-tiled kernel
    as :func:`conv2d`, but the input must be an exact halo window (the shape
    each shard assembles after its ``ppermute`` exchanges — no dead rows).
    Plans resolve for the *local* shape, so each shard tiles its own block."""
    warn_legacy_kernel_kwargs("conv2d_shard", target=target)
    if ctx is None and (target is not None or interpret is not None):
        # absorb the legacy kwargs here so the inner conv2d doesn't re-warn
        from types import SimpleNamespace
        ctx = SimpleNamespace(target=target, interpret=interpret,
                              autotune=None)
    N, c_I, H, W = x.shape
    _, _, h_F, w_F = w.shape
    sh, sw = stride
    if not exact_window(H, W, h_F, w_F, sh, sw):
        raise ValueError(
            f"shard-local conv window ({H}, {W}) is not exact for filter "
            f"({h_F}, {w_F}) stride ({sh}, {sw}): halo rows were "
            "mis-exchanged upstream")
    return conv2d(x, w, stride=stride, out_dtype=out_dtype, ctx=ctx,
                  plan=plan)


def conv2d_access_plan(
    x,  # array or ShapeDtypeStruct, (N, c_I, H, W)
    w,  # array or ShapeDtypeStruct, (c_O, c_I, h_F, w_F)
    stride: Tuple[int, int] = (1, 1),
    tiles: Optional[Sequence[int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    out_dtype=jnp.float32,
):
    """The :class:`repro.verify.access.KernelAccessPlan` of one ``conv2d``
    launch, restated from :func:`_launch_geometry`.

    The input's ``requires`` region is derived *independently* of the DMA
    window — from the output rows of the tile through the strided tap
    arithmetic (output row o, tap hf reads input row o*sh + hf) — so an
    off-by-one halo window fails the auditor's coverage check even though
    its word count is unchanged."""
    from repro.verify.access import (BlockAccess, KernelAccessPlan,
                                     ScratchAlloc, WindowAccess)
    from repro.verify.hazards import double_buffered_schedule

    N, c_I, H, W = x.shape
    c_O, _, h_F, w_F = w.shape
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    in_bits = jnp.dtype(x.dtype).itemsize * 8
    t, _ = resolve_kernel_plan(
        _conv_spec(N, c_I, c_O, h_O, w_O, h_F, w_F, sh, sw, in_bits),
        plan=plan, target=target, tiles=tiles)
    t = _normalize_tiles(t, h_O, w_O)
    bN, b_cI, b_cO, bh, bw = t
    (Np, cIp, cOp, hOp, wOp, Hp, Wp, h_in, w_in,
     grid) = _launch_geometry(N, c_I, c_O, H, W, h_F, w_F, sh, sw, t)
    p_in = jnp.dtype(x.dtype).itemsize / 4.0
    p_flt = jnp.dtype(w.dtype).itemsize / 4.0
    p_out = jnp.dtype(out_dtype).itemsize / 4.0

    def x_requires(n, co, h, wb, ci):
        # first/last output row of the tile -> strided tap extent
        row_lo, row_hi = h * bh, h * bh + bh - 1
        col_lo, col_hi = wb * bw, wb * bw + bw - 1
        return ((n * bN, (n + 1) * bN),
                (ci * b_cI, (ci + 1) * b_cI),
                (row_lo * sh, row_hi * sh + h_F),
                (col_lo * sw, col_hi * sw + w_F))

    accesses = (
        WindowAccess(
            name="input", kind="load", array_shape=(Np, cIp, Hp, Wp),
            word_size=p_in,
            window=lambda n, co, h, wb, ci: (
                (n * bN, bN), (ci * b_cI, b_cI),
                (h * bh * sh, h_in), (wb * bw * sw, w_in)),
            requires=x_requires),
        WindowAccess(
            name="filter", kind="load", array_shape=(cOp, cIp, h_F, w_F),
            word_size=p_flt,
            window=lambda n, co, h, wb, ci: (
                (co * b_cO, b_cO), (ci * b_cI, b_cI), (0, h_F), (0, w_F)),
            requires=lambda n, co, h, wb, ci: (
                (co * b_cO, (co + 1) * b_cO), (ci * b_cI, (ci + 1) * b_cI),
                (0, h_F), (0, w_F))),
        BlockAccess(
            name="output", kind="store", block_shape=(bN, b_cO, bh, bw),
            array_shape=(Np, cOp, hOp, wOp), word_size=p_out,
            index_map=lambda n, co, h, wb, ci: (n, co, h, wb)),
    )
    scratch = (
        ScratchAlloc("x_vmem[2]", 2 * bN * b_cI * h_in * w_in * p_in),
        ScratchAlloc("w_vmem[2]", 2 * b_cO * b_cI * h_F * w_F * p_flt),
        ScratchAlloc("acc_f32", float(bN * b_cO * bh * bw)),
    )
    return KernelAccessPlan(
        op="conv2d", grid=grid, accesses=accesses, scratch=scratch,
        dma=double_buffered_schedule(grid[4], n_slots=2,
                                     name="input/filter c_I stream"),
        note="DMA schedule repeats identically per (n, co, h, w) tile")


def conv2d_hbm_words(
    x,  # array or ShapeDtypeStruct, (N, c_I, H, W)
    w,  # array or ShapeDtypeStruct, (c_O, c_I, h_F, w_F)
    stride: Tuple[int, int] = (1, 1),
    tiles: Optional[Sequence[int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    out_dtype=jnp.float32,
) -> float:
    """Measured HBM words (32-bit) one ``conv2d`` dispatch moves.

    Counts exactly what the kernel lowers for these arguments: one input
    halo window + one filter block DMA'd per grid step, one output block
    stored per (n, co, h, w) tile — padding included. Only shapes/dtypes are
    consulted, so ``jax.ShapeDtypeStruct`` arguments work (no execution)."""
    N, c_I, H, W = x.shape
    c_O, _, h_F, w_F = w.shape
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    in_bits = jnp.dtype(x.dtype).itemsize * 8
    t, _ = resolve_kernel_plan(
        _conv_spec(N, c_I, c_O, h_O, w_O, h_F, w_F, sh, sw, in_bits),
        plan=plan, target=target, tiles=tiles)
    t = _normalize_tiles(t, h_O, w_O)
    bN, b_cI, b_cO, bh, bw = t
    (Np, cIp, cOp, hOp, wOp, _, _, h_in, w_in,
     grid) = _launch_geometry(N, c_I, c_O, H, W, h_F, w_F, sh, sw, t)
    n_steps = math.prod(grid)
    p_in = jnp.dtype(x.dtype).itemsize / 4.0
    p_flt = jnp.dtype(w.dtype).itemsize / 4.0
    p_out = jnp.dtype(out_dtype).itemsize / 4.0
    return (n_steps * bN * b_cI * h_in * w_in * p_in
            + n_steps * b_cO * b_cI * h_F * w_F * p_flt
            + Np * cOp * hOp * wOp * p_out)
