"""Communication-optimal direct convolution as a Pallas TPU kernel.

This is the paper's §5 tiling, retargeted from GEMMINI to the TPU memory
hierarchy: the blocking LP (core.tiling.optimize_blocking, eq. 6 + the §5
buffer model) picks the channel/batch tile sizes; the f32 output tile plays
the accumulator (held in VMEM across the c_I reduction, which is the innermost
grid axis); input/filter tiles stream HBM->VMEM in low precision.

Layout: NCHW input, OIHW filter, VALID padding, arbitrary stride — the exact
7NL CNN of §2.1. Inside a tile the (h_F, w_F) loops are fully unrolled and
each tap is one MXU GEMM of shape (bN*h_O*w_O, b_cI) x (b_cI, b_cO): the
small-filter lift's q/r axes land in the unroll, channel axes land in the MXU.

Spatial (h_O) tiling is expressible too because the stride-s window of an
output row block [i*bh, (i+1)*bh) starts at input row i*bh*s: when bh*s is the
input block step, overlapping halos of h_F - s rows are covered by loading
(bh*s + h_F - s) rounded up to the next multiple of bh*s rows — we keep v1
simple (full spatial extent per tile; the LP rarely tiles spatial for LM-sized
convs) and expose spatial tiling through ``grid_h`` when the footprint needs it.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_model import Precision, round_up
from repro.plan import (ConvSpec, ExecutionPlan, HardwareTarget,
                        resolve_kernel_plan)


def _conv_spec(N: int, c_I: int, c_O: int, h_O: int, w_O: int, h_F: int,
               w_F: int, sh: int, sw: int, in_bits: int) -> ConvSpec:
    p_in = in_bits / 32.0
    return ConvSpec(N=N, c_I=c_I, c_O=c_O, w_O=w_O, h_O=h_O, w_F=w_F, h_F=h_F,
                    sw=sw, sh=sh, prec=Precision(p_in, p_in, 1.0))


def _conv_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_ci: int, h_F: int,
                 w_F: int, sh: int, sw: int, h_O: int, w_O: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bN, b_cI, H, W)
    w = w_ref[...]  # (b_cO, b_cI, h_F, w_F)
    bN, b_cI = x.shape[0], x.shape[1]
    b_cO = w.shape[0]
    acc = acc_ref[...]
    for hf in range(h_F):
        for wf in range(w_F):
            # strided tap window: (bN, b_cI, h_O, w_O)
            tap = jax.lax.slice(
                x,
                (0, 0, hf, wf),
                (bN, b_cI, hf + (h_O - 1) * sh + 1, wf + (w_O - 1) * sw + 1),
                (1, 1, sh, sw),
            )
            # MXU GEMM: (bN*h_O*w_O, b_cI) @ (b_cI, b_cO)
            lhs = tap.transpose(0, 2, 3, 1).reshape(bN * h_O * w_O, b_cI)
            rhs = w[:, :, hf, wf].T  # (b_cI, b_cO)
            out = jnp.dot(lhs, rhs, preferred_element_type=jnp.float32)
            acc = acc + out.reshape(bN, h_O, w_O, b_cO).transpose(0, 3, 1, 2)
    acc_ref[...] = acc

    @pl.when(ci == n_ci - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def conv2d(
    x: jax.Array,  # (N, c_I, H, W)
    w: jax.Array,  # (c_O, c_I, h_F, w_F)
    stride: Tuple[int, int] = (1, 1),
    out_dtype=jnp.float32,
    tiles: Optional[Tuple[int, int, int]] = None,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Direct convolution with paper-LP tiling. VALID padding.

    Tiles come from (in priority order) an explicit legacy ``tiles`` triple,
    an ``ExecutionPlan`` (``repro.plan.plan``), or a fresh plan solved for
    ``target`` (default TPU_V5E). ``interpret`` defaults to the target's
    policy (True everywhere until a real TPU backend is attached)."""
    N, c_I, H, W = x.shape
    c_O, c_I2, h_F, w_F = w.shape
    assert c_I == c_I2
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    in_bits = jnp.dtype(x.dtype).itemsize * 8
    (bN, b_cI, b_cO), interpret = resolve_kernel_plan(
        _conv_spec(N, c_I, c_O, h_O, w_O, h_F, w_F, sh, sw, in_bits),
        plan=plan, target=target, tiles=tiles, interpret=interpret)

    Np, cIp, cOp = round_up(N, bN), round_up(c_I, b_cI), round_up(c_O, b_cO)
    if (Np, cIp) != (N, c_I):
        x = jnp.pad(x, ((0, Np - N), (0, cIp - c_I), (0, 0), (0, 0)))
    if (cOp, cIp) != (c_O, c_I):
        w = jnp.pad(w, ((0, cOp - c_O), (0, cIp - c_I), (0, 0), (0, 0)))

    n_n, n_co, n_ci = Np // bN, cOp // b_cO, cIp // b_cI
    out = pl.pallas_call(
        functools.partial(_conv_kernel, n_ci=n_ci, h_F=h_F, w_F=w_F, sh=sh,
                          sw=sw, h_O=h_O, w_O=w_O),
        grid=(n_n, n_co, n_ci),
        in_specs=[
            pl.BlockSpec((bN, b_cI, H, W), lambda n, co, ci: (n, ci, 0, 0)),
            pl.BlockSpec((b_cO, b_cI, h_F, w_F), lambda n, co, ci: (co, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bN, b_cO, h_O, w_O), lambda n, co, ci: (n, co, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, cOp, h_O, w_O), out_dtype),
        scratch_shapes=[pltpu.VMEM((bN, b_cO, h_O, w_O), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:N, :c_O]
