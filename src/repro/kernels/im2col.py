"""Im2Col baseline convolution: materialized patches -> LP-tiled Pallas GEMM.

The algorithm the paper's §5 tiling is measured against (Figs 2-4): lower the
7NL convolution to one big GEMM by materializing the patch matrix

    P[(n, ho, wo), (ci, hf, wf)] = Input[n, ci, ho*sh + hf, wo*sw + wf]

of shape (N*h_O*w_O, c_I*h_F*w_F) — every input element is copied up to
h_F*w_F times — then computing P @ Filter.T with the LP-tiled Pallas matmul.
The patch expansion is plain XLA (its cost is pure data movement, which is
exactly what the baseline is supposed to pay); the GEMM is the same
double-buffered Pallas kernel the direct path uses for its taps, so the
comparison isolates the *algorithm's* communication, not kernel quality.

``im2col_hbm_words`` counts the words the baseline moves: read the input,
write the expanded patch matrix, then the GEMM's measured stream/store words
— the number the direct kernel's halo tiling is supposed to beat (the
paper's 13-150% Im2Col-vs-tiled gap).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.plan import ExecutionPlan, HardwareTarget

from .matmul import matmul, matmul_access_plan, matmul_hbm_words


def im2col_patches(x: jax.Array, h_F: int, w_F: int,
                   stride: Tuple[int, int]) -> jax.Array:
    """(N, c_I, H, W) -> (N*h_O*w_O, c_I*h_F*w_F) patch matrix whose column
    order (ci, hf, wf) matches ``filter.reshape(c_O, -1)``."""
    N, c_I, H, W = x.shape
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    taps = [
        jax.lax.slice(
            x, (0, 0, hf, wf),
            (N, c_I, hf + (h_O - 1) * sh + 1, wf + (w_O - 1) * sw + 1),
            (1, 1, sh, sw))  # (N, c_I, h_O, w_O)
        for hf in range(h_F) for wf in range(w_F)
    ]
    p = jnp.stack(taps, axis=2)  # (N, c_I, h_F*w_F, h_O, w_O)
    p = p.transpose(0, 3, 4, 1, 2)  # (N, h_O, w_O, c_I, h_F*w_F)
    return p.reshape(N * h_O * w_O, c_I * h_F * w_F)


def conv2d_im2col(
    x: jax.Array,  # (N, c_I, H, W)
    w: jax.Array,  # (c_O, c_I, h_F, w_F)
    stride: Tuple[int, int] = (1, 1),
    out_dtype=jnp.float32,
    ctx=None,
    target: Optional[HardwareTarget] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Im2Col convolution (VALID padding): patches -> LP-tiled Pallas GEMM.
    Execution policy rides ``ctx``; ``target=`` is legacy (DeprecationWarning;
    lint VRF015)."""
    from repro.plan import warn_legacy_kernel_kwargs

    warn_legacy_kernel_kwargs("conv2d_im2col", target=target)
    if ctx is None and (target is not None or interpret is not None):
        # absorb the legacy kwargs so the inner matmul doesn't re-warn
        from types import SimpleNamespace
        ctx = SimpleNamespace(target=target, interpret=interpret,
                              autotune=None)
    N, c_I, H, W = x.shape
    c_O, c_I2, h_F, w_F = w.shape
    assert c_I == c_I2
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    patches = im2col_patches(x, h_F, w_F, stride)
    wmat = w.reshape(c_O, c_I * h_F * w_F).T  # (k, c_O)
    out = matmul(patches, wmat, out_dtype=out_dtype,
                 ctx=ctx)  # (N*h_O*w_O, c_O)
    return out.reshape(N, h_O, w_O, c_O).transpose(0, 3, 1, 2)


def im2col_hbm_words(
    x,  # array or ShapeDtypeStruct, (N, c_I, H, W)
    w,  # array or ShapeDtypeStruct, (c_O, c_I, h_F, w_F)
    stride: Tuple[int, int] = (1, 1),
    target: Optional[HardwareTarget] = None,
    out_dtype=jnp.float32,
) -> float:
    """Measured HBM words (32-bit) one ``conv2d_im2col`` dispatch moves:
    patch expansion (read input + write the expanded matrix, as in the
    paper's im2col volume model) plus the Pallas GEMM's measured words for
    the launch geometry its plan resolves. Shapes/dtypes only."""
    N, c_I, H, W = x.shape
    c_O, _, h_F, w_F = w.shape
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    m, k = N * h_O * w_O, c_I * h_F * w_F
    p_in = jnp.dtype(x.dtype).itemsize / 4.0
    expand = p_in * (N * c_I * H * W) + p_in * m * k
    gemm = matmul_hbm_words(
        jax.ShapeDtypeStruct((m, k), x.dtype),
        jax.ShapeDtypeStruct((k, c_O), w.dtype),
        target=target, out_dtype=out_dtype)
    return expand + gemm


def im2col_access_plan(
    x,  # array or ShapeDtypeStruct, (N, c_I, H, W)
    w,  # array or ShapeDtypeStruct, (c_O, c_I, h_F, w_F)
    stride: Tuple[int, int] = (1, 1),
    target: Optional[HardwareTarget] = None,
    out_dtype=jnp.float32,
):
    """The :class:`repro.verify.access.KernelAccessPlan` of one
    ``conv2d_im2col`` dispatch: the GEMM's access plan (same grid, same A/B
    windows over the patch matrix) prefixed with the XLA patch expansion as
    flat traffic — read the input once, write the (m, k) patch matrix once —
    exactly what ``im2col_hbm_words`` charges."""
    import dataclasses as _dc

    from repro.verify.access import FlatAccess

    N, c_I, H, W = x.shape
    c_O, _, h_F, w_F = w.shape
    sh, sw = stride
    h_O = (H - h_F) // sh + 1
    w_O = (W - w_F) // sw + 1
    m, k = N * h_O * w_O, c_I * h_F * w_F
    p_in = jnp.dtype(x.dtype).itemsize / 4.0
    gemm = matmul_access_plan(
        jax.ShapeDtypeStruct((m, k), x.dtype),
        jax.ShapeDtypeStruct((k, c_O), w.dtype),
        target=target, out_dtype=out_dtype, op="conv2d[im2col]")
    expand = (
        FlatAccess(name="im2col_input_read", kind="load",
                   words=p_in * N * c_I * H * W,
                   note="XLA patch expansion reads the input once"),
        FlatAccess(name="im2col_patch_write", kind="store",
                   words=p_in * float(m) * k,
                   note="XLA patch expansion writes the (m, k) matrix"),
    )
    return _dc.replace(gemm, accesses=expand + gemm.accesses,
                       note="patch expansion + LP-tiled GEMM")
