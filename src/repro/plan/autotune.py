"""``repro.plan.autotune`` — the bound-guided measured autotune stage.

The blocking LP minimizes *words*; real launches also pay per-DMA issue
latency, so the words-optimal tiles are not always the fastest feasible ones.
Following the shape of arxiv 2012.15667 (use the I/O lower bound to prune the
search, then measure the survivors — viable only because, per arxiv
1802.06905, the near-bound frontier is small):

  1. **Frontier enumeration** — walk a deterministic tile neighborhood of the
     analytic plan (axis halvings/doublings, spatial divisors), clamp every
     candidate through ``fit_conv_kernel_tiles`` and keep only those that fit
     the exact halo-window VMEM budget (``conv_kernel_tiles_fit`` / the GEMM
     footprint), move words within ``policy.slack`` of the analytic optimum
     AND stay ≤ ``policy.bound_cap`` x the Thm 2.1 bound, and pass the
     ``verify.audit`` exactness check (the candidate's access plan must
     reproduce its words_fn word-for-word) — only auditable candidates are
     ever timed.
  2. **Timing** — each surviving candidate runs on-device through the
     existing ``ops.dispatch_call`` path (explicit ``plan=`` override,
     best-of-k, warmed) when an accelerator is present; otherwise the
     deterministic offline fallback prices it with the alpha-beta roofline
     ``analysis.roofline.alpha_beta_seconds`` (``hbm_seconds`` bandwidth term
     + DMA-issue latency term), under which the winner is reproducible
     bit-for-bit.
  3. **Persistence** — the winner lands in the process-wide plan cache (it
     *replaces* the analytic entry for the (op, target) pair) and in the
     versioned :class:`TuningRecord` store keyed by (op spec — shapes +
     dtypes — and target fingerprint). ``Planner.cache.save()/load()`` round-
     trips both, so production serving never re-searches: the
     ``search_count()`` counter asserts exactly that in
     ``benchmarks/autotune_bench.py``.

The analytic tiles are always in the timed set, so the tuned plan is never
slower than the analytic one under the model that ranked it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import threading
import time
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.analysis.roofline import alpha_beta_seconds
from repro.core.conv_model import ConvShape, ceil_div, round_up
from repro.core.tiling import (conv_kernel_tiles_fit, fit_conv_kernel_tiles,
                               snap_tile)

from . import planner as _planner
from .ops import ConvSpec, MatmulSpec, OpSpec, as_op_spec, op_from_dict
from .planner import ExecutionPlan, TunedSection, analytic_plan
from .target import HardwareTarget, TPU_V5E

# v1: {version, op, target, target_fingerprint, tiles, grid, tuned}.
TUNING_FORMAT_VERSION = 1

# words -> storage dtype of a spec stream (the inverse of the kernels'
# itemsize/4 spec precision); exotic widths are unsearchable.
_WIDTH_DTYPES = {1.0: jnp.float32, 0.5: jnp.bfloat16, 0.25: jnp.int8}


@dataclasses.dataclass(frozen=True)
class AutotunePolicy:
    """Knobs of one frontier search. Frozen/hashable so it can ride
    ``ExecutionContext(autotune=...)`` into jit-static cache keys.

    ``slack`` bounds candidate words relative to the analytic optimum (the
    frontier width); ``bound_cap`` additionally caps words against the plan's
    Thm 2.1 lower bound so no winner ever leaves the audited regime (on
    shapes where the analytic optimum itself exceeds the cap, the analytic
    words become the cap — tuning never worsens the bound ratio);
    ``max_candidates`` limits how many frontier survivors are audited+timed
    (ranked by the offline alpha-beta model first); ``timer`` picks the
    harness — ``"device"`` (best-of-``best_of``, ``warmup`` warmed calls,
    through ``ops.dispatch_call``), ``"roofline"`` (offline, deterministic),
    or ``"auto"`` (device iff a non-CPU jax backend is attached)."""

    slack: float = 1.25
    bound_cap: float = 1.3
    max_candidates: int = 16
    best_of: int = 3
    warmup: int = 1
    timer: str = "auto"  # "auto" | "device" | "roofline"

    @classmethod
    def coerce(cls, value: Any) -> Optional["AutotunePolicy"]:
        """None/False -> None (autotune off); True -> defaults; a policy
        passes through. Anything else is a caller bug."""
        if value is None or value is False:
            return None
        if value is True:
            return cls()
        if isinstance(value, cls):
            return value
        raise TypeError(f"autotune policy must be None/bool/AutotunePolicy, "
                        f"got {type(value).__name__}")


@dataclasses.dataclass(frozen=True)
class TuningRecord:
    """One persisted frontier winner: the (op, target) key — op spec carries
    the shapes and dtypes, the target its fingerprint — plus the winning
    tiles/grid and the :class:`TunedSection` provenance."""

    op: OpSpec
    target: HardwareTarget
    tiles: Tuple[int, ...]
    grid: Tuple[int, ...]
    tuned: TunedSection

    @property
    def fingerprint(self) -> str:
        return target_fingerprint(self.target)

    def to_dict(self) -> Dict[str, Any]:
        return {"version": TUNING_FORMAT_VERSION,
                "op": self.op.to_dict(),
                "target": self.target.to_dict(),
                "target_fingerprint": self.fingerprint,
                "tiles": list(self.tiles),
                "grid": list(self.grid),
                "tuned": self.tuned.to_dict()}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TuningRecord":
        if d.get("version", 1) > TUNING_FORMAT_VERSION:
            raise ValueError(f"tuning record format {d['version']} is newer "
                             f"than supported {TUNING_FORMAT_VERSION}")
        target = HardwareTarget.from_dict(d["target"])
        fp = d.get("target_fingerprint")
        if fp is not None and fp != target_fingerprint(target):
            raise ValueError(
                f"tuning record fingerprint {fp} does not match its own "
                "target dict — the record was edited or the target "
                "serialization changed; re-tune instead of trusting it")
        return cls(op=op_from_dict(d["op"]), target=target,
                   tiles=tuple(int(v) for v in d["tiles"]),
                   grid=tuple(int(v) for v in d["grid"]),
                   tuned=TunedSection.from_dict(d["tuned"]))


def _normalize(op: OpSpec, target: HardwareTarget) -> OpSpec:
    """Pin ``prec=None`` (target-default precision) specs to the target's
    concrete precision: a kernel entry re-derives its spec from real dtypes
    (explicit prec), so records must key on the resolved form for both entry
    paths to share one TuningRecord."""
    if getattr(op, "prec", None) is None:
        return dataclasses.replace(op, prec=target.precision)
    return op


def target_fingerprint(target: HardwareTarget) -> str:
    """Stable 12-hex digest of the target's serialized form — the part of
    the TuningRecord key that invalidates records when the hardware model
    (VMEM size, alignment, precision policy...) changes."""
    blob = json.dumps(target.to_dict(), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


# ---------------------------------------------------------------------------
# The TuningRecord store (process-wide, mirrored to disk by PlanCache).
# ---------------------------------------------------------------------------

_RECORDS: Dict[Tuple[OpSpec, HardwareTarget], TuningRecord] = {}
_LOCK = threading.Lock()
_SEARCHES = 0  # frontier searches actually run (cache hits don't count)


def search_count() -> int:
    """Frontier searches run so far in this process. Survives
    ``PlanCache.clear()`` on purpose: a save/clear/load round trip followed
    by re-planning must leave this unchanged (zero re-searches)."""
    return _SEARCHES


def reset_search_count() -> None:
    global _SEARCHES
    _SEARCHES = 0


def records() -> List[TuningRecord]:
    """Snapshot of every stored tuning record (insertion order)."""
    with _LOCK:
        return list(_RECORDS.values())


def clear_records() -> None:
    """Drop all tuning records and evict their materialized plans from the
    plan cache (analytic entries stay)."""
    with _LOCK:
        _RECORDS.clear()
    with _planner._CACHE_LOCK:
        for key in [k for k, p in _planner._CACHE.items()
                    if p.tuned is not None]:
            del _planner._CACHE[key]


def install_record(rec: TuningRecord) -> None:
    """Adopt a tuning record (fresh search or cache load). The stale plan-
    cache entry for its key is evicted so the winner takes over process-wide
    on the next resolve."""
    key = (rec.op, rec.target)
    with _LOCK:
        _RECORDS[key] = rec
    with _planner._CACHE_LOCK:
        _planner._CACHE.pop(key, None)


def lookup_plan(op: Union[OpSpec, ConvShape], target: HardwareTarget
                ) -> Optional[ExecutionPlan]:
    """The tuned plan for (op, target) if a record exists, else None.
    Materialization is memoized through the process-wide plan cache."""
    op = _normalize(as_op_spec(op), target)
    with _LOCK:
        rec = _RECORDS.get((op, target))
    if rec is None:
        return None
    return _materialize(rec, op, target)


def _materialize(rec: TuningRecord, op: OpSpec, target: HardwareTarget
                 ) -> ExecutionPlan:
    """Graft the record's winner onto an analytic base plan (bounds,
    blocking witness, sharding and dtypes are the base's), validate it
    through the registered plan-audit hooks, and install it as THE cached
    plan for the pair."""
    key = (op, target)
    with _planner._CACHE_LOCK:
        cached = _planner._CACHE.get(key)
    if cached is not None and cached.tuned == rec.tuned \
            and cached.tiles == rec.tiles:
        return cached
    base = cached if (cached is not None and cached.tuned is None) else None
    if base is None:
        base = (_planner._plan_conv(op, target) if isinstance(op, ConvSpec)
                else _planner._plan_matmul(op, target))
    tuned = dataclasses.replace(
        base, tiles=rec.tiles, grid=rec.grid,
        comm_volume=float(rec.tuned.winner_words),
        efficiency=float(rec.tuned.winner_words) / max(base.lower_bound, 1.0),
        tuned=rec.tuned)
    for hook in _planner._PLAN_AUDIT_HOOKS:
        hook(tuned)
    with _planner._CACHE_LOCK:
        _planner._CACHE[key] = tuned
    return tuned


# ---------------------------------------------------------------------------
# Op call derivation: OpSpec -> (op name, spec args, spec kw) for the
# registry's pallas entry — the same call shape ops.explain consumes.
# ---------------------------------------------------------------------------

def _dtype_of(width: float):
    try:
        return _WIDTH_DTYPES[float(width)]
    except KeyError:
        raise ValueError(f"no searchable dtype for stream width {width}")


def _op_call(op: OpSpec, target: HardwareTarget
             ) -> Tuple[str, tuple, Dict[str, Any]]:
    prec = op.prec or target.precision
    if isinstance(op, ConvSpec):
        H = (op.h_O - 1) * op.sh + op.h_F  # tight VALID input extent
        W = (op.w_O - 1) * op.sw + op.w_F
        xd, wd, od = (_dtype_of(prec.p_I), _dtype_of(prec.p_F),
                      _dtype_of(prec.p_O))
        xs = jax.ShapeDtypeStruct((op.N, op.c_I, H, W), xd)
        ws = jax.ShapeDtypeStruct((op.c_O, op.c_I, op.h_F, op.w_F), wd)
        kw = {"stride": (op.sh, op.sw), "out_dtype": od}
        if xd == jnp.int8:
            sc = jax.ShapeDtypeStruct((1, op.c_O), jnp.float32)
            return "conv2d_q", (xs, ws, sc), kw
        return "conv2d", (xs, ws), kw
    if isinstance(op, MatmulSpec):
        ad, bd, od = (_dtype_of(prec.p_I), _dtype_of(prec.p_F),
                      _dtype_of(prec.p_O))
        a = jax.ShapeDtypeStruct((op.m, op.k), ad)
        b = jax.ShapeDtypeStruct((op.k, op.n), bd)
        kw = {"out_dtype": od}
        if ad == jnp.int8:
            sc = jax.ShapeDtypeStruct((1, op.n), jnp.float32)
            return "matmul_q", (a, b, sc), kw
        return "matmul", (a, b), kw
    raise TypeError(f"autotune cannot search {type(op).__name__} plans "
                    "(attention tiles are closed-form)")


def supports(op: Union[OpSpec, ConvShape],
             target: HardwareTarget = TPU_V5E) -> bool:
    """True iff the frontier enumerator can search this (op, target)."""
    try:
        _op_call(as_op_spec(op), target)
        return True
    except (TypeError, ValueError):
        return False


# ---------------------------------------------------------------------------
# Frontier enumeration.
# ---------------------------------------------------------------------------

def _axis_options(v: int, cap: int, spatial: bool) -> List[int]:
    opts = {1, v // 2, v, v * 2, v * 4, cap}
    if spatial:
        # divisor-aligned spatial blocks avoid padded-launch waste entirely
        opts |= {d for d in range(1, cap + 1) if cap % d == 0}
    return sorted({min(cap, max(1, o)) for o in opts if o})


def _conv_candidates(op: ConvSpec, target: HardwareTarget,
                     base: Tuple[int, ...]) -> List[Tuple[int, ...]]:
    shape = op.to_shape(target.precision)
    mem = target.memory_model()
    caps = (op.N, op.c_I, op.c_O, op.h_O, op.w_O)
    axes = [_axis_options(base[0], caps[0], False),
            _axis_options(base[1], caps[1], False),
            _axis_options(base[2], caps[2], False),
            _axis_options(base[3], caps[3], True),
            _axis_options(base[4], caps[4], True)]
    seen: Dict[Tuple[int, ...], None] = {tuple(base): None}
    for bN in axes[0]:
        for b_cI in axes[1]:
            for b_cO in axes[2]:
                for bh in axes[3]:
                    for bw in axes[4]:
                        t = fit_conv_kernel_tiles(
                            shape, (bN, b_cI, b_cO, bh, bw), mem)
                        if conv_kernel_tiles_fit(shape, t, mem):
                            seen.setdefault(tuple(t), None)
    return list(seen)


def _matmul_candidates(op: MatmulSpec, target: HardwareTarget,
                       base: Tuple[int, ...]) -> List[Tuple[int, ...]]:
    prec = op.prec or target.precision
    mem = target.memory_model()
    al = (max(target.align_sublane, 1), max(target.align_lane, 1),
          max(target.align_lane, 1))
    caps = (op.m, op.n, op.k)

    def fp(t):
        return (t[0] * t[2] * prec.p_I + t[2] * t[1] * prec.p_F
                + t[0] * t[1] * prec.p_O)

    seen: Dict[Tuple[int, ...], None] = {tuple(base): None}
    axes = [_axis_options(base[i], caps[i], False) for i in range(3)]
    for bm in axes[0]:
        for bn in axes[1]:
            for bk in axes[2]:
                t = (min(snap_tile(bm, al[0], caps[0]),
                         round_up(caps[0], al[0])),
                     min(snap_tile(bn, al[1], caps[1]),
                         round_up(caps[1], al[1])),
                     min(snap_tile(bk, al[2], caps[2]),
                         round_up(caps[2], al[2])))
                t = _planner._fit_matmul_tiles(t, prec, mem, target)
                if fp(t) <= mem.M_eff:
                    seen.setdefault(tuple(t), None)
    return list(seen)


def _candidate_grid(op: OpSpec, t: Tuple[int, ...]) -> Tuple[int, ...]:
    if isinstance(op, ConvSpec):
        return (ceil_div(op.N, t[0]), ceil_div(op.c_O, t[2]),
                ceil_div(op.h_O, t[3]), ceil_div(op.w_O, t[4]),
                ceil_div(op.c_I, t[1]))
    return (ceil_div(op.m, t[0]), ceil_div(op.n, t[1]),
            ceil_div(op.k, t[2]))


def _transfers(grid: Tuple[int, ...]) -> int:
    """DMA issues of one launch: two streamed operand copies per grid step
    (both kernels double-buffer input+filter / A+B) plus one output store
    per outer cell (the last grid axis is the reduction)."""
    steps = math.prod(grid)
    return 2 * steps + steps // max(grid[-1], 1)


def _offline_seconds(words: float, grid: Tuple[int, ...]) -> float:
    return alpha_beta_seconds(words, _transfers(grid))


def predicted_seconds(plan: ExecutionPlan,
                      words: Optional[float] = None) -> float:
    """Offline alpha-beta wall time of one launch of ``plan`` — the same
    model the roofline timer ranks candidates with, so analytic and tuned
    plans are comparable on it. ``words`` defaults to the plan's
    ``comm_volume``; pass the measured words for the exact launch geometry
    when available (``benchmarks/autotune_bench.py`` does)."""
    w = float(plan.comm_volume if words is None else words)
    return _offline_seconds(w, plan.grid)


def _candidate_plan(base: ExecutionPlan, op: OpSpec, tiles: Tuple[int, ...],
                    words: float) -> ExecutionPlan:
    return dataclasses.replace(
        base, tiles=tuple(tiles), grid=_candidate_grid(op, tiles),
        comm_volume=float(words),
        efficiency=float(words) / max(base.lower_bound, 1.0))


# ---------------------------------------------------------------------------
# The search: enumerate -> filter (slack, bound, audit) -> time -> persist.
# ---------------------------------------------------------------------------

def _use_device_timer(policy: AutotunePolicy) -> bool:
    if policy.timer == "device":
        return True
    if policy.timer == "roofline":
        return False
    return jax.default_backend() not in ("cpu",)


def _time_device(op_name: str, ctx, spec_args: tuple, spec_kw: dict,
                 cand: ExecutionPlan, policy: AutotunePolicy) -> float:
    """Best-of-k warmed wall clock of one candidate through the real
    dispatch path (explicit plan override -> the kernel lowers exactly the
    candidate's tiles)."""
    from repro import ops as _ops

    args = tuple(jnp.zeros(a.shape, a.dtype) for a in spec_args)
    kw = dict(spec_kw)
    fn = jax.jit(lambda *xs: _ops.dispatch_call(
        op_name, ctx, str(xs[0].dtype), (), xs, spec_kw=kw, plan=cand))
    for _ in range(max(1, policy.warmup)):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(1, policy.best_of)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _search(op: OpSpec, target: HardwareTarget, policy: AutotunePolicy
            ) -> TuningRecord:
    global _SEARCHES
    _SEARCHES += 1
    from repro.ops import ExecutionContext
    from repro.ops import registry as _registry
    from repro.ops.dispatch import DispatchDecision
    from repro.verify import audit as _audit

    op_name, spec_args, spec_kw = _op_call(op, target)
    ctx = ExecutionContext(target=target, backend="pallas")
    entry = _registry.get_backend("pallas").ops[op_name]
    got = entry.spec_fn(*spec_args, **spec_kw)
    if got != op:
        raise ValueError(
            f"autotune spec round-trip failed: derived call re-specs to "
            f"{got}, not {op} — refusing to tune the wrong op")

    base = analytic_plan(op, target)
    if base.tuned is not None:  # cache already holds a winner's plan: rebuild
        base = (_planner._plan_conv(op, target) if isinstance(op, ConvSpec)
                else _planner._plan_matmul(op, target))

    def words_of(cand: ExecutionPlan) -> float:
        return float(entry.words_fn(ctx, cand, *spec_args, **spec_kw))

    tiles_list = (_conv_candidates(op, target, base.tiles)
                  if isinstance(op, ConvSpec)
                  else _matmul_candidates(op, target, base.tiles))
    base_words = words_of(base)
    # The bound cap never excludes the analytic plan itself: on shapes whose
    # irreducible halo/store overhead puts even the LP optimum above
    # bound_cap x the Thm 2.1 bound (ResNet-50 conv5_x measures 1.35x), the
    # analytic words become the cap — tuning may never *worsen* the ratio.
    cap = max(policy.bound_cap * base.lower_bound, base_words)
    frontier: List[Tuple[ExecutionPlan, float]] = []
    for t in tiles_list:
        cand = _candidate_plan(base, op, t, 0.0)
        w = words_of(cand)
        if w > policy.slack * base_words + 1e-9:
            continue
        if w > cap + 1e-9:
            continue
        frontier.append((_candidate_plan(base, op, t, w), w))
    # rank by the offline model; the analytic tiles are always kept so the
    # winner can never rank behind the plan it started from
    frontier.sort(key=lambda cw: (_offline_seconds(cw[1], cw[0].grid),
                                  cw[1], cw[0].tiles))
    keep = frontier[:max(1, policy.max_candidates)]
    if not any(c.tiles == base.tiles for c, _ in keep):
        keep.append((_candidate_plan(base, op, base.tiles, base_words),
                     base_words))

    # audit gate: only candidates whose access plan reproduces their words_fn
    # exactly (and fits VMEM, and holds the bound ratio) may be timed
    audited: List[Tuple[ExecutionPlan, float]] = []
    for cand, w in keep:
        decision = DispatchDecision(op=op_name, requested="pallas",
                                    chosen="pallas", plan=cand,
                                    measured_words=w, plan_source="explicit")
        ap = entry.access_plan_fn(ctx, cand, *spec_args, **spec_kw)
        if _audit.audit_decision(ap, decision, target=target).ok:
            audited.append((cand, w))

    device = _use_device_timer(policy)
    timed: List[Tuple[float, float, ExecutionPlan]] = []
    for cand, w in audited:
        if device:
            secs = _time_device(op_name, ctx, spec_args, spec_kw, cand,
                                policy)
        else:
            secs = _offline_seconds(w, cand.grid)
        timed.append((secs, w, cand))
    secs, w, winner = min(timed, key=lambda swc: (swc[0], swc[1],
                                                  swc[2].tiles))
    tuned = TunedSection(source="device" if device else "roofline",
                         candidates_timed=len(timed), winner_words=w,
                         winner_seconds=secs)
    return TuningRecord(op=op, target=target, tiles=winner.tiles,
                        grid=winner.grid, tuned=tuned)


def autotune(op: Union[OpSpec, ConvShape], target: HardwareTarget = TPU_V5E,
             policy: Any = None) -> ExecutionPlan:
    """Tuned plan for (op, target): reuse the stored TuningRecord, else run
    one frontier search and persist the winner (plan cache + record store).
    Raises TypeError/ValueError for unsearchable ops — guard with
    :func:`supports` when tuning opportunistically."""
    op = _normalize(as_op_spec(op), target)
    pol = AutotunePolicy.coerce(policy if policy is not None else True)
    with _LOCK:
        rec = _RECORDS.get((op, target))
    if rec is None:
        rec = _search(op, target, pol)
        install_record(rec)
    return _materialize(rec, op, target)
