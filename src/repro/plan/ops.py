"""Operation specs: what the planner is asked to lower.

``OpSpec = ConvSpec | MatmulSpec | AttentionSpec`` — all hashable value
objects so the pair (op, target) keys the process-wide plan cache. ``prec=None`` defers the
precision choice to the target's policy; an explicit ``Precision`` (e.g. built
from the input dtype by the kernels) overrides it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Union

from repro.core.conv_model import ConvShape, Precision, matmul_as_conv


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """The 7NL CNN of paper §2.1 (output-size parameterization, as ConvShape)."""

    N: int
    c_I: int
    c_O: int
    w_O: int
    h_O: int
    w_F: int
    h_F: int
    sw: int = 1
    sh: int = 1
    prec: Optional[Precision] = None

    @classmethod
    def from_shape(cls, shape: ConvShape) -> "ConvSpec":
        return cls(N=shape.N, c_I=shape.c_I, c_O=shape.c_O, w_O=shape.w_O,
                   h_O=shape.h_O, w_F=shape.w_F, h_F=shape.h_F, sw=shape.sw,
                   sh=shape.sh, prec=shape.prec)

    def to_shape(self, default_prec: Precision) -> ConvShape:
        return ConvShape(N=self.N, c_I=self.c_I, c_O=self.c_O, w_O=self.w_O,
                         h_O=self.h_O, w_F=self.w_F, h_F=self.h_F, sw=self.sw,
                         sh=self.sh, prec=self.prec or default_prec)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "conv", "N": self.N, "c_I": self.c_I, "c_O": self.c_O,
                "w_O": self.w_O, "h_O": self.h_O, "w_F": self.w_F,
                "h_F": self.h_F, "sw": self.sw, "sh": self.sh,
                "prec": None if self.prec is None else list(self.prec.as_tuple())}


@dataclasses.dataclass(frozen=True)
class MatmulSpec:
    """C[m,n] += A[m,k] B[k,n] as the degenerate 7NL CNN (N=m, c_I=k, c_O=n)."""

    m: int
    n: int
    k: int
    prec: Optional[Precision] = None

    def to_shape(self, default_prec: Precision) -> ConvShape:
        return matmul_as_conv(self.m, self.n, self.k, self.prec or default_prec)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "matmul", "m": self.m, "n": self.n, "k": self.k,
                "prec": None if self.prec is None else list(self.prec.as_tuple())}


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """GQA attention as two chained 7NL degenerates (QK^T then PV).

    ``Lq`` is the per-head query length *before* any GQA group folding (the
    planner accounts the fold itself); ``Lk`` the key/value length; ``KV``
    the number of distinct KV heads (``KV | H``). Decode is ``Lq == 1``.
    ``prec`` maps (p_I, p_F, p_O) -> (query, key/value, output) stream
    widths."""

    B: int
    H: int
    KV: int
    Lq: int
    Lk: int
    hd: int
    prec: Optional[Precision] = None

    def to_shape(self, default_prec: Precision) -> ConvShape:
        raise TypeError("attention ops have no single ConvShape view; "
                        "the planner bounds them via core.bounds.attention_bound")

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "attention", "B": self.B, "H": self.H, "KV": self.KV,
                "Lq": self.Lq, "Lk": self.Lk, "hd": self.hd,
                "prec": None if self.prec is None else list(self.prec.as_tuple())}


OpSpec = Union[ConvSpec, MatmulSpec, AttentionSpec]


def op_from_dict(d: Dict[str, Any]) -> OpSpec:
    prec = None if d.get("prec") is None else Precision(*d["prec"])
    if d["kind"] == "conv":
        return ConvSpec(N=d["N"], c_I=d["c_I"], c_O=d["c_O"], w_O=d["w_O"],
                        h_O=d["h_O"], w_F=d["w_F"], h_F=d["h_F"], sw=d["sw"],
                        sh=d["sh"], prec=prec)
    if d["kind"] == "matmul":
        return MatmulSpec(m=d["m"], n=d["n"], k=d["k"], prec=prec)
    if d["kind"] == "attention":
        return AttentionSpec(B=d["B"], H=d["H"], KV=d["KV"], Lq=d["Lq"],
                             Lk=d["Lk"], hd=d["hd"], prec=prec)
    raise ValueError(f"unknown op kind {d.get('kind')!r}")


def as_op_spec(op: Union[OpSpec, ConvShape]) -> OpSpec:
    """Coerce a raw ConvShape (or pass through an OpSpec)."""
    if isinstance(op, (ConvSpec, MatmulSpec, AttentionSpec)):
        return op
    if isinstance(op, ConvShape):
        return ConvSpec.from_shape(op)
    raise TypeError(f"cannot plan {type(op).__name__}; "
                    "expected ConvSpec, MatmulSpec, AttentionSpec, or ConvShape")
