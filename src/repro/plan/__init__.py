"""``repro.plan`` — the single planning subsystem (HardwareTarget -> ExecutionPlan).

The paper's optimization discipline — solve the HBL-derived blocking LP
against a memory-hierarchy model, then lower the solution to tilings and
processor grids (§3.2 eq. 6, §4.2, §5) — behind one front door:

    from repro.plan import ConvSpec, Planner, TPU_V5E

    planner = Planner(TPU_V5E)          # optional: quant=..., autotune=True
    ep = planner.plan(ConvSpec(N=32, c_I=64, c_O=64, w_O=56, h_O=56,
                               w_F=3, h_F=3))
    ep.tiles          # (bN, b_cI, b_cO, b_hO, b_wO) for the Pallas kernel
    ep.comm_volume    # modeled HBM<->VMEM words
    ep.efficiency     # vs the Thm 2.1 lower bound
    ep.sharding       # PartitionSpecs when the target has mesh axes

    planner.autotune(op)   # measured frontier search (repro.plan.autotune)
    Planner.cache.save(p)  # persist plans + tuning records; .load/.clear/.size

Kernels take ``ctx=ExecutionContext(...)``; the module-level ``plan()`` /
``*_plan_cache()`` functions and the kernels' ``plan=``/``target=`` kwargs
are one-PR deprecation shims (messages start with "legacy" so CI can promote
them to errors). `core.tiling` / `core.sharding_opt` remain the planner's
low-level building blocks.
"""

from .ops import (  # noqa: F401
    AttentionSpec,
    ConvSpec,
    MatmulSpec,
    OpSpec,
    as_op_spec,
)
from .planner import (  # noqa: F401
    PLAN_FORMAT_VERSION,
    ExecutionPlan,
    ParallelSection,
    PlanCache,
    Planner,
    TunedSection,
    analytic_plan,
    clear_plan_cache,
    load_plan_cache,
    plan,
    plan_cache_size,
    register_plan_audit_hook,
    resolve_kernel_plan,
    resolve_plan,
    save_plan_cache,
    warn_legacy_kernel_kwargs,
)
from .autotune import (  # noqa: F401
    AutotunePolicy,
    TuningRecord,
    predicted_seconds,
    target_fingerprint,
)
from .target import (  # noqa: F401
    CPU_INTERPRET,
    GEMMINI,
    TARGETS,
    TPU_V5E,
    HardwareTarget,
    get_target,
)
