"""``repro.plan`` — the single planning subsystem (HardwareTarget -> ExecutionPlan).

The paper's optimization discipline — solve the HBL-derived blocking LP
against a memory-hierarchy model, then lower the solution to tilings and
processor grids (§3.2 eq. 6, §4.2, §5) — behind one API:

    from repro.plan import ConvSpec, HardwareTarget, TPU_V5E, plan

    ep = plan(ConvSpec(N=32, c_I=64, c_O=64, w_O=56, h_O=56, w_F=3, h_F=3),
              TPU_V5E)
    ep.tiles          # (bN, b_cI, b_cO, b_hO, b_wO) for the Pallas kernel
    ep.comm_volume    # modeled HBM<->VMEM words
    ep.efficiency     # vs the Thm 2.1 lower bound
    ep.sharding       # PartitionSpecs when the target has mesh axes

Every kernel (`kernels.conv2d`, `kernels.matmul`, ...) accepts ``plan=`` /
``target=``. The legacy per-module planners (`plan_conv_tiles`,
`plan_tiles`) are retired; `core.tiling` / `core.sharding_opt` remain as the
planner's low-level building blocks.
"""

from .ops import (  # noqa: F401
    AttentionSpec,
    ConvSpec,
    MatmulSpec,
    OpSpec,
    as_op_spec,
)
from .planner import (  # noqa: F401
    PLAN_FORMAT_VERSION,
    ExecutionPlan,
    ParallelSection,
    clear_plan_cache,
    load_plan_cache,
    plan,
    plan_cache_size,
    register_plan_audit_hook,
    resolve_kernel_plan,
    save_plan_cache,
)
from .target import (  # noqa: F401
    CPU_INTERPRET,
    GEMMINI,
    TARGETS,
    TPU_V5E,
    HardwareTarget,
    get_target,
)
