"""Hardware targets: one declarative description of the memory hierarchy the
blocking LP optimizes against (paper §3.2/§5) plus the device mesh the
parallel LP shards over (paper §4.2).

A ``HardwareTarget`` is the single input every planner consumer constructs —
kernels, launchers, benchmarks, and serving all describe *where* they run with
this dataclass and let ``repro.plan.plan`` decide *how* (tiles, grids,
shardings). It subsumes the ad-hoc ``MemoryModel`` constructions that used to
be scattered across ``kernels/*`` and ``benchmarks/*``.

Presets:
  * ``TPU_V5E``      - 16 MiB unified VMEM, bf16 streams / f32 accumulate,
                       MXU (8, 128) alignment. ``interpret=True`` because this
                       container has no TPU; flip on real hardware.
  * ``GEMMINI``      - the paper's §5 accelerator: 256 KiB scratchpad (int8)
                       + 64 KiB accumulator (f32), split-buffer mode.
  * ``CPU_INTERPRET``- correctness target: Pallas interpret mode, f32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

from repro.core.conv_model import BF16_ACC32, FP32, INT8_ACC32, Precision
from repro.core.tiling import MemoryModel, TPU_VMEM_WORDS
from repro.quant.spec import PrecisionSpec

MeshAxes = Tuple[Tuple[str, int], ...]


@dataclasses.dataclass(frozen=True)
class HardwareTarget:
    """Full memory-hierarchy + mesh description of one deployment target.

    Capacities are in the paper's unit (words of 32 bits). ``mesh_axes`` is
    empty for single-device targets; a non-empty tuple makes ``plan`` attach a
    ``ShardingPlan`` (PartitionSpecs) to the returned ``ExecutionPlan``.
    """

    name: str
    vmem_words: float = float(TPU_VMEM_WORDS)  # scratchpad / cache / VMEM
    acc_words: Optional[float] = None  # separate accumulator ("split" only)
    hbm_words: float = 4 * 2**30 / 4.0  # main-memory capacity (KV-cache pool)
    memory: str = "unified"  # "unified" | "split" (paper eq. 6 vs §5)
    double_buffer: bool = True  # §5: halves usable capacity
    precision: Precision = BF16_ACC32  # default when the OpSpec has none
    interpret: bool = True  # Pallas interpret default for kernels
    use_pallas: bool = False  # whether consumers should take the Pallas path
    mesh_axes: MeshAxes = ()  # ((name, size), ...) for multi-device targets
    align_sublane: int = 8  # MXU sublane multiple (1 = no alignment)
    align_lane: int = 128  # MXU lane multiple (1 = no alignment)
    # Optional quantized storage policy (repro.quant). When set, consumers
    # that opt into the quantized path (ops.conv2d_q / matmul_q callers, the
    # serving engine's kv_dtype knob) read the per-operand dtypes from here;
    # its ``.precision`` projection is what the LP and bounds then price.
    # ``precision`` above stays the full-precision default for ops that
    # don't quantize.
    quant: Optional[PrecisionSpec] = None

    def memory_model(self) -> MemoryModel:
        """The capacity model the blocking LP consumes."""
        return MemoryModel(M=self.vmem_words, M_acc=self.acc_words,
                           mode=self.memory, double_buffer=self.double_buffer)

    @property
    def n_devices(self) -> int:
        out = 1
        for _, size in self.mesh_axes:
            out *= size
        return out

    # -- builders -------------------------------------------------------------
    def with_mesh(self, axes: Sequence[Tuple[str, int]]) -> "HardwareTarget":
        return dataclasses.replace(
            self, mesh_axes=tuple((str(n), int(s)) for n, s in axes))

    def with_precision(self, prec: Precision) -> "HardwareTarget":
        return dataclasses.replace(self, precision=prec)

    def with_vmem(self, vmem_words: float) -> "HardwareTarget":
        return dataclasses.replace(self, vmem_words=float(vmem_words))

    def with_quant(self, spec: Optional[PrecisionSpec]) -> "HardwareTarget":
        """Attach (or clear, with None) a quantized storage policy."""
        return dataclasses.replace(self, quant=spec)

    @classmethod
    def from_mesh(cls, mesh: Any, base: Optional["HardwareTarget"] = None
                  ) -> "HardwareTarget":
        """Target whose mesh_axes mirror a ``jax.sharding.Mesh``."""
        base = base or TPU_V5E
        return base.with_mesh(tuple(zip(mesh.axis_names, mesh.devices.shape)))

    # -- (de)serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "vmem_words": self.vmem_words,
            "acc_words": self.acc_words,
            "hbm_words": self.hbm_words,
            "memory": self.memory,
            "double_buffer": self.double_buffer,
            "precision": list(self.precision.as_tuple()),
            "interpret": self.interpret,
            "use_pallas": self.use_pallas,
            "mesh_axes": [list(ax) for ax in self.mesh_axes],
            "align_sublane": self.align_sublane,
            "align_lane": self.align_lane,
            "quant": None if self.quant is None else self.quant.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "HardwareTarget":
        return cls(
            name=d["name"],
            vmem_words=float(d["vmem_words"]),
            acc_words=None if d.get("acc_words") is None else float(d["acc_words"]),
            hbm_words=float(d.get("hbm_words", 4 * 2**30 / 4.0)),
            memory=d.get("memory", "unified"),
            double_buffer=bool(d.get("double_buffer", True)),
            precision=Precision(*d.get("precision", (0.5, 0.5, 1.0))),
            interpret=bool(d.get("interpret", True)),
            use_pallas=bool(d.get("use_pallas", False)),
            mesh_axes=tuple((str(n), int(s)) for n, s in d.get("mesh_axes", ())),
            align_sublane=int(d.get("align_sublane", 8)),
            align_lane=int(d.get("align_lane", 128)),
            quant=(None if d.get("quant") is None
                   else PrecisionSpec.from_dict(d["quant"])),
        )


# ---------------------------------------------------------------------------
# Presets.
# ---------------------------------------------------------------------------

TPU_V5E = HardwareTarget(
    name="tpu_v5e",
    vmem_words=float(TPU_VMEM_WORDS),
    hbm_words=16 * 2**30 / 4.0,  # 16 GiB HBM per v5e chip
    memory="unified",
    precision=BF16_ACC32,
    interpret=True,  # no TPU in this container; set False on real hardware
    use_pallas=True,
)

# GEMMINI defaults from the paper §5: 256 KiB scratchpad of 8-bit words and a
# 64 KiB accumulator of 32-bit words, both double buffered. No MXU lane
# alignment — the systolic array constraint is folded into the LP capacities.
GEMMINI = HardwareTarget(
    name="gemmini",
    vmem_words=256 * 1024 / 4.0,
    acc_words=64 * 1024 / 4.0,
    hbm_words=2**30 / 4.0,  # 1 GiB FireSim DRAM

    memory="split",
    precision=INT8_ACC32,
    interpret=True,
    use_pallas=False,
    align_sublane=1,
    align_lane=1,
)

CPU_INTERPRET = HardwareTarget(
    name="cpu_interpret",
    vmem_words=float(TPU_VMEM_WORDS),
    memory="unified",
    precision=FP32,
    interpret=True,
    use_pallas=False,
)

TARGETS: Dict[str, HardwareTarget] = {
    t.name: t for t in (TPU_V5E, GEMMINI, CPU_INTERPRET)
}


def get_target(name: str) -> HardwareTarget:
    """Look up a preset by name (CLI flags)."""
    try:
        return TARGETS[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware target {name!r}; presets: {sorted(TARGETS)}")
