"""The planning front door: ``Planner(target).plan(op) -> ExecutionPlan``.

One discipline for the whole codebase (paper §3.2 eq. 6, §4.2, §5): solve the
HBL-derived blocking LP against the target's memory-hierarchy model, refine to
integers, then lower the solution to (a) Pallas tile/grid shapes and (b) — for
multi-device targets — a mesh ``ShardingPlan`` with PartitionSpecs.

:class:`Planner` is the single public entry point: ``.plan(op)`` resolves
through the shared :func:`resolve_plan` path (explicit > tuned > analytic),
``.autotune(op)`` runs the measured frontier search of ``repro.plan.autotune``
and ``Planner.cache`` (a process-wide :class:`PlanCache`) saves/loads both the
memoized plans and the autotuner's :class:`~repro.plan.autotune.TuningRecord`
store. The PR-1 module-level functions (``plan``, ``save_plan_cache``,
``load_plan_cache``, ``clear_plan_cache``, ``plan_cache_size``) remain as thin
shims that emit ``DeprecationWarning``.

Plans are memoized process-wide, keyed on the (op, target) value pair; this
replaces the per-kernel ``functools.lru_cache``s the planners used to carry.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from repro.core.bounds import (attention_bound, combined_parallel_bound,
                               single_processor_bound)
from repro.core.conv_model import ConvShape, Precision, ceil_div, round_up
from repro.core.parallel_tiling import optimize_parallel_blocking
from repro.core.sharding_opt import ShardingPlan, plan_conv_sharding
from repro.core.tiling import (Blocking, attention_block_size,
                               conv_kernel_footprints, fit_conv_kernel_tiles,
                               matmul_blocking, optimize_blocking, snap_tile)

from .ops import (AttentionSpec, ConvSpec, MatmulSpec, OpSpec, as_op_spec,
                  op_from_dict)
from .target import HardwareTarget, TPU_V5E

# v2: conv tiles/grid widened from (bN, b_cI, b_cO) / 3-axis grids to the
# spatial-blocked (bN, b_cI, b_cO, b_hO, b_wO) / 5-axis form. v1 conv dumps
# are upgraded on load (spatial kept whole, the old kernel behavior).
# v3: multi-device conv plans carry a ``parallel`` section (the integer
# processor grid the parallel LP chose plus the predicted per-processor
# words and the Thm 2.2/2.3 bound). v2 dumps load with parallel=None.
# v4: attention plans (kind="attention", closed-form (bq, bk) tiles, bound
# from core.bounds.attention_bound, empty blocking). Older dumps load as-is.
# v5: plans carry the per-operand storage dtype map (``dtypes``) derived
# from the op's word-widths — quantized ops record int8 streams / bf16
# stores so tools (roofline byte conversion, bench dumps) need not guess.
# Older dumps load with dtypes=().
# v6: plans may carry a ``tuned`` section ({source, candidates_timed,
# winner_words, winner_seconds}) stamped by the measured autotuner
# (``repro.plan.autotune``) — absent (None) on analytic plans and in every
# older dump.
PLAN_FORMAT_VERSION = 6


def _width_dtype(width: float) -> str:
    """Storage-dtype name of a word width (int8 canonicalizes the 0.25-word
    class; fractional widths such as a quantized KV stream's p_F = 0.25 +
    1/hd keep their numeric form)."""
    names = {1.0: "float32", 0.5: "bfloat16", 0.25: "int8"}
    return names.get(float(width), f"words:{float(width):g}")


def _plan_dtypes(prec: Precision) -> Tuple[Tuple[str, str], ...]:
    """The per-operand dtype map a v5 plan carries. Accumulation is always
    f32 (every kernel's discipline, VRF013)."""
    return (("input", _width_dtype(prec.p_I)),
            ("filter", _width_dtype(prec.p_F)),
            ("output", _width_dtype(prec.p_O)),
            ("accum", "float32"))


@dataclasses.dataclass(frozen=True)
class ParallelSection:
    """The distributed leg of a multi-device conv plan (paper §4.2).

    ``grid`` is the integer processor grid the parallel LP chose (sorted
    (axis, procs) pairs over the distributable axes), ``comm_words`` the
    blocking model's predicted per-processor network words, and
    ``lower_bound`` the combined Thm 2.2/2.3 per-processor bound at the
    target's effective local capacity. ``repro.distributed`` lowers exactly
    this grid onto a mesh; ``DispatchDecision.bound_ratio`` for the
    ``conv2d_dist`` op divides measured inter-device words by this bound."""

    grid: Tuple[Tuple[str, int], ...]  # sorted (axis, procs), procs > 1 only
    P: int
    comm_words: float
    lower_bound: float

    @property
    def grid_dict(self) -> Dict[str, int]:
        return dict(self.grid)

    def to_dict(self) -> Dict[str, Any]:
        return {"grid": [list(kv) for kv in self.grid], "P": self.P,
                "comm_words": self.comm_words,
                "lower_bound": self.lower_bound}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ParallelSection":
        return cls(grid=tuple((str(k), int(v)) for k, v in d["grid"]),
                   P=int(d["P"]), comm_words=float(d["comm_words"]),
                   lower_bound=float(d["lower_bound"]))


@dataclasses.dataclass(frozen=True)
class TunedSection:
    """The measured-autotune provenance a v6 plan carries (None = analytic).

    ``source`` records how the winner was timed — ``"device"`` (best-of-k
    wall clock through ``ops.dispatch_call``) or ``"roofline"`` (the offline
    alpha-beta model ``analysis.roofline.alpha_beta_seconds``); ``winner_words``
    is the winner's exact measured HBM words (== the plan's ``comm_volume``)
    and ``winner_seconds`` its timed/modeled launch seconds."""

    source: str  # "device" | "roofline"
    candidates_timed: int
    winner_words: float
    winner_seconds: float

    def to_dict(self) -> Dict[str, Any]:
        return {"source": self.source,
                "candidates_timed": self.candidates_timed,
                "winner_words": self.winner_words,
                "winner_seconds": self.winner_seconds}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TunedSection":
        return cls(source=str(d["source"]),
                   candidates_timed=int(d["candidates_timed"]),
                   winner_words=float(d["winner_words"]),
                   winner_seconds=float(d["winner_seconds"]))


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Everything a consumer needs to execute one op on one target.

    ``tiles`` is the kernel-facing tuple — (bN, b_cI, b_cO, b_hO, b_wO) for
    conv (spatial blocks included: the kernel loads overlapping halo windows
    of (b_hO - 1) * sh + h_F input rows), (bm, bn, bk) for matmul — and
    ``blocking`` the full 9-axis integer LP solution it was collapsed from.
    ``grid`` is the Pallas launch grid over the padded problem. ``sharding``
    is present iff the target has mesh axes; conv plans for such targets
    additionally carry ``parallel`` — the §4.2 processor grid + predicted
    per-processor words that ``repro.distributed`` executes.
    """

    op: OpSpec
    target: HardwareTarget
    blocking: Tuple[Tuple[str, int], ...]  # sorted (axis, block) pairs
    tiles: Tuple[int, ...]
    grid: Tuple[int, ...]
    comm_volume: float  # modeled slow<->fast words moved
    lower_bound: float  # Thm 2.1 bound at the target's effective capacity
    efficiency: float  # comm_volume / lower_bound
    sharding: Optional[ShardingPlan] = None
    parallel: Optional[ParallelSection] = None
    # v5: per-operand storage dtypes ((operand, dtype) pairs — input/filter/
    # output/accum), derived from the op's effective Precision. () in
    # pre-v5 dumps.
    dtypes: Tuple[Tuple[str, str], ...] = ()
    # v6: measured-autotune provenance; None on analytic plans and in every
    # pre-v6 dump. A tuned plan's tiles/grid/comm_volume are the frontier
    # winner's, so consumers need not special-case it.
    tuned: Optional[TunedSection] = None

    # -- views ---------------------------------------------------------------
    @property
    def blocking_dict(self) -> Dict[str, int]:
        return dict(self.blocking)

    @property
    def precision(self) -> Precision:
        return self.op.prec or self.target.precision

    def to_shape(self) -> ConvShape:
        return self.op.to_shape(self.target.precision)

    def as_blocking(self) -> Blocking:
        return Blocking(self.blocking_dict, self.to_shape())

    def conv_tiles(self) -> Tuple[int, int, int, int, int]:
        if not isinstance(self.op, ConvSpec):
            raise TypeError("conv_tiles() on a non-conv plan")
        return self.tiles  # (bN, b_cI, b_cO, b_hO, b_wO)

    def matmul_tiles(self) -> Tuple[int, int, int]:
        if not isinstance(self.op, MatmulSpec):
            raise TypeError("matmul_tiles() on a non-matmul plan")
        return self.tiles  # (bm, bn, bk)

    def conv_tile(self) -> Dict[str, int]:
        """The collapsed per-axis conv tile (as_conv_tile view)."""
        return self.as_blocking().as_conv_tile()

    def footprints(self) -> Dict[str, float]:
        """Words each array block occupies in fast memory (split-buffer
        accounting: input+filter -> scratchpad, output -> accumulator)."""
        blk = self.as_blocking()
        return {"input": blk.in_block_words, "filter": blk.filt_block_words,
                "output": blk.out_block_words}

    def kernel_footprints(self) -> Dict[str, float]:
        """Words the lowered conv2d kernel actually allocates per tile: the
        exact halo window ((b_hO - 1) * sh + h_F) x ((b_wO - 1) * sw + w_F)
        for the input and the full unrolled (h_F, w_F) filter block — the
        view ``fit_conv_kernel_tiles`` clamped the tiles against."""
        if not isinstance(self.op, ConvSpec):
            raise TypeError("kernel_footprints() on a non-conv plan")
        return conv_kernel_footprints(self.to_shape(), self.tiles)

    def pallas_specs(self):
        """(grid, in_specs, out_specs) mirroring what the kernels lower.
        Lazy pallas import so plan inspection works without a jax runtime.

        Both kernels keep their inputs in ANY/HBM memory and stream
        double-buffered DMA windows into VMEM scratch themselves (the conv
        input needs overlapping halo windows, which no blocked BlockSpec can
        express), so the in_specs carry only the memory space; the output
        spec is blocked as before."""
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        if isinstance(self.op, AttentionSpec):
            raise TypeError("pallas_specs() on an attention plan: the flash "
                            "kernels own their BlockSpecs (tiles = (bq, bk))")
        in_specs = [pl.BlockSpec(memory_space=pltpu.ANY),
                    pl.BlockSpec(memory_space=pltpu.ANY)]
        if isinstance(self.op, MatmulSpec):
            bm, bn, bk = self.tiles
            return (self.grid, in_specs,
                    pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
        bN, b_cI, b_cO, b_hO, b_wO = self.tiles
        return (self.grid, in_specs,
                pl.BlockSpec((bN, b_cO, b_hO, b_wO),
                             lambda n, co, h, w, ci: (n, co, h, w)))

    # -- (de)serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "version": PLAN_FORMAT_VERSION,
            "op": self.op.to_dict(),
            "target": self.target.to_dict(),
            "blocking": [list(kv) for kv in self.blocking],
            "tiles": list(self.tiles),
            "grid": list(self.grid),
            "comm_volume": self.comm_volume,
            "lower_bound": self.lower_bound,
            "efficiency": self.efficiency,
            "sharding": None,
            "parallel": (None if self.parallel is None
                         else self.parallel.to_dict()),
            "dtypes": [list(kv) for kv in self.dtypes],
            "tuned": None if self.tuned is None else self.tuned.to_dict(),
        }
        if self.sharding is not None:
            s = self.sharding
            d["sharding"] = {
                "binding": dict(s.binding),
                "mesh_axes": [list(ax) for ax in s.mesh_axes],
                "comm_per_processor": s.comm_per_processor,
                "grid": dict(s.grid),
            }
        return d

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExecutionPlan":
        if d.get("version", 1) > PLAN_FORMAT_VERSION:
            raise ValueError(f"plan format {d['version']} is newer than "
                             f"supported {PLAN_FORMAT_VERSION}")
        if d.get("version", 1) < 2 and d["op"].get("kind") == "conv":
            # v1 conv plans: 3-tuple tiles, (nN, n_cO, n_cI) grid. Upgrade to
            # the spatial-blocked form with spatial kept whole.
            op = d["op"]
            d = dict(d, tiles=list(d["tiles"]) + [op["h_O"], op["w_O"]],
                     grid=[d["grid"][0], d["grid"][1], 1, 1, d["grid"][2]])
        sharding = None
        if d.get("sharding") is not None:
            s = d["sharding"]
            sharding = ShardingPlan(
                binding=dict(s["binding"]),
                mesh_axes=tuple((str(n), int(sz)) for n, sz in s["mesh_axes"]),
                comm_per_processor=float(s["comm_per_processor"]),
                grid={k: int(v) for k, v in s["grid"].items()},
            )
        parallel = None
        if d.get("parallel") is not None:  # absent in v1/v2 dumps
            parallel = ParallelSection.from_dict(d["parallel"])
        tuned = None
        if d.get("tuned") is not None:  # absent in pre-v6 dumps
            tuned = TunedSection.from_dict(d["tuned"])
        return cls(
            op=op_from_dict(d["op"]),
            target=HardwareTarget.from_dict(d["target"]),
            blocking=tuple((str(k), int(v)) for k, v in d["blocking"]),
            tiles=tuple(int(v) for v in d["tiles"]),
            grid=tuple(int(v) for v in d["grid"]),
            comm_volume=float(d["comm_volume"]),
            lower_bound=float(d["lower_bound"]),
            efficiency=float(d["efficiency"]),
            sharding=sharding,
            parallel=parallel,
            dtypes=tuple((str(k), str(v))
                         for k, v in d.get("dtypes", [])),
            tuned=tuned,
        )

    @classmethod
    def from_json(cls, s: str) -> "ExecutionPlan":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# The process-wide plan cache (one memoizer for every consumer).
# ---------------------------------------------------------------------------

_CACHE: Dict[Tuple[OpSpec, HardwareTarget], ExecutionPlan] = {}
_CACHE_LOCK = threading.Lock()
# Bounded like the per-kernel lru_caches it replaces (256 + 512): long-running
# servers planning many distinct shapes must not grow memory without limit.
PLAN_CACHE_MAX = 1024


class PlanCache:
    """Facade over the process-wide plan memoizer *and* the autotuner's
    TuningRecord store — one save/load/clear/size surface, reached as
    ``Planner.cache`` (a process singleton: every instance views the same
    state). The JSON dump is a ``{"format", "plans", "tuning"}`` dict;
    pre-v6 dumps (a bare list of plan dicts) still load."""

    def size(self) -> int:
        """Number of memoized plans (analytic and materialized tuned)."""
        with _CACHE_LOCK:
            return len(_CACHE)

    def clear(self) -> None:
        """Drop every memoized plan and every tuning record. The autotune
        search counter is *not* reset — re-searches stay observable across
        a clear()/load() round trip."""
        with _CACHE_LOCK:
            _CACHE.clear()
        from . import autotune as _autotune

        _autotune.clear_records()

    def save(self, path: str) -> int:
        """Dump memoized plans + tuning records; returns entries written."""
        from . import autotune as _autotune

        with _CACHE_LOCK:
            plans = list(_CACHE.values())
        records = _autotune.records()
        with open(path, "w") as f:
            json.dump({"format": PLAN_FORMAT_VERSION,
                       "plans": [p.to_dict() for p in plans],
                       "tuning": [r.to_dict() for r in records]}, f, indent=1)
        return len(plans) + len(records)

    def load(self, path: str) -> int:
        """Pre-populate plans + tuning records from a dump; returns entries
        loaded. Restored tuning records make ``resolve_plan`` serve tuned
        plans without re-searching (the zero-re-search serving contract)."""
        with open(path) as f:
            dump = json.load(f)
        plan_dicts = dump if isinstance(dump, list) else dump.get("plans", [])
        n = 0
        with _CACHE_LOCK:
            for d in plan_dicts:
                p = ExecutionPlan.from_dict(d)
                _CACHE.setdefault((p.op, p.target), p)
                n += 1
        if isinstance(dump, dict):
            from . import autotune as _autotune

            for d in dump.get("tuning", []):
                _autotune.install_record(_autotune.TuningRecord.from_dict(d))
                n += 1
        return n


def _warn_legacy(old: str, new: str) -> None:
    warnings.warn(f"legacy planning API: {old} is deprecated; use {new}",
                  DeprecationWarning, stacklevel=3)


def clear_plan_cache() -> None:
    _warn_legacy("clear_plan_cache()", "Planner.cache.clear()")
    Planner.cache.clear()


def plan_cache_size() -> int:
    _warn_legacy("plan_cache_size()", "Planner.cache.size()")
    return Planner.cache.size()


def save_plan_cache(path: str) -> int:
    _warn_legacy("save_plan_cache()", "Planner.cache.save()")
    return Planner.cache.save(path)


def load_plan_cache(path: str) -> int:
    _warn_legacy("load_plan_cache()", "Planner.cache.load()")
    return Planner.cache.load(path)


# ---------------------------------------------------------------------------
# Lowering: OpSpec x HardwareTarget -> ExecutionPlan.
# ---------------------------------------------------------------------------

def _conv_align(shape: ConvShape, target: HardwareTarget) -> Optional[Dict[str, int]]:
    align: Dict[str, int] = {}
    if target.align_lane > 1:
        align["cO"] = min(target.align_lane, shape.c_O)
    if target.align_sublane > 1:
        align["cI"] = min(target.align_sublane, shape.c_I)
    return align or None


def _plan_conv(op: ConvSpec, target: HardwareTarget) -> ExecutionPlan:
    shape = op.to_shape(target.precision)
    mem = target.memory_model()
    blk = optimize_blocking(shape, mem, align=_conv_align(shape, target))
    t = blk.as_conv_tile()
    # Kernel tiles carry the LP's spatial choice: the kernel blocks h_O/w_O
    # with overlapping input halos of (b - 1) * s + f rows/cols. The lifted
    # LP footprint can undercount the kernel's (it may block filter taps the
    # kernel unrolls in full), so clamp against the exact halo-window model.
    tiles = fit_conv_kernel_tiles(shape, (
        max(1, min(op.N, t["N"])), t["cI"], t["cO"],
        max(1, min(op.h_O, t["hO"])), max(1, min(op.w_O, t["wO"]))), mem)
    grid = (ceil_div(op.N, tiles[0]), ceil_div(op.c_O, tiles[2]),
            ceil_div(op.h_O, tiles[3]), ceil_div(op.w_O, tiles[4]),
            ceil_div(op.c_I, tiles[1]))
    vol = blk.comm_volume()
    lb = single_processor_bound(shape, mem.M_eff).value
    sharding = None
    parallel = None
    if target.mesh_axes:
        sharding = plan_conv_sharding(shape, target.mesh_axes)
        parallel = _parallel_section(shape, target.n_devices, mem.M_eff)
    return ExecutionPlan(
        op=op, target=target, blocking=tuple(sorted(blk.b.items())),
        tiles=tiles, grid=grid, comm_volume=vol, lower_bound=lb,
        efficiency=vol / max(lb, 1.0), sharding=sharding, parallel=parallel,
        dtypes=_plan_dtypes(op.prec or target.precision))


def _parallel_section(shape: ConvShape, P: int, M_eff: float
                      ) -> ParallelSection:
    """The §4.2 leg of a multi-device conv plan: the parallel LP's integer
    grid restricted to the axes ``repro.distributed`` can lower, its modeled
    per-processor words, and the combined Thm 2.2/2.3 bound."""
    # local import keeps repro.plan importable without the distributed pkg
    from repro.distributed.geometry import DIST_AXES

    pb = optimize_parallel_blocking(shape, P, restrict_axes=DIST_AXES)
    return ParallelSection(
        grid=tuple(sorted((k, v) for k, v in pb.grid.items() if v > 1)),
        P=pb.P,
        comm_words=pb.comm_per_processor(),
        lower_bound=combined_parallel_bound(shape, P, M_eff))


def _fit_matmul_tiles(tiles: Tuple[int, int, int], prec, mem,
                      target: HardwareTarget) -> Tuple[int, int, int]:
    """Shrink snapped (bm, bn, bk) until the GEMM tile footprint
    ``bm*bk*p_I + bk*bn*p_F + bm*bn*p_O`` fits the double-buffered budget
    ``mem.M_eff`` (the constraint ``optimize_blocking`` solved under, which
    alignment snapping can violate). Alignment floors are respected."""
    bm, bn, bk = tiles
    aligns = (max(target.align_sublane, 1), max(target.align_lane, 1),
              max(target.align_lane, 1))

    def fp(t):
        return (t[0] * t[2] * prec.p_I + t[2] * t[1] * prec.p_F
                + t[0] * t[1] * prec.p_O)

    def shrink(v, al):
        nv = (v // 2 // al) * al if v // 2 >= al else min(v, al)
        return max(nv, 1)

    b = [bm, bn, bk]
    while fp(b) > mem.M_eff:
        best_i, best_gain = None, 0.0
        for i, al in enumerate(aligns):
            nv = shrink(b[i], al)
            if nv >= b[i]:
                continue
            trial = list(b)
            trial[i] = nv
            gain = fp(b) - fp(trial)
            if gain > best_gain:
                best_i, best_gain = i, gain
        if best_i is None:
            break  # nothing left to shrink; keep the least-bad tiles
        b[best_i] = shrink(b[best_i], aligns[best_i])
    return b[0], b[1], b[2]


def _plan_matmul(op: MatmulSpec, target: HardwareTarget) -> ExecutionPlan:
    prec = op.prec or target.precision
    mem = target.memory_model()
    blk = matmul_blocking(op.m, op.n, op.k, mem=mem, prec=prec,
                          align_m=target.align_sublane,
                          align_n=target.align_lane,
                          align_k=target.align_lane)
    bm, bk, bn = blk.b["N"], blk.b["cI"], blk.b["cO"]
    bm = snap_tile(bm, target.align_sublane, op.m)
    bn = snap_tile(bn, target.align_lane, op.n)
    bk = snap_tile(bk, target.align_lane, op.k)
    # clamp so the BlockSpecs divide the padded problem evenly
    bm = min(bm, round_up(op.m, max(target.align_sublane, 1)))
    bn = min(bn, round_up(op.n, max(target.align_lane, 1)))
    bk = min(bk, round_up(op.k, max(target.align_lane, 1)))
    # MXU alignment can inflate a tile past the LP's feasible point (e.g. the
    # lane snap turns b_k = 1 into 128 on tall-skinny im2col GEMMs), silently
    # breaking the double-buffered capacity discipline the kernel allocates
    # under — caught by the repro.verify static auditor. Re-fit like
    # fit_conv_kernel_tiles: halve the best-gain axis (alignment floors kept)
    # until the A + B + accumulator footprint obeys M_eff again.
    bm, bn, bk = _fit_matmul_tiles((bm, bn, bk), prec, mem, target)
    tiles = (bm, bn, bk)
    grid = (ceil_div(op.m, bm), ceil_div(op.n, bn), ceil_div(op.k, bk))
    shape = op.to_shape(target.precision)
    vol = blk.comm_volume()
    lb = single_processor_bound(shape, mem.M_eff).value
    sharding = None
    if target.mesh_axes:
        sharding = plan_conv_sharding(shape, target.mesh_axes,
                                      shardable=("N", "cI", "cO"))
    return ExecutionPlan(
        op=op, target=target, blocking=tuple(sorted(blk.b.items())),
        tiles=tiles, grid=grid, comm_volume=vol, lower_bound=lb,
        efficiency=vol / max(lb, 1.0), sharding=sharding,
        dtypes=_plan_dtypes(prec))


def _plan_attention(op: AttentionSpec, target: HardwareTarget) -> ExecutionPlan:
    """Closed-form attention plan: the flash schedule's (bq, bk) capacity
    argument (``core.tiling.attention_block_size``) instead of the conv LP,
    bounded by Thm 2.1 applied to attention's two GEMMs
    (``core.bounds.attention_bound``). GQA group folding is accounted here:
    each of the B*KV kernel batch rows carries g = H/KV stacked query groups,
    so k/v stream once per folded q tile — exactly the launch geometry
    ``kernels.flash_attention`` lowers."""
    prec = op.prec or target.precision
    mem = target.memory_model()
    blk = attention_block_size(op.hd, mem.M_eff, p_kv=prec.p_F)
    g = max(1, op.H // max(op.KV, 1))
    lqf = g * op.Lq  # the folded query axis of one (batch, kv-head) row
    sub = max(target.align_sublane, 1)
    bq = min(blk, round_up(lqf, sub))
    bk = min(blk, round_up(op.Lk, sub))
    n_q, n_k = ceil_div(lqf, bq), ceil_div(op.Lk, bk)
    rows = op.B * op.KV
    vol = (prec.p_I * rows * n_q * bq * op.hd          # q tiles, loaded once
           + 2.0 * prec.p_F * rows * n_q * n_k * bk * op.hd  # k/v per q tile
           + prec.p_O * rows * n_q * bq * op.hd)       # output stores
    lb = attention_bound(op.B, op.H, op.KV, op.Lq, op.Lk, op.hd,
                         mem.M_eff, prec).value
    return ExecutionPlan(
        op=op, target=target, blocking=(), tiles=(bq, bk),
        grid=(rows, n_q, n_k), comm_volume=vol, lower_bound=lb,
        efficiency=vol / max(lb, 1.0), dtypes=_plan_dtypes(prec))


def warn_legacy_kernel_kwargs(fn: str, **passed) -> None:
    """Emit the one-PR deprecation warning for retired kernel kwargs
    (``target=``/``tiles=``): execution policy now rides a single
    ``ctx: ExecutionContext``. (``plan=`` stays — it is the dispatcher's and
    the autotuner's explicit-plan handoff.) Lint VRF015 flags new in-repo
    uses of the legacy kwargs."""
    names = [k for k, v in sorted(passed.items()) if v is not None]
    if names:
        warnings.warn(
            f"legacy kernel kwargs {names} on {fn}(): pass "
            "ctx=ExecutionContext(target=..., interpret=...) instead",
            DeprecationWarning, stacklevel=3)


def resolve_kernel_plan(
    op: OpSpec,
    plan: Optional[ExecutionPlan] = None,
    target: Optional[HardwareTarget] = None,
    tiles: Optional[Tuple[int, ...]] = None,
    interpret: Optional[bool] = None,
    ctx: Optional[Any] = None,
) -> Tuple[Tuple[int, ...], bool]:
    """Shared kernel-side resolution of (tiles, interpret).

    ``op`` is the spec the kernel built from its actual arrays (precision
    included). Priority: explicit legacy ``tiles``, then a caller-supplied
    ``plan`` (validated for geometry and precision), then a fresh plan via
    :func:`resolve_plan` — for ``ctx.target`` (autotune-aware, any object
    with ``target``/``interpret``/``autotune`` attributes; duck-typed so the
    kernel layer needs no ``repro.ops`` import) or legacy ``target``. One
    implementation so conv2d/matmul/... cannot diverge."""
    if ctx is not None:
        if target is None:
            target = ctx.target
        if interpret is None:
            interpret = getattr(ctx, "interpret", None)
    if tiles is None and plan is None:
        plan, _ = resolve_plan(op, target or TPU_V5E,
                               autotune=getattr(ctx, "autotune", None))
    if plan is not None:
        if not isinstance(plan.op, type(op)) or (
                dataclasses.replace(plan.op, prec=None)
                != dataclasses.replace(op, prec=None)):
            raise ValueError(f"plan was made for {plan.op}, not {op}")
        data_p = (op.prec or plan.target.precision).p_I
        if plan.precision.p_I < data_p:
            raise ValueError(
                f"plan assumed {plan.precision.p_I}-word input streams but "
                f"the data is {data_p} words: its tiles would overflow the "
                "modeled fast-memory budget")
    if interpret is None:
        if plan is not None:
            interpret = plan.target.interpret
        else:
            interpret = target.interpret if target is not None else True
    return (tiles if tiles is not None else plan.tiles), interpret


_PLAN_AUDIT_HOOKS: List[Callable[[ExecutionPlan], None]] = []


def register_plan_audit_hook(fn: Callable[[ExecutionPlan], None]) -> None:
    """Register ``fn`` to be called on every freshly built ExecutionPlan
    (cache hits skip it — the cached object already passed). Hooks raise to
    reject a plan; ``repro.verify.audit.install_plan_audit`` uses this to
    run the static plan validator at construction time. Idempotent."""
    if fn not in _PLAN_AUDIT_HOOKS:
        _PLAN_AUDIT_HOOKS.append(fn)


def _memoize_plan(key: Tuple[OpSpec, HardwareTarget], built: ExecutionPlan
                  ) -> ExecutionPlan:
    with _CACHE_LOCK:
        while len(_CACHE) >= PLAN_CACHE_MAX:
            _CACHE.pop(next(iter(_CACHE)))  # FIFO eviction of the oldest plan
        # first writer wins so concurrent planners still converge on one object
        return _CACHE.setdefault(key, built)


def analytic_plan(op: Union[OpSpec, ConvShape],
                  target: HardwareTarget = TPU_V5E) -> ExecutionPlan:
    """Solve the blocking LP for one (op, target) pair. Memoized: repeated
    calls with an equal pair return the identical ExecutionPlan object. A
    tuned plan previously memoized for the pair (its ``tuned`` section set)
    is returned as-is — the cache holds one winner per key."""
    op = as_op_spec(op)
    key = (op, target)
    with _CACHE_LOCK:
        cached = _CACHE.get(key)
    if cached is not None:
        return cached
    if isinstance(op, ConvSpec):
        built = _plan_conv(op, target)
    elif isinstance(op, AttentionSpec):
        built = _plan_attention(op, target)
    else:
        built = _plan_matmul(op, target)
    for hook in _PLAN_AUDIT_HOOKS:
        hook(built)
    return _memoize_plan(key, built)


def resolve_plan(
    op: Union[OpSpec, ConvShape],
    target: HardwareTarget = TPU_V5E,
    explicit: Optional[ExecutionPlan] = None,
    autotune: Optional[Any] = None,
) -> Tuple[ExecutionPlan, str]:
    """THE shared plan-resolution path — ``ctx.plan()``, ``ops.explain``,
    ``resolve_kernel_plan`` and :class:`Planner` all funnel through here, so
    an explicitly-passed plan, a cached tuned plan, and a fresh analytic plan
    are distinguishable everywhere. Returns ``(plan, source)`` with source in
    ``"explicit"`` (caller-supplied, returned untouched) > ``"tuned"`` (a
    TuningRecord exists for the pair — or ``autotune`` is a truthy
    :class:`~repro.plan.autotune.AutotunePolicy` / ``True`` and the op is
    searchable, running the frontier search once) > ``"analytic"``."""
    if explicit is not None:
        return explicit, "explicit"
    op = as_op_spec(op)
    from . import autotune as _autotune

    tuned = _autotune.lookup_plan(op, target)
    if tuned is not None:
        return tuned, "tuned"
    policy = _autotune.AutotunePolicy.coerce(autotune)
    if policy is not None and _autotune.supports(op, target):
        return _autotune.autotune(op, target, policy=policy), "tuned"
    return analytic_plan(op, target), "analytic"


def plan(op: Union[OpSpec, ConvShape], target: HardwareTarget = TPU_V5E
         ) -> ExecutionPlan:
    """Deprecated module-level entry point (use ``Planner(target).plan(op)``):
    resolves through :func:`resolve_plan`, so a tuned plan cached for the
    pair is returned over the analytic one."""
    _warn_legacy("plan()", "Planner(target).plan(op)")
    return resolve_plan(op, target)[0]


class Planner:
    """The one public planning front door.

    ``Planner(target, quant=None, autotune=None)``:

      * ``quant``    - optional quantized storage policy; a non-None spec is
                       attached via ``target.with_quant`` so every plan prices
                       the quantized stream widths;
      * ``autotune`` - ``None`` (analytic only), ``True`` (default
                       :class:`~repro.plan.autotune.AutotunePolicy`), or a
                       policy instance: ``.plan()`` then runs the measured
                       frontier search on first sight of a searchable op and
                       serves the tuned winner from the TuningRecord store
                       afterwards.

    ``.plan(op)`` resolves (tuned > analytic); ``.resolve(op, explicit=...)``
    additionally reports the plan source; ``.autotune(op)`` forces a search.
    ``Planner.cache`` is the process-wide :class:`PlanCache` (save/load/
    clear/size), shared by every instance."""

    cache: PlanCache = PlanCache()

    def __init__(self, target: HardwareTarget = TPU_V5E, quant: Any = None,
                 autotune: Any = None):
        if quant is not None:
            target = target.with_quant(quant)
        self.target = target
        from . import autotune as _autotune

        self.autotune_policy = _autotune.AutotunePolicy.coerce(autotune)

    def plan(self, op: Union[OpSpec, ConvShape]) -> ExecutionPlan:
        return self.resolve(op)[0]

    def resolve(self, op: Union[OpSpec, ConvShape],
                explicit: Optional[ExecutionPlan] = None
                ) -> Tuple[ExecutionPlan, str]:
        return resolve_plan(op, self.target, explicit=explicit,
                            autotune=self.autotune_policy)

    def autotune(self, op: Union[OpSpec, ConvShape],
                 policy: Any = None) -> ExecutionPlan:
        """Run (or reuse) the measured frontier search for ``op`` and return
        the tuned plan. Raises TypeError for ops the frontier enumerator
        cannot search (attention plans are closed-form)."""
        from . import autotune as _autotune

        pol = _autotune.AutotunePolicy.coerce(
            policy if policy is not None
            else (self.autotune_policy or True))
        return _autotune.autotune(op, self.target, policy=pol)
