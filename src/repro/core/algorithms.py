"""Modeled communication volumes for the convolution algorithms the paper
compares (§3.2 Fig 2, §4.2 Fig 3): naive, im2col, LP blocking, Winograd, FFT.

These are *symbolic* volume models, as in the paper ("we symbolically
calculate the amount of communication each one requires"), using:
  * the near-optimal GEMM volume  2 sqrt(p_A p_B p_C) mnk / sqrt(M) + IO
    ([12] Kwasniewski et al., COSMA, adapted to mixed precision), and
  * the Hong-Kung FFT bound  Theta(n log n / log M)  ([7] Elango).

All volumes are in words. The single-processor model charges HBM<->cache
traffic; the parallel model charges network words per processor.
"""

from __future__ import annotations

import math
from typing import Dict

from .bounds import combined_parallel_bound, single_processor_bound
from .conv_model import ConvShape
from .parallel_tiling import optimize_parallel_blocking
from .tiling import MemoryModel, optimize_blocking


# ---------------------------------------------------------------------------
# Single-processor volumes (words), cache of M words.
# ---------------------------------------------------------------------------

def gemm_volume(m: int, n: int, k: int, M: float,
                p_A: float = 1.0, p_B: float = 1.0, p_C: float = 1.0) -> float:
    """Near-optimal single-processor GEMM communication (COSMA-style)."""
    io = p_A * m * k + p_B * k * n + p_C * m * n
    return 2.0 * math.sqrt(p_A * p_B * p_C) * m * n * k / math.sqrt(M) + io


def naive_volume(shape: ConvShape) -> float:
    """No blocking: every update streams its input and filter operand from
    slow memory; the output element is register-resident across the innermost
    reduction only."""
    p = shape.prec
    return (p.p_I + p.p_F) * shape.G + 2.0 * p.p_O * shape.output_size


def im2col_volume(shape: ConvShape, M: float) -> float:
    """Materialize the im2col matrix (read input, write the expanded matrix),
    then GEMM: (N wO hO) x (cI wF hF) times (cI wF hF) x cO."""
    p = shape.prec
    m = shape.N * shape.w_O * shape.h_O
    k = shape.c_I * shape.w_F * shape.h_F
    n = shape.c_O
    expand = p.p_I * (shape.input_size + m * k)  # read input + write expanded
    return expand + gemm_volume(m, n, k, M, p.p_I, p.p_F, p.p_O)


def blocking_volume(shape: ConvShape, M: float) -> float:
    """The paper's LP blocking (§3.2) under a unified cache of M words."""
    mem = MemoryModel(M=M, mode="unified", double_buffer=False)
    return optimize_blocking(shape, mem).comm_volume()


def fft_volume(shape: ConvShape, M: float) -> float:
    """FFT convolution: 2D FFTs of input (per image x channel) and filter
    (padded), frequency-domain batched GEMM over channels per frequency,
    inverse FFTs of the output. Complex data doubles the word count."""
    p = shape.prec
    wi, hi = shape.w_I, shape.h_I
    pts = wi * hi
    logM = max(math.log2(M), 1.0)

    def fft_words(batch: int, n_pts: int, prec: float) -> float:
        # Hong-Kung: n log2(n) / log2(M) per transform, complex => 2x words
        return 2.0 * prec * batch * n_pts * math.log2(max(n_pts, 2)) / logM

    vol = fft_words(shape.N * shape.c_I, pts, p.p_I)  # forward input FFTs
    vol += fft_words(shape.c_I * shape.c_O, pts, p.p_F)  # filter FFTs (padded)
    # frequency-domain contraction: for each of the pts frequencies, an
    # (N x cI) @ (cI x cO) GEMM with complex operands
    vol += pts * gemm_volume(shape.N, shape.c_O, shape.c_I, M,
                             2 * p.p_I, 2 * p.p_F, 2 * p.p_O)
    vol += fft_words(shape.N * shape.c_O, pts, p.p_O)  # inverse output FFTs
    return vol


def winograd_volume(shape: ConvShape, M: float, m_tile: int = 2) -> float:
    """Winograd F(m x m, r x r): per-tile transforms + (m+r-1)^2 batched GEMMs
    of (N * ceil(wO/m) * ceil(hO/m)) x cI x cO. Only exact for stride 1; for
    strided convs we fall back to stride-decomposed Winograd (volume scales by
    the stride product)."""
    p = shape.prec
    r = max(shape.w_F, shape.h_F)
    t = m_tile + r - 1  # transformed tile side
    tiles = shape.N * math.ceil(shape.w_O / m_tile) * math.ceil(shape.h_O / m_tile)
    # input transform: read t^2 window, write t^2 transformed, per (tile, cI)
    vol = p.p_I * tiles * shape.c_I * (2.0 * t * t)
    # filter transform: per (cI, cO), r^2 -> t^2
    vol += p.p_F * shape.c_I * shape.c_O * (r * r + t * t)
    # t^2 independent GEMMs: tiles x cI x cO
    vol += t * t * gemm_volume(tiles, shape.c_O, shape.c_I, M, p.p_I, p.p_F, p.p_O)
    # inverse transform: t^2 -> m^2 per (tile, cO)
    vol += p.p_O * tiles * shape.c_O * (t * t + m_tile * m_tile)
    return vol * (shape.sw * shape.sh)


def single_processor_volumes(shape: ConvShape, M: float) -> Dict[str, float]:
    """All algorithms + the Thm 2.1 lower bound, for Fig-2-style comparisons."""
    return {
        "lower_bound": single_processor_bound(shape, M).value,
        "naive": naive_volume(shape),
        "im2col": im2col_volume(shape, M),
        "blocking": blocking_volume(shape, M),
        "winograd": winograd_volume(shape, M),
        "fft": fft_volume(shape, M),
    }


# ---------------------------------------------------------------------------
# Parallel volumes (words per processor), P processors.
# ---------------------------------------------------------------------------

def gemm_volume_parallel(m: int, n: int, k: int, P: int,
                         p_A: float = 1.0, p_B: float = 1.0, p_C: float = 1.0) -> float:
    """Per-processor 2.5D/COSMA GEMM volume: ~ 2 (p^3 mnk / P)^{1/2}... using
    the memory-independent form  X >= 2 (p_A p_B p_C)^{1/3} (mnk/P)^{2/3} /
    ... simplified to the attainable 3D-algorithm volume 3 (mnk/P)^{2/3}."""
    pf = (p_A * p_B * p_C) ** (1.0 / 3.0)
    return 3.0 * pf * (m * n * k / P) ** (2.0 / 3.0)


def naive_volume_parallel(shape: ConvShape, P: int) -> float:
    """Owner-computes over outputs with no blocking design: each processor
    gathers the full filter and its input slab."""
    p = shape.prec
    return (p.p_F * shape.filter_size
            + p.p_I * shape.input_size / P
            + p.p_O * shape.output_size / P)


def im2col_volume_parallel(shape: ConvShape, P: int) -> float:
    """Only inter-processor words count in the distributed model: the im2col
    expansion is processor-local (each rank expands its own input shard), so
    the network cost is the distributed GEMM."""
    p = shape.prec
    m = shape.N * shape.w_O * shape.h_O
    k = shape.c_I * shape.w_F * shape.h_F
    n = shape.c_O
    return gemm_volume_parallel(m, n, k, P, p.p_I, p.p_F, p.p_O)


def blocking_volume_parallel(shape: ConvShape, P: int) -> float:
    return optimize_parallel_blocking(shape, P).comm_per_processor()


def fft_volume_parallel(shape: ConvShape, P: int) -> float:
    p = shape.prec
    pts = shape.w_I * shape.h_I
    # distributed FFT: each transform needs an all-to-all of its data (~1x
    # volume per butterfly stage across the processor boundary; model: 2 passes)
    vol = 2.0 * (2 * p.p_I * shape.N * shape.c_I * pts) / P
    vol += 2.0 * (2 * p.p_F * shape.c_I * shape.c_O * pts) / P
    # frequency-domain contraction: pts independent (N x cI)@(cI x cO) GEMMs,
    # modeled as one batched GEMM with m = N*pts distributed over P
    vol += gemm_volume_parallel(shape.N * pts, shape.c_O, shape.c_I, P,
                                2 * p.p_I, 2 * p.p_F, 2 * p.p_O)
    vol += 2.0 * (2 * p.p_O * shape.N * shape.c_O * pts) / P
    return vol


def winograd_volume_parallel(shape: ConvShape, P: int, m_tile: int = 2) -> float:
    p = shape.prec
    r = max(shape.w_F, shape.h_F)
    t = m_tile + r - 1
    tiles = shape.N * math.ceil(shape.w_O / m_tile) * math.ceil(shape.h_O / m_tile)
    vol = 2.0 * p.p_I * tiles * shape.c_I * t * t / P
    vol += 2.0 * p.p_F * shape.c_I * shape.c_O * t * t / P
    vol += t * t * gemm_volume_parallel(tiles, shape.c_O, shape.c_I, P,
                                        p.p_I, p.p_F, p.p_O)
    vol += 2.0 * p.p_O * tiles * shape.c_O * m_tile * m_tile / P
    return vol * (shape.sw * shape.sh)


def parallel_volumes(shape: ConvShape, P: int, M: float) -> Dict[str, float]:
    return {
        "lower_bound": combined_parallel_bound(shape, P, M),
        "naive": naive_volume_parallel(shape, P),
        "im2col": im2col_volume_parallel(shape, P),
        "blocking": blocking_volume_parallel(shape, P),
        "winograd": winograd_volume_parallel(shape, P),
        "fft": fft_volume_parallel(shape, P),
    }
