"""Closed-form communication lower bounds (paper Theorems 2.1, 2.2, 2.3).

All bounds are in *words* (32-bit). Mixed precision enters through
(p_I, p_F, p_O) and the constant C_p.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

from .conv_model import ConvShape, Precision


def C_p(prec: Precision) -> float:
    """The precision constant of Thm 2.1:

        C_p = p_T^2 / 4                 if the triangle condition holds
        C_p = p_j (p_k + p_l)           if p_j > p_k + p_l for some j

    In the standard case p_I = p_F = p_O = 1, C_p = 9/4.
    """
    if prec.triangle_ok():
        return prec.p_T ** 2 / 4.0
    p = prec.as_tuple()
    for j in range(3):
        rest = sum(p) - p[j]
        if p[j] > rest:
            return p[j] * rest
    raise AssertionError("unreachable")


@dataclasses.dataclass(frozen=True)
class BoundTerms:
    """The individual max{...} terms of a bound, in words."""

    terms: Dict[str, float]

    @property
    def value(self) -> float:
        return max(self.terms.values())

    @property
    def dominant(self) -> str:
        return max(self.terms, key=self.terms.get)  # type: ignore[arg-type]


# ---------------------------------------------------------------------------
# Theorem 2.1 — single processor, cache size M words.
# ---------------------------------------------------------------------------

def single_processor_bound(shape: ConvShape, M: float) -> BoundTerms:
    """X >= max{ p_I|I| + p_F|F| + p_O|O|,
                 C_p G / M - M,
                 2 (p_I p_F p_O)^{1/2} (sw sh)^{1/2} G (w_F h_F M)^{-1/2} - 2M }."""
    p = shape.prec
    G = shape.G
    memfree = p.p_I * shape.input_size + p.p_F * shape.filter_size + p.p_O * shape.output_size
    per_M = C_p(p) * G / M - M
    small_filter = (
        2.0 * math.sqrt(p.p_I * p.p_F * p.p_O) * math.sqrt(shape.sw * shape.sh) * G
        / math.sqrt(shape.w_F * shape.h_F * M)
        - 2.0 * M
    )
    return BoundTerms(
        {"memory_independent": memfree, "per_M": per_M, "small_filter": small_filter}
    )


def small_filter_regime(shape: ConvShape, M: float) -> bool:
    """The third bound eclipses the second iff w_F h_F < 64 M sw sh / 81
    (paper §3.1, standard precision)."""
    return shape.w_F * shape.h_F < 64.0 * M * shape.sw * shape.sh / 81.0


# ---------------------------------------------------------------------------
# Theorem 2.2 — P distributed processors, each with M words.
# ---------------------------------------------------------------------------

def parallel_bound(shape: ConvShape, P: int, M: float) -> BoundTerms:
    """X >= max{ C_p G/(P M) - M,
                 2 (p_I p_F p_O)^{1/2}(sw sh)^{1/2} G / (P (w_F h_F M)^{1/2}) - 2M }."""
    p = shape.prec
    G = shape.G
    per_M = C_p(p) * G / (P * M) - M
    small_filter = (
        2.0 * math.sqrt(p.p_I * p.p_F * p.p_O) * math.sqrt(shape.sw * shape.sh) * G
        / (P * math.sqrt(shape.w_F * shape.h_F * M))
        - 2.0 * M
    )
    return BoundTerms({"per_M": per_M, "small_filter": small_filter})


# ---------------------------------------------------------------------------
# Theorem 2.3 — memory-independent (2.5D-style), load-balanced start.
# ---------------------------------------------------------------------------

def memory_independent_parallel_bound(shape: ConvShape, P: int) -> BoundTerms:
    """X >= (p_I p_F p_O)^{1/3} max{ (G/P)^{1/2},
                                     (G sw sh)^{2/3} / (P w_F h_F)^{2/3} } - A_P/P."""
    p = shape.prec
    G = shape.G
    A_P = max(
        p.p_I * shape.input_size, p.p_F * shape.filter_size, p.p_O * shape.output_size
    )
    pf = (p.p_I * p.p_F * p.p_O) ** (1.0 / 3.0)
    t1 = pf * math.sqrt(G / P) - A_P / P
    t2 = pf * (G * shape.sw * shape.sh) ** (2.0 / 3.0) / (P * shape.w_F * shape.h_F) ** (2.0 / 3.0) - A_P / P
    return BoundTerms({"cube_root": t1, "small_filter": t2})


def combined_parallel_bound(shape: ConvShape, P: int, M: float) -> float:
    """max of Thm 2.2 and Thm 2.3 (the latter assumes load balance)."""
    return max(parallel_bound(shape, P, M).value,
               memory_independent_parallel_bound(shape, P).value)


# ---------------------------------------------------------------------------
# Attention specialization (Thm 2.1 applied to the two attention GEMMs).
# ---------------------------------------------------------------------------

def attention_bound(B: int, H: int, KV: int, Lq: int, Lk: int, hd: int,
                    M: float, prec: Precision = Precision()) -> BoundTerms:
    """Single-processor bound for GQA attention in words.

    Attention is two chained 7NL degenerates — S = QK^T and O = PV — with
    G = 2 B H Lq Lk hd total MACs. A flash-style schedule keeps S/P resident
    in fast memory (never spilled), so the memory-independent term charges
    only the four HBM-resident arrays: Q and O at ``p_I``/``p_O`` words per
    element and the un-repeated K/V streams (|K| = |V| = B KV Lk hd, GQA
    keeps them factored) at ``p_F``. The per-M and small-filter terms are the
    w_F = h_F = s = 1 specializations of Thm 2.1, exactly as
    ``matmul_bound``; for decode (Lq = 1) the memory-independent term — the
    pure KV-cache stream — dominates, which is the paper's thesis applied to
    serving."""
    G = 2.0 * B * H * Lq * Lk * hd
    memfree = (prec.p_I * B * H * Lq * hd
               + prec.p_F * 2.0 * B * KV * Lk * hd
               + prec.p_O * B * H * Lq * hd)
    per_M = C_p(prec) * G / M - M
    small_filter = (2.0 * math.sqrt(prec.p_I * prec.p_F * prec.p_O) * G
                    / math.sqrt(M) - 2.0 * M)
    return BoundTerms(
        {"memory_independent": memfree, "per_M": per_M,
         "small_filter": small_filter}
    )


# ---------------------------------------------------------------------------
# Matmul specialization (sanity anchor: classical results).
# ---------------------------------------------------------------------------

def matmul_bound(m: int, n: int, k: int, M: float, prec: Precision = Precision()) -> float:
    """Single-processor GEMM bound via the 7NL specialization
    (w_F=h_F=w_O=h_O=1). With p=1 this is max{mk+kn+mn, 9mnk/(4M)-M,
    2mnk/sqrt(M)-2M} - the familiar 2mnk/sqrt(M) Loomis-Whitney bound."""
    from .conv_model import matmul_as_conv

    return single_processor_bound(matmul_as_conv(m, n, k, prec), M).value


# ---------------------------------------------------------------------------
# Mixed-precision variants — the same theorems evaluated under a quantized
# per-operand storage policy (``repro.quant.PrecisionSpec``), so "how much
# does int8 storage move the bound" is a first-class query.
# ---------------------------------------------------------------------------

def _as_precision(prec) -> Precision:
    """Accept a ``Precision`` word-width triple or anything exposing one via
    a ``.precision`` property (``repro.quant.PrecisionSpec`` — duck-typed to
    keep ``core`` free of upward imports)."""
    if isinstance(prec, Precision):
        return prec
    p = getattr(prec, "precision", None)
    if isinstance(p, Precision):
        return p
    raise TypeError(f"expected Precision or PrecisionSpec, got {type(prec)!r}")


def mixed_precision_bound(shape: ConvShape, M: float, prec) -> BoundTerms:
    """Thm 2.1 with the shape's operands re-priced at a quantized storage
    policy's word-widths. Every term moves: the memory-independent term
    scales linearly per operand, the per-M term through C_p, the
    small-filter term through sqrt(p_I p_F p_O) — narrower storage lowers
    the attainable bound itself, not just the array sizes."""
    return single_processor_bound(shape.with_precision(_as_precision(prec)), M)


def mixed_precision_bound_ratio(shape: ConvShape, M: float, prec) -> float:
    """bound(quantized) / bound(shape's own precision): the factor by which
    the storage policy moves the Thm 2.1 bound for this shape (e.g. ~0.5 for
    int8-in/bf16-out vs bf16-in/f32-out in the memory-independent regime)."""
    base = single_processor_bound(shape, M).value
    return mixed_precision_bound(shape, M, prec).value / max(base, 1.0)


def mixed_precision_attention_bound(B: int, H: int, KV: int, Lq: int,
                                    Lk: int, hd: int, M: float,
                                    prec) -> BoundTerms:
    """:func:`attention_bound` under a quantized KV policy. For the serving
    decode regime (Lq = 1) the memory-independent term is the pure KV-cache
    stream at ``p_F`` words per element, so an int8 pool (p_F = 0.25) halves
    the decode bound relative to bf16 (p_F = 0.5) — the bound-level statement
    of what the quantized paged pool's doubled block capacity buys."""
    return attention_bound(B, H, KV, Lq, Lk, hd, M, prec=_as_precision(prec))
