"""Parallel-LP-driven sharding selection (paper §4.2 -> JAX meshes).

The paper's parallel blocking assigns loop axes to processors; on a TPU mesh
the processor grid is factored into named axes (pod, data, model). This module
enumerates the ways to bind 7NL loop axes to mesh axes, scores each candidate
with the ParallelBlocking communication model, and emits NamedSharding
PartitionSpecs for the three arrays — i.e. the paper's technique deciding
`in_shardings` for pjit.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .conv_model import ConvShape
from .parallel_tiling import PAR_AXES, ParallelBlocking

# Array layouts (NCHW / OIHW-as-(cI,cO,wF,hF) / NCHW) -> which loop axis each
# array dimension corresponds to.
INPUT_DIMS = ("N", "cI", "wI", "hI")  # wI/hI shard with wO/hO (halo exchange)
FILTER_DIMS = ("cI", "cO", "wF", "hF")
OUTPUT_DIMS = ("N", "cO", "wO", "hO")


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Loop-axis -> mesh-axis binding plus the derived PartitionSpecs."""

    binding: Dict[str, str]  # loop axis -> mesh axis name
    mesh_axes: Tuple[Tuple[str, int], ...]  # (name, size) in order
    comm_per_processor: float
    grid: Dict[str, int]

    def spec(self, dims: Sequence[str]) -> Tuple[Optional[str], ...]:
        """PartitionSpec entries for an array with the given loop-axis dims."""
        out: List[Optional[str]] = []
        used = set()
        for d in dims:
            loop_axis = {"wI": "wO", "hI": "hO"}.get(d, d)
            ax = self.binding.get(loop_axis)
            if ax is not None and ax not in used:
                out.append(ax)
                used.add(ax)
            else:
                out.append(None)
        return tuple(out)

    @property
    def input_spec(self) -> Tuple[Optional[str], ...]:
        return self.spec(INPUT_DIMS)

    @property
    def filter_spec(self) -> Tuple[Optional[str], ...]:
        return self.spec(FILTER_DIMS)

    @property
    def output_spec(self) -> Tuple[Optional[str], ...]:
        return self.spec(OUTPUT_DIMS)


def _axis_dims(shape: ConvShape) -> Dict[str, int]:
    return dict(zip(PAR_AXES, shape.loop_bounds()))


def plan_conv_sharding(
    shape: ConvShape,
    mesh_axes: Sequence[Tuple[str, int]],
    shardable: Sequence[str] = ("N", "cI", "cO", "wO", "hO"),
) -> ShardingPlan:
    """Choose the loop-axis binding for each mesh axis minimizing the modeled
    per-processor communication (the parallel LP's integer analogue under the
    mesh-factorization constraint).

    Filter spatial axes (wF, hF) are never sharded: their extents are tiny and
    sharding them forces halo-heavy input replication.
    """
    dims = _axis_dims(shape)
    best: Optional[ShardingPlan] = None
    # each mesh axis independently picks one loop axis (or none -> replicate)
    options: List[List[Optional[str]]] = []
    for name, size in mesh_axes:
        opts: List[Optional[str]] = [None]
        for la in shardable:
            if dims[la] >= size and dims[la] % size == 0:
                opts.append(la)
        options.append(opts)
    for combo in itertools.product(*options):
        # a loop axis may be claimed by at most one mesh axis
        claimed = [c for c in combo if c is not None]
        if len(claimed) != len(set(claimed)):
            continue
        grid = {k: 1 for k in PAR_AXES}
        binding: Dict[str, str] = {}
        for (name, size), la in zip(mesh_axes, combo):
            if la is None:
                continue
            grid[la] *= size
            binding[la] = name
        pb = ParallelBlocking(grid, shape)
        # unbound mesh axes replicate -> pure overhead for weight traffic;
        # penalize so the planner prefers binding every axis when legal
        unbound = sum(1 for (n, s), la in zip(mesh_axes, combo) if la is None)
        cost = pb.comm_per_processor() * (1.0 + 0.5 * unbound)
        if best is None or cost < best.comm_per_processor:
            best = ShardingPlan(
                binding=binding,
                mesh_axes=tuple(mesh_axes),
                comm_per_processor=cost,
                grid=grid,
            )
    assert best is not None
    return best


def plan_gemm_sharding(
    m: int, n: int, k: int,
    mesh_axes: Sequence[Tuple[str, int]],
    prec=None,
) -> ShardingPlan:
    """GEMM C[m,n] = A[m,k] B[k,n] as the degenerate conv: N=m, c_I=k, c_O=n.
    Returns a plan whose input/filter/output specs map to A/B/C (first two
    dims of each)."""
    from .conv_model import matmul_as_conv, Precision

    shape = matmul_as_conv(m, n, k, prec or Precision())
    return plan_conv_sharding(shape, mesh_axes, shardable=("N", "cI", "cO"))


def rank_lm_shardings(
    batch: int, d_model: int, d_ff: int, n_heads: int,
    mesh_axes: Sequence[Tuple[str, int]],
) -> List[Tuple[str, float]]:
    """Rank standard LM layer sharding strategies by the summed GEMM comm
    model over a transformer block's GEMMs (QKV, out-proj, up, down).

    Strategies:
      dp_only    - batch on all axes
      megatron   - batch on data, heads/ffn on model (column->row pairing)
      weight_rep - batch on data, weights replicated
    """
    strategies = {}
    P = math.prod(s for _, s in mesh_axes)
    data = math.prod(s for n_, s in mesh_axes if n_ != "model")
    model = P // data

    def gemm_cost(m: int, n: int, k: int, grid: Dict[str, int]) -> float:
        from .conv_model import matmul_as_conv

        shape = matmul_as_conv(m, n, k)
        g = {ax: 1 for ax in PAR_AXES}
        g.update(grid)
        return ParallelBlocking(g, shape).comm_per_processor()

    gemms = [
        (batch, 3 * d_model, d_model),  # QKV
        (batch, d_model, d_model),  # out proj
        (batch, d_ff, d_model),  # up
        (batch, d_model, d_ff),  # down
    ]
    strategies["dp_only"] = sum(
        gemm_cost(m, n, k, {"N": min(P, batch)}) for m, n, k in gemms)
    strategies["megatron"] = sum(
        gemm_cost(m, n, k, {"N": min(data, batch), "cO": min(model, n)})
        for m, n, k in gemms)
    strategies["weight_rep"] = sum(
        gemm_cost(m, n, k, {"N": min(data, batch)}) for m, n, k in gemms)
    return sorted(strategies.items(), key=lambda kv: kv[1])
