"""Communication-optimal blocking via linear programming (paper §3.2 eq. (6)
and the GEMMINI-adapted integer variant of §5), re-targeted at the TPU memory
hierarchy (HBM <-> VMEM).

Blocking variables (the paper's small-filter trick, i6 = sw*q6 + r6):

    B = (b_N, b_cI, b_cO, b_wO, b_hO, b_q6, b_q7, b_r6, b_r7)

with b_q6 in [1, ceil(w_F/sw)], b_r6 in [1, sw] (similarly for h). The LP works
in log space: maximize sum(log b) (updates per tile) subject to the three
arrays' blocks fitting in memory.  The input-window product
(b_wO + b_q6)(b_hO + b_q7) is expanded into four monomial terms each bounded by
M/(4 p_T), exactly as in the paper.

Memory models:
  * ``unified``  - one cache of M words shared by all three blocks (eq. 6).
  * ``split``    - GEMMINI/TPU style: scratchpad (input+filter, low precision)
                   of M words + separate accumulator (output, high precision)
                   of M_acc words; double-buffering halves both (paper §5).

Integer refinement replaces the paper's Mathematica NMaximize with a greedy
divisor-aware hill climb on the modeled communication volume under the *exact*
(non-relaxed) footprint constraints.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from scipy.optimize import linprog

from .conv_model import ConvShape, Precision, ceil_div

AXES = ("N", "cI", "cO", "wO", "hO", "q6", "q7", "r6", "r7")


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Capacity model for the fast memory the blocks must inhabit."""

    M: float  # words (32-bit) of scratchpad / cache / VMEM
    M_acc: Optional[float] = None  # words of accumulator (``split`` mode only)
    mode: str = "unified"  # "unified" | "split"
    double_buffer: bool = True  # paper §5: halves usable capacity

    @property
    def M_eff(self) -> float:
        return self.M / 2.0 if self.double_buffer else self.M

    @property
    def M_acc_eff(self) -> float:
        if self.M_acc is None:
            return self.M_eff
        return self.M_acc / 2.0 if self.double_buffer else self.M_acc


# TPU v5e-flavoured defaults: ~16 MiB VMEM per core -> 4 Mi words of 32 bits.
TPU_VMEM_WORDS = (16 * 1024 * 1024) // 4
TPU_VMEM = MemoryModel(M=TPU_VMEM_WORDS, mode="unified", double_buffer=True)
# GEMMINI defaults from the paper: 256 KiB scratchpad of 8-bit words and a
# 64 KiB accumulator of 32-bit words, both double buffered.
GEMMINI = MemoryModel(M=256 * 1024 / 4.0, M_acc=64 * 1024 / 4.0, mode="split",
                      double_buffer=True)


@dataclasses.dataclass(frozen=True)
class Blocking:
    """An integer blocking of the (lifted) 7NL CNN loops."""

    b: Dict[str, int]  # keys = AXES
    shape: ConvShape

    def __post_init__(self):
        for k in AXES:
            assert k in self.b, f"missing block var {k}"

    # -- lifted loop bounds ---------------------------------------------------
    @staticmethod
    def lifted_bounds(shape: ConvShape) -> Dict[str, int]:
        return {
            "N": shape.N,
            "cI": shape.c_I,
            "cO": shape.c_O,
            "wO": shape.w_O,
            "hO": shape.h_O,
            "q6": ceil_div(shape.w_F, shape.sw),
            "q7": ceil_div(shape.h_F, shape.sh),
            "r6": shape.sw,
            "r7": shape.sh,
        }

    # -- block footprints in words -------------------------------------------
    @property
    def out_block_words(self) -> float:
        b = self.b
        return self.shape.prec.p_O * b["N"] * b["cO"] * b["wO"] * b["hO"]

    @property
    def filt_block_words(self) -> float:
        b = self.b
        return self.shape.prec.p_F * b["cI"] * b["cO"] * b["q6"] * b["q7"] * b["r6"] * b["r7"]

    @property
    def in_block_words(self) -> float:
        """Exact lifted input window: (b_wO + b_q6 - 1) x b_r6 in the lifted w
        axis (sw-strided), similarly for h."""
        b = self.b
        w_win = (b["wO"] + b["q6"] - 1) * b["r6"]
        h_win = (b["hO"] + b["q7"] - 1) * b["r7"]
        return self.shape.prec.p_I * b["N"] * b["cI"] * w_win * h_win

    def fits(self, mem: MemoryModel) -> bool:
        if mem.mode == "split":
            return (
                self.in_block_words + self.filt_block_words <= mem.M_eff
                and self.out_block_words <= mem.M_acc_eff
            )
        return (
            self.in_block_words + self.filt_block_words + self.out_block_words
            <= mem.M_eff
        )

    # -- tile grid -------------------------------------------------------------
    def tile_counts(self) -> Dict[str, int]:
        d = self.lifted_bounds(self.shape)
        return {k: ceil_div(d[k], self.b[k]) for k in AXES}

    @property
    def num_tiles(self) -> int:
        t = self.tile_counts()
        return math.prod(t.values())

    @property
    def num_output_tiles(self) -> int:
        t = self.tile_counts()
        return t["N"] * t["cO"] * t["wO"] * t["hO"]

    @property
    def updates_per_tile(self) -> int:
        b = self.b
        return math.prod(b[k] for k in AXES)

    def comm_volume(self) -> float:
        """Modeled HBM<->VMEM words moved. Loop order keeps reduction axes
        (cI, q6, q7, r6, r7) innermost so the output block stays resident in
        the accumulator across the reduction (paper §5); input and filter
        blocks are (re)loaded at every tile step."""
        per_tile = self.in_block_words + self.filt_block_words
        out_words = self.shape.prec.p_O * self.shape.output_size
        return self.num_tiles * per_tile + out_words

    def as_conv_tile(self) -> Dict[str, int]:
        """Collapse the lifted (q, r) split back to filter/image tile dims for
        kernel consumption."""
        b = self.b
        return {
            "N": b["N"],
            "cI": b["cI"],
            "cO": b["cO"],
            "wO": b["wO"],
            "hO": b["hO"],
            "wF": min(b["q6"] * b["r6"], self.shape.w_F),
            "hF": min(b["q7"] * b["r7"], self.shape.h_F),
        }


# ---------------------------------------------------------------------------
# The LP (continuous relaxation, log space) - paper eq. (6).
# ---------------------------------------------------------------------------

def _lp_blocking(shape: ConvShape, mem: MemoryModel) -> Dict[str, float]:
    """Solve the log-space LP and return continuous block sizes."""
    p = shape.prec
    d = Blocking.lifted_bounds(shape)
    n = len(AXES)
    idx = {k: i for i, k in enumerate(AXES)}

    def row(keys: Sequence[str]) -> List[float]:
        r = [0.0] * n
        for k in keys:
            r[idx[k]] += 1.0
        return r

    A_ub: List[List[float]] = []
    b_ub: List[float] = []

    if mem.mode == "split":
        M_sp, M_acc = mem.M_eff, mem.M_acc_eff
        # output block alone in the accumulator
        A_ub.append(row(["N", "cO", "wO", "hO"]))
        b_ub.append(math.log(max(M_acc / p.p_O, 1.0)))
        # scratchpad shared between filter and input: give each half
        # (the integer refinement re-optimizes the split exactly)
        A_ub.append(row(["cI", "cO", "q6", "q7", "r6", "r7"]))
        b_ub.append(math.log(max(M_sp / (2.0 * p.p_F), 1.0)))
        for wk in ("wO", "q6"):
            for hk in ("hO", "q7"):
                A_ub.append(row(["N", "cI", wk, hk, "r6", "r7"]))
                b_ub.append(math.log(max(M_sp / (2.0 * 4.0 * p.p_I), 1.0)))
    else:
        M = mem.M_eff
        p_T = p.p_T
        # eq. (6): each array block gets its p_j/p_T share of M
        A_ub.append(row(["N", "cO", "wO", "hO"]))
        b_ub.append(math.log(max(M / p_T, 1.0)))
        A_ub.append(row(["cI", "cO", "q6", "q7", "r6", "r7"]))
        b_ub.append(math.log(max(M / p_T, 1.0)))
        # input term expanded into four monomials, each <= M/(4 p_T)
        for wk in ("wO", "q6"):
            for hk in ("hO", "q7"):
                A_ub.append(row(["N", "cI", wk, hk, "r6", "r7"]))
                b_ub.append(math.log(max(M / (4.0 * p_T), 1.0)))

    bounds = [(0.0, math.log(max(d[k], 1))) for k in AXES]
    c = [-1.0] * n  # maximize sum of logs
    res = linprog(c, A_ub=np.asarray(A_ub), b_ub=np.asarray(b_ub), bounds=bounds,
                  method="highs")
    if not res.success:
        raise RuntimeError(f"blocking LP failed: {res.message}")
    return {k: math.exp(res.x[idx[k]]) for k in AXES}


# ---------------------------------------------------------------------------
# Integer refinement (replaces NMaximize, paper §5).
# ---------------------------------------------------------------------------

def _candidates(dim: int, x: float) -> List[int]:
    """Integer candidates in [1, dim]: all divisors (ragged-edge-free), powers
    of two, and the continuous LP value's floor/ceil."""
    lo = max(1, min(dim, int(math.floor(x))))
    cands = {1, lo, min(lo + 1, dim), dim}
    v = 1
    while v <= dim:
        cands.add(v)
        v *= 2
    if dim <= 4096:
        for d in range(1, int(math.isqrt(dim)) + 1):
            if dim % d == 0:
                cands.add(d)
                cands.add(dim // d)
    return sorted(cands)


def _clip_to_feasible(b: Dict[str, int], shape: ConvShape, mem: MemoryModel) -> Dict[str, int]:
    """Shrink blocks (largest contributors first) until they fit."""
    b = dict(b)
    while not Blocking(b, shape).fits(mem):
        # shrink the axis whose reduction most decreases footprint
        best_k, best_gain = None, 0.0
        cur = _footprint(b, shape, mem)
        for k in AXES:
            if b[k] == 1:
                continue
            trial = dict(b)
            trial[k] = max(1, b[k] // 2)
            gain = cur - _footprint(trial, shape, mem)
            if gain > best_gain:
                best_k, best_gain = k, gain
        if best_k is None:
            break
        b[best_k] = max(1, b[best_k] // 2)
    return b


def _footprint(b: Dict[str, int], shape: ConvShape, mem: MemoryModel) -> float:
    blk = Blocking(b, shape)
    if mem.mode == "split":
        return max(blk.in_block_words + blk.filt_block_words - mem.M_eff,
                   blk.out_block_words - mem.M_acc_eff, 0.0) + (
            blk.in_block_words + blk.filt_block_words + blk.out_block_words)
    return blk.in_block_words + blk.filt_block_words + blk.out_block_words


def optimize_blocking(
    shape: ConvShape,
    mem: MemoryModel = TPU_VMEM,
    align: Optional[Dict[str, int]] = None,
    sweeps: int = 3,
) -> Blocking:
    """LP + greedy integer hill-climb -> communication-minimizing Blocking.

    ``align`` optionally maps axis -> multiple (e.g. {"cO": 128, "cI": 8} for
    MXU lane/sublane alignment); respected when the axis bound allows it.
    """
    d = Blocking.lifted_bounds(shape)
    cont = _lp_blocking(shape, mem)
    b = {k: max(1, min(d[k], int(round(cont[k])))) for k in AXES}
    if align:
        for k, m in align.items():
            if k in b and d[k] >= m:
                b[k] = max(m, (b[k] // m) * m)
    b = _clip_to_feasible(b, shape, mem)

    def cost(bb: Dict[str, int]) -> float:
        return Blocking(bb, shape).comm_volume()

    def ok_align(k: str, v: int) -> bool:
        if not align or k not in align or d[k] < align[k]:
            return True
        return v % align[k] == 0 or v == d[k]

    cands = {k: [v for v in _candidates(d[k], cont[k]) if ok_align(k, v)] for k in AXES}

    starts = [
        dict(b),
        {k: 1 for k in AXES},
        # spatial-first and channel-first seeds escape accumulator-bound optima
        {**{k: 1 for k in AXES}, "wO": d["wO"], "hO": d["hO"], "q6": d["q6"],
         "q7": d["q7"], "r6": d["r6"], "r7": d["r7"]},
        {**{k: 1 for k in AXES}, "cI": d["cI"], "cO": d["cO"]},
    ]
    best, best_cost = None, float("inf")
    for start in starts:
        cur = _clip_to_feasible(start, shape, mem)
        cur_cost = cost(cur)
        for _ in range(max(sweeps, 8)):
            improved = False
            # single-axis moves
            for k in AXES:
                for v in cands[k]:
                    trial = dict(cur)
                    trial[k] = v
                    blk = Blocking(trial, shape)
                    if not blk.fits(mem):
                        continue
                    c = blk.comm_volume()
                    if c < cur_cost - 1e-9:
                        cur, cur_cost = trial, c
                        improved = True
            # paired moves: trade capacity between two axes at once
            for ki in AXES:
                for kj in AXES:
                    if ki == kj:
                        continue
                    for vi in cands[ki]:
                        if vi <= cur[ki]:
                            continue
                        for vj in cands[kj]:
                            if vj >= cur[kj]:
                                continue
                            trial = dict(cur)
                            trial[ki], trial[kj] = vi, vj
                            blk = Blocking(trial, shape)
                            if not blk.fits(mem):
                                continue
                            c = blk.comm_volume()
                            if c < cur_cost - 1e-9:
                                cur, cur_cost = trial, c
                                improved = True
            if not improved:
                break
        if cur_cost < best_cost:
            best, best_cost = cur, cur_cost
    blk = Blocking(best, shape)
    assert blk.fits(mem), "integer refinement produced an infeasible blocking"
    return blk


# ---------------------------------------------------------------------------
# Kernel-level footprints: what the lowered conv2d kernel actually holds in
# VMEM for a (bN, b_cI, b_cO, b_hO, b_wO) tile. Differs from the lifted
# Blocking model in two ways: the kernel always unrolls the full (h_F, w_F)
# filter (no q/r blocking), and its input window is the exact halo extent
# (b_hO - 1) * sh + h_F rather than the lifted (b_hO + b_q7 - 1) * b_r7.
# ---------------------------------------------------------------------------

def conv_kernel_footprints(shape: ConvShape, tiles: Sequence[int],
                           prec: Optional[Precision] = None
                           ) -> Dict[str, float]:
    """Words each array block of the spatially-tiled conv2d kernel occupies
    in fast memory, for kernel tiles ``(bN, b_cI, b_cO, b_hO, b_wO)``.
    ``prec`` overrides the shape's own word-widths — the byte-weighted view
    a quantized storage policy (``repro.quant.PrecisionSpec.precision``)
    prices the same tiles at (int8 streams take a quarter of the VMEM the
    shape's nominal precision would charge)."""
    bN, b_cI, b_cO, b_hO, b_wO = tiles
    p = prec if prec is not None else shape.prec
    h_in = (b_hO - 1) * shape.sh + shape.h_F
    w_in = (b_wO - 1) * shape.sw + shape.w_F
    return {
        "input": p.p_I * bN * b_cI * h_in * w_in,
        "filter": p.p_F * b_cO * b_cI * shape.h_F * shape.w_F,
        "output": p.p_O * bN * b_cO * b_hO * b_wO,
    }


def conv_kernel_tiles_fit(shape: ConvShape, tiles: Sequence[int],
                          mem: MemoryModel) -> bool:
    """Whether the kernel tile's halo-window footprint obeys the same
    double-buffered capacity discipline the blocking LP planned under."""
    fp = conv_kernel_footprints(shape, tiles)
    if mem.mode == "split":
        return (fp["input"] + fp["filter"] <= mem.M_eff
                and fp["output"] <= mem.M_acc_eff)
    return sum(fp.values()) <= mem.M_eff


def fit_conv_kernel_tiles(shape: ConvShape, tiles: Sequence[int],
                          mem: MemoryModel) -> Tuple[int, int, int, int, int]:
    """Shrink kernel tiles (best-gain axis first) until the halo-window
    footprint fits; the LP solution is usually already feasible, but its
    lifted model can undercount when it blocked the filter taps."""
    b = list(tiles)
    while not conv_kernel_tiles_fit(shape, b, mem):
        cur = sum(conv_kernel_footprints(shape, b).values())
        best_i, best_gain = None, 0.0
        for i in range(5):
            if b[i] == 1:
                continue
            trial = list(b)
            trial[i] = max(1, b[i] // 2)
            gain = cur - sum(conv_kernel_footprints(shape, trial).values())
            if gain > best_gain:
                best_i, best_gain = i, gain
        if best_i is None:
            break
        b[best_i] = max(1, b[best_i] // 2)
    return tuple(b)


def blocking_efficiency(shape: ConvShape, mem: MemoryModel) -> Tuple[float, float, float]:
    """(modeled comm volume, lower bound, ratio) for the optimized blocking."""
    from .bounds import single_processor_bound

    blk = optimize_blocking(shape, mem)
    vol = blk.comm_volume()
    lb = single_processor_bound(shape, mem.M_eff).value
    return vol, lb, vol / max(lb, 1.0)


# ---------------------------------------------------------------------------
# Attention block sizing: the capacity argument of the flash kernel.
# ---------------------------------------------------------------------------

def attention_block_size(dh: int, m_eff: float, p_kv: float = 1.0) -> int:
    """The (block_q = block_k) tile of the blocked flash-attention schedule:
    f32 q/acc/stats residents plus streamed k/v tiles (``p_kv`` words per
    element) must fit the double-buffered budget ``m_eff``. The LP
    degenerates to this closed form because both attention GEMMs share the
    b_q x b_k footprint term; returns the largest MXU-saturating power of
    two <= 512 that fits."""
    for b in (512, 256, 128, 64, 32, 16, 8):
        words = 2.0 * b * dh + 2.0 * b * dh * p_kv + b * b + 2.0 * b
        if words <= m_eff:
            return b
    raise ValueError(
        f"no attention block fits: dh={dh} needs more than "
        f"M_eff={m_eff:.0f} words even at block 8")


# ---------------------------------------------------------------------------
# Matmul convenience: LP-tiled GEMM block shapes for the Pallas kernels.
# ---------------------------------------------------------------------------

def matmul_blocking(
    m: int, n: int, k: int,
    mem: Optional[MemoryModel] = None,
    prec=None,
    align_m: int = 8, align_n: int = 128, align_k: int = 128,
) -> Blocking:
    """The full Blocking for C[m,n] += A[m,k]B[k,n] as the degenerate 7NL CNN
    (N=m, c_I=k, c_O=n) under an arbitrary memory model."""
    from .conv_model import matmul_as_conv, Precision

    shape = matmul_as_conv(m, n, k, prec or Precision(0.5, 0.5, 1.0))
    if mem is None:
        mem = MemoryModel(M=TPU_VMEM_WORDS, mode="unified", double_buffer=True)
    align = {k_: v for k_, v in
             (("N", align_m), ("cO", align_n), ("cI", align_k)) if v > 1}
    return optimize_blocking(shape, mem, align=align or None)


def snap_tile(v: int, align: int, dim: int) -> int:
    """Round a tile down to the alignment multiple (whole dim when it is
    smaller than one aligned tile)."""
    if align <= 1:
        return min(v, dim)
    if dim < align:
        return dim
    v = max(align, (v // align) * align)
    return min(v, (dim // align) * align if dim % align == 0 else v)


def matmul_tiles(
    m: int, n: int, k: int,
    vmem_words: float = TPU_VMEM_WORDS,
    prec=None,
    align_m: int = 8, align_n: int = 128, align_k: int = 128,
    mem: Optional[MemoryModel] = None,
) -> Tuple[int, int, int]:
    """Block sizes (bm, bn, bk) for C[m,n] += A[m,k]B[k,n] from the 7NL LP,
    MXU-aligned. The degenerate conv has N=m, c_I=k, c_O=n."""
    if mem is None:
        mem = MemoryModel(M=vmem_words, mode="unified", double_buffer=True)
    blk = matmul_blocking(m, n, k, mem=mem, prec=prec, align_m=align_m,
                          align_n=align_n, align_k=align_k)
    bm, bk, bn = blk.b["N"], blk.b["cI"], blk.b["cO"]
    return (snap_tile(bm, align_m, m), snap_tile(bn, align_n, n),
            snap_tile(bk, align_k, k))
