"""Core of the reproduction: communication lower bounds (HBL) and
communication-optimal tilings for 7NL CNN (Chen/Demmel/Dinh/Haberle/Holtz,
PASC'22), plus the comm models that turn them into Pallas BlockSpecs and mesh
shardings.
"""

from .conv_model import (  # noqa: F401
    BF16_ACC32,
    FP32,
    INT8_ACC32,
    ConvShape,
    Precision,
    alexnet_layers,
    matmul_as_conv,
    resnet50_layers,
)
from .bounds import (  # noqa: F401
    BoundTerms,
    C_p,
    combined_parallel_bound,
    matmul_bound,
    memory_independent_parallel_bound,
    parallel_bound,
    single_processor_bound,
    small_filter_regime,
)
from .hbl import (  # noqa: F401
    Homomorphism,
    Subspace,
    constraint_table,
    conv7nl_lifted_phis,
    conv7nl_phis,
    hbl_constraints,
    matmul_phis,
    solve_exponents,
    subgroup_lattice,
)
from .tiling import (  # noqa: F401
    GEMMINI,
    TPU_VMEM,
    TPU_VMEM_WORDS,
    Blocking,
    MemoryModel,
    blocking_efficiency,
    matmul_tiles,
    optimize_blocking,
)
from .parallel_tiling import (  # noqa: F401
    ParallelBlocking,
    optimize_parallel_blocking,
    parallel_efficiency,
)
from .sharding_opt import (  # noqa: F401
    ShardingPlan,
    plan_conv_sharding,
    plan_gemm_sharding,
    rank_lm_shardings,
)
from . import algorithms  # noqa: F401

# ---------------------------------------------------------------------------
# repro.plan re-exports (lazy to avoid a circular import: repro.plan itself
# imports the core submodules above). ``repro.core.plan(...)`` etc. resolve to
# the unified planner; the MemoryModel-level names above remain the low-level
# building blocks. Note: ``repro.core.GEMMINI`` stays the legacy MemoryModel —
# the HardwareTarget preset of the same name lives at ``repro.plan.GEMMINI``.
# ---------------------------------------------------------------------------

_PLAN_EXPORTS = (
    "HardwareTarget", "ExecutionPlan", "ConvSpec", "MatmulSpec", "OpSpec",
    "plan", "TPU_V5E", "CPU_INTERPRET", "get_target",
    "clear_plan_cache", "save_plan_cache", "load_plan_cache",
)


def __getattr__(name):
    if name in _PLAN_EXPORTS:
        from repro import plan as _plan_mod

        return getattr(_plan_mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_PLAN_EXPORTS))
