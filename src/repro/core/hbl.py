"""Hölder-Brascamp-Lieb machinery (paper §2.3, Thm 2.4, Prop 2.5).

Given array-access homomorphisms phi_j : Z^d -> Z^{d_j} (as integer matrices),
we generate the subgroup lattice spanned by their kernels (closed under sum and
intersection), emit the rank constraints

    rank(H) <= sum_j s_j * rank(phi_j(H))    for each H in Lattice(ker phi_j)

and solve the LP minimizing sum_j s_j. By Prop 2.5 checking the lattice
suffices; the optimum s = sum_j s_j yields the asymptotic communication lower
bound  Omega(G / M^{s-1}).

All linear algebra is exact over Q (fractions.Fraction), matrices are tiny
(d <= 9), so this costs microseconds.
"""

from __future__ import annotations

from fractions import Fraction
from typing import Dict, List, Sequence, Tuple

import numpy as np
from scipy.optimize import linprog

Matrix = Tuple[Tuple[Fraction, ...], ...]  # rows


def _to_matrix(rows: Sequence[Sequence[int]]) -> Matrix:
    return tuple(tuple(Fraction(x) for x in row) for row in rows)


def rref(rows: Sequence[Sequence[Fraction]]) -> List[List[Fraction]]:
    """Reduced row-echelon form over Q; returns the nonzero rows."""
    m = [list(r) for r in rows]
    if not m:
        return []
    nrows, ncols = len(m), len(m[0])
    pivot_row = 0
    for col in range(ncols):
        # find pivot
        sel = None
        for r in range(pivot_row, nrows):
            if m[r][col] != 0:
                sel = r
                break
        if sel is None:
            continue
        m[pivot_row], m[sel] = m[sel], m[pivot_row]
        pv = m[pivot_row][col]
        m[pivot_row] = [x / pv for x in m[pivot_row]]
        for r in range(nrows):
            if r != pivot_row and m[r][col] != 0:
                f = m[r][col]
                m[r] = [a - f * b for a, b in zip(m[r], m[pivot_row])]
        pivot_row += 1
        if pivot_row == nrows:
            break
    return [row for row in m[:pivot_row] if any(x != 0 for x in row)]


def rank(rows: Sequence[Sequence[Fraction]]) -> int:
    return len(rref(rows))


def nullspace(rows: Sequence[Sequence[Fraction]], dim: int) -> List[List[Fraction]]:
    """Basis (as row vectors of length ``dim``) of the kernel of the map whose
    matrix rows are ``rows``."""
    R = rref(rows)
    pivots: List[int] = []
    for row in R:
        for j, x in enumerate(row):
            if x != 0:
                pivots.append(j)
                break
    free = [j for j in range(dim) if j not in pivots]
    basis = []
    for f in free:
        v = [Fraction(0)] * dim
        v[f] = Fraction(1)
        # back-substitute: each pivot row gives pivot_col value
        for row, p in zip(R, pivots):
            v[p] = -row[f]
        basis.append(v)
    return basis


class Subspace:
    """A subspace of Q^d with a canonical (RREF) basis -> hashable."""

    __slots__ = ("dim", "basis", "_key")

    def __init__(self, dim: int, vectors: Sequence[Sequence[Fraction]]):
        self.dim = dim
        self.basis = rref(vectors)
        self._key = tuple(tuple(r) for r in self.basis)

    @property
    def rank(self) -> int:
        return len(self.basis)

    def __eq__(self, other) -> bool:
        return isinstance(other, Subspace) and self._key == other._key

    def __hash__(self) -> int:
        return hash(self._key)

    def __repr__(self) -> str:
        return f"Subspace(rank={self.rank}, basis={self.basis})"

    def sum(self, other: "Subspace") -> "Subspace":
        return Subspace(self.dim, list(self.basis) + list(other.basis))

    def intersect(self, other: "Subspace") -> "Subspace":
        """V cap W via the kernel of [A; B]-coordinates trick:
        x in V cap W  <=>  x = a^T A = b^T B. Solve [A^T | -B^T] y = 0."""
        if not self.basis or not other.basis:
            return Subspace(self.dim, [])
        A, B = self.basis, other.basis
        # unknowns: coefficients (a_1..a_k, b_1..b_l); equations: one per dim
        k, l = len(A), len(B)
        rows = []
        for j in range(self.dim):
            rows.append([A[i][j] for i in range(k)] + [-B[i][j] for i in range(l)])
        ns = nullspace(rows, k + l)
        vecs = []
        for y in ns:
            v = [Fraction(0)] * self.dim
            for i in range(k):
                for j in range(self.dim):
                    v[j] += y[i] * A[i][j]
            vecs.append(v)
        return Subspace(self.dim, vecs)


class Homomorphism:
    """phi : Z^d -> Z^{dj} given by an integer matrix (dj x d), row-acting."""

    def __init__(self, rows: Sequence[Sequence[int]], name: str = "phi"):
        self.mat = _to_matrix(rows)
        self.name = name
        self.dj = len(self.mat)
        self.d = len(self.mat[0]) if self.mat else 0

    def kernel(self) -> Subspace:
        return Subspace(self.d, nullspace(self.mat, self.d))

    def image_rank(self, H: Subspace) -> int:
        """rank of phi(H): apply the matrix to each basis vector of H."""
        imgs = []
        for v in H.basis:
            imgs.append([sum(self.mat[i][j] * v[j] for j in range(self.d)) for i in range(self.dj)])
        return rank(imgs)

    def __repr__(self) -> str:
        return f"Homomorphism({self.name}: Z^{self.d} -> Z^{self.dj})"


def subgroup_lattice(generators: Sequence[Subspace], max_size: int = 4096) -> List[Subspace]:
    """Close a family of subspaces under pairwise sum and intersection
    (Prop 2.5: these are the only subgroups whose rank constraints matter)."""
    seen = set(generators)
    frontier = list(generators)
    while frontier:
        new: List[Subspace] = []
        items = list(seen)
        for a in frontier:
            for b in items:
                for c in (a.sum(b), a.intersect(b)):
                    if c.rank and c not in seen:
                        seen.add(c)
                        new.append(c)
                        if len(seen) > max_size:
                            raise RuntimeError("lattice closure exploded")
        frontier = new
    return sorted(seen, key=lambda s: (s.rank, s._key))


def hbl_constraints(phis: Sequence[Homomorphism]) -> List[Tuple[int, Tuple[int, ...]]]:
    """All (rank(H), (rank phi_j(H))_j) pairs over the kernel lattice, deduped.
    The ambient space Z^d is always included: for injective maps the kernel
    lattice is trivial but the full-space rank constraint still binds."""
    d = phis[0].d
    full = Subspace(d, [[Fraction(int(i == j)) for j in range(d)]
                        for i in range(d)])
    lat = subgroup_lattice([phi.kernel() for phi in phis] + [full])
    out = set()
    for H in lat:
        out.add((H.rank, tuple(phi.image_rank(H) for phi in phis)))
    return sorted(out)


def solve_exponents(
    phis: Sequence[Homomorphism],
    weights: Sequence[float] | None = None,
) -> Tuple[np.ndarray, float]:
    """Solve  min sum_j w_j s_j  s.t. the HBL rank constraints and 0<=s_j<=1.

    Returns (s, sum_j s_j). The minimal *unweighted* sum gives the exponent in
    the Omega(G / M^{s-1}) communication bound.
    """
    cons = hbl_constraints(phis)
    m = len(phis)
    c = np.asarray(weights if weights is not None else [1.0] * m, dtype=float)
    A_ub, b_ub = [], []
    for rk_H, rk_imgs in cons:
        if rk_H == 0:
            continue
        A_ub.append([-r for r in rk_imgs])
        b_ub.append(-rk_H)
    res = linprog(c, A_ub=np.asarray(A_ub, float), b_ub=np.asarray(b_ub, float),
                  bounds=[(0.0, 1.0)] * m, method="highs")
    if not res.success:
        raise RuntimeError(f"HBL exponent LP infeasible: {res.message}")
    s = res.x
    return s, float(np.sum(s))


# ---------------------------------------------------------------------------
# The paper's homomorphisms.
# ---------------------------------------------------------------------------

def conv7nl_phis(sw: int = 1, sh: int = 1) -> List[Homomorphism]:
    """phi_I, phi_F, phi_O for 7NL CNN over indices (i1..i7) (paper §3.1):

        phi_I(i) = (i1, i2, i6 + sw*i4, i7 + sh*i5)
        phi_F(i) = (i2, i3, i6, i7)
        phi_O(i) = (i1, i3, i4, i5)
    """
    phi_I = Homomorphism(
        [
            [1, 0, 0, 0, 0, 0, 0],
            [0, 1, 0, 0, 0, 0, 0],
            [0, 0, 0, sw, 0, 1, 0],
            [0, 0, 0, 0, sh, 0, 1],
        ],
        name="phi_I",
    )
    phi_F = Homomorphism(
        [
            [0, 1, 0, 0, 0, 0, 0],
            [0, 0, 1, 0, 0, 0, 0],
            [0, 0, 0, 0, 0, 1, 0],
            [0, 0, 0, 0, 0, 0, 1],
        ],
        name="phi_F",
    )
    phi_O = Homomorphism(
        [
            [1, 0, 0, 0, 0, 0, 0],
            [0, 0, 1, 0, 0, 0, 0],
            [0, 0, 0, 1, 0, 0, 0],
            [0, 0, 0, 0, 1, 0, 0],
        ],
        name="phi_O",
    )
    return [phi_I, phi_F, phi_O]


def conv7nl_lifted_phis() -> List[Homomorphism]:
    """The small-filter lifted homomorphisms (paper Lemma 3.4) over indices
    (i1, i2, i3, i4, i5, r6, r7) with (q6, q7) held fixed:

        phi'_I = (i1, i2, i4, r6, i5, r7)
        phi'_F = (i2, i3, r6, r7)
        phi'_O = (i1, i3, i4, i5)

    Every index appears in exactly two maps -> tensor-contraction case, optimal
    exponents s = (1/2, 1/2, 1/2).
    """
    phi_I = Homomorphism(
        [
            [1, 0, 0, 0, 0, 0, 0],
            [0, 1, 0, 0, 0, 0, 0],
            [0, 0, 0, 1, 0, 0, 0],
            [0, 0, 0, 0, 0, 1, 0],
            [0, 0, 0, 0, 1, 0, 0],
            [0, 0, 0, 0, 0, 0, 1],
        ],
        name="phi_I'",
    )
    phi_F = Homomorphism(
        [
            [0, 1, 0, 0, 0, 0, 0],
            [0, 0, 1, 0, 0, 0, 0],
            [0, 0, 0, 0, 0, 1, 0],
            [0, 0, 0, 0, 0, 0, 1],
        ],
        name="phi_F'",
    )
    phi_O = Homomorphism(
        [
            [1, 0, 0, 0, 0, 0, 0],
            [0, 0, 1, 0, 0, 0, 0],
            [0, 0, 0, 1, 0, 0, 0],
            [0, 0, 0, 0, 1, 0, 0],
        ],
        name="phi_O'",
    )
    return [phi_I, phi_F, phi_O]


def matmul_phis() -> List[Homomorphism]:
    """Loomis-Whitney / 3NL matmul: C[i,k] += A[i,j] B[j,k] over (i, j, k)."""
    return [
        Homomorphism([[1, 0, 0], [0, 1, 0]], name="phi_A"),
        Homomorphism([[0, 1, 0], [0, 0, 1]], name="phi_B"),
        Homomorphism([[1, 0, 0], [0, 0, 1]], name="phi_C"),
    ]


def constraint_table(phis: Sequence[Homomorphism]) -> List[Dict]:
    """Human-readable constraint table (mirrors the paper's §3.1 table)."""
    rows = []
    for rk_H, rk_imgs in hbl_constraints(phis):
        terms = " + ".join(
            f"{r}*s_{phi.name.split('_')[-1]}" for r, phi in zip(rk_imgs, phis) if r
        )
        rows.append({"rank_H": rk_H, "ranks": rk_imgs, "constraint": f"{rk_H} <= {terms}"})
    return rows
