"""The 7NL CNN computation model from the paper (§2.1).

A single convolution layer written as seven nested loops:

    for {i1..i7} = 0 : {N, c_I, c_O, w_O, h_O, w_F, h_F} - 1
        Output(i1,i3,i4,i5) += Input(i1,i2, sw*i4+i6, sh*i5+i7) * Filter(i2,i3,i6,i7)

Array sizes (paper §2.1):
    |I| = N * c_I * (sw*w_O + w_F) * (sh*h_O + h_F)
    |O| = N * c_O * w_O * h_O
    |F| = c_I * c_O * w_F * h_F
    G   = N * c_I * c_O * w_O * h_O * w_F * h_F     (total updates)

Precisions p_I, p_F, p_O are in *words* (the paper's unit, 32 bits); mixed
precision is first-class throughout.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class Precision:
    """Per-array word precisions (1.0 = one 32-bit word)."""

    p_I: float = 1.0
    p_F: float = 1.0
    p_O: float = 1.0

    @property
    def p_T(self) -> float:
        return self.p_I + self.p_F + self.p_O

    def triangle_ok(self) -> bool:
        """The paper's triangle condition: p_j <= p_k + p_l for all distinct j,k,l."""
        p = (self.p_I, self.p_F, self.p_O)
        return all(p[j] <= sum(p) - p[j] for j in range(3))

    def as_tuple(self) -> Tuple[float, float, float]:
        return (self.p_I, self.p_F, self.p_O)


# Common precision regimes on TPU (words of 32 bits).
FP32 = Precision(1.0, 1.0, 1.0)
BF16_ACC32 = Precision(0.5, 0.5, 1.0)  # bf16 in/filter, f32 accumulate (MXU native)
INT8_ACC32 = Precision(0.25, 0.25, 1.0)  # GEMMINI's regime (8-bit scratchpad words)


@dataclasses.dataclass(frozen=True)
class ConvShape:
    """Loop bounds of 7NL CNN.

    Paper assumptions (§2.1): w_F <= sw*w_O, h_F <= sh*h_O (filters smaller than
    images) and sw <= w_F, sh <= h_F (every input element used).
    """

    N: int  # batch (images)
    c_I: int  # input channels
    c_O: int  # output channels
    w_O: int  # output width
    h_O: int  # output height
    w_F: int  # filter width
    h_F: int  # filter height
    sw: int = 1  # horizontal stride
    sh: int = 1  # vertical stride
    prec: Precision = FP32

    def __post_init__(self):
        for name in ("N", "c_I", "c_O", "w_O", "h_O", "w_F", "h_F", "sw", "sh"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{name} must be a positive int, got {v!r}")

    # ---- sizes ------------------------------------------------------------
    @property
    def w_I(self) -> int:
        """Input width under the paper's convention (sw*w_O + w_F)."""
        return self.sw * self.w_O + self.w_F

    @property
    def h_I(self) -> int:
        return self.sh * self.h_O + self.h_F

    @property
    def input_size(self) -> int:
        return self.N * self.c_I * self.w_I * self.h_I

    @property
    def filter_size(self) -> int:
        return self.c_I * self.c_O * self.w_F * self.h_F

    @property
    def output_size(self) -> int:
        return self.N * self.c_O * self.w_O * self.h_O

    @property
    def G(self) -> int:
        """Total number of scalar updates."""
        return self.N * self.c_I * self.c_O * self.w_O * self.h_O * self.w_F * self.h_F

    @property
    def flops(self) -> int:
        return 2 * self.G  # one multiply + one add per update

    def words(self) -> float:
        """Total words of all three arrays (the memory-independent bound term)."""
        p = self.prec
        return p.p_I * self.input_size + p.p_F * self.filter_size + p.p_O * self.output_size

    # ---- helpers ----------------------------------------------------------
    def loop_bounds(self) -> Tuple[int, ...]:
        return (self.N, self.c_I, self.c_O, self.w_O, self.h_O, self.w_F, self.h_F)

    def with_precision(self, prec: Precision) -> "ConvShape":
        return dataclasses.replace(self, prec=prec)

    def assumptions_ok(self) -> bool:
        return (
            self.w_F <= self.sw * self.w_O
            and self.h_F <= self.sh * self.h_O
            and self.sw <= self.w_F
            and self.sh <= self.h_F
        )

    def arithmetic_intensity(self) -> float:
        """FLOPs per word touched (upper bound: each word touched once)."""
        return self.flops / self.words()


def matmul_as_conv(m: int, n: int, k: int, prec: Precision = FP32) -> ConvShape:
    """GEMM C[m,n] += A[m,k] B[k,n] as the degenerate 7NL CNN.

    Mapping: N=m (batch index = rows), c_I=k (reduction), c_O=n (cols),
    w_O=h_O=w_F=h_F=1, strides 1. Then G = m*n*k as expected and the
    second bound of Thm 2.1 becomes the classical (p_T^2/4) * mnk / M matmul
    bound (Loomis-Whitney / [12] in the paper).
    """
    return ConvShape(N=m, c_I=k, c_O=n, w_O=1, h_O=1, w_F=1, h_F=1, sw=1, sh=1, prec=prec)


# --- canonical layer shapes used by the paper's experiments -----------------
def resnet50_layers(batch: int = 1000) -> dict:
    """The five standard ResNet-50 convolution sizes [He et al. 2016], as used
    in the paper's §3.2/§5 experiments. conv1 is the 7x7/stride-2 stem; convN_x
    are the representative 3x3 convolutions of each stage.
    """
    return {
        "conv1": ConvShape(N=batch, c_I=3, c_O=64, w_O=112, h_O=112, w_F=7, h_F=7, sw=2, sh=2),
        "conv2_x": ConvShape(N=batch, c_I=64, c_O=64, w_O=56, h_O=56, w_F=3, h_F=3, sw=1, sh=1),
        "conv3_x": ConvShape(N=batch, c_I=128, c_O=128, w_O=28, h_O=28, w_F=3, h_F=3, sw=1, sh=1),
        "conv4_x": ConvShape(N=batch, c_I=256, c_O=256, w_O=14, h_O=14, w_F=3, h_F=3, sw=1, sh=1),
        "conv5_x": ConvShape(N=batch, c_I=512, c_O=512, w_O=7, h_O=7, w_F=3, h_F=3, sw=1, sh=1),
    }


def alexnet_layers(batch: int = 128) -> dict:
    """AlexNet convolution layers (paper §3.2 uses AlexNet parameters)."""
    return {
        "conv1": ConvShape(N=batch, c_I=3, c_O=96, w_O=55, h_O=55, w_F=11, h_F=11, sw=4, sh=4),
        "conv2": ConvShape(N=batch, c_I=96, c_O=256, w_O=27, h_O=27, w_F=5, h_F=5, sw=1, sh=1),
        "conv3": ConvShape(N=batch, c_I=256, c_O=384, w_O=13, h_O=13, w_F=3, h_F=3, sw=1, sh=1),
        "conv4": ConvShape(N=batch, c_I=384, c_O=384, w_O=13, h_O=13, w_F=3, h_F=3, sw=1, sh=1),
        "conv5": ConvShape(N=batch, c_I=384, c_O=256, w_O=13, h_O=13, w_F=3, h_F=3, sw=1, sh=1),
    }


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def prod(xs) -> int:
    return math.prod(xs)
