"""Parallel (distributed-memory) blocking via linear programming (paper §4.2).

Each of the 7 loop axes gets a per-processor block a_j; the processor grid has
P_j = ceil(d_j / a_j) processors along axis j with prod_j P_j ~ P. Each
processor owns one block of each array:

    I_blk = p_I a_N a_cI (sw*a_wO + a_wF)(sh*a_hO + a_hF)
    F_blk = p_F a_cI a_cO a_wF a_hF
    O_blk = p_O a_N a_cO a_wO a_hO

and its communication is (up to the data it already owns, A_P/P) the sum of
the blocks it must gather plus the partial outputs it must reduce. The paper
solves a log-space LP maximizing per-processor work subject to per-array
constraints; we formulate the equivalent min-max geometric program: minimize
the largest block (communication-balancing), subject to the work-balance
equality sum_j log a_j = log(G/P). As P grows this meets the Thm 2.3
memory-independent bound (big-filter regime: all three blocks equal at
(p_I p_F p_O)^{1/3} (G/P)^{1/2}).

The integer refinement snaps the processor grid to an exact factorization of
P, which is what a mesh needs (mesh axis sizes must multiply to P).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from scipy.optimize import linprog

from .conv_model import ConvShape, ceil_div

PAR_AXES = ("N", "cI", "cO", "wO", "hO", "wF", "hF")


@dataclasses.dataclass(frozen=True)
class ParallelBlocking:
    """Per-axis processor counts (the processor grid)."""

    grid: Dict[str, int]  # axis -> number of processors splitting that axis
    shape: ConvShape

    @classmethod
    def from_grid(cls, shape: ConvShape, grid: Dict[str, int]
                  ) -> "ParallelBlocking":
        """Build from a partial axis->procs mapping (unlisted axes get 1) —
        the form tests and ``repro.distributed`` pass grids around in."""
        full = {k: 1 for k in PAR_AXES}
        for k, v in grid.items():
            if k not in PAR_AXES:
                raise ValueError(f"unknown loop axis {k!r} "
                                 f"(expected one of {PAR_AXES})")
            full[k] = int(v)
        return cls(full, shape)

    @property
    def P(self) -> int:
        return math.prod(self.grid.values())

    def block(self, axis: str) -> int:
        dims = dict(zip(PAR_AXES, self.shape.loop_bounds()))
        return ceil_div(dims[axis], self.grid[axis])

    # -- per-processor array blocks (words) -----------------------------------
    @property
    def in_block_words(self) -> float:
        s = self.shape
        a = {k: self.block(k) for k in PAR_AXES}
        return s.prec.p_I * a["N"] * a["cI"] * (s.sw * a["wO"] + a["wF"]) * (
            s.sh * a["hO"] + a["hF"])

    @property
    def filt_block_words(self) -> float:
        a = {k: self.block(k) for k in PAR_AXES}
        return self.shape.prec.p_F * a["cI"] * a["cO"] * a["wF"] * a["hF"]

    @property
    def out_block_words(self) -> float:
        a = {k: self.block(k) for k in PAR_AXES}
        return self.shape.prec.p_O * a["N"] * a["cO"] * a["wO"] * a["hO"]

    def comm_per_processor(self) -> float:
        """Words in/out of one processor: gather the input+filter blocks it
        does not own, plus reduce partial outputs when the reduction axes
        (cI, wF, hF) are split (each split copy must be combined)."""
        s = self.shape
        own = max(s.prec.p_I * s.input_size, s.prec.p_F * s.filter_size,
                  s.prec.p_O * s.output_size) / self.P
        red = self.grid["cI"] * self.grid["wF"] * self.grid["hF"]
        out_traffic = self.out_block_words * (2.0 if red > 1 else 1.0)
        vol = self.in_block_words + self.filt_block_words + out_traffic - own
        return max(vol, 0.0)

    def work_per_processor(self) -> int:
        return math.prod(self.block(k) for k in PAR_AXES)

    def imbalance(self) -> float:
        """max work / mean work over the grid (1.0 = perfectly balanced)."""
        ideal = self.shape.G / self.P
        return self.work_per_processor() / ideal


def _lp_parallel(shape: ConvShape, P: int) -> Dict[str, float]:
    """Continuous log-space min-max LP. Variables: x_j = log a_j (per-processor
    block), t = log(max block words). Returns continuous block sizes a_j."""
    s = shape
    p = s.prec
    dims = dict(zip(PAR_AXES, s.loop_bounds()))
    n = len(PAR_AXES)
    idx = {k: i for i, k in enumerate(PAR_AXES)}
    NV = n + 1  # + t
    t_i = n

    A_ub: List[List[float]] = []
    b_ub: List[float] = []

    def term(keys: Sequence[str], const: float):
        """log(const) + sum_k x_k <= t"""
        r = [0.0] * NV
        for k in keys:
            r[idx[k]] += 1.0
        r[t_i] = -1.0
        A_ub.append(r)
        b_ub.append(-math.log(max(const, 1e-300)))

    term(["N", "cO", "wO", "hO"], p.p_O)
    term(["cI", "cO", "wF", "hF"], p.p_F)
    # input window expanded into 4 monomials (paper's relaxation)
    term(["N", "cI", "wO", "hO"], p.p_I * s.sw * s.sh)
    term(["N", "cI", "wO", "hF"], p.p_I * s.sw)
    term(["N", "cI", "wF", "hO"], p.p_I * s.sh)
    term(["N", "cI", "wF", "hF"], p.p_I)

    # work balance: sum_j x_j = log(G / P)
    A_eq = [[1.0] * n + [0.0]]
    b_eq = [math.log(s.G / P)]

    bounds = [(0.0, math.log(max(dims[k], 1))) for k in PAR_AXES] + [(None, None)]
    c = [0.0] * n + [1.0]  # minimize t
    res = linprog(c, A_ub=np.asarray(A_ub), b_ub=np.asarray(b_ub),
                  A_eq=np.asarray(A_eq), b_eq=np.asarray(b_eq), bounds=bounds,
                  method="highs")
    if not res.success:
        raise RuntimeError(f"parallel blocking LP failed: {res.message}")
    return {k: math.exp(res.x[idx[k]]) for k in PAR_AXES}


def _factorizations(P: int, naxes: int, max_out: int = 20000) -> List[Tuple[int, ...]]:
    """All ordered factorizations of P into naxes factors."""

    def divisors(x: int) -> List[int]:
        out = []
        for d in range(1, int(math.isqrt(x)) + 1):
            if x % d == 0:
                out.append(d)
                if d != x // d:
                    out.append(x // d)
        return sorted(out)

    results: List[Tuple[int, ...]] = []

    def rec(rem: int, k: int, acc: Tuple[int, ...]):
        if len(results) >= max_out:
            return
        if k == 1:
            results.append(acc + (rem,))
            return
        for d in divisors(rem):
            rec(rem // d, k - 1, acc + (d,))

    rec(P, naxes, ())
    return results


def optimize_parallel_blocking(
    shape: ConvShape,
    P: int,
    restrict_axes: Optional[Sequence[str]] = None,
) -> ParallelBlocking:
    """LP + exact-factorization search: processor grid minimizing the modeled
    per-processor communication. ``restrict_axes`` limits which loop axes may
    be split (e.g. ("N", "cO") for a (data, model) mesh)."""
    axes = list(restrict_axes) if restrict_axes else list(PAR_AXES)
    dims = dict(zip(PAR_AXES, shape.loop_bounds()))
    cont = _lp_parallel(shape, P)
    # target processor count per axis from the continuous solution
    target = {k: dims[k] / cont[k] for k in PAR_AXES}

    best: Optional[ParallelBlocking] = None
    best_cost = float("inf")
    for fac in _factorizations(P, len(axes)):
        grid = {k: 1 for k in PAR_AXES}
        ok = True
        for k, f in zip(axes, fac):
            if f > dims[k]:
                ok = False
                break
            grid[k] = f
        if not ok:
            continue
        pb = ParallelBlocking(grid, shape)
        # penalize imbalance from ragged splits, weight toward LP target
        cost = pb.comm_per_processor() * pb.imbalance()
        if cost < best_cost:
            best, best_cost = pb, cost
    if best is None:
        # fall back: put everything on the largest axis
        k = max(axes, key=lambda a: dims[a])
        grid = {a: 1 for a in PAR_AXES}
        grid[k] = min(P, dims[k])
        best = ParallelBlocking(grid, shape)
    _ = target  # (kept for debug/inspection)
    return best


def parallel_efficiency(shape: ConvShape, P: int, M: float) -> Tuple[float, float, float]:
    """(modeled per-processor comm, lower bound, ratio)."""
    from .bounds import combined_parallel_bound

    pb = optimize_parallel_blocking(shape, P)
    vol = pb.comm_per_processor()
    lb = combined_parallel_bound(shape, P, M)
    return vol, lb, vol / max(lb, 1.0)
