"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts in results/dryrun/."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(variant: str = "base") -> List[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("variant", "base") == variant:
            recs.append(r)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9, r["mesh"]))
    return recs


def _fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b / 1e9:.2f}GB"
    if b >= 1e6:
        return f"{b / 1e6:.1f}MB"
    return f"{b / 1e3:.0f}KB"


def dryrun_table(recs: List[dict]) -> str:
    out = ["| arch | shape | mesh | FLOPs/chip | bytes/chip | wire/chip | "
           "temp/chip | compile |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        chips = r["chips"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['hlo_flops'] / chips:.3e} "
            f"| {r['hlo_bytes'] / chips:.3e} "
            f"| {_fmt_bytes(r['wire_bytes_per_chip'])} "
            f"| {_fmt_bytes(r['bytes_per_device'].get('temp_bytes', 0))} "
            f"| {r.get('compile_s', 0):.0f}s |")
    return "\n".join(out)


def roofline_table(recs: List[dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "useful | roofline-MFU |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['compute_s'] * 1e3:.2f}ms | {r['memory_s'] * 1e3:.2f}ms "
            f"| {r['collective_s'] * 1e3:.2f}ms | **{r['dominant']}** "
            f"| {r['useful_flops_frac']:.3f} | {r['mfu']:.4f} |")
    return "\n".join(out)


def collective_breakdown(recs: List[dict], arch: str, shape: str,
                         mesh: str = "16x16") -> Dict[str, float]:
    for r in recs:
        if (r["arch"], r["shape"], r["mesh"]) == (arch, shape, mesh):
            return r["collectives"]
    return {}


def pick_hillclimb_cells(recs: List[dict], mesh: str = "16x16") -> Dict[str, dict]:
    """worst roofline fraction / most collective-bound / paper-representative
    (the conv1d-bearing hybrid: jamba train)."""
    pool = [r for r in recs if r["mesh"] == mesh]
    if not pool:
        return {}
    worst = min(pool, key=lambda r: r["mfu"])
    coll = max(pool, key=lambda r: r["collective_s"] / max(r["step_time_s"], 1e-12))
    rep = next((r for r in pool
                if r["arch"] == "jamba_1_5_large" and r["shape"] == "train_4k"),
               pool[0])
    return {"worst_mfu": worst, "most_collective": coll, "paper_rep": rep}


if __name__ == "__main__":
    recs = load()
    print(f"{len(recs)} records\n")
    print(roofline_table(recs))
    picks = pick_hillclimb_cells(recs)
    print("\nhillclimb picks:")
    for k, r in picks.items():
        print(f"  {k}: {r['arch']} x {r['shape']} (mfu={r['mfu']:.4f}, "
              f"dominant={r['dominant']})")
