"""Roofline terms from compiled dry-run artifacts (no hardware required).

    compute term    = HLO_FLOPs / (chips * peak_FLOPs)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = wire_bytes_per_chip / link_bw

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). Collective bytes
are NOT in cost_analysis: we parse the post-SPMD module text and sum the
result sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, with ring-model wire multipliers (all-reduce 2x).

Hardware model: TPU v5e -> 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

# The unit every words_fn / bound / static audit counts in: 32-bit words.
WORD_BYTES = 4


def words_to_bytes(words, dtypes=None):
    """32-bit words (the paper's and ``repro.verify``'s unit) -> bytes.

    Scalar form (``dtypes`` omitted): ``words`` is a dtype-weighted word
    count — every ``words_fn`` and static-audit total is already priced in
    32-bit words, so bytes are a flat ``words * 4``.

    Per-operand form: ``words`` is a mapping operand -> ELEMENT count and
    ``dtypes`` the plan's per-operand dtype map (``ExecutionPlan.dtypes``
    pairs, or a dict) as carried by plan format v5. Each operand converts at
    its own storage width — an int8 input stream moves 1 byte per element
    where the f32 view would charge 4 — and a dict of per-operand bytes
    comes back. Operands absent from the map price as float32.
    """
    if dtypes is None:
        return float(words) * WORD_BYTES
    from repro.quant.spec import dtype_words
    dmap = dict(dtypes)
    out = {}
    for operand, elems in words.items():
        dt = dmap.get(operand, "float32")
        try:
            w = dtype_words(dt)
        except ValueError:
            w = 1.0  # "words:<x>" placeholders from exotic plan widths
            if dt.startswith("words:"):
                w = float(dt.split(":", 1)[1])
        out[operand] = float(elems) * w * WORD_BYTES
    return out


def hbm_seconds(words: float, chips: int = 1) -> float:
    """Roofline memory time for a word count — the bridge from the static
    auditor's exact HBM words to the same time model the dry-run rooflines
    use (``memory_s = bytes / (chips * HBM_BW)``)."""
    return words_to_bytes(words) / (chips * HBM_BW)


# Per-DMA-transfer issue latency (descriptor setup + dispatch), the alpha of
# the alpha-beta model below. ~2us is the order of a TPU async-copy issue; the
# exact constant only has to rank tile candidates, not predict wall clock.
DMA_SETUP_SECONDS = 2e-6


def alpha_beta_seconds(words: float, transfers: float, chips: int = 1
                       ) -> float:
    """Latency + bandwidth (alpha-beta) roofline for one kernel launch:
    ``hbm_seconds(words)`` (the bandwidth term every words_fn prices) plus
    ``transfers`` DMA issues at ``DMA_SETUP_SECONDS`` each. This is the
    offline cost model of ``repro.plan.autotune``: the blocking LP minimizes
    the bandwidth term alone, so near-bound tile candidates that trade a few
    percent more words for far fewer (bigger) DMA transfers rank faster here
    — exactly the frontier a measured autotuner exists to explore."""
    return hbm_seconds(words, chips) + float(transfers) * DMA_SETUP_SECONDS

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# wire multiplier per result byte (ring model)
_WIRE_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|tuple\([^)]*\)|[\w\[\],{}]+)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind wire bytes (per device, post-SPMD local shapes).
    '-done' ops are skipped so async pairs aren't double counted."""
    out = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "-done(" in s or "-done.1" in s:
            continue
        m = _OP_RE.search(s)
        if not m:
            continue
        kind = m.group(1)
        lhs = s.split("=", 1)[0]
        rhs_head = s.split("=", 1)[1]
        # result type appears right after '=' (e.g.  %x = bf16[8,128]{1,0} all-reduce(...)
        head = rhs_head.split(kind)[0]
        nbytes = _shape_bytes(head)
        if nbytes == 0:  # fall back: operand types inside parens
            nbytes = _shape_bytes(s[m.end():])
        out[kind] += nbytes * _WIRE_MULT[kind]
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    wire_bytes_per_chip: float
    collectives: Dict[str, float]
    model_flops: float
    bytes_per_device: Dict[str, float]

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much of compiled compute is useful."""
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def mfu(self) -> float:
        """Model FLOPs / (roofline step time * peak): the roofline-fraction
        score (upper bounds real MFU)."""
        return self.model_flops / (
            self.step_time_s * self.chips * PEAK_FLOPS + 1e-30)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "collectives": self.collectives,
            "model_flops": self.model_flops,
            "bytes_per_device": self.bytes_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "useful_flops_frac": self.useful_flops_frac,
            "mfu": self.mfu,
        }


def model_flops(arch: str, shape_name: str) -> float:
    """6*N_active*tokens for training; 2*N_active*tokens for serving."""
    from repro.configs import get_config
    from repro.models.config import LM_SHAPES

    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build(arch: str, shape_name: str, mesh_name: str, chips: int,
          cost: dict, mem: dict, hlo_text: str) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops * chips if flops > 0 else 0.0,
        hlo_bytes=nbytes * chips if nbytes > 0 else 0.0,
        wire_bytes_per_chip=coll["total"],
        collectives=coll,
        model_flops=model_flops(arch, shape_name),
        bytes_per_device=mem,
    )
