from . import roofline  # noqa: F401
