"""Symmetric quantization numerics shared by kernels, serving, and tests.

Per-channel (or per-tensor) symmetric int8: q = round(x / s) with
s = amax / 127, so dequantization is exact at zero, never clips in-range
values (amax / s == qmax), and the round-trip error is bounded by s / 2 —
the properties the hypothesis suite in ``tests/test_quant.py`` pins down.

Scale folding: conv(x_q * s_x, w_q * s_w[c_O]) = s_x * s_w[c_O] *
conv_int(x_q, w_q), so the quantized kernels stream ONE folded f32 scale
vector per output channel instead of dequantizing either operand — the
int8 streams stay int8 all the way into VMEM and only the f32 accumulator
sees full-width values.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_symmetric(x: jax.Array, axis: Optional[int] = None,
                       bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Symmetric quantization to int8 storage.

    ``axis=None`` -> one per-tensor scale (scalar); ``axis=i`` -> one scale
    per slice along axis i (per-channel), reduced over every other axis.
    Returns ``(q, scale)`` with ``q`` int8 and ``scale`` f32 shaped ``()`` or
    ``(x.shape[axis],)``. All-zero slices get scale 1.0 (and quantize to 0),
    so dequantization is always well-defined.
    """
    xf = jnp.asarray(x, jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    if axis is None:
        amax = jnp.max(jnp.abs(xf))
        scale = jnp.where(amax > 0, amax / qmax, 1.0)
        sb = scale
    else:
        axis = axis % xf.ndim
        red = tuple(d for d in range(xf.ndim) if d != axis)
        amax = jnp.max(jnp.abs(xf), axis=red)
        scale = jnp.where(amax > 0, amax / qmax, 1.0)
        shape = [1] * xf.ndim
        shape[axis] = xf.shape[axis]
        sb = scale.reshape(shape)
    q = jnp.clip(jnp.round(xf / sb), -qmax, qmax).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array, axis: Optional[int] = None,
               dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_symmetric` (up to the <= scale/2 error)."""
    qf = jnp.asarray(q).astype(jnp.float32)
    if axis is not None:
        shape = [1] * qf.ndim
        shape[axis % qf.ndim] = qf.shape[axis % qf.ndim]
        scale = jnp.asarray(scale).reshape(shape)
    return (qf * scale).astype(dtype)


def fold_output_scales(s_in: jax.Array, s_out_channel: jax.Array
                       ) -> jax.Array:
    """Fold a per-tensor input scale and a per-output-channel filter scale
    into the single (1, c_O) f32 vector the quantized kernels stream —
    2D so the TPU operand has a (sublane, lane) layout."""
    folded = jnp.asarray(s_in, jnp.float32) * jnp.asarray(s_out_channel,
                                                          jnp.float32)
    return folded.reshape(1, -1)


def quantize_conv_operands(x: jax.Array, w: jax.Array
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(x_q int8, w_q int8, folded (1, c_O) scale) for ``ops.conv2d_q``:
    per-tensor input scale, per-output-channel (OIHW axis 0) filter scales."""
    x_q, s_x = quantize_symmetric(x, axis=None)
    w_q, s_w = quantize_symmetric(w, axis=0)
    return x_q, w_q, fold_output_scales(s_x, s_w)


def quantize_matmul_operands(a: jax.Array, b: jax.Array
                             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(a_q int8, b_q int8, folded (1, n) scale) for ``ops.matmul_q``:
    per-tensor A scale, per-column (axis 1) B scales."""
    a_q, s_a = quantize_symmetric(a, axis=None)
    b_q, s_b = quantize_symmetric(b, axis=1)
    return a_q, b_q, fold_output_scales(s_a, s_b)
