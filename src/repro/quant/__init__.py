"""``repro.quant`` — mixed-precision / quantized execution policy.

The subsystem that makes the paper's "mixed precision" real end-to-end:

  * :class:`PrecisionSpec` — per-operand storage dtypes (int8/fp8 streams,
    f32 accumulation, per-channel scales) projecting to the paper's
    ``Precision`` word-widths, so the blocking LP, the VMEM fits, and the
    Thm 2.1/attention bounds all price operands at their *stored* width
    (narrower operands buy bigger tiles and a lower bound);
  * symmetric quantize/dequantize numerics with folded per-output-channel
    scales (``quantize_conv_operands`` / ``quantize_matmul_operands`` feed
    ``ops.conv2d_q`` / ``ops.matmul_q``);
  * presets (``INT8_SPEC`` et al.) that ``HardwareTarget.with_quant`` and
    the serving engine's KV-quant knob consume.

Depends only on ``repro.core`` so every higher layer (plan, kernels, ops,
serving) can import it without cycles.
"""

from .numerics import (  # noqa: F401
    dequantize,
    fold_output_scales,
    quantize_conv_operands,
    quantize_matmul_operands,
    quantize_symmetric,
)
from .spec import (  # noqa: F401
    DTYPE_WORDS,
    FP8_E4M3_SPEC,
    INT8_SPEC,
    KV_INT8_SPEC,
    NARROW_DTYPES,
    PrecisionSpec,
    dtype_words,
)
