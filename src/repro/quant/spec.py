"""Per-operand precision policy: which dtype each operand is *stored* in.

The paper's ``Precision`` (core.conv_model) speaks word-widths — p_I, p_F,
p_O in units of 32-bit words — which is exactly what the Thm 2.1 bounds and
the blocking LP consume. A :class:`PrecisionSpec` is the dtype-level view of
the same policy: it names the storage dtype of every operand (input, filter,
output) plus the in-kernel accumulation dtype, and projects down to a
``Precision`` so the whole planning stack (LP words objective,
``conv_kernel_footprints`` VMEM fits, Thm 2.1 bounds) prices each operand at
its stored width. Narrower storage therefore *moves the bound itself* —
int8 streams buy ~2x bigger LP tiles and halve the memory-independent term
relative to bf16 (cf. "Communication Lower Bound in Convolution
Accelerators", arxiv 1911.05662) — rather than merely shrinking the
arrays after the plan is fixed.

Rules the lint (VRF013) and the constructor both enforce: a spec whose
storage includes a sub-16-bit dtype (int8 / fp8) must accumulate in f32 or
wider — low-precision operands, high-precision accumulator, the discipline
every kernel in ``kernels/`` follows.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

from repro.core.conv_model import Precision

# Canonical storage widths in 32-bit words (the paper's unit). Keys are
# normalized jnp-style dtype names; fp8 variants share int8's width but keep
# distinct names so plans and benchmarks can tell them apart.
DTYPE_WORDS: Dict[str, float] = {
    "float32": 1.0,
    "int32": 1.0,
    "bfloat16": 0.5,
    "float16": 0.5,
    "int8": 0.25,
    "float8_e4m3fn": 0.25,
    "float8_e5m2": 0.25,
}

# dtypes the VRF013 lint treats as "narrow storage" (must declare f32+ accum)
NARROW_DTYPES = frozenset(
    name for name, w in DTYPE_WORDS.items() if w <= 0.25)


def dtype_words(name: str) -> float:
    """Storage width of a dtype name in 32-bit words (e.g. 'int8' -> 0.25)."""
    key = str(name).lower()
    try:
        return DTYPE_WORDS[key]
    except KeyError:
        raise ValueError(
            f"unknown storage dtype {name!r}; known: {sorted(DTYPE_WORDS)}")


@dataclasses.dataclass(frozen=True)
class PrecisionSpec:
    """Per-operand storage dtypes + accumulation dtype + scale granularity.

    ``scale_granularity`` documents how quantization scales are laid out:
    ``"per_channel"`` (one scale per output channel, folded input x filter —
    what ``kernels.quant`` streams) or ``"per_tensor"``. The accumulator is
    never narrower than f32 (enforced here and by lint rule VRF013).
    """

    input_dtype: str = "int8"
    filter_dtype: str = "int8"
    out_dtype: str = "bfloat16"
    acc_dtype: str = "float32"
    scale_granularity: str = "per_channel"

    def __post_init__(self):
        for name in (self.input_dtype, self.filter_dtype, self.out_dtype):
            dtype_words(name)  # raises on unknown dtypes
        if dtype_words(self.acc_dtype) < 1.0:
            raise ValueError(
                f"accumulation dtype {self.acc_dtype!r} is narrower than "
                "f32; quantized kernels must accumulate at full precision")
        if self.scale_granularity not in ("per_channel", "per_tensor"):
            raise ValueError(
                f"unknown scale granularity {self.scale_granularity!r}")

    @property
    def precision(self) -> Precision:
        """Project to the paper's word-width triple (feeds bounds + LP)."""
        return Precision(p_I=dtype_words(self.input_dtype),
                         p_F=dtype_words(self.filter_dtype),
                         p_O=dtype_words(self.out_dtype))

    def operand_dtypes(self) -> Tuple[Tuple[str, str], ...]:
        """The per-operand dtype map plan format v5 carries."""
        return (("input", self.input_dtype), ("filter", self.filter_dtype),
                ("output", self.out_dtype), ("accum", self.acc_dtype))

    @property
    def is_quantized(self) -> bool:
        return (self.input_dtype in NARROW_DTYPES
                or self.filter_dtype in NARROW_DTYPES)

    # -- (de)serialization (rides HardwareTarget.to_dict) --------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "input_dtype": self.input_dtype,
            "filter_dtype": self.filter_dtype,
            "out_dtype": self.out_dtype,
            "acc_dtype": self.acc_dtype,
            "scale_granularity": self.scale_granularity,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PrecisionSpec":
        return cls(
            input_dtype=d.get("input_dtype", "int8"),
            filter_dtype=d.get("filter_dtype", "int8"),
            out_dtype=d.get("out_dtype", "bfloat16"),
            acc_dtype=d.get("acc_dtype", "float32"),
            scale_granularity=d.get("scale_granularity", "per_channel"),
        )


# Presets. INT8_SPEC is what `ops.conv2d_q` / `ops.matmul_q` implement: int8
# input+filter streams, f32 accumulation, bf16 stores. The fp8 variants share
# its word-widths (the LP and bounds cannot tell them apart) but no kernel
# implements them yet — they exist so plans/targets can already describe
# fp8-storage hardware.
INT8_SPEC = PrecisionSpec()
FP8_E4M3_SPEC = PrecisionSpec(input_dtype="float8_e4m3fn",
                              filter_dtype="float8_e4m3fn")
KV_INT8_SPEC = PrecisionSpec(out_dtype="float32", scale_granularity="per_channel")
