"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 - pruned nemotron. [arXiv:2407.14679; hf]"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab_size=256000, pattern=("attn",),
)
SMOKE = reduced(CONFIG)
