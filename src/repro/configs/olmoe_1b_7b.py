"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, pattern=("attn",),
    n_experts=64, experts_per_token=8,
)
SMOKE = reduced(CONFIG)
