"""The paper's own benchmark shapes: the five standard ResNet-50 convolution
sizes [He et al. 2016] evaluated on GEMMINI in paper SS5 (batch 1000)."""
from repro.core.conv_model import resnet50_layers, alexnet_layers  # noqa: F401

RESNET50 = resnet50_layers(1000)
ALEXNET = alexnet_layers(128)
