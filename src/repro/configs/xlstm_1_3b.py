"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 - alternating
mLSTM + sLSTM blocks. [arXiv:2405.04517; unverified]

Sub-quadratic (chunked recurrent) -> runs the long_500k cell."""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, pattern=("mlstm", "slstm"),
    ssm_expand=2, chunk_size=256,
)
SMOKE = reduced(CONFIG)
