"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 - InternViT frontend STUBBED (input_specs provides patch
embeddings); the LM backbone decodes text. [arXiv:2404.16821; hf]"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151655, pattern=("attn",),
    inputs_are_embeddings=True,  # train/prefill consume stub patch embeds
)
SMOKE = reduced(CONFIG)
