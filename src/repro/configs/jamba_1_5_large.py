"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave.
[arXiv:2403.19887; hf]

Pattern unit: 8 blocks (attn at position 4, mamba elsewhere) repeated 9x;
MoE FFN on every other position (moe_every=2), dense SwiGLU otherwise.
Hybrid (mamba O(1) state, 9 attention layers) -> runs the long_500k cell.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="jamba-1.5-large", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    pattern=("mamba", "mamba", "mamba", "mamba", "attn",
             "mamba", "mamba", "mamba"),
    n_experts=16, experts_per_token=2, moe_every=2,
    ssm_expand=2, ssm_state_dim=16, conv_kernel=4, chunk_size=256,
)
SMOKE = reduced(CONFIG)
