"""Assigned architecture configs (--arch <id>) + the paper's own ResNet50
conv benchmark shapes. Each module exposes CONFIG (full size, dry-run only)
and SMOKE (reduced, runs a step on CPU)."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import LM_SHAPES, ModelConfig, ShapeSpec, reduced

ARCH_IDS: List[str] = [
    "qwen2_5_3b",
    "stablelm_1_6b",
    "phi3_medium_14b",
    "minitron_8b",
    "phi3_5_moe_42b",
    "olmoe_1b_7b",
    "xlstm_1_3b",
    "hubert_xlarge",
    "internvl2_1b",
    "jamba_1_5_large",
]

# shape cells skipped per arch (DESIGN.md §4): long_500k needs sub-quadratic
# attention; encoder-only models have no decode step.
SKIPS: Dict[str, List[str]] = {
    "qwen2_5_3b": ["long_500k"],
    "stablelm_1_6b": ["long_500k"],
    "phi3_medium_14b": ["long_500k"],
    "minitron_8b": ["long_500k"],
    "phi3_5_moe_42b": ["long_500k"],
    "olmoe_1b_7b": ["long_500k"],
    "xlstm_1_3b": [],
    "hubert_xlarge": ["decode_32k", "long_500k"],
    "internvl2_1b": ["long_500k"],
    "jamba_1_5_large": [],
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return getattr(mod, "SMOKE", None) or reduced(mod.CONFIG)


def cells(arch: str) -> List[ShapeSpec]:
    return [s for n, s in LM_SHAPES.items() if n not in SKIPS[arch]]


def all_cells() -> List[tuple]:
    return [(a, s) for a in ARCH_IDS for s in cells(a)]
