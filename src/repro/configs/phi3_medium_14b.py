"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 - RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab_size=100352, pattern=("attn",),
)
SMOKE = reduced(CONFIG)
