"""hubert-xlarge [audio]: 48L d_model=1280 16H d_ff=5120 vocab=504 -
encoder-only backbone; the conv waveform frontend is a STUB (input_specs
provides precomputed frame embeddings). [arXiv:2106.07447; unverified]

Encoder-only: no decode shapes (DESIGN.md skip)."""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504, pattern=("attn",),
    causal=False, inputs_are_embeddings=True,
)
SMOKE = reduced(CONFIG)
