"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="phi3.5-moe-42b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064, pattern=("attn",),
    n_experts=16, experts_per_token=2,
)
SMOKE = reduced(CONFIG)
