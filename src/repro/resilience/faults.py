"""Deterministic seeded fault injection: :class:`FaultCampaign` + the
dispatch hook.

A campaign is a seeded stream of go/no-go decisions consumed at named
**sites**: the dispatcher consults it around every eager kernel launch
(``dispatch/<op>``), and the serving engine consults it at its host-side
scheduling points (``admit/launch``, ``admit/numeric``, ``admit/oom``,
``decode/numeric``, ``decode/pool``, ``finish/pool``). Each positive draw
yields an :class:`Injection` record; the handler that recovers from the
fault stamps ``Injection.resolution`` (``"degraded"``, ``"retried"``,
``"row_failed"``, ``"backpressure"``, ``"rebuilt"``, ``"fatal"``).
``unresolved()``/``verify_accounted()`` then prove no handler silently
swallowed a fault — the property the ``fault_swallowed`` seeded mutant
plants a violation of.

Fault kinds:

  * ``launch``  - raise :class:`KernelLaunchError` before the kernel runs
  * ``dma``     - raise :class:`DmaTimeout` before the kernel runs
  * ``numeric`` - let the kernel run, then poison its output with NaN
  * ``device``  - raise :class:`DeviceLost` (fatal; must propagate)
  * ``oom``     - starve the paged block pool at admission (engine site)
  * ``pool``    - corrupt the block allocator's invariants (engine site)

Activation: ``with activate(FaultCampaign(...)):`` installs the campaign
process-wide (engine sites read :func:`active_campaign`; the dispatch hook
is installed on ``repro.ops.dispatch``). The ``REPRO_FAULTS`` environment
knob does the same persistently, e.g.::

    REPRO_FAULTS="rate=0.05,seed=0,kinds=launch+numeric,ops=conv2d,max=10"

The dispatch hook NEVER fires under tracing (any argument a
``jax.core.Tracer``): a fault injected at trace time would be compiled into
the cached executable and replayed on every subsequent call — a permanent
failure wearing a transient's name. Engine sites are host-side and eager,
so they are unaffected; the quarantine in ``repro.ops.dispatch`` *does*
apply at trace time, which is exactly the demote-the-compiled-variant
semantics wanted there.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .errors import (DeviceLost, DmaTimeout, FaultAccountingError,
                     KernelLaunchError, NumericFault)

FAULTS_ENV = "REPRO_FAULTS"

FAULT_KINDS = ("launch", "dma", "numeric", "device", "oom", "pool")
# kinds the dispatch hook can realize on an eager op call
DISPATCH_KINDS = ("launch", "dma", "numeric", "device")

_FAULT_TYPES = {"launch": KernelLaunchError, "dma": DmaTimeout,
                "numeric": NumericFault, "device": DeviceLost}


@dataclasses.dataclass
class Injection:
    """One planted fault: where, what, and how the system dealt with it."""

    seq: int
    site: str
    kind: str
    op: Optional[str] = None
    resolution: Optional[str] = None  # stamped by the recovering handler
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)


class FaultCampaign:
    """A seeded, rate-limited fault plan.

    ``rate`` is the per-site-visit injection probability; ``kinds`` the
    fault kinds this campaign may plant (a site additionally narrows to the
    kinds it can realize); ``ops`` optionally restricts dispatch-site
    injections to the named ops; ``max_faults`` caps total injections so
    rate-1.0 chaos schedules still terminate. The decision stream is a
    ``numpy`` Generator seeded with ``seed`` — same seed, same visit order,
    same faults, which is what lets benchmarks compare a faulted run
    against its fault-free twin row by row."""

    def __init__(self, seed: int = 0, rate: float = 0.05,
                 kinds: Sequence[str] = ("launch", "numeric"),
                 ops: Optional[Sequence[str]] = None,
                 max_faults: Optional[int] = None):
        bad = set(kinds) - set(FAULT_KINDS)
        if bad:
            raise ValueError(f"unknown fault kinds {sorted(bad)}; "
                             f"known: {FAULT_KINDS}")
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        self.seed = seed
        self.rate = float(rate)
        self.kinds = tuple(kinds)
        self.ops = None if ops is None else tuple(ops)
        self.max_faults = max_faults
        self._rng = np.random.default_rng(seed)
        self.injections: List[Injection] = []
        self.draws = 0

    # -- the decision stream ------------------------------------------------

    def draw(self, site: str, kinds: Optional[Sequence[str]] = None,
             op: Optional[str] = None) -> Optional[Injection]:
        """One deterministic fault decision at a named site. Returns the
        :class:`Injection` to realize, or None. Every visit consumes exactly
        one uniform draw (plus one kind choice on a hit), so the stream is a
        pure function of the visit order — not of which faults fired."""
        if op is not None and self.ops is not None and op not in self.ops:
            return None
        allowed = [k for k in self.kinds if kinds is None or k in kinds]
        self.draws += 1
        u = float(self._rng.random())
        if not allowed or u >= self.rate:
            return None
        if (self.max_faults is not None
                and len(self.injections) >= self.max_faults):
            return None
        kind = allowed[int(self._rng.integers(len(allowed)))]
        inj = Injection(seq=len(self.injections), site=site, kind=kind, op=op)
        self.injections.append(inj)
        return inj

    def fault_for(self, inj: Injection, op: Optional[str] = None,
                  backend: Optional[str] = None):
        """The taxonomy exception realizing ``inj`` (raise-style kinds)."""
        cls = _FAULT_TYPES[inj.kind]
        return cls(f"injected {inj.kind} fault at {inj.site} "
                   f"(campaign seed={self.seed}, seq={inj.seq})",
                   op=op or inj.op, backend=backend, injection=inj)

    # -- corruption helpers (realize-style kinds) ---------------------------

    def corrupt_output(self, out, inj: Injection):
        """NaN-poison the first element of the first floating leaf."""
        import jax
        import jax.numpy as jnp

        leaves, treedef = jax.tree_util.tree_flatten(out)
        for i, leaf in enumerate(leaves):
            if (hasattr(leaf, "dtype")
                    and jnp.issubdtype(leaf.dtype, jnp.floating)):
                flat = jnp.ravel(leaf).at[0].set(jnp.nan)
                leaves[i] = flat.reshape(leaf.shape)
                inj.detail["leaf"] = i
                break
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def corrupt_rows(self, logits, rows: Sequence[int], inj: Injection):
        """NaN an entire logits row drawn from ``rows`` (active slots), so
        the engine's per-row guard — not a whole-batch abort — must fire."""
        import jax.numpy as jnp

        victim = int(rows[int(self._rng.integers(len(rows)))])
        inj.detail["row"] = victim
        return jnp.asarray(logits).at[victim].set(jnp.nan)

    def corrupt_allocator(self, alloc, inj: Optional[Injection] = None):
        """Break exactly one ``BlockAllocator`` invariant (deterministically
        picking whichever state the pool is in): leak a free block, dangle
        an evictable block's key mapping, or fabricate a phantom refcount.
        ``alloc.check()`` must then raise :class:`PoolIntegrityFault`."""
        detail = inj.detail if inj is not None else {}
        if alloc._free:
            detail["corruption"] = f"leaked free block {alloc._free[-1]}"
            alloc._free.pop()
        elif alloc._evictable:
            bid, _ = alloc._evictable.popitem()
            detail["corruption"] = f"dangled evictable block {bid}"
        else:
            detail["corruption"] = "phantom refcount on block id -1"
            alloc._rc[-1] = 1

    # -- accounting ---------------------------------------------------------

    def resolve(self, inj_or_fault, resolution: str) -> None:
        """Stamp how a planted fault was handled. Accepts the Injection or
        the taxonomy exception carrying one; a None/organic fault is a
        no-op so handlers need no injected-vs-organic branch."""
        inj = getattr(inj_or_fault, "injection", inj_or_fault)
        if isinstance(inj, Injection):
            inj.resolution = resolution

    def resolve_kind(self, kind: str, resolution: str) -> None:
        """Stamp every still-unresolved injection of one kind (e.g. all
        pending ``pool`` corruptions once a rebuild repaired the pool)."""
        for inj in self.injections:
            if inj.kind == kind and inj.resolution is None:
                inj.resolution = resolution

    def unresolved(self) -> List[Injection]:
        return [i for i in self.injections if i.resolution is None]

    def verify_accounted(self) -> None:
        """Raise :class:`FaultAccountingError` if any injection was
        swallowed without a recorded resolution."""
        leaks = self.unresolved()
        if leaks:
            first = leaks[0]
            raise FaultAccountingError(
                f"{len(leaks)} injected fault(s) were swallowed without a "
                f"resolution; first: {first.kind} at {first.site} "
                f"(seq {first.seq})", injection=first)

    def summary(self) -> Dict[str, Any]:
        by_res: Dict[str, int] = {}
        for inj in self.injections:
            key = inj.resolution or "UNRESOLVED"
            by_res[key] = by_res.get(key, 0) + 1
        return {"seed": self.seed, "rate": self.rate, "draws": self.draws,
                "injected": len(self.injections), "resolutions": by_res}


# ---------------------------------------------------------------------------
# The dispatch hook: realizes dispatch-site faults around eager op calls.
# ---------------------------------------------------------------------------

class DispatchFaultHook:
    """Installed on ``repro.ops.dispatch`` while a campaign is active."""

    def __init__(self, campaign: FaultCampaign):
        self.campaign = campaign

    def run(self, op: str, backend: str, runner, tracing: bool):
        if tracing:
            # never bake a fault into a compiled artifact (module docstring)
            return runner()
        c = self.campaign
        inj = c.draw(f"dispatch/{op}", kinds=DISPATCH_KINDS, op=op)
        if inj is not None and inj.kind in ("launch", "dma", "device"):
            if inj.kind == "device":
                # fatal by construction: account it here, since no handler
                # below the caller is supposed to catch it
                inj.resolution = "fatal"
            raise c.fault_for(inj, op=op, backend=backend)
        out = runner()
        if inj is not None:  # numeric: poison after the kernel ran
            out = c.corrupt_output(out, inj)
        if not _all_finite(out):
            raise NumericFault(f"non-finite output from {op}", op=op,
                               backend=backend, injection=inj)
        return out


def _all_finite(out) -> bool:
    import jax
    import jax.numpy as jnp

    for leaf in jax.tree_util.tree_leaves(out):
        if (hasattr(leaf, "dtype")
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            if not bool(jnp.all(jnp.isfinite(leaf))):
                return False
    return True


# ---------------------------------------------------------------------------
# Activation (process-wide): context manager, persistent install, env knob.
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultCampaign] = None


def active_campaign() -> Optional[FaultCampaign]:
    """The campaign engine-level sites should consult (None = no faults)."""
    return _ACTIVE


def install(campaign: Optional[FaultCampaign]) -> Optional[FaultCampaign]:
    """Persistently (de)activate a campaign: sets the module-level campaign
    and the dispatch hook. Prefer :func:`activate` in tests."""
    global _ACTIVE
    _ACTIVE = campaign
    from repro.ops import dispatch as _dispatch  # lazy: avoids a cycle

    _dispatch.set_fault_hook(
        DispatchFaultHook(campaign) if campaign is not None else None)
    return campaign


@contextlib.contextmanager
def activate(campaign: FaultCampaign) -> Iterator[FaultCampaign]:
    """Scoped activation, restoring whatever was active before."""
    prev = _ACTIVE
    install(campaign)
    try:
        yield campaign
    finally:
        install(prev)


def campaign_from_spec(spec: str) -> FaultCampaign:
    """Parse a ``REPRO_FAULTS`` spec:
    ``rate=0.05,seed=0,kinds=launch+numeric,ops=conv2d+matmul,max=10``."""
    fields: Dict[str, str] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"bad {FAULTS_ENV} field {part!r} "
                             "(expected key=value)")
        key, val = part.split("=", 1)
        fields[key.strip()] = val.strip()
    known = {"rate", "seed", "kinds", "ops", "max"}
    unknown = set(fields) - known
    if unknown:
        raise ValueError(f"unknown {FAULTS_ENV} field(s) {sorted(unknown)}; "
                         f"known: {sorted(known)}")
    kinds: Tuple[str, ...] = tuple(
        fields.get("kinds", "launch+numeric").split("+"))
    ops = tuple(fields["ops"].split("+")) if "ops" in fields else None
    max_faults = int(fields["max"]) if "max" in fields else None
    return FaultCampaign(seed=int(fields.get("seed", "0")),
                         rate=float(fields.get("rate", "0.05")),
                         kinds=kinds, ops=ops, max_faults=max_faults)


def install_env_campaign() -> Optional[FaultCampaign]:
    """Install the campaign the ``REPRO_FAULTS`` env var describes (no-op
    when unset). Called once from the dispatcher's first eager dispatch."""
    spec = os.environ.get(FAULTS_ENV)
    if not spec:
        return None
    return install(campaign_from_spec(spec))
