"""Structured runtime-failure taxonomy for dispatch, serving, and the mesh.

Every runtime failure in the execution stack is either **transient** (retry
or demote to a cheaper backend and keep serving) or **fatal** (no amount of
retrying helps; surface it). The split is the contract the graceful-
degradation machinery is built on:

  * ``repro.ops.dispatch`` catches :class:`TransientFault` from a kernel
    entry, quarantines the failing ``(op, backend, shape-key)``, and
    re-dispatches down the fallback chain with the degradation re-priced
    (``DispatchDecision.degraded``/``fault``);
  * ``serving.Engine`` catches transients at admission/decode and converts
    them into bounded retries, row-level failures (``finish_reason="error"``)
    or backpressure — never a poisoned lockstep batch;
  * :class:`FatalFault` always propagates.

Everything subclasses ``RuntimeError`` so pre-taxonomy callers (and tests)
that catch ``RuntimeError`` keep working; ``serving.kv.BlockOOM`` is
reclassified as a :class:`TransientFault` subclass for the same reason.

Faults carry structured context: ``op``/``backend`` name the failing
dispatch, ``injection`` points at the :class:`repro.resilience.faults.
Injection` record when a campaign planted the fault (None for organic
failures), and free-form keyword ``diagnostics`` (pool occupancy, shape
keys, deadlines) ride along for the operator instead of being baked into
the message string.
"""

from __future__ import annotations

from typing import Any, Dict, Optional


class Fault(RuntimeError):
    """Base of the typed failure taxonomy (see module docstring)."""

    transient: bool = False

    def __init__(self, message: str = "", *, op: Optional[str] = None,
                 backend: Optional[str] = None, injection: Any = None,
                 **diagnostics: Any):
        super().__init__(message)
        self.op = op
        self.backend = backend
        self.injection = injection
        self.diagnostics: Dict[str, Any] = dict(diagnostics)

    def __str__(self) -> str:
        base = super().__str__()
        ctx = []
        if self.op is not None:
            ctx.append(f"op={self.op}")
        if self.backend is not None:
            ctx.append(f"backend={self.backend}")
        ctx.extend(f"{k}={v}" for k, v in sorted(self.diagnostics.items()))
        return f"{base} [{', '.join(ctx)}]" if ctx else base


class TransientFault(Fault):
    """Recoverable: retry in place, demote along the fallback chain, or
    degrade the single affected request — the system keeps serving."""

    transient = True


class FatalFault(Fault):
    """Unrecoverable: no retry/demotion policy applies; must propagate."""

    transient = False


class KernelLaunchError(TransientFault):
    """A kernel entry failed at launch (lowering/launch-time error). The
    dispatcher demotes the call to the next backend in the chain."""


class NumericFault(TransientFault):
    """An op produced NaN/Inf output. Idempotent call sites (decode steps
    rewrite the same cache positions with the same values) retry; persistent
    non-finite logits fail only the affected batch rows."""


class DmaTimeout(TransientFault):
    """A manual DMA (async copy) never landed within its window — treated
    exactly like a launch failure: demote and quarantine."""


class PoolIntegrityFault(TransientFault):
    """A ``kv.BlockAllocator.check()`` invariant is broken (leaked block,
    dangling prefix key, phantom refcount). Transient because the engine can
    rebuild the pool from host-side request state (prompts + accepted
    tokens) without losing any request."""


class DeviceLost(FatalFault):
    """The accelerator is gone. Nothing downstream of the dispatch can
    recover this; the caller (or its supervisor) must re-plan placement."""


class AdmissionImpossible(FatalFault):
    """No schedule could ever admit this request — e.g. the paged KV pool is
    too small for the prompt even with every slot free. Retrying the same
    configuration can never succeed; the pool must be resized."""


class SchedulerStall(FatalFault):
    """The serving loop made no progress for an implausible number of
    scheduling rounds — the never-deadlock backstop for pathological
    (rate=1, unbounded) fault campaigns."""


class FaultAccountingError(FatalFault):
    """A campaign injection was swallowed: some handler caught a planted
    fault without recording a resolution. Raised by
    ``FaultCampaign.verify_accounted()`` — the check the ``fault_swallowed``
    seeded mutant exists to exercise."""
