"""``repro.resilience`` — the typed fault taxonomy + seeded fault injection.

``errors`` defines the :class:`TransientFault`/:class:`FatalFault` split the
dispatcher and serving engine recover by; ``faults`` the deterministic
:class:`FaultCampaign` harness that plants failures at named sites (and
proves every one was handled). See each module's docstring, and the README
"Robustness" section for the operator-facing view.
"""

from .errors import (  # noqa: F401
    AdmissionImpossible,
    DeviceLost,
    DmaTimeout,
    FatalFault,
    Fault,
    FaultAccountingError,
    KernelLaunchError,
    NumericFault,
    PoolIntegrityFault,
    SchedulerStall,
    TransientFault,
)
from .faults import (  # noqa: F401
    DISPATCH_KINDS,
    FAULT_KINDS,
    FAULTS_ENV,
    DispatchFaultHook,
    FaultCampaign,
    Injection,
    activate,
    active_campaign,
    campaign_from_spec,
    install,
    install_env_campaign,
)
