"""Paged KV-cache subsystem: fixed-size blocks, block tables, prefix sharing.

The serving engine's decode cost is almost pure KV-cache traffic (the
memory-independent term of ``core.bounds.attention_bound`` dominates at
Lq = 1), so the pool exists to make that traffic proportional to *live*
tokens rather than to ``batch * max_len``:

- The cache is one physical pool of ``num_blocks`` fixed-size blocks
  (``block_size`` token positions each, vLLM-style); a request holds a
  *block table* — the list of physical block ids backing its logical
  positions — instead of a contiguous slice.
- Full prompt blocks are content-addressed by a chained hash key
  (``parent_key, block_tokens``), so two requests sharing a system prompt
  share physical blocks with reference counting; the pool charges the prefix
  once.
- Allocation is explicit: ``BlockAllocator.alloc`` raises :class:`BlockOOM`
  when the pool (plus the LRU pool of retained rc=0 prefix blocks) is
  exhausted, and the engine turns that into admission backpressure rather
  than silent eviction of live state.
- Block id 0 is a reserved garbage block: dead batch rows and padded table
  entries point at it, so lockstep decode can write/read it harmlessly.

``plan_pool_blocks`` sizes the pool from ``HardwareTarget.hbm_words`` the
same way ``Engine.plan_batch_size`` sizes the slot pool.
"""

from __future__ import annotations

import collections
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.resilience.errors import PoolIntegrityFault, TransientFault

DEFAULT_BLOCK_SIZE = 16
GARBAGE_BLOCK = 0

# A full prompt block's content address: (parent block's key or None, the
# block_size token ids it holds). Chaining the parent key makes equal token
# windows at different prefix positions distinct, like vLLM's hash chain.
PrefixKey = Tuple[Optional[tuple], Tuple[int, ...]]


class BlockOOM(TransientFault):
    """The pool cannot satisfy an allocation; admission must back off.

    A :class:`repro.resilience.errors.TransientFault` (still a
    ``RuntimeError`` through the taxonomy base): pool pressure is the
    canonical recoverable condition — the engine answers with bounded
    backpressure, never an abort."""


def prefix_chain(tokens: Sequence[int], block_size: int) -> List[PrefixKey]:
    """Content keys for every FULL block of ``tokens``, in chain order.

    Only full blocks are shareable: a partial tail block will be appended to
    during decode, so it is always private to its request."""
    keys: List[PrefixKey] = []
    parent: Optional[PrefixKey] = None
    for s in range(0, len(tokens) - block_size + 1, block_size):
        key: PrefixKey = (parent, tuple(int(t) for t in tokens[s:s + block_size]))
        keys.append(key)
        parent = key
    return keys


class BlockAllocator:
    """Refcounted block allocator with LRU retention of shareable blocks.

    States a (non-reserved) block can be in — exactly one at any time:

    - **free**: on the free list, contents meaningless.
    - **in use**: refcount >= 1 (held by one or more requests).
    - **evictable**: refcount == 0 but registered under a prefix key; its
      contents are kept so a future request with the same prefix can revive
      it. Evicted (moved to free) lazily, oldest first, only when the free
      list runs dry.

    ``num_blocks`` counts the whole pool including reserved ids, matching the
    physical pool array's leading axis.
    """

    def __init__(self, num_blocks: int,
                 reserved: Sequence[int] = (GARBAGE_BLOCK,)):
        if num_blocks <= len(reserved):
            raise ValueError(
                f"pool of {num_blocks} blocks leaves nothing to allocate "
                f"after {len(reserved)} reserved")
        self.num_blocks = num_blocks
        self.reserved = tuple(reserved)
        self._free: collections.deque[int] = collections.deque(
            b for b in range(num_blocks) if b not in self.reserved)
        self._rc: Dict[int, int] = {}
        self._key_of: Dict[int, PrefixKey] = {}
        self._block_of: Dict[PrefixKey, int] = {}
        # rc==0 registered blocks, insertion order == LRU order
        self._evictable: "collections.OrderedDict[int, None]" = (
            collections.OrderedDict())

    # -- allocation ---------------------------------------------------------

    def alloc(self) -> int:
        """A block with refcount 1. Raises :class:`BlockOOM` when neither the
        free list nor the evictable LRU can supply one."""
        if self._free:
            bid = self._free.popleft()
        elif self._evictable:
            bid, _ = self._evictable.popitem(last=False)  # oldest first
            del self._block_of[self._key_of.pop(bid)]
        else:
            raise BlockOOM(
                f"all {self.num_blocks - len(self.reserved)} allocatable "
                f"blocks are referenced")
        self._rc[bid] = 1
        return bid

    def ref(self, bid: int) -> int:
        """Take an additional reference (reviving an evictable block)."""
        rc = self._rc.get(bid, 0)
        if rc == 0:
            if bid not in self._evictable:
                raise ValueError(f"block {bid} is not live or evictable")
            del self._evictable[bid]
        self._rc[bid] = rc + 1
        return bid

    def free(self, bid: int) -> None:
        """Drop one reference. A registered block that reaches refcount 0
        becomes evictable (contents retained for prefix reuse); an anonymous
        one returns to the free list."""
        rc = self._rc.get(bid, 0)
        if rc <= 0:
            raise ValueError(f"double free of block {bid}")
        if rc > 1:
            self._rc[bid] = rc - 1
            return
        del self._rc[bid]
        if bid in self._key_of:
            self._evictable[bid] = None  # most-recently-used end
        else:
            self._free.append(bid)

    # -- prefix sharing -----------------------------------------------------

    def lookup(self, key: PrefixKey) -> Optional[int]:
        return self._block_of.get(key)

    def register(self, bid: int, key: PrefixKey) -> None:
        """Content-address a live block so later requests can share it."""
        if self._rc.get(bid, 0) <= 0:
            raise ValueError(f"cannot register non-live block {bid}")
        other = self._block_of.get(key)
        if other is not None and other != bid:
            raise ValueError(f"key already registered to block {other}")
        prev = self._key_of.get(bid)
        if prev is not None and prev != key:
            del self._block_of[prev]
        self._key_of[bid] = key
        self._block_of[key] = bid

    # -- accounting ---------------------------------------------------------

    def refcount(self, bid: int) -> int:
        return self._rc.get(bid, 0)

    def available(self) -> int:
        """Blocks an alloc() can obtain right now (free + evictable)."""
        return len(self._free) + len(self._evictable)

    def live_blocks(self) -> int:
        """Blocks with refcount >= 1."""
        return len(self._rc)

    def used_words(self, words_per_block: float) -> float:
        """Pool occupancy in words — shared prefix blocks counted ONCE."""
        return self.live_blocks() * words_per_block

    def check(self) -> None:
        """Invariant check: every non-reserved block is in exactly one of
        {free, live, evictable}, and key maps are mutually inverse. Raises
        :class:`PoolIntegrityFault` (transient: the engine rebuilds the
        pool from host-side request state) with occupancy diagnostics."""
        free = set(self._free)
        live = set(self._rc)
        evict = set(self._evictable)
        problems: List[str] = []
        if (free & live) or (free & evict) or (live & evict):
            problems.append("a block is in two states at once")
        if free | live | evict != (
                set(range(self.num_blocks)) - set(self.reserved)):
            problems.append("free|live|evictable does not partition the pool")
        if not all(rc > 0 for rc in self._rc.values()):
            problems.append("non-positive refcount on a live block")
        if {k: b for b, k in self._key_of.items()} != self._block_of:
            problems.append("prefix-key maps are not mutually inverse")
        if not all(b in self._rc or b in self._evictable
                   for b in self._key_of):
            problems.append("a keyed block is neither live nor evictable")
        if problems:
            raise PoolIntegrityFault(
                "; ".join(problems), num_blocks=self.num_blocks,
                free=len(free), live=len(live), evictable=len(evict))


# ---------------------------------------------------------------------------
# Pool sizing (words per block, blocks per HBM budget)
# ---------------------------------------------------------------------------

def block_words(cfg, block_size: int, dtype_itemsize: int = 2,
                quantized: bool = False) -> float:
    """32-bit words one physical block occupies across all attention layers
    (K and V, un-repeated GQA heads). ``quantized`` switches to the int8
    pool layout: one byte per element plus one f32 scale per (kv_head,
    position) row — (1 + 4/hd) bytes per element, vs bf16's 2 — so a
    quantized pool packs ~2x the blocks into the same budget (the
    ``capacity_gain`` gate in benchmarks/quant_bench.py)."""
    n_attn = cfg.repeats * sum(1 for kind in cfg.pattern if kind == "attn")
    elems = n_attn * 2 * cfg.n_kv_heads * block_size * cfg.hd
    if quantized:
        return elems * (1.0 + 4.0 / cfg.hd) / 4.0
    return elems * dtype_itemsize / 4.0


def plan_pool_blocks(cfg, max_len: int, batch_size: int,
                     block_size: int = DEFAULT_BLOCK_SIZE,
                     target=None, hbm_fraction: float = 0.25,
                     dtype_itemsize: int = 2, quantized: bool = False) -> int:
    """Pool size in blocks: enough for every slot to hold ``max_len`` tokens
    (plus the reserved garbage block), clamped to ``hbm_fraction`` of the
    target's HBM — but never below one full sequence, mirroring
    ``Engine.plan_batch_size``'s budget policy. ``quantized`` prices blocks
    at the int8+scales layout (see :func:`block_words`)."""
    per_seq = math.ceil(max_len / block_size)
    want = 1 + batch_size * per_seq
    if target is None:
        return want
    budget = hbm_fraction * target.hbm_words
    cap = 1 + int(budget // max(
        block_words(cfg, block_size, dtype_itemsize, quantized=quantized),
        1.0))
    return max(min(want, cap), 1 + per_seq)
