from .engine import Engine, Request, make_decode_step, make_prefill_step  # noqa: F401
