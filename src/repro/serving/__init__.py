from .engine import (  # noqa: F401
    Engine,
    Request,
    WaveEngine,
    plan_batch_size,
)
from .kv import (  # noqa: F401
    DEFAULT_BLOCK_SIZE,
    BlockAllocator,
    BlockOOM,
    block_words,
    plan_pool_blocks,
    prefix_chain,
)
