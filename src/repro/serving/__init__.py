from .engine import (  # noqa: F401
    Engine,
    Request,
    WaveEngine,
    plan_batch_size,
)
