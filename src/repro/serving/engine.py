"""Batched serving engine: prefill + lockstep decode with wave-style
continuous batching.

A wave = a fixed batch of requests padded to a common prompt length. The
engine prefills the whole wave in one pjit'd call (chunked-sequence forward
writes the KV cache / recurrent state), then decodes in lockstep; finished
sequences are masked. When every sequence in a wave finishes, the next wave
is formed from the queue. This is the batching regime the decode_32k /
long_500k dry-run cells lower: serve_step = one token for the whole batch
against a seq_len-deep cache.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.plan import CPU_INTERPRET, HardwareTarget

PyTree = Any


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    out_tokens: Optional[np.ndarray] = None


def make_prefill_step(cfg: ModelConfig, use_pallas: bool = False):
    def prefill(params, cache, tokens):  # tokens (B, Lp)
        logits, cache, _ = T.forward(params, cfg, tokens=tokens, cache=cache,
                                     cache_index=jnp.zeros((), jnp.int32),
                                     use_pallas=use_pallas)
        return logits[:, -1], cache
    return jax.jit(prefill, donate_argnums=(1,))


def make_decode_step(cfg: ModelConfig, use_pallas: bool = False):
    def decode(params, cache, token, index):  # token (B,1), index scalar
        logits, cache, _ = T.forward(params, cfg, tokens=token, cache=cache,
                                     cache_index=index, decode=True,
                                     use_pallas=use_pallas)
        return logits[:, -1], cache
    return jax.jit(decode, donate_argnums=(1,))


class Engine:
    def __init__(self, cfg: ModelConfig, params: PyTree, max_len: int = 512,
                 batch_size: int = 4, use_pallas: Optional[bool] = None,
                 seed: int = 0, target: Optional[HardwareTarget] = None):
        assert cfg.causal, "serving requires a decoder model"
        self.cfg, self.params = cfg, params
        self.max_len, self.batch_size = max_len, batch_size
        self.target = target or CPU_INTERPRET
        if use_pallas is None:
            use_pallas = self.target.use_pallas
        self.prefill_step = make_prefill_step(cfg, use_pallas)
        self.decode_step = make_decode_step(cfg, use_pallas)
        self.key = jax.random.PRNGKey(seed)

    def _sample_wave(self, logits: jax.Array, temps: np.ndarray) -> jax.Array:
        """Per-request sampling: row i uses wave[i].temperature, greedy rows
        (temperature 0) take the argmax — mixing greedy and sampling requests
        in one wave must not randomize the greedy ones."""
        greedy = jnp.argmax(logits, axis=-1)
        hot = temps > 0.0
        if not hot.any():
            return greedy
        self.key, sub = jax.random.split(self.key)
        safe_t = jnp.asarray(np.where(hot, temps, 1.0), logits.dtype)
        sampled = jax.random.categorical(sub, logits / safe_t[:, None], axis=-1)
        return jnp.where(jnp.asarray(hot), sampled, greedy)

    def _run_wave(self, wave: List[Request]) -> None:
        B = len(wave)
        Lp = max(len(r.prompt) for r in wave)
        prompts = np.zeros((B, Lp), np.int32)
        for i, r in enumerate(wave):  # left-pad to right-align the prompts
            prompts[i, Lp - len(r.prompt):] = r.prompt
        cache = T.init_cache(self.cfg, B, self.max_len)
        logits, cache = self.prefill_step(self.params, cache,
                                          jnp.asarray(prompts))
        max_new = max(r.max_new_tokens for r in wave)
        temps = np.array([r.temperature for r in wave], np.float32)
        out = np.zeros((B, max_new), np.int32)
        tok = self._sample_wave(logits, temps)
        index = jnp.asarray(Lp, jnp.int32)
        for t in range(max_new):
            out[:, t] = np.asarray(tok)
            if t == max_new - 1 or int(index) >= self.max_len - 1:
                break
            logits, cache = self.decode_step(self.params, cache,
                                             tok[:, None], index)
            tok = self._sample_wave(logits, temps)
            index = index + 1
        for i, r in enumerate(wave):
            r.out_tokens = out[i, :r.max_new_tokens]

    def serve(self, requests: List[Request]) -> List[Request]:
        """Continuous wave batching over the queue."""
        queue = list(requests)
        while queue:
            wave, queue = queue[:self.batch_size], queue[self.batch_size:]
            self._run_wave(wave)
        return requests
