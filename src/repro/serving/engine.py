"""Slot-based continuous-batching serving engine.

A fixed pool of ``batch_size`` KV-cache/state *slots* decodes in lockstep;
each slot carries its own cache depth (``cache_index``), so the moment a
request finishes its slot is refilled from the queue mid-flight
(prefill-into-slot) instead of barriering until the whole batch drains.
This is the decode-axis analogue of the paper's processor-utilization
argument for distributed convolutions: never let a fast processor idle on
the slowest one's critical path.

Scheduling contract:
  * admission: a queued request is prefilled alone at its exact prompt
    length (no padding -> exact for attention *and* recurrent archs), then
    its batch-1 cache row is spliced into the freed slot
    (``transformer.insert_cache_slot``) while other slots keep decoding.
    Pure-attention archs round prompt lengths up to ``prefill_bucket``
    (pad tail masked via ``attn_mask`` — still exact, see
    test_masked_cached_prefill_ignores_pad_tail) so ragged traffic compiles
    at most max_len/bucket prefill variants instead of one per length.
  * decode: one pjit'd step for the whole pool with per-slot write offsets
    and positions; a slot only attends to its own prefix (per-row causal
    masking in the dispatched XLA attention op). Free/finished slots ride along
    masked-out: their sampled tokens are discarded and their rows are fully
    overwritten at the next admission.
  * accounting: per-request EOS/stop tokens, ``max_new_tokens``, and the
    cache-capacity budget are tracked per slot; ``out_tokens`` holds ONLY
    tokens that were really generated (the old wave engine zero-padded).

Sampling is stateless: the key for a sampled token is
``fold_in(fold_in(PRNGKey(engine_seed), request_seed), step)``, a pure
function of the engine seed, the request's ``rng_seed`` (default: its
submission index) and how many tokens that request has produced — never of
which other requests share the batch. Greedy rows take an argmax and touch
no randomness. Together with exact-length prefill this makes every
request's output batch-invariant, greedy or sampled.

``WaveEngine`` keeps the old wave-lockstep *scheduling* (admission only
when every slot is free) on top of the same corrected primitives; it exists
as the benchmark baseline for ``benchmarks/serving_bench.py``.

Graceful degradation (``repro.resilience``): transient faults at the host
scheduling sites never poison the lockstep batch. Admission-time faults
retry with bounded backoff and then fail only the one request
(``finish_reason="error"``); a NaN-logit guard in the sample step fails
only the affected rows after idempotent decode retries (a decode step
rewrites the same cache positions with the same values, so re-running it
is safe); an injected pool starvation rides the normal backpressure path;
a ``kv.check()`` integrity fault triggers a full pool rebuild from
host-side request state (prompts + accepted tokens — K/V projections are
position-local, so re-prefilling reproduces the incrementally-written
cache). Per-request ``deadline_s`` adds ``finish_reason="timeout"``. The
active :func:`repro.resilience.faults.active_campaign` is consulted at the
``admit/*``, ``decode/*`` and ``finish/*`` sites.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.ops import ExecutionContext
from repro.plan import CPU_INTERPRET, HardwareTarget
from repro.resilience import errors as flt
from repro.resilience import faults as fj

from . import kv

PyTree = Any

# never-deadlock backstop: consecutive scheduling rounds with queued work,
# no active slot, and no admission before the loop declares itself stalled
_STALL_LIMIT = 10_000


@dataclasses.dataclass
class Request:
    """One generation request.

    ``stop_tokens``: emitting any of these token ids ends the request; the
    stop token is kept as the last element of ``out_tokens``.
    ``rng_seed``: per-request sampling stream id (default: submission index).
    Fix it to make a sampled request reproducible across batch compositions.
    After serving, ``out_tokens`` holds exactly the generated tokens and
    ``finish_reason`` is one of:
      * ``"stop"``        - a stop token was emitted
      * ``"length"``      - ``max_new_tokens`` reached
      * ``"cache_limit"`` - the ``max_len`` cache filled up first
      * ``"error"``       - an unrecoverable per-request fault (persistent
                            NaN logits on this row, admission retries
                            exhausted); other rows keep decoding
      * ``"timeout"``     - ``deadline_s`` elapsed since admission

    ``deadline_s``: optional wall-clock budget, measured from admission;
    an expired request keeps the tokens generated so far.
    """

    prompt: np.ndarray  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    stop_tokens: Tuple[int, ...] = ()
    rng_seed: Optional[int] = None
    deadline_s: Optional[float] = None
    out_tokens: Optional[np.ndarray] = None
    finish_reason: Optional[str] = None


@dataclasses.dataclass
class _Slot:
    """Host-side bookkeeping for one occupied cache slot."""

    request: Request
    budget: int  # min(max_new_tokens, cache capacity left after the prompt)
    generated: List[int] = dataclasses.field(default_factory=list)
    t0: float = 0.0  # admission wall-clock (deadline anchor)


def plan_batch_size(cfg: ModelConfig, max_len: int, target: HardwareTarget,
                    cap: int = 64, hbm_fraction: float = 0.25,
                    block_size: Optional[int] = None) -> int:
    """Slot-pool size from the target's memory model: how many ``max_len``
    cache rows fit in a fraction of HBM (params/activations keep the rest),
    rounded to the MXU sublane multiple so decode GEMMs keep full rows.
    ``block_size`` switches to block-granular footprints (paged engines):
    admission math then matches actual pool occupancy."""
    slot_words = T.cache_footprint_words(cfg, max_len, block_size=block_size)
    b = int((hbm_fraction * target.hbm_words) // max(slot_words, 1.0))
    b = max(1, min(cap, b))
    if b >= target.align_sublane > 1:
        b -= b % target.align_sublane
    return b


@functools.lru_cache(maxsize=None)
def _make_steps(cfg: ModelConfig, max_len: int, ctx: ExecutionContext):
    """Compiled (prefill, insert, decode, sample) steps, shared across every
    engine with the same (cfg, max_len, ctx) so warm jit caches carry
    over between engines (and between the bench's wave/continuous runs).
    ``ctx`` arrives backend-resolved (``ExecutionContext.resolved``) so the
    cache key cannot alias across environment changes."""

    def prefill(params, tokens, attn_mask, last):  # tokens (1, Lp)
        """Lp is the exact prompt length, or a bucket length with the pad
        tail masked out (attention archs); ``last`` indexes the real last
        token's logits. Pad junk written into the cache tail is hidden by
        per-row causal masking until decode overwrites it in place."""
        cache = T.init_cache(cfg, 1, max_len)
        logits, cache, _ = T.forward(params, cfg, tokens=tokens, cache=cache,
                                     cache_index=jnp.zeros((), jnp.int32),
                                     attn_mask=attn_mask, ctx=ctx)
        return jax.lax.dynamic_index_in_dim(logits, last, axis=1,
                                            keepdims=False), cache

    def insert(pool, row, slot):
        return T.insert_cache_slot(pool, row, slot)

    def decode(params, cache, token, index):  # token (B, 1), index (B,)
        logits, cache, _ = T.forward(params, cfg, tokens=token, cache=cache,
                                     cache_index=index, decode=True, ctx=ctx)
        return logits[:, -1], cache

    def sample(logits, base_key, seeds, steps, temps):
        """Row i: greedy argmax if temps[i] == 0, else a categorical draw
        keyed by (base_key, seeds[i], steps[i]) — no shared key state, so
        batch composition can never shift anyone's sampling stream.

        Also returns the per-row NaN/Inf flag (the resilience layer's
        numeric guard): the host already syncs on the sampled tokens every
        step, so the flag rides along with zero extra device round-trips.
        Flagged rows sample from zeroed logits; the engine never records
        their tokens."""
        bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
        safe = jnp.where(bad[:, None], 0.0, logits)
        greedy = jnp.argmax(safe, axis=-1)

        def one(seed, step, row, t):
            key = jax.random.fold_in(jax.random.fold_in(base_key, seed), step)
            return jax.random.categorical(
                key, row / jnp.maximum(t, 1e-6), axis=-1)

        sampled = jax.vmap(one)(seeds, steps, safe, temps)
        toks = jnp.where(temps > 0.0, sampled, greedy).astype(jnp.int32)
        return toks, bad

    return (jax.jit(prefill),
            jax.jit(insert, donate_argnums=(0,)),
            jax.jit(decode, donate_argnums=(1,)),
            jax.jit(sample))


@functools.lru_cache(maxsize=None)
def _make_paged_steps(cfg: ModelConfig, max_len: int, ctx: ExecutionContext,
                      block_size: int, quantized: bool = False):
    """Compiled (insert, decode) for the paged pool. Prefill and sampling are
    shared with ``_make_steps`` — prefill still runs contiguous at batch 1;
    only its landing in the pool and the decode step are paged.

    ``insert`` retraces per distinct block count (<= max_len/block_size
    variants, the same ladder as the bucketed prefills); ``decode`` retraces
    per distinct table width w (ditto) — positions and table *contents* are
    data, never trace constants.

    ``quantized`` targets the int8 pool layout: the scatter quantizes each
    (kv_head, position) row symmetrically over hd (matching the decode
    step's ``layers._quantize_kv_row`` write path) and lands the int8 codes
    plus the f32 scales on the pool's kp/ks/vp/vs leaves."""

    def insert(pool, row, blocks):  # row: batch-1 contiguous cache; (nt,) ids
        nt = blocks.shape[0]

        def block_rows(r):  # r (R, 1, KV, max_len, hd) -> (R, nt, KV, bs, hd)
            R, _, KV, L, hd = r.shape
            rb = r[:, 0, :, :min(nt * block_size, L), :]
            if nt * block_size > L:  # max_len below a whole block: zero-pad
                rb = jnp.pad(rb, ((0, 0), (0, 0),
                                  (0, nt * block_size - L), (0, 0)))
            return rb.reshape(R, KV, nt, block_size, hd).transpose(
                0, 2, 1, 3, 4)

        def scatter(p, r):  # p (R, nb, KV, bs, hd)
            return p.at[:, blocks].set(block_rows(r).astype(p.dtype))

        def scatter_q(p, s, r):  # + s (R, nb, KV, bs): per-row f32 scales
            rb = block_rows(r).astype(jnp.float32)
            amax = jnp.max(jnp.abs(rb), axis=-1)
            scale = jnp.where(amax > 0, amax / 127.0, 1.0)
            q = jnp.clip(jnp.round(rb / scale[..., None]), -127.0,
                         127.0).astype(jnp.int8)
            return p.at[:, blocks].set(q), s.at[:, blocks].set(scale)

        if quantized:
            out = {}
            for u, leaves in pool.items():
                kp, ks = scatter_q(leaves["kp"], leaves["ks"], row[u]["k"])
                vp, vs = scatter_q(leaves["vp"], leaves["vs"], row[u]["v"])
                out[u] = {"kp": kp, "ks": ks, "vp": vp, "vs": vs}
            return out
        return {u: {"kp": scatter(leaves["kp"], row[u]["k"]),
                    "vp": scatter(leaves["vp"], row[u]["v"])}
                for u, leaves in pool.items()}

    def decode(params, pool, token, index, tables):  # token (B,1), index (B,)
        logits, pool, _ = T.forward(params, cfg, tokens=token, cache=pool,
                                    cache_index=index, decode=True, ctx=ctx,
                                    block_tables=tables)
        return logits[:, -1], pool

    return (jax.jit(insert, donate_argnums=(0,)),
            jax.jit(decode, donate_argnums=(1,)))


class Engine:
    """Continuous-batching engine over a fixed slot pool.

    ``batch_size=None`` sizes the pool from the ``HardwareTarget``'s memory
    model (``plan_batch_size``); ``ctx=None`` builds the execution context
    from ``target`` (backend per the ``repro.ops`` resolution order).

    ``paged`` (default: on for pure-attention models) replaces the per-slot
    contiguous KV assumption with the ``repro.serving.kv`` block pool:
    admission *reserves* a request's whole block budget up front (shared
    prompt-prefix blocks counted once, refcounted), turns pool exhaustion
    into backpressure (the request waits in queue) instead of an overcommit,
    and the decode step reads K/V straight out of the pool through per-row
    block tables (``ops.attention_decode`` — Pallas end-to-end, no
    capability fallback). ``num_blocks=None`` sizes the pool for every slot
    to reach ``max_len``, capped by the target's HBM budget
    (``kv.plan_pool_blocks``).

    ``kv_dtype="int8"`` (paged only) quantizes the pool: int8 blocks plus
    per-(block, head, position) f32 scales — (0.25 + 1/hd) words per cached
    element instead of bf16's 0.5, so the same HBM budget holds ~2x the
    blocks (``kv.plan_pool_blocks(quantized=True)``) and each decode step
    streams about half the cache words (the Lq=1 memory-independent term of
    ``core.bounds.mixed_precision_attention_bound``). Output quality against
    the bf16 pool is gated in ``benchmarks/quant_bench.py``."""

    def __init__(self, cfg: ModelConfig, params: PyTree, max_len: int = 512,
                 batch_size: Optional[int] = None,
                 ctx: Optional[ExecutionContext] = None,
                 seed: int = 0, target: Optional[HardwareTarget] = None,
                 prefill_bucket: Optional[int] = None,
                 paged: Optional[bool] = None,
                 block_size: int = kv.DEFAULT_BLOCK_SIZE,
                 num_blocks: Optional[int] = None,
                 kv_dtype: str = "bf16",
                 admission_retries: int = 3,
                 numeric_retries: int = 2,
                 retry_backoff_s: float = 0.001):
        assert cfg.causal, "serving requires a decoder model"
        # resilience policy: transient admission faults retry with
        # exponential backoff; NaN decode steps retry idempotently before
        # failing only the affected rows (module docstring)
        self.admission_retries = admission_retries
        self.numeric_retries = numeric_retries
        self.retry_backoff_s = retry_backoff_s
        self.cfg, self.params = cfg, params
        self.max_len = max_len
        self.target = target or CPU_INTERPRET
        if ctx is None:
            ctx = ExecutionContext(target=self.target)
        self.ctx = ctx.resolved()
        attn_only = set(cfg.pattern) == {"attn"}
        if paged is None:
            paged = attn_only and not cfg.fused_kv_cache
        elif paged and not attn_only:
            raise ValueError(
                "paged KV requires a pure-attention pattern; recurrent "
                f"blocks carry O(1) state (pattern={cfg.pattern})")
        elif paged and cfg.fused_kv_cache:
            raise ValueError("paged KV uses split k/v pools; "
                             "disable fused_kv_cache")
        self.paged = paged
        self.block_size = block_size
        if kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype must be 'bf16' or 'int8', "
                             f"got {kv_dtype!r}")
        if kv_dtype == "int8" and not paged:
            raise ValueError("kv_dtype='int8' requires the paged KV pool "
                             "(the quantized layout lives on pool blocks)")
        self.kv_quant = kv_dtype == "int8"
        if batch_size is None:
            batch_size = plan_batch_size(
                cfg, max_len, self.target,
                block_size=block_size if paged else None)
        self.batch_size = batch_size
        if paged:
            if num_blocks is None:
                num_blocks = kv.plan_pool_blocks(
                    cfg, max_len, batch_size, block_size, target=self.target,
                    quantized=self.kv_quant)
            self.num_blocks = num_blocks
            self._paged_insert, self._paged_decode = _make_paged_steps(
                cfg, max_len, self.ctx, block_size,
                quantized=self.kv_quant)
        if prefill_bucket is None:
            # ragged prompts each jit a prefill per distinct length; rounding
            # lengths up to a bucket bounds that to max_len/bucket traces.
            # Masked padded prefill is exact only for attention blocks —
            # recurrent state consumes every position, so those archs stay
            # at exact lengths (one trace per distinct length).
            prefill_bucket = 16 if set(cfg.pattern) == {"attn"} else 1
        elif prefill_bucket > 1 and set(cfg.pattern) != {"attn"}:
            raise ValueError(
                "prefill_bucket > 1 requires a pure-attention pattern: "
                "recurrent blocks fold pad tokens into their state")
        self.prefill_bucket = max(1, prefill_bucket)
        (self._prefill, self._insert, self._decode, self._sample) = \
            _make_steps(cfg, max_len, self.ctx)
        self.base_key = jax.random.PRNGKey(seed)

    # -- scheduling policy ----------------------------------------------------
    def _admission_open(self, slots: List[Optional[_Slot]]) -> bool:
        """Continuous batching: any free slot may be refilled immediately."""
        return True

    # -- serving loop ---------------------------------------------------------
    def serve(self, requests: List[Request]) -> List[Request]:
        B = self.batch_size
        for r in requests:
            if not 1 <= len(r.prompt) <= self.max_len:
                raise ValueError(
                    f"prompt length {len(r.prompt)} outside [1, {self.max_len}]")
            if r.max_new_tokens < 1:
                raise ValueError("max_new_tokens must be >= 1")
            if r.rng_seed is not None and not -2**31 <= r.rng_seed < 2**31:
                raise ValueError("rng_seed must fit in int32")
            if r.deadline_s is not None and r.deadline_s <= 0:
                raise ValueError("deadline_s must be positive")
        camp = fj.active_campaign()
        queue: Deque[Tuple[int, Request]] = collections.deque(
            enumerate(requests))
        bs = self.block_size
        if self.paged:
            cache = T.init_paged_cache(self.cfg, self.num_blocks, bs,
                                       quantized=self.kv_quant)
            alloc = kv.BlockAllocator(self.num_blocks)
            tables = np.zeros((B, -(-self.max_len // bs)), np.int32)
            slot_blocks: List[List[int]] = [[] for _ in range(B)]
            # device-side table cache: tables only change at admission/finish,
            # so most decode steps skip the host->device upload
            tables_dev: Dict[int, jax.Array] = {}  # width -> device slice
        else:
            cache = T.init_cache(self.cfg, B, self.max_len)
        slots: List[Optional[_Slot]] = [None] * B
        tok = np.zeros(B, np.int32)    # last accepted token per slot
        pos = np.zeros(B, np.int32)    # cache depth: next decode write offset
        seeds = np.zeros(B, np.int32)  # per-slot sampling stream ids
        temps = np.zeros(B, np.float32)

        def finish(s: int, reason: str) -> None:
            """Close slot s with ``reason``, keeping its generated tokens,
            and release its resources (the ``finish/*`` campaign site)."""
            slot = slots[s]
            r = slot.request
            r.out_tokens = np.asarray(slot.generated, np.int32)
            r.finish_reason = reason
            slots[s] = None
            tok[s], temps[s] = 0, 0.0  # dead row decodes greedily into void
            if self.paged:
                if camp is not None:
                    inj = camp.draw("finish/pool", kinds=("pool",))
                    if inj is not None:  # repaired at the next check/rebuild
                        camp.corrupt_allocator(alloc, inj)
                for bid in slot_blocks[s]:
                    alloc.free(bid)  # shared prefixes -> refcount decrements
                slot_blocks[s] = []
                tables[s, :] = 0  # dead row reads/writes garbage block 0
                tables_dev.clear()

        def record(s: int, t: int) -> None:
            """Account one generated token for slot s; free it when done."""
            slot = slots[s]
            slot.generated.append(int(t))
            r = slot.request
            if int(t) in r.stop_tokens:
                finish(s, "stop")
            elif len(slot.generated) >= slot.budget:
                finish(s, "length" if slot.budget >= r.max_new_tokens
                       else "cache_limit")
            else:
                tok[s] = int(t)

        def reserve(r: Request, budget: int) -> Optional[List[int]]:
            """Reserve the request's whole block budget (prompt + decode
            growth), sharing registered prompt-prefix blocks. None = the pool
            cannot cover it now -> admission backpressure."""
            plen = len(r.prompt)
            need = -(-(plen + budget - 1) // bs)
            chain = kv.prefix_chain(r.prompt, bs)
            hits: List[Tuple[kv.PrefixKey, int]] = []
            for key in chain:
                bid = alloc.lookup(key)
                if bid is None:
                    break  # chained keys: later blocks cannot match either
                hits.append((key, bid))
            fresh = need - len(hits)
            # an evictable hit leaves the available pool the moment we take a
            # reference, so it cannot also satisfy a fresh allocation
            evictable_hits = sum(1 for _, b in hits if alloc.refcount(b) == 0)
            if alloc.available() - evictable_hits < fresh:
                return None
            blocks = [alloc.ref(b) for _, b in hits]
            for key in chain[len(hits):]:
                b = alloc.alloc()
                alloc.register(b, key)  # a full prompt block: shareable
                blocks.append(b)
            while len(blocks) < need:  # partial tail + decode growth: private
                blocks.append(alloc.alloc())
            return blocks

        def rebuild_pool():
            """Fresh allocator + pool, rebuilt from host-side request state.

            Every live slot re-reserves its block budget against the new
            allocator (prefix sharing intact — reservation order is slot
            order, deterministic) and re-prefills exactly the tokens already
            written to the cache (``prompt + generated[:pos - plen]``). K/V
            projections are position-local and RoPE is applied pre-cache, so
            the rebuilt pool matches the incrementally-written one bit for
            bit over every read-visible position."""
            new_alloc = kv.BlockAllocator(self.num_blocks)
            new_cache = T.init_paged_cache(self.cfg, self.num_blocks, bs,
                                           quantized=self.kv_quant)
            tables_dev.clear()
            for s in range(B):
                slot = slots[s]
                if slot is None:
                    slot_blocks[s] = []
                    tables[s, :] = 0
                    continue
                r = slot.request
                plen = len(r.prompt)
                need = len(slot_blocks[s])
                chain = kv.prefix_chain(r.prompt, bs)
                blocks: List[int] = []
                for key in chain:
                    bid = new_alloc.lookup(key)
                    if bid is not None:
                        blocks.append(new_alloc.ref(bid))
                        continue
                    b = new_alloc.alloc()
                    new_alloc.register(b, key)
                    blocks.append(b)
                while len(blocks) < need:
                    blocks.append(new_alloc.alloc())
                slot_blocks[s] = blocks
                tables[s, :] = 0
                tables[s, :len(blocks)] = blocks
                # the cache holds the prompt plus every already-written
                # accepted token; the pending token (tok[s]) is rewritten by
                # the next decode step as usual
                written = int(pos[s])
                lp = min(self.max_len,
                         -(-written // self.prefill_bucket)
                         * self.prefill_bucket)
                tokens = np.zeros((1, lp), np.int32)
                tokens[0, :plen] = r.prompt
                tokens[0, plen:written] = slot.generated[:written - plen]
                mask = np.zeros((1, lp), bool)
                mask[0, :written] = True
                _, row = self._prefill(
                    self.params, jnp.asarray(tokens), jnp.asarray(mask),
                    jnp.asarray(written - 1, jnp.int32))
                nt = -(-written // bs)
                new_cache = self._paged_insert(
                    new_cache, row, jnp.asarray(blocks[:nt], jnp.int32))
            return new_alloc, new_cache

        def admit_prefill(plen: int, prompt: np.ndarray):
            """Batch-1 prefill with bounded retry-with-backoff around the
            transient-fault sites (``admit/launch`` raises, ``admit/numeric``
            poisons, plus the always-on finite-logits guard). Returns
            ``(logits, cache_row)``, or None once ``admission_retries``
            retries are exhausted — the caller then fails that one request,
            nobody else."""
            lp = min(self.max_len,
                     -(-plen // self.prefill_bucket) * self.prefill_bucket)
            tokens = np.zeros((1, lp), np.int32)
            tokens[0, :plen] = prompt
            mask = np.zeros((1, lp), bool)
            mask[0, :plen] = True
            for attempt in range(self.admission_retries + 1):
                num_inj = None
                try:
                    if camp is not None:
                        inj = camp.draw("admit/launch",
                                        kinds=("launch", "dma"), op="prefill")
                        if inj is not None:
                            raise camp.fault_for(inj, op="prefill",
                                                 backend=self.ctx.backend)
                    logits1, row = self._prefill(
                        self.params, jnp.asarray(tokens), jnp.asarray(mask),
                        jnp.asarray(plen - 1, jnp.int32))
                    if camp is not None:
                        num_inj = camp.draw("admit/numeric",
                                            kinds=("numeric",), op="prefill")
                        if num_inj is not None:
                            logits1 = camp.corrupt_output(logits1, num_inj)
                    if not np.all(np.isfinite(
                            np.asarray(logits1, np.float32))):
                        raise flt.NumericFault("non-finite prefill logits",
                                               op="prefill",
                                               injection=num_inj)
                except flt.TransientFault as e:
                    last = attempt == self.admission_retries
                    if camp is not None:
                        camp.resolve(e, "row_failed" if last else "retried")
                    if not last:
                        time.sleep(min(self.retry_backoff_s * (2 ** attempt),
                                       0.05))
                    continue
                return logits1, row
            return None

        rounds = 0
        stall = 0  # consecutive no-slot no-admission rounds with queued work
        while queue or any(s is not None for s in slots):
            rounds += 1
            # -- deadline sweep: expire requests past their wall budget -----
            now = time.monotonic()
            for s in range(B):
                slot = slots[s]
                if (slot is not None
                        and slot.request.deadline_s is not None
                        and now - slot.t0 >= slot.request.deadline_s):
                    finish(s, "timeout")
            # -- pool integrity: check every round under a campaign (finish
            # may have just corrupted the allocator), periodically otherwise;
            # a tripped invariant rebuilds pool + allocator from host state
            if self.paged and (camp is not None or rounds % 256 == 0):
                if camp is not None:
                    inj = camp.draw("decode/pool", kinds=("pool",))
                    if inj is not None:
                        camp.corrupt_allocator(alloc, inj)
                try:
                    alloc.check()
                except flt.PoolIntegrityFault:
                    alloc, cache = rebuild_pool()
                    if camp is not None:
                        camp.resolve_kind("pool", "rebuilt")
            # -- admission: prefill queued requests into freed slots --------
            admitted = 0
            if queue and self._admission_open(slots):
                for s in range(B):
                    if not queue or slots[s] is not None:
                        continue
                    rid, r = queue[0]
                    plen = len(r.prompt)
                    # token 1 comes from the prefill logits; token k needs a
                    # cache write at plen + k - 2 <= max_len - 1
                    budget = min(r.max_new_tokens, self.max_len - plen + 1)
                    if self.paged:
                        if camp is not None:
                            inj = camp.draw("admit/oom", kinds=("oom",))
                            if inj is not None:
                                # injected pool starvation rides the normal
                                # backpressure path: the request just waits
                                camp.resolve(inj, "backpressure")
                                break
                        blocks = reserve(r, budget)
                        if blocks is None:
                            if not any(x is not None for x in slots):
                                try:
                                    alloc.check()
                                except flt.PoolIntegrityFault:
                                    # a corrupted allocator can fake
                                    # exhaustion: repair, retry next round
                                    alloc, cache = rebuild_pool()
                                    if camp is not None:
                                        camp.resolve_kind("pool", "rebuilt")
                                    break
                                raise flt.AdmissionImpossible(
                                    f"paged KV pool of {self.num_blocks} "
                                    f"blocks cannot ever admit a "
                                    f"{plen}-token prompt with budget "
                                    f"{budget}; raise num_blocks",
                                    num_blocks=self.num_blocks,
                                    blocks_needed=-(-(plen + budget - 1)
                                                    // bs),
                                    available_blocks=alloc.available(),
                                    live_blocks=alloc.live_blocks())
                            break  # backpressure: wait for a slot to finish
                        slot_blocks[s] = blocks
                        tables[s, :] = 0
                        tables[s, :len(blocks)] = blocks
                        tables_dev.clear()
                    queue.popleft()
                    admitted += 1
                    out = admit_prefill(plen, r.prompt)
                    if out is None:
                        # transient faults exhausted the retry budget: this
                        # request alone fails; its reservation is returned
                        r.out_tokens = np.asarray([], np.int32)
                        r.finish_reason = "error"
                        if self.paged:
                            for bid in slot_blocks[s]:
                                alloc.free(bid)
                            slot_blocks[s] = []
                            tables[s, :] = 0
                            tables_dev.clear()
                        continue
                    logits1, row = out
                    slots[s] = _Slot(request=r, budget=budget,
                                     t0=time.monotonic())
                    seeds[s] = r.rng_seed if r.rng_seed is not None else rid
                    temps[s] = r.temperature
                    pos[s] = plen
                    if self.paged:
                        # land the prompt's blocks in the pool (a shared hit
                        # is rewritten with bit-identical K/V: same tokens,
                        # positions, params; RoPE is applied pre-cache)
                        nt = -(-plen // bs)
                        cache = self._paged_insert(
                            cache, row,
                            jnp.asarray(slot_blocks[s][:nt], jnp.int32))
                    else:
                        cache = self._insert(cache, row, s)
                    first, _ = self._sample(
                        logits1, self.base_key,
                        jnp.asarray(seeds[s:s + 1]),
                        jnp.zeros(1, jnp.int32),
                        jnp.asarray(temps[s:s + 1]))
                    record(s, int(np.asarray(first)[0]))
            active = [s for s in range(B) if slots[s] is not None]
            if not active:
                # everything admitted this round finished instantly, or
                # admission backpressured with an empty pool. The stall
                # backstop turns a scheduler that stopped making progress
                # into a typed fatal instead of a silent infinite loop.
                stall = 0 if admitted else stall + 1
                if stall > _STALL_LIMIT and queue:
                    raise flt.SchedulerStall(
                        f"no admission progress for {stall} rounds with "
                        f"{len(queue)} request(s) queued and no active slot",
                        queued=len(queue), rounds=rounds)
                continue
            stall = 0
            # -- one lockstep decode step over the pool ---------------------
            # Free rows ride along at a clamped offset; their writes land in
            # rows that are fully overwritten at the next insert (contiguous)
            # or in reserved garbage block 0 (paged) and their samples are
            # never recorded (active-slot masking). A decode step rewrites
            # the same cache positions with the same values, so the numeric
            # retry below can simply re-run it.
            steps = np.array([len(slots[s].generated) if slots[s] else 0
                              for s in range(B)], np.int32)
            idx = np.where([slots[s] is not None for s in range(B)], pos, 0)
            for attempt in range(self.numeric_retries + 1):
                if self.paged:
                    # table width follows the deepest active row; dead rows
                    # are all-zero (garbage) tables. Shape-driven retrace.
                    w = max(int(pos[s]) // bs + 1 for s in active)
                    if w not in tables_dev:
                        tables_dev[w] = jnp.asarray(tables[:, :w])
                    logits, cache = self._paged_decode(
                        self.params, cache, jnp.asarray(tok)[:, None],
                        jnp.asarray(idx, jnp.int32), tables_dev[w])
                else:
                    logits, cache = self._decode(
                        self.params, cache, jnp.asarray(tok)[:, None],
                        jnp.asarray(idx, jnp.int32))
                num_inj = None
                if camp is not None:
                    num_inj = camp.draw("decode/numeric",
                                        kinds=("numeric",), op="decode")
                    if num_inj is not None:
                        logits = camp.corrupt_rows(logits, active, num_inj)
                nxt_dev, bad_dev = self._sample(
                    logits, self.base_key, jnp.asarray(seeds),
                    jnp.asarray(steps), jnp.asarray(temps))
                nxt = np.asarray(nxt_dev)
                bad = np.asarray(bad_dev)
                bad_rows = [s for s in active if bad[s]]
                if not bad_rows:
                    if camp is not None and num_inj is not None:
                        camp.resolve(num_inj, "retried")  # unreachable guard
                    break
                last = attempt == self.numeric_retries
                if camp is not None and num_inj is not None:
                    camp.resolve(num_inj, "row_failed" if last else "retried")
                if last:
                    # persistent NaN on these rows: fail them alone, keep
                    # their generated-so-far tokens, never record this step
                    for s in bad_rows:
                        finish(s, "error")
                    break
                time.sleep(min(self.retry_backoff_s * (2 ** attempt), 0.05))
            for s in active:
                if slots[s] is None:
                    continue  # failed/expired rows were closed above
                pos[s] += 1
                record(s, int(nxt[s]))
        if camp is not None:
            # the pool dies with the loop: any still-latent corruption from a
            # last-round finish is discarded wholesale — the degenerate
            # rebuild — so the accounting never shows a swallowed fault
            camp.resolve_kind("pool", "rebuilt")
        return requests


class WaveEngine(Engine):
    """Wave-lockstep baseline: the old engine's scheduling (admit a full
    batch, then barrier until every request in it finishes) on top of the
    same corrected slot primitives. Kept as the benchmark baseline so
    ``benchmarks/serving_bench.py`` can show what continuous batching buys
    on mixed prompt/output lengths."""

    def _admission_open(self, slots: List[Optional[_Slot]]) -> bool:
        return all(s is None for s in slots)
