"""Serving launcher: batched decode against a (smoke or checkpointed) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --smoke \
        --requests 8 --prompt-len 16 --max-new 32
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--target", default="cpu_interpret",
                    help="hardware target preset (tpu_v5e | gemmini | "
                         "cpu_interpret); decides the kernel path")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    from repro.configs import get_config, get_smoke
    from repro.models import transformer as T
    from repro.plan import get_target
    from repro.serving.engine import Engine, Request
    from repro.train import checkpoint as ckpt

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    assert cfg.causal, "encoder-only archs have no decode path"
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    if args.ckpt_dir:
        tree = {"params": params}
        restored, _ = ckpt.restore(args.ckpt_dir, tree)
        params = restored["params"]

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(2, args.prompt_len + 1),
                                        dtype=np.int32).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    eng = Engine(cfg, params, max_len=args.max_len, batch_size=args.batch,
                 target=get_target(args.target))
    t0 = time.time()
    eng.serve(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    for i, r in enumerate(reqs[:4]):
        print(f"  req{i}: prompt={r.prompt[:8].tolist()}... "
              f"out={r.out_tokens[:12].tolist()}")


if __name__ == "__main__":
    main()
