"""Serving launcher: continuous-batching decode against a (smoke or
checkpointed) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --smoke \
        --requests 8 --prompt-len 16 --max-new 32

``--engine wave`` runs the wave-lockstep baseline scheduler instead (same
primitives, admission barriers until the whole batch drains) for A/B
comparison. ``--batch 0`` sizes the slot pool from the hardware target's
memory model. Reported tok/s counts only tokens that were actually
generated (EOS / cache-limit truncation shortens ``out_tokens``; nothing is
zero-padded).
"""

from __future__ import annotations

import argparse
import collections
import logging
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4,
                    help="slot-pool size; 0 = plan from the hardware target")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--stop-token", type=int, default=None,
                    help="token id that ends a request (EOS)")
    ap.add_argument("--engine", choices=("slot", "wave"), default="slot",
                    help="continuous batching (slot) or the wave baseline")
    ap.add_argument("--target", default="cpu_interpret",
                    help="hardware target preset (tpu_v5e | gemmini | "
                         "cpu_interpret); sets plan/precision policy and "
                         "the default backend")
    ap.add_argument("--backend", choices=("xla", "pallas"), default=None,
                    help="kernel backend override; default resolves from "
                         "REPRO_BACKEND and then the --target preset")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock budget from admission; "
                         "expired requests finish with reason 'timeout'")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    from repro.configs import get_config, get_smoke
    from repro.models import transformer as T
    from repro.ops import ExecutionContext
    from repro.plan import get_target
    from repro.serving.engine import Engine, Request, WaveEngine
    from repro.train import checkpoint as ckpt

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    assert cfg.causal, "encoder-only archs have no decode path"
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    if args.ckpt_dir:
        tree = {"params": params}
        restored, _ = ckpt.restore(args.ckpt_dir, tree)
        params = restored["params"]

    rng = np.random.default_rng(0)
    stop = () if args.stop_token is None else (args.stop_token,)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(2, args.prompt_len + 1),
                                        dtype=np.int32).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature,
                    stop_tokens=stop,
                    deadline_s=args.deadline_s)
            for _ in range(args.requests)]
    cls = WaveEngine if args.engine == "wave" else Engine
    target = get_target(args.target)
    eng = cls(cfg, params, max_len=args.max_len,
              batch_size=args.batch or None, target=target,
              ctx=ExecutionContext(target=target, backend=args.backend))
    t0 = time.time()
    eng.serve(reqs)
    dt = time.time() - t0
    # a request may not complete: resilience failures ("error") and expired
    # deadlines ("timeout") still return whatever tokens were generated, so
    # count and report per-reason rather than assuming success
    total_new = sum(len(r.out_tokens) for r in reqs
                    if r.out_tokens is not None)  # real tokens only
    reasons = collections.Counter(r.finish_reason for r in reqs)
    ok = len(reqs) - reasons.get("error", 0) - reasons.get("timeout", 0)
    print(f"[{args.engine}] served {len(reqs)} requests "
          f"(batch={eng.batch_size}), {total_new} generated tokens in "
          f"{dt:.2f}s ({total_new / dt:.1f} tok/s); "
          f"completed={ok}/{len(reqs)} finish={dict(reasons)}")
    for i, r in enumerate(reqs[:4]):
        out = [] if r.out_tokens is None else r.out_tokens[:12].tolist()
        print(f"  req{i}: prompt={r.prompt[:8].tolist()}... "
              f"out={out} ({r.finish_reason})")


if __name__ == "__main__":
    main()
