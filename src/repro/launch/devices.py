"""Fake-device configuration for host-CPU multi-device runs.

jax locks the device count at first backend initialization, so the
``--xla_force_host_platform_device_count`` XLA flag must be set before any
device query. Every consumer (the dry-run driver, the distributed tests,
``benchmarks/dist_bench``) routes through :func:`fake_devices`, which either
sets the flag in time or fails with an actionable error — replacing the
import-time ``os.environ`` mutation that used to live in ``launch/dryrun.py``.
"""

from __future__ import annotations

import os
import re
import sys

_FLAG = "--xla_force_host_platform_device_count"


def _declared_count() -> int:
    """The fake-device count currently requested via XLA_FLAGS (1 if unset)."""
    m = re.search(rf"{_FLAG}=(\d+)", os.environ.get("XLA_FLAGS", ""))
    return int(m.group(1)) if m else 1


def _backend_initialized() -> bool:
    jx = sys.modules.get("jax")
    if jx is None:
        return False
    try:
        from jax._src import xla_bridge

        return xla_bridge.backends_are_initialized()
    except (ImportError, AttributeError):  # pragma: no cover - old/new jax
        return True  # cannot tell: be conservative, refuse to mutate


def fake_devices(n: int) -> int:
    """Ensure this process sees ``n`` (fake) host devices; returns ``n``.

    Idempotent when the flag already requests ``n``. Raises ``RuntimeError``
    with a clear fix when jax has already initialized its backends with a
    different count — env mutation after that point is silently ignored by
    jax, which is exactly the failure mode this helper exists to surface.
    """
    if n < 1:
        raise ValueError(f"device count must be >= 1, got {n}")
    if _backend_initialized():
        import jax

        have = len(jax.devices())
        if have == n:
            return n
        raise RuntimeError(
            f"jax is already initialized with {have} device(s); cannot "
            f"switch to {n}. Call repro.launch.fake_devices({n}) before any "
            f"jax device query, or set XLA_FLAGS={_FLAG}={n} in the "
            f"environment before starting python.")
    if _declared_count() == n:
        return n  # flag already requests n; nothing to rewrite
    flags = os.environ.get("XLA_FLAGS", "")
    if re.search(rf"{_FLAG}=\d+", flags):
        flags = re.sub(rf"{_FLAG}=\d+", f"{_FLAG}={n}", flags)
    else:
        flags = f"{flags} {_FLAG}={n}".strip()
    os.environ["XLA_FLAGS"] = flags
    return n
