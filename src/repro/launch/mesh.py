"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (device count is locked on first jax init, and only
dryrun.py sets the 512-fake-device XLA flag).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16); the pod axis
    is the DCN/outer-DP axis (hierarchical gradient reduction)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
