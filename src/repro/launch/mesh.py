"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (device count is locked on first jax init; consumers that
need fake host devices call ``repro.launch.fake_devices`` first).
"""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16); the pod axis
    is the DCN/outer-DP axis (hierarchical gradient reduction)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_conv_mesh(blocking):
    """Snap a conv processor grid onto a device mesh for ``shard_map``.

    ``blocking`` is a :class:`repro.core.parallel_tiling.ParallelBlocking`
    (or a plain axis->procs dict). The mesh always carries the four axes the
    distributed conv lowering shards over — ``("N", "cI", "hO", "wO")``, in
    that order, size 1 for unsplit axes — and uses the first ``P`` available
    devices (``P`` = the grid's processor count), so grids smaller than the
    host's device count work."""
    from repro.distributed.geometry import DIST_AXES, dist_grid

    sizes = dist_grid(blocking)
    P = math.prod(sizes)
    devs = jax.devices()
    if P > len(devs):
        raise ValueError(
            f"blocking grid {dict(zip(DIST_AXES, sizes))} needs {P} devices "
            f"but only {len(devs)} exist (launch.fake_devices(n) must run "
            f"before jax initializes)")
    return jax.make_mesh(sizes, DIST_AXES, devices=devs[:P])
