"""ShapeDtypeStruct stand-ins for every dry-run cell: weak-type-correct,
shardable, zero allocation.

input_specs(arch, shape_name) returns the full kwargs pytree for the step
function being lowered:
    train   -> params(f32), opt_state, batch{tokens|embeds+labels}
    prefill -> params(bf16), cache, tokens/embeds
    decode  -> params(bf16), cache, token(B,1), index
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.config import LM_SHAPES, ModelConfig, ShapeSpec
from repro.train.optimizer import AdamWState, init_state

PyTree = Any


def abstract(tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def param_structs(arch: str, dtype: str = "float32", cfg=None) -> PyTree:
    import dataclasses
    cfg = cfg or get_config(arch)
    if dtype != cfg.param_dtype:
        cfg = dataclasses.replace(cfg, param_dtype=dtype)
    return jax.eval_shape(
        lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))


def opt_structs(arch: str, cfg=None) -> PyTree:
    p = param_structs(arch, "float32", cfg)
    f32 = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=f32, v=jax.tree.map(lambda s: s, f32))


def cache_structs(arch: str, batch: int, max_len: int, cfg=None) -> PyTree:
    cfg = cfg or get_config(arch)
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))


def batch_structs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, L = shape.global_batch, shape.seq_len
    if cfg.inputs_are_embeddings and shape.kind != "decode":
        out = {"embeds": jax.ShapeDtypeStruct((B, L, cfg.d_model), jnp.bfloat16)}
        if not cfg.causal:
            out["labels"] = jax.ShapeDtypeStruct((B, L), jnp.int32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((B, L), jnp.int32)
        return out
    return {"tokens": jax.ShapeDtypeStruct((B, L), jnp.int32)}


def input_specs(arch: str, shape_name: str, cfg=None) -> Dict[str, Any]:
    """The abstract inputs for the step lowered in this cell."""
    cfg = cfg or get_config(arch)
    shape = LM_SHAPES[shape_name]
    if shape.kind == "train":
        return {
            "params": param_structs(arch, "float32", cfg),
            "opt_state": opt_structs(arch, cfg),
            "batch": batch_structs(cfg, shape),
        }
    if shape.kind == "prefill":
        return {
            "params": param_structs(arch, "bfloat16", cfg),
            "cache": cache_structs(arch, shape.global_batch, shape.seq_len, cfg),
            "batch": batch_structs(cfg, shape),
        }
    # decode: one new token against a seq_len-deep cache
    return {
        "params": param_structs(arch, "bfloat16", cfg),
        "cache": cache_structs(arch, shape.global_batch, shape.seq_len, cfg),
        "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }


def n_groups_for(shape: ShapeSpec, n_devices: int) -> int:
    return math.gcd(shape.tokens, n_devices)
