"""Launchers: production mesh, multi-pod dry-run, train/serve drivers."""
from .mesh import make_host_mesh, make_production_mesh  # noqa: F401
