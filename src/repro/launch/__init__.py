"""Launchers: production mesh, conv mesh, fake devices, dry-run, drivers."""
from .devices import fake_devices  # noqa: F401
from .mesh import (  # noqa: F401
    make_conv_mesh,
    make_host_mesh,
    make_production_mesh,
)
