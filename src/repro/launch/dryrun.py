import argparse
import dataclasses
import json
import math
import os
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rl
from repro.configs import ARCH_IDS, SKIPS, get_config
from repro.launch import specs as sp
from repro.launch.devices import fake_devices
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shd
from repro.models import transformer as T
from repro.models.config import LM_SHAPES
from repro.train.optimizer import AdamWConfig, AdamWState
from repro.train.trainer import TrainConfig, make_train_step


def ensure_dryrun_devices() -> int:
    """Request the dry-run fake-device count (``REPRO_DRYRUN_DEVICES``,
    default 512) through ``launch.fake_devices``. Called on the driver paths
    that build their own production mesh — not at import, so importing this
    module no longer mutates ``XLA_FLAGS`` or locks the jax device count for
    embedding processes (tests pass an explicit ``mesh=`` instead)."""
    return fake_devices(int(os.environ.get("REPRO_DRYRUN_DEVICES", "512")))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


# ---------------------------------------------------------------------------
# Step builders (what gets lowered per cell kind)
# ---------------------------------------------------------------------------

def build_train_step(cfg, mesh, n_groups: int, act_seq_shard: bool = True,
                     loss_chunks: Optional[int] = None, remat: bool = True):
    ba = shd.batch_axes(mesh)
    act = P(ba, "model", None) if act_seq_shard else P(ba, None, None)
    if loss_chunks is None:
        loss_chunks = 16 if cfg.vocab_size > 32000 else 4
    tc = TrainConfig(remat=remat, n_groups=n_groups,
                     loss_chunks=loss_chunks, act_spec=act)
    oc = AdamWConfig()
    step = make_train_step(cfg, oc, tc)

    def train_step(params, opt_state, batch):
        return step(params, opt_state, batch)

    return train_step


def build_prefill_step(cfg, n_groups: int, act_spec=None):
    def prefill_step(params, cache, batch):
        if cfg.causal:
            logits, cache, _ = T.forward(
                params, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), cache=cache,
                cache_index=jnp.zeros((), jnp.int32), n_groups=n_groups,
                act_spec=act_spec)
            return logits[:, -1], cache
        # encoder: full bidirectional forward, no cache
        logits, _, _ = T.forward(params, cfg, tokens=batch.get("tokens"),
                                 embeds=batch.get("embeds"),
                                 n_groups=n_groups, act_spec=act_spec)
        return logits, cache
    return prefill_step


def build_decode_step(cfg, n_groups: int):
    def serve_step(params, cache, token, index):
        logits, cache, _ = T.forward(params, cfg, tokens=token, cache=cache,
                                     cache_index=index, decode=True,
                                     n_groups=n_groups)
        return logits[:, -1], cache
    return serve_step


# ---------------------------------------------------------------------------
# Sharding assembly per cell
# ---------------------------------------------------------------------------

def cell_shardings(arch: str, shape_name: str, mesh, cfg=None):
    cfg = cfg or get_config(arch)
    shape = LM_SHAPES[shape_name]
    ba = shd.batch_axes(mesh)
    pspec = shd.param_specs(cfg)
    out = {}
    if shape.kind == "train":
        out["params"] = pspec
        out["opt_state"] = AdamWState(step=P(), m=pspec, v=pspec)
        bspecs = {}
        if cfg.inputs_are_embeddings:
            bspecs["embeds"] = P(ba, "model", None)
            bspecs["labels" if not cfg.causal else "tokens"] = P(ba, None)
        else:
            bspecs["tokens"] = P(ba, None)
        out["batch"] = bspecs
    elif shape.kind == "prefill":
        out["params"] = pspec
        out["cache"] = jax.tree.map(
            lambda s: s,
            shd.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len),
            is_leaf=lambda x: isinstance(x, P))
        bspecs = {}
        if cfg.inputs_are_embeddings:
            bspecs["embeds"] = P(ba, None, None)
            bspecs["labels" if not cfg.causal else "tokens"] = P(ba, None)
        else:
            bspecs["tokens"] = P(ba, None)
        out["batch"] = bspecs
    else:  # decode
        dsize = math.prod(mesh.shape[a] for a in shd.data_axes(mesh))
        tok_spec = P(ba, None) if shape.global_batch % max(dsize, 1) == 0 \
            and shape.global_batch > 1 else P(None, None)
        out["params"] = pspec
        out["cache"] = shd.cache_specs(cfg, mesh, shape.global_batch,
                                       shape.seq_len)
        out["token"] = tok_spec
        out["index"] = P()
    return out


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------

def _recurrent_heads(cfg) -> int:
    """Largest per-head chunk-decay width among recurrent blocks (0 if none)."""
    h = 0
    for kind in cfg.pattern:
        if kind == "mamba":
            di = cfg.d_inner
            h = max(h, di // cfg.hd if di % cfg.hd == 0 else 1)
        elif kind == "mlstm":
            h = max(h, cfg.n_heads)
    return h


def choose_chunk(cfg, shape) -> int:
    """Dry-run chunk size for recurrent blocks: the scans are fully unrolled
    (REPRO_UNROLL_SCANS - see scan_util), so the chunk count nc = L/c directly
    multiplies compile time, while the intra-chunk decay tensor (B, c, c, H)
    multiplies the memory footprint. Pick the largest c with
    B*c^2*H <= ~3.8e10 elements (~300 MB f32/device at 512 chips), nc <= 32,
    c in [256, 4096]."""
    H = _recurrent_heads(cfg)
    if H == 0 or shape.kind == "decode":
        return cfg.chunk_size
    L, B = shape.seq_len, shape.global_batch
    budget = 1.4e11  # global f32 elements for one decay tensor (~1GB/chip)
    c = int(math.sqrt(budget / max(B * H, 1)))
    c = max(256, min(c, 4096, L))
    # snap to a power-of-two divisor of L with nc <= 16
    c2 = 256
    while c2 * 2 <= c and L % (c2 * 2) == 0:
        c2 *= 2
    while L // c2 > 16:
        c2 *= 2
    return min(c2, L)


def _extrapolate_cell(arch: str, shape_name: str, multi_pod: bool,
                      save: bool, verbose: bool, mesh, variant: str,
                      ov: dict) -> dict:
    """Two-point repeat extrapolation for compile-heavy recurrent cells.

    The unrolled program is homogeneous in pattern repeats, so every additive
    cost (FLOPs, bytes, per-kind wire bytes) is exactly affine in R:
    cost(R) = cost(2) + (R-2) * (cost(2) - cost(1)). We compile R=1 and R=2
    and extrapolate to the real depth; numerics are untouched (this is a
    cost-model evaluation, the full-depth program still lowers - decode cells
    prove the stacked params/cache shard).
    """
    cfg_full = get_config(arch)
    unit = len(cfg_full.pattern)
    R = cfg_full.repeats
    recs = []
    for r in (1, 2):
        ov_r = dict(ov)
        ov_r["_n_layers"] = unit * r
        recs.append(run_cell(arch, shape_name, multi_pod=multi_pod,
                             save=False, verbose=False, mesh=mesh,
                             variant=variant, overrides=ov_r))
    one, two = recs
    out = dict(two)

    def lin(a, b):
        return b + (R - 2) * (b - a)

    for key in ("hlo_flops", "hlo_bytes", "wire_bytes_per_chip"):
        out[key] = lin(one[key], two[key])
    out["collectives"] = {k: lin(one["collectives"][k], two["collectives"][k])
                          for k in two["collectives"]}
    out["bytes_per_device"] = {
        k: (lin(one["bytes_per_device"][k], two["bytes_per_device"][k])
            if k in ("argument_bytes", "output_bytes")
            else two["bytes_per_device"][k])  # temps: buffer-reuse bound
        for k in two["bytes_per_device"]}
    out["model_flops"] = rl.model_flops(arch, shape_name)
    chips = out["chips"]
    out["compute_s"] = out["hlo_flops"] / (chips * rl.PEAK_FLOPS)
    out["memory_s"] = out["hlo_bytes"] / (chips * rl.HBM_BW)
    out["collective_s"] = out["wire_bytes_per_chip"] / rl.ICI_BW
    terms = {"compute": out["compute_s"], "memory": out["memory_s"],
             "collective": out["collective_s"]}
    out["dominant"] = max(terms, key=terms.get)
    out["step_time_s"] = max(terms.values())
    out["useful_flops_frac"] = out["model_flops"] / max(out["hlo_flops"], 1.0)
    out["mfu"] = out["model_flops"] / (
        out["step_time_s"] * chips * rl.PEAK_FLOPS + 1e-30)
    out["extrapolated"] = f"R=1,2 -> R={R}"
    mesh_name = out["mesh"]
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] (extrapolated R={R}) "
              f"compute={out['compute_s']*1e3:.2f}ms "
              f"memory={out['memory_s']*1e3:.2f}ms "
              f"collective={out['collective_s']*1e3:.2f}ms "
              f"dominant={out['dominant']} mfu={out['mfu']:.3f}", flush=True)
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = "" if variant == "base" else f"__{variant}"
        fn = os.path.join(RESULTS_DIR,
                          f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(out, f, indent=1)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             save: bool = True, verbose: bool = True, mesh=None,
             variant: str = "base", overrides: Optional[dict] = None) -> dict:
    """``overrides`` (perf-iteration knobs, recorded under ``variant``):
        act_seq_shard: bool   sequence-parallel activations (default True)
        loss_chunks: int      chunked cross-entropy chunk count
        remat: bool           scan-body rematerialization (default True)
        param_dtype: str      "float32" (default) | "bfloat16" train params
        chunk_size: int       recurrent-block chunk length
        moe_groups: int       MoE dispatch group count
        cache_seq_axis: str   "model" (default) | "none" decode KV layout
    """
    ov = overrides or {}
    shape = LM_SHAPES[shape_name]
    cfg = get_config(arch)
    if ("_n_layers" not in ov and shape.kind != "decode"
            and _recurrent_heads(cfg) > 0 and cfg.repeats > 2
            and os.environ.get("REPRO_NO_EXTRAPOLATE", "0") != "1"):
        return _extrapolate_cell(arch, shape_name, multi_pod, save, verbose,
                                 mesh, variant, ov)
    if mesh is None:
        ensure_dryrun_devices()
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    if "_n_layers" in ov:
        cfg = dataclasses.replace(cfg, n_layers=ov["_n_layers"])
    cfg = dataclasses.replace(
        cfg, chunk_size=ov.get("chunk_size", choose_chunk(cfg, shape)))
    if "param_dtype" in ov and shape.kind == "train":
        cfg = dataclasses.replace(cfg, param_dtype=ov["param_dtype"])
    if ov.get("moe_shard_hints"):
        cfg = dataclasses.replace(cfg, moe_shard_hints=True)
    if ov.get("fused_kv_cache"):
        cfg = dataclasses.replace(cfg, fused_kv_cache=True)
    if "compute_dtype" in ov:
        cfg = dataclasses.replace(cfg, compute_dtype=ov["compute_dtype"])
    n_groups = ov.get("moe_groups", sp.n_groups_for(shape, chips))

    inputs = sp.input_specs(arch, shape_name, cfg)
    if "param_dtype" in ov and shape.kind == "train":
        inputs["params"] = sp.param_structs(arch, ov["param_dtype"], cfg)
    specs = cell_shardings(arch, shape_name, mesh, cfg)
    if ov.get("cache_seq_axis") == "none" and "cache" in specs:
        specs["cache"] = jax.tree.map(
            lambda s: P(*[None if ax == "model" else ax for ax in s]),
            specs["cache"], is_leaf=lambda x: isinstance(x, P))
    in_shardings = _named(mesh, specs)

    if shape.kind == "train":
        step = build_train_step(cfg, mesh, n_groups,
                                act_seq_shard=ov.get("act_seq_shard", True),
                                loss_chunks=ov.get("loss_chunks"),
                                remat=ov.get("remat", True))
        args = (inputs["params"], inputs["opt_state"], inputs["batch"])
        in_sh = (in_shardings["params"], in_shardings["opt_state"],
                 in_shardings["batch"])
        out_sh = (in_shardings["params"], in_shardings["opt_state"], None)
    elif shape.kind == "prefill":
        ba = shd.batch_axes(mesh)
        act = P(ba, "model", None) if ov.get("act_seq_shard", True) \
            else P(ba, None, None)
        step = build_prefill_step(cfg, n_groups, act_spec=act)
        args = (inputs["params"], inputs["cache"], inputs["batch"])
        in_sh = (in_shardings["params"], in_shardings["cache"],
                 in_shardings["batch"])
        out_sh = (None, in_shardings["cache"])
    else:
        step = build_decode_step(cfg, n_groups)
        args = (inputs["params"], inputs["cache"], inputs["token"],
                inputs["index"])
        in_sh = (in_shardings["params"], in_shardings["cache"],
                 in_shardings["token"], in_shardings["index"])
        out_sh = (None, in_shardings["cache"])

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes",
                              getattr(mem, "temp_size_in_bytes", 0)),
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    roof = rl.build(arch, shape_name, mesh_name, chips, cost, mem_d, hlo)
    rec = roof.to_dict()
    rec.update({
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "n_groups": n_groups,
        "multi_pod": multi_pod,
        "status": "ok",
        "variant": variant,
        "overrides": ov,
        "chunk_size": cfg.chunk_size,
    })
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"compute={roof.compute_s*1e3:.2f}ms memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms dominant={roof.dominant} "
              f"mfu={roof.mfu:.3f} args/dev={mem_d['argument_bytes']/chips/1e9:.2f}GB "
              f"temp/dev={mem_d['temp_bytes']/chips/1e9:.2f}GB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = "" if variant == "base" else f"__{variant}"
        fn = os.path.join(RESULTS_DIR,
                          f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ensure_dryrun_devices()
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape cell (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch in archs:
        shapes = [args.shape] if args.shape else \
            [s for s in LM_SHAPES if s not in SKIPS[arch]]
        for shape_name in shapes:
            if shape_name in SKIPS[arch]:
                print(f"[{arch} x {shape_name}] SKIP (per DESIGN.md)")
                continue
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                fn = os.path.join(RESULTS_DIR,
                                  f"{arch}__{shape_name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(fn):
                    print(f"[{arch} x {shape_name} x {mesh_name}] cached")
                    continue
                try:
                    run_cell(arch, shape_name, multi_pod=mp)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_name, str(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f[:3])
        raise SystemExit(1)
    print("\nALL CELLS GREEN")


if __name__ == "__main__":
    main()
