"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_3b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real TPU fleet this binary runs once per host (jax.distributed
initializes from TPU metadata); in this container it drives the same code
single-process. --mesh data,model shapes a device mesh over the visible
devices and shards params/optimizer/batch with the LP-derived specs.
"""

from __future__ import annotations

import argparse
import logging

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (full configs need a real pod)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default=None, help="token file (uint32)")
    ap.add_argument("--mesh", default=None,
                    help="e.g. '2,4' -> (data=2, model=4) over local devices")
    ap.add_argument("--backend", choices=("xla", "pallas", "im2col"),
                    default=None,
                    help="kernel backend override; default resolves from "
                         "REPRO_BACKEND and then the --target preset")
    ap.add_argument("--target", default=None,
                    help="hardware target preset (tpu_v5e | gemmini | "
                         "cpu_interpret); sets the plan/precision policy "
                         "and the default backend")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    from repro.configs import get_config, get_smoke
    from repro.data.pipeline import DataConfig
    from repro.ops import ExecutionContext, default_context
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import TrainConfig, Trainer

    backend = args.backend
    if args.target:
        from repro.plan import get_target

        ctx = ExecutionContext(target=get_target(args.target),
                               backend=backend).resolved()
    else:
        ctx = default_context() if backend is None else \
            default_context().with_backend(backend)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh(shape, ("data", "model")[:len(shape)])

    dcfg = DataConfig(batch_size=args.batch, seq_len=args.seq,
                      vocab_size=cfg.vocab_size, path=args.data)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                       total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, microbatches=args.microbatches,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       remat=args.remat, ctx=ctx,
                       compress_grads=args.compress_grads,
                       n_groups=max(1, np.gcd(args.batch * args.seq,
                                              len(jax.devices()))))
    trainer = Trainer(cfg, ocfg, tcfg, dcfg, mesh=mesh)
    hist = trainer.run()
    if hist["loss"]:
        print(f"final loss {hist['loss'][-1]:.4f} over {len(hist['loss'])} steps "
              f"({np.mean(hist['step_time'][1:] or hist['step_time']):.3f}s/step, "
              f"skipped={trainer.skipped_steps})")


if __name__ == "__main__":
    main()
