"""Backend registry: named backends, per-op entries, declared capabilities.

A :class:`Backend` is a table ``op name -> OpEntry``; each entry carries the
kernel adapter plus an :class:`OpCapabilities` declaring what call shapes it
can serve (accepted dtypes and feature flags such as ``per_row_q_offset`` or
``key_mask``). The dispatcher (``repro.ops.dispatch``) walks the requested
backend's fallback chain until an entry's capabilities cover the call — this
replaces the ad-hoc ``if use_pallas and cache is None and key_mask is None``
branches that used to live in ``models/layers.py``.

Three backends ship:

  * ``xla``    - pure jnp/lax reference path. Universal: every capability
                 flag, every dtype; the terminal fallback.
  * ``pallas`` - the LP-tiled Pallas kernels. Declares exactly what the
                 kernels support: static, traced-scalar, and per-row
                 ``q_offset`` (the flash kernel's scalar-prefetch path), plus
                 the paged ``attention_decode`` entry — so the serving decode
                 hot path runs Pallas end-to-end; only ``key_mask`` (padded
                 batched prefill) still falls back to masked XLA *by declared
                 capability*. Attention serves GQA by folding query groups
                 into the sequence axis — K/V are never materialized repeated
                 in HBM (the old wrapper's ``jnp.repeat`` cost g x the KV
                 stream traffic).
  * ``im2col`` - the paper's baseline conv algorithm (materialized patches
                 -> LP-tiled Pallas GEMM), conv2d only, falling through to
                 ``xla`` for everything else. Exists so benchmarks can
                 dispatch the algorithm the §5 tiling is measured against.

Adapters take ``(ctx, plan, *args, **kw)``: ``plan`` is the ExecutionPlan the
dispatcher resolved from the entry's ``spec_fn`` (None for ops whose tiling is
closed-form), so plan -> precision -> kernel is connected in one place. An
entry may also declare a ``words_fn`` — the measured-HBM-words counter for
the launch geometry the kernel would lower — which the dispatcher attaches to
the :class:`DispatchDecision` next to the plan's Thm 2.1 lower bound.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, Optional, Tuple

import jax
import jax.numpy as jnp

import numpy as np

from repro.core.conv_model import Precision
from repro.kernels.conv1d import (conv1d_access_plan,
                                  conv1d_causal as _conv1d_pallas,
                                  conv1d_hbm_words)
from repro.kernels.conv2d import (_conv_spec, conv2d as _conv2d_pallas,
                                  conv2d_access_plan, conv2d_hbm_words)
from repro.kernels.flash_attention import (attention_blocks,
                                           attention_hbm_words,
                                           flash_attention as _flash_pallas,
                                           flash_attention_access_plan,
                                           paged_decode_access_plan,
                                           paged_decode_attention,
                                           paged_decode_hbm_words)
from repro.kernels.im2col import (conv2d_im2col, im2col_access_plan,
                                  im2col_hbm_words)
from repro.kernels.matmul import (_matmul_spec, matmul as _matmul_pallas,
                                  matmul_access_plan, matmul_hbm_words)
from repro.kernels.quant import (_conv_spec_q, _matmul_spec_q,
                                 conv2d_q as _conv2d_q_pallas,
                                 conv2d_q_access_plan, conv2d_q_hbm_words,
                                 matmul_q as _matmul_q_pallas,
                                 matmul_q_access_plan, matmul_q_hbm_words)
from repro.kernels import ref
from repro.plan import AttentionSpec

from .context import ExecutionContext

# Capability flags a call can require (derived per call in dispatch.*):
#   dynamic_q_offset  - q_offset is a traced scalar (any in-cache path)
#   per_row_q_offset  - q_offset is a (B,) vector (continuous-batching decode)
#   key_mask          - a (B, Lk) validity mask over the keys (padded prefill)
ATTN_FLAGS = ("dynamic_q_offset", "per_row_q_offset", "key_mask")


@dataclasses.dataclass(frozen=True)
class OpCapabilities:
    """What one backend's op entry can serve.

    ``dtypes`` is the accepted input dtypes ("*" = anything); ``flags`` the
    supported optional call features. Entries accepting narrow storage
    (int8/fp8 streams) must declare ``accum_dtype`` — the in-kernel
    accumulation dtype, f32 or wider (lint rule VRF013)."""

    dtypes: Tuple[str, ...] = ("float32", "bfloat16")
    flags: FrozenSet[str] = frozenset()
    accum_dtype: Optional[str] = None

    def missing(self, dtype: Optional[str] = None,
                needs: Tuple[str, ...] = ()) -> Tuple[str, ...]:
        """The subset of requirements this entry cannot serve (empty = capable)."""
        out = []
        if dtype is not None and "*" not in self.dtypes and dtype not in self.dtypes:
            out.append(f"dtype:{dtype}")
        out.extend(f for f in needs if f not in self.flags)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class OpEntry:
    """One backend's implementation of one op."""

    fn: Callable  # (ctx, plan, *args, **kw) -> result
    caps: OpCapabilities = OpCapabilities()
    # builds the planner OpSpec from the call's arrays; None = closed-form
    # tiling (conv1d lane widths, flash-attention blocks), no LP plan.
    spec_fn: Optional[Callable] = None
    # measured HBM words the kernel's launch geometry moves for this call:
    # (ctx, plan, *spec_args, **spec_kw) -> float. None = not instrumented
    # (XLA entries delegate data movement to the compiler).
    words_fn: Optional[Callable] = None
    # structured launch metadata for the static auditor: (ctx, plan,
    # *spec_args, **spec_kw) -> repro.verify.access.KernelAccessPlan. The
    # auditor abstractly interprets it and must reproduce words_fn exactly;
    # None = not statically auditable (XLA entries, and conv2d_dist whose
    # execution is a shard_map program, not one Pallas launch — its
    # shard-local conv2d entry is audited instead).
    access_plan_fn: Optional[Callable] = None
    # runtime-degradation target: the backend dispatch_call demotes to when
    # this entry raises a TransientFault at execution. None = follow the
    # backend's capability fallback. Naming an *instrumented* backend here
    # (conv2d pallas -> im2col) keeps the degraded decision priced —
    # measured_words/bound_ratio show what the demotion costs.
    degrade_to: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class Backend:
    """A named op table with a fallback chain terminating at ``xla``."""

    name: str
    ops: Dict[str, OpEntry]
    fallback: Optional[str] = None  # next backend when capabilities miss


_BACKENDS: Dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    if not backend.ops:
        raise ValueError(f"backend {backend.name!r} registers no ops")
    _BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; "
                       f"registered: {sorted(_BACKENDS)}")


def backends() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def registered_ops() -> Tuple[str, ...]:
    """Op names dispatchable from any backend (the union: every fallback
    chain terminates at ``xla``, which serves everything, so a partial
    backend like ``im2col`` widens nothing but narrows nothing either)."""
    names = set()
    for b in _BACKENDS.values():
        names |= set(b.ops)
    return tuple(sorted(names))


# ---------------------------------------------------------------------------
# XLA backend: jnp/lax implementations. Terminal fallback; supports
# everything (grouped GQA kept factored, per-row offsets, key masks).
# ---------------------------------------------------------------------------

def _xla_matmul(ctx, plan, a, b, out_dtype=jnp.float32):
    return ref.matmul_ref(a, b, out_dtype=out_dtype)


def _xla_conv2d(ctx, plan, x, w, stride=(1, 1), out_dtype=jnp.float32):
    return ref.conv2d_ref(x, w, stride=stride, out_dtype=out_dtype)


def _xla_conv1d(ctx, plan, x, w):
    return ref.conv1d_causal_ref(x, w)


def xla_attention(q, k, v, causal: bool = True, q_offset=0,
                  key_mask=None) -> jax.Array:
    """jnp GQA attention with the grouping kept factored (no KV repeat in HBM).

    ``q_offset`` is the absolute position of the first query: a scalar for
    lockstep batches or a (B,) vector when every row decodes at its own depth.
    ``key_mask`` is an optional (B, Lk) validity mask over the keys. Logits,
    softmax, and PV accumulate in f32 (the paper's mixed-precision
    discipline)."""
    B, H, Lq, hd = q.shape
    KV, Lk = k.shape[1], k.shape[2]
    g = H // KV
    qg = q.reshape(B, KV, g, Lq, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bkgqd,bkld->bkgql", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = None
    if causal:
        off = jnp.asarray(q_offset, jnp.int32)
        if off.ndim:
            qpos = jnp.arange(Lq, dtype=jnp.int32)[None, :] + off[:, None]
        else:
            qpos = (jnp.arange(Lq, dtype=jnp.int32) + off)[None, :]
        kpos = jnp.arange(Lk, dtype=jnp.int32)
        mask = kpos[None, None, :] <= qpos[:, :, None]  # (B|1, Lq, Lk)
    if key_mask is not None:
        km = key_mask[:, None, :]  # (B, 1, Lk)
        mask = km if mask is None else (mask & km)
    if mask is not None:
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgql,bkld->bkgqd", probs, v.astype(jnp.float32))
    return o.reshape(B, H, Lq, hd).astype(q.dtype)


def _xla_attention_entry(ctx, plan, q, k, v, causal=True, q_offset=0,
                         key_mask=None):
    return xla_attention(q, k, v, causal=causal, q_offset=q_offset,
                        key_mask=key_mask)


def xla_attention_decode(q, kp, vp, tables, lengths) -> jax.Array:
    """Reference paged decode: gather each row's blocks and attend in block
    layout. The gather materializes a copy of the live cache in HBM — exactly
    the traffic the Pallas entry's table-following index_map avoids — but the
    einsums keep the (w, bs) block axes factored, so no transpose/reshape
    copies follow it."""
    B, H, Lq, hd = q.shape
    if Lq != 1:
        raise ValueError(f"paged decode expects Lq == 1, got {Lq}")
    KV, bs = kp.shape[1], kp.shape[2]
    w = tables.shape[1]
    g = H // KV
    qg = q.reshape(B, KV, g, hd).astype(jnp.float32)
    kb = kp[tables]  # (B, w, KV, bs, hd)
    vb = vp[tables]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bkgd,bwksd->bkgws", qg,
                        kb.astype(jnp.float32)) * scale
    pos = jnp.arange(w * bs, dtype=jnp.int32).reshape(w, bs)
    mask = pos[None] < lengths[:, None, None]  # (B, w, bs)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits.reshape(B, KV, g, w * bs), axis=-1)
    o = jnp.einsum("bkgws,bwksd->bkgd", probs.reshape(B, KV, g, w, bs),
                   vb.astype(jnp.float32))
    return o.reshape(B, H, 1, hd).astype(q.dtype)


def _xla_attention_decode_entry(ctx, plan, q, kp, vp, tables, lengths):
    return xla_attention_decode(q, kp, vp, tables, lengths)


# -- quantized references: integer-exact math in f32, scale applied once ----

def _xla_conv2d_q(ctx, plan, x, w, scale, stride=(1, 1),
                  out_dtype=jnp.bfloat16):
    out = ref.conv2d_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                         stride=stride, out_dtype=jnp.float32)
    return (out * scale[0][None, :, None, None]).astype(out_dtype)


def _xla_matmul_q(ctx, plan, a, b, scale, out_dtype=jnp.bfloat16):
    out = ref.matmul_ref(a.astype(jnp.float32), b.astype(jnp.float32),
                         out_dtype=jnp.float32)
    return (out * scale).astype(out_dtype)


def xla_attention_decode_quant(q, kp, ks, vp, vs, tables, lengths
                               ) -> jax.Array:
    """Paged decode against an int8-quantized pool: ``kp``/``vp`` are
    (num_blocks, KV, bs, hd) int8 and ``ks``/``vs`` the matching
    (num_blocks, KV, bs) f32 per-(block, head, position) scales written by
    the engine's quantizing insert. Dequantization happens in f32 on the
    gathered view; the attention math is then exactly
    :func:`xla_attention_decode`. xla-only: every backend's fallback chain
    reaches it, and it keeps the quantized pool off the VJP path (decode is
    inference)."""
    kf = kp.astype(jnp.float32) * ks[..., None]
    vf = vp.astype(jnp.float32) * vs[..., None]
    return xla_attention_decode(q, kf, vf, tables, lengths)


def _xla_attention_decode_quant_entry(ctx, plan, q, kp, ks, vp, vs, tables,
                                      lengths):
    return xla_attention_decode_quant(q, kp, ks, vp, vs, tables, lengths)


# -- plan-spec builders (shared by every backend's instrumented entries) ----

def _matmul_plan_spec(a, b, **kw):
    m, k = a.shape
    n = b.shape[1]
    return _matmul_spec(m, n, k, jnp.dtype(a.dtype).itemsize * 8)


def _conv2d_plan_spec(x, w, stride=(1, 1), **kw):
    N, c_I, H, W = x.shape
    c_O, _, h_F, w_F = w.shape
    sh, sw = stride
    return _conv_spec(N, c_I, c_O, (H - h_F) // sh + 1, (W - w_F) // sw + 1,
                      h_F, w_F, sh, sw, jnp.dtype(x.dtype).itemsize * 8)


def _conv2d_q_plan_spec(x, w, scale=None, stride=(1, 1),
                        out_dtype=jnp.bfloat16, **kw):
    N, c_I, H, W = x.shape
    c_O, _, h_F, w_F = w.shape
    sh, sw = stride
    return _conv_spec_q(N, c_I, c_O, (H - h_F) // sh + 1,
                        (W - w_F) // sw + 1, h_F, w_F, sh, sw,
                        x.dtype, w.dtype, out_dtype)


def _matmul_q_plan_spec(a, b, scale=None, out_dtype=jnp.bfloat16, **kw):
    m, k = a.shape
    n = b.shape[1]
    return _matmul_spec_q(m, n, k, a.dtype, b.dtype, out_dtype)


def _attention_decode_quant_plan_spec(q, kp, ks, vp, vs, tables, lengths,
                                      **kw):
    """The quantized pool stream priced at its stored width: p_F counts the
    int8 block bytes plus the f32 scale per (head, position) row —
    (0.25 * hd + 1) / hd words per cached element."""
    B, H, _, hd = q.shape
    KV, bs = kp.shape[1], kp.shape[2]
    w = tables.shape[1]
    p_io = jnp.dtype(q.dtype).itemsize / 4.0
    p_kv = (jnp.dtype(kp.dtype).itemsize / 4.0) + 1.0 / hd
    return AttentionSpec(B=B, H=H, KV=KV, Lq=1, Lk=w * bs, hd=hd,
                         prec=Precision(p_I=p_io, p_F=p_kv, p_O=p_io))


def _attention_plan_spec(q, k, v, **kw):
    B, H, Lq, hd = q.shape
    KV, Lk = k.shape[1], k.shape[2]
    p_io = jnp.dtype(q.dtype).itemsize / 4.0
    p_kv = jnp.dtype(k.dtype).itemsize / 4.0
    return AttentionSpec(B=B, H=H, KV=KV, Lq=Lq, Lk=Lk, hd=hd,
                         prec=Precision(p_I=p_io, p_F=p_kv, p_O=p_io))


def _attention_decode_plan_spec(q, kp, vp, tables, lengths, **kw):
    """Paged decode as an AttentionSpec: Lq = 1, Lk = the table window's
    token capacity (w * block_size) — the keys one decode step streams."""
    B, H, _, hd = q.shape
    KV, bs = kp.shape[1], kp.shape[2]
    w = tables.shape[1]
    p_io = jnp.dtype(q.dtype).itemsize / 4.0
    p_kv = jnp.dtype(kp.dtype).itemsize / 4.0
    return AttentionSpec(B=B, H=H, KV=KV, Lq=1, Lk=w * bs, hd=hd,
                         prec=Precision(p_I=p_io, p_F=p_kv, p_O=p_io))


# -- conv2d_dist: the distributed halo-exchange conv (repro.distributed) ----
#
# One op, registered on both backends: the backend picks which kernel serves
# the *shard-local* conv inside shard_map (xla -> the lax reference, pallas
# -> the PR-4 LP-tiled kernel). The entry's words_fn measures *inter-device*
# words (halo ppermute + cI psum volume per device, from the same launch
# geometry the execution lowers) — DispatchDecision.bound_ratio divides it
# by the plan's Thm 2.2/2.3 ``parallel`` bound instead of the single-device
# Thm 2.1 bound. Imports are lazy: repro.distributed dispatches back through
# repro.ops for the shard-local conv, so a top-level import would be
# circular.

def _dist_entry(local_backend: str):
    def run(ctx, plan, x, w, stride=(1, 1), out_dtype=jnp.float32,
            blocking=None, mesh=None):
        from repro.distributed.halo import halo_conv

        return halo_conv(x, w, stride=stride, blocking=blocking, mesh=mesh,
                         ctx=ctx, local_backend=local_backend,
                         out_dtype=out_dtype)
    return run


def _conv2d_dist_words(ctx, plan, x, w, stride=(1, 1), out_dtype=None,
                       blocking=None, **kw):
    from repro.distributed.halo import conv2d_dist_comm_words

    return conv2d_dist_comm_words(x, w, stride=stride, blocking=blocking,
                                  out_dtype=out_dtype or ctx.acc_dtype)


register_backend(Backend(
    name="xla",
    ops={
        "matmul": OpEntry(_xla_matmul, OpCapabilities(dtypes=("*",))),
        "conv2d": OpEntry(_xla_conv2d, OpCapabilities(dtypes=("*",))),
        "conv1d_causal": OpEntry(_xla_conv1d, OpCapabilities(dtypes=("*",))),
        "attention": OpEntry(
            _xla_attention_entry,
            OpCapabilities(dtypes=("*",), flags=frozenset(ATTN_FLAGS))),
        "attention_decode": OpEntry(
            _xla_attention_decode_entry, OpCapabilities(dtypes=("*",))),
        "attention_decode_quant": OpEntry(
            _xla_attention_decode_quant_entry,
            OpCapabilities(dtypes=("*",), accum_dtype="float32"),
            spec_fn=_attention_decode_quant_plan_spec),
        "conv2d_q": OpEntry(
            _xla_conv2d_q,
            OpCapabilities(dtypes=("*",), accum_dtype="float32"),
            spec_fn=_conv2d_q_plan_spec),
        "matmul_q": OpEntry(
            _xla_matmul_q,
            OpCapabilities(dtypes=("*",), accum_dtype="float32"),
            spec_fn=_matmul_q_plan_spec),
        "conv2d_dist": OpEntry(_dist_entry("xla"), OpCapabilities(dtypes=("*",)),
                               spec_fn=_conv2d_plan_spec,
                               words_fn=_conv2d_dist_words),
    },
))


# ---------------------------------------------------------------------------
# Pallas backend: LP-tiled kernels. Plans resolve through ctx.plan (the
# process-wide cache); interpret mode comes from the target unless the
# context overrides it.
#
# Differentiability: pallas_call has no JVP rule for scratch-carrying
# kernels, and the missing rule fires inside lax.scan/checkpoint jaxpr
# differentiation where no call-time feature detection could catch it. So
# every pallas entry is wrapped in jax.custom_vjp: the forward runs the
# LP-tiled kernel, the backward recomputes through the XLA reference
# implementation (the standard flash-attention fwd-kernel/bwd-recompute
# design) — training works on the pallas backend without a hand-written
# backward kernel.
# ---------------------------------------------------------------------------

def _with_xla_vjp(pallas_fn: Callable, xla_fn: Callable, *arrays):
    """Run ``pallas_fn(*arrays)`` forward with gradients defined by
    ``jax.vjp`` through ``xla_fn`` (both close over their static config)."""
    f = jax.custom_vjp(pallas_fn)

    def fwd(*arrays):
        return pallas_fn(*arrays), arrays

    def bwd(res, g):
        _, vjp = jax.vjp(xla_fn, *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f(*arrays)


def _pallas_matmul(ctx, plan, a, b, out_dtype=jnp.float32):
    return _with_xla_vjp(
        lambda a_, b_: _matmul_pallas(a_, b_, out_dtype=out_dtype, plan=plan,
                                      interpret=ctx.interpret),
        lambda a_, b_: ref.matmul_ref(a_, b_, out_dtype=out_dtype), a, b)


def _pallas_conv2d(ctx, plan, x, w, stride=(1, 1), out_dtype=jnp.float32):
    return _with_xla_vjp(
        lambda x_, w_: _conv2d_pallas(x_, w_, stride=stride,
                                      out_dtype=out_dtype, plan=plan,
                                      interpret=ctx.interpret),
        lambda x_, w_: ref.conv2d_ref(x_, w_, stride=stride,
                                      out_dtype=out_dtype), x, w)


def _pallas_conv1d(ctx, plan, x, w):
    return _with_xla_vjp(
        lambda x_, w_: _conv1d_pallas(x_, w_, target=ctx.target,
                                      interpret=ctx.interpret),
        ref.conv1d_causal_ref, x, w)


def _pallas_attention(ctx, plan, q, k, v, causal=True, q_offset=0,
                      key_mask=None):
    """GQA via group-folding: queries of the g heads sharing one KV head are
    stacked along the sequence axis ((B*Hkv, g*Lq, Dh)), so K/V stream at
    their (B*Hkv, Lk, Dh) size instead of being repeated g x in HBM. The
    kernel recovers per-query absolute positions with ``q_seq_len``.

    A traced scalar or (B,) ``q_offset`` selects the flash kernel's dynamic
    path (scalar-prefetch offsets). Offsets ride as an explicit int32 operand
    through ``_with_xla_vjp`` — never closed over — so custom_vjp sees them
    as a differentiable-in-name-only arg (float0 cotangent) instead of a
    leaked tracer."""
    assert key_mask is None, "capability-gated: pallas serves no key masks"
    B, H, Lq, Dh = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    g = H // Hkv

    if isinstance(q_offset, (int, np.integer)):
        def fwd(q, k, v):
            kf = k.reshape(B * Hkv, Lk, Dh)
            vf = v.reshape(B * Hkv, Lk, Dh)
            if g == 1:
                out = _flash_pallas(q.reshape(B * H, Lq, Dh), kf, vf,
                                    causal=causal, q_offset=q_offset,
                                    target=ctx.target, interpret=ctx.interpret)
                return out.reshape(B, H, Lq, Dh)
            qf = q.reshape(B * Hkv, g * Lq, Dh)  # groups stacked on seq axis
            out = _flash_pallas(qf, kf, vf, causal=causal, q_offset=q_offset,
                                q_seq_len=Lq, target=ctx.target,
                                interpret=ctx.interpret)
            return out.reshape(B, H, Lq, Dh)

        return _with_xla_vjp(
            fwd,
            lambda q_, k_, v_: xla_attention(q_, k_, v_, causal=causal,
                                             q_offset=q_offset), q, k, v)

    offs = jnp.asarray(q_offset, jnp.int32)
    per_row = bool(offs.ndim)
    # row b of the folded (B*Hkv) axis carries batch row b // Hkv
    row_offs = (jnp.repeat(offs, Hkv) if per_row
                else jnp.broadcast_to(offs, (B * Hkv,)))

    def fwd(q, k, v, row_offs):
        kf = k.reshape(B * Hkv, Lk, Dh)
        vf = v.reshape(B * Hkv, Lk, Dh)
        qf = q.reshape(B * Hkv, g * Lq, Dh)
        out = _flash_pallas(qf, kf, vf, causal=causal, q_offset=row_offs,
                            q_seq_len=Lq, target=ctx.target,
                            interpret=ctx.interpret)
        return out.reshape(B, H, Lq, Dh)

    def xla_fn(q, k, v, row_offs):
        off = row_offs.reshape(B, Hkv)[:, 0] if per_row else row_offs[0]
        return xla_attention(q, k, v, causal=causal, q_offset=off)

    return _with_xla_vjp(fwd, xla_fn, q, k, v, row_offs)


def _pallas_attention_decode(ctx, plan, q, kp, vp, tables, lengths):
    """Paged decode on the block-table-gathering kernel; backward recomputes
    through the XLA gather reference (tables/lengths are int32 operands, so
    their cotangents are float0)."""
    def fwd(q, kp, vp, tables, lengths):
        return paged_decode_attention(q, kp, vp, tables, lengths,
                                      target=ctx.target,
                                      interpret=ctx.interpret)

    return _with_xla_vjp(fwd, xla_attention_decode, q, kp, vp,
                         tables, lengths)


def _pallas_conv2d_q(ctx, plan, x, w, scale, stride=(1, 1),
                     out_dtype=jnp.bfloat16):
    """No custom_vjp wrapper: the quantized entries are the inference path —
    int8 operands carry no meaningful cotangent, and QAT differentiates the
    fake-quantized f32 graph, never the int8 kernel itself."""
    return _conv2d_q_pallas(x, w, scale, stride=stride, out_dtype=out_dtype,
                            plan=plan, interpret=ctx.interpret)


def _pallas_matmul_q(ctx, plan, a, b, scale, out_dtype=jnp.bfloat16):
    return _matmul_q_pallas(a, b, scale, out_dtype=out_dtype, plan=plan,
                            interpret=ctx.interpret)


def _pallas_conv2d_q_words(ctx, plan, x, w, scale=None, stride=(1, 1),
                           out_dtype=jnp.bfloat16, **kw):
    return conv2d_q_hbm_words(x, w, scale, stride=stride, plan=plan,
                              target=ctx.target, out_dtype=out_dtype)


def _pallas_matmul_q_words(ctx, plan, a, b, scale=None,
                           out_dtype=jnp.bfloat16, **kw):
    return matmul_q_hbm_words(a, b, scale, plan=plan, target=ctx.target,
                              out_dtype=out_dtype)


def _pallas_conv2d_q_access(ctx, plan, x, w, scale=None, stride=(1, 1),
                            out_dtype=jnp.bfloat16, **kw):
    return conv2d_q_access_plan(x, w, scale, stride=stride, plan=plan,
                                target=ctx.target, out_dtype=out_dtype)


def _pallas_matmul_q_access(ctx, plan, a, b, scale=None,
                            out_dtype=jnp.bfloat16, **kw):
    return matmul_q_access_plan(a, b, scale, plan=plan, target=ctx.target,
                                out_dtype=out_dtype)


def _pallas_matmul_words(ctx, plan, a, b, out_dtype=None, **kw):
    return matmul_hbm_words(a, b, plan=plan, target=ctx.target,
                            out_dtype=out_dtype or ctx.acc_dtype)


def _pallas_conv2d_words(ctx, plan, x, w, stride=(1, 1), out_dtype=None,
                         **kw):
    return conv2d_hbm_words(x, w, stride=stride, plan=plan,
                            target=ctx.target,
                            out_dtype=out_dtype or ctx.acc_dtype)


def _pallas_attention_words(ctx, plan, q, k, v, **kw):
    B, H, Lq, Dh = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    g = H // Hkv
    p_io = jnp.dtype(q.dtype).itemsize / 4.0
    p_kv = jnp.dtype(k.dtype).itemsize / 4.0
    bq, bk = attention_blocks(Dh, ctx.target, kv_word=p_kv)
    return attention_hbm_words(B * Hkv, g * Lq, Lk, Dh, bq, bk,
                               p_q=p_io, p_kv=p_kv, p_o=p_io)


def _pallas_attention_decode_words(ctx, plan, q, kp, vp, tables, lengths,
                                   **kw):
    B, H, _, hd = q.shape
    KV, bs = kp.shape[1], kp.shape[2]
    p_io = jnp.dtype(q.dtype).itemsize / 4.0
    p_kv = jnp.dtype(kp.dtype).itemsize / 4.0
    return paged_decode_hbm_words(B, KV, H // KV, tables.shape[1], bs, hd,
                                  p_q=p_io, p_kv=p_kv, p_o=p_io)


# -- access plans (repro.verify): the same geometry as the words_fns, as
# structured data the static auditor can abstractly interpret -----------------

def _pallas_matmul_access(ctx, plan, a, b, out_dtype=None, **kw):
    return matmul_access_plan(a, b, plan=plan, target=ctx.target,
                              out_dtype=out_dtype or ctx.acc_dtype)


def _pallas_conv2d_access(ctx, plan, x, w, stride=(1, 1), out_dtype=None,
                          **kw):
    return conv2d_access_plan(x, w, stride=stride, plan=plan,
                              target=ctx.target,
                              out_dtype=out_dtype or ctx.acc_dtype)


def _pallas_conv1d_words(ctx, plan, x, w, **kw):
    return conv1d_hbm_words(x, w, target=ctx.target)


def _pallas_conv1d_access(ctx, plan, x, w, **kw):
    return conv1d_access_plan(x, w, target=ctx.target)


def _pallas_attention_access(ctx, plan, q, k, v, **kw):
    # the static-kernel launch over the GQA-folded view; the dynamic variant
    # adds only uncounted scalar-prefetch operands (see the builder docstring)
    B, H, Lq, Dh = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    g = H // Hkv
    p_io = jnp.dtype(q.dtype).itemsize / 4.0
    p_kv = jnp.dtype(k.dtype).itemsize / 4.0
    bq, bk = attention_blocks(Dh, ctx.target, kv_word=p_kv)
    return flash_attention_access_plan(B * Hkv, g * Lq, Lk, Dh, bq, bk,
                                       p_q=p_io, p_kv=p_kv, p_o=p_io)


def _pallas_attention_decode_access(ctx, plan, q, kp, vp, tables, lengths,
                                    **kw):
    B, H, _, hd = q.shape
    KV, bs = kp.shape[1], kp.shape[2]
    p_io = jnp.dtype(q.dtype).itemsize / 4.0
    p_kv = jnp.dtype(kp.dtype).itemsize / 4.0
    # concrete table values are used when available (explain() passes
    # ShapeDtypeStructs and jit passes tracers; the builder then synthesizes
    # an all-distinct table, the allocator's normal traffic-maximal case)
    try:
        t_np = np.asarray(tables, dtype=np.int64)
        if t_np.ndim != 2:
            t_np = None
    except Exception:
        t_np = None
    return paged_decode_access_plan(
        B, KV, H // KV, tables.shape[1], bs, hd, num_blocks=kp.shape[0],
        p_q=p_io, p_kv=p_kv, p_o=p_io, tables=t_np)


register_backend(Backend(
    name="pallas",
    fallback="xla",
    ops={
        "matmul": OpEntry(_pallas_matmul, spec_fn=_matmul_plan_spec,
                          words_fn=_pallas_matmul_words,
                          access_plan_fn=_pallas_matmul_access),
        # runtime faults demote to the instrumented Im2Col baseline (not
        # straight to uninstrumented xla) so the 3.9-7.2x words cost of
        # degradation stays measured (PR 4's conv_bench gap)
        "conv2d": OpEntry(_pallas_conv2d, spec_fn=_conv2d_plan_spec,
                          words_fn=_pallas_conv2d_words,
                          access_plan_fn=_pallas_conv2d_access,
                          degrade_to="im2col"),
        # quantized entries: int8 streams only (f32/bf16 callers should use
        # the full-precision ops); accumulation declared per VRF013
        "conv2d_q": OpEntry(
            _pallas_conv2d_q,
            OpCapabilities(dtypes=("int8",), accum_dtype="float32"),
            spec_fn=_conv2d_q_plan_spec,
            words_fn=_pallas_conv2d_q_words,
            access_plan_fn=_pallas_conv2d_q_access),
        "matmul_q": OpEntry(
            _pallas_matmul_q,
            OpCapabilities(dtypes=("int8",), accum_dtype="float32"),
            spec_fn=_matmul_q_plan_spec,
            words_fn=_pallas_matmul_q_words,
            access_plan_fn=_pallas_matmul_q_access),
        "conv1d_causal": OpEntry(_pallas_conv1d,
                                 words_fn=_pallas_conv1d_words,
                                 access_plan_fn=_pallas_conv1d_access),
        # flash kernel: dynamic (traced scalar or per-row) q_offset rides the
        # scalar-prefetch path; only key_mask still falls back to masked xla
        # (padded batched prefill), so the decode hot path never leaves pallas.
        "attention": OpEntry(
            _pallas_attention,
            OpCapabilities(flags=frozenset({"dynamic_q_offset",
                                            "per_row_q_offset"})),
            spec_fn=_attention_plan_spec,
            words_fn=_pallas_attention_words,
            access_plan_fn=_pallas_attention_access),
        "attention_decode": OpEntry(
            _pallas_attention_decode,
            spec_fn=_attention_decode_plan_spec,
            words_fn=_pallas_attention_decode_words,
            access_plan_fn=_pallas_attention_decode_access),
        "conv2d_dist": OpEntry(_dist_entry("pallas"),
                               spec_fn=_conv2d_plan_spec,
                               words_fn=_conv2d_dist_words),
    },
))


# ---------------------------------------------------------------------------
# Im2Col backend: the paper's baseline conv algorithm as a third dispatchable
# conv2d entry (patches materialized in XLA, GEMM on the LP-tiled Pallas
# matmul). Every other op falls through the chain to xla.
# ---------------------------------------------------------------------------

def _im2col_conv2d(ctx, plan, x, w, stride=(1, 1), out_dtype=jnp.float32):
    return _with_xla_vjp(
        lambda x_, w_: conv2d_im2col(x_, w_, stride=stride,
                                     out_dtype=out_dtype, ctx=ctx),
        lambda x_, w_: ref.conv2d_ref(x_, w_, stride=stride,
                                      out_dtype=out_dtype), x, w)


def _im2col_conv2d_words(ctx, plan, x, w, stride=(1, 1), out_dtype=None,
                         **kw):
    return im2col_hbm_words(x, w, stride=stride, target=ctx.target,
                            out_dtype=out_dtype or ctx.acc_dtype)


def _im2col_conv2d_access(ctx, plan, x, w, stride=(1, 1), out_dtype=None,
                          **kw):
    return im2col_access_plan(x, w, stride=stride, target=ctx.target,
                              out_dtype=out_dtype or ctx.acc_dtype)


register_backend(Backend(
    name="im2col",
    fallback="xla",
    ops={
        # spec_fn resolves the same conv plan as the direct path so the
        # decision reports the identical Thm 2.1 lower bound; the GEMM's own
        # matmul plan is solved inside the kernel (memoized process-wide).
        "conv2d": OpEntry(_im2col_conv2d, spec_fn=_conv2d_plan_spec,
                          words_fn=_im2col_conv2d_words,
                          access_plan_fn=_im2col_conv2d_access),
    },
))
