"""ExecutionContext: the single execution-policy object threaded through the
model stack.

The paper's premise is that the *same* op should execute differently per
target — mixed-precision word sizes change the Thm 2.1 bound and therefore the
optimal tiling — so "which implementation runs, with which tiles, at which
precision" is a per-(op, target) decision. ``ExecutionContext`` bundles the
three inputs of that decision:

  * ``target``  - the :class:`repro.plan.HardwareTarget` whose memory model the
                  blocking LP plans against and whose ``precision`` policy sets
                  stream/accumulator dtypes;
  * ``backend`` - an explicit backend override (``"xla"`` | ``"pallas"``).
                  ``None`` defers to the ``REPRO_BACKEND`` environment variable
                  and then to the target's own default;
  * ``interpret`` - Pallas interpret-mode override (``None`` = the target's);
  * ``autotune`` - measured-autotune policy (``None``/``False`` = off,
                  ``True`` = default :class:`repro.plan.AutotunePolicy`, or a
                  policy instance). When set, plan resolution may run one
                  frontier search per (op, target) and then serve the tuned
                  winner from the TuningRecord store.

Plans are resolved through ``repro.plan.resolve_plan`` — the one shared path
(explicit plan > stored tuned plan > analytic LP plan) behind ``ctx.plan()``,
``ops.explain`` and the kernels' ``resolve_kernel_plan`` — backed by the
process-wide memoized cache in ``repro.plan.planner``, so every consumer of
one context converges on identical ``ExecutionPlan`` objects.

Backend resolution order: explicit ``ctx.backend`` > ``REPRO_BACKEND`` env var
> the target default. (The PR-3 ``REPRO_USE_PALLAS`` env var is gone;
``REPRO_BACKEND`` is the only environment knob.)
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro.plan import HardwareTarget, TPU_V5E

BACKEND_ENV = "REPRO_BACKEND"

# Paper word-widths (units of 32-bit words) -> jnp dtypes. The precision
# policy of a HardwareTarget speaks words; kernels speak dtypes.
_WORD_DTYPES = {1.0: jnp.float32, 0.5: jnp.bfloat16, 0.25: jnp.int8}


def dtype_for_words(words: float):
    """The jnp dtype of a paper word-width (1.0 -> f32, 0.5 -> bf16, ...)."""
    try:
        return _WORD_DTYPES[float(words)]
    except KeyError:
        raise ValueError(f"no dtype for precision {words} words; "
                         f"known: {sorted(_WORD_DTYPES)}")


def env_backend() -> Optional[str]:
    """Backend requested via ``REPRO_BACKEND=xla|pallas|im2col``, or None."""
    name = os.environ.get(BACKEND_ENV)
    if name:
        name = name.strip().lower()
        if name not in ("xla", "pallas", "im2col"):
            raise ValueError(
                f"{BACKEND_ENV}={name!r} is not a known backend "
                "(expected 'xla', 'pallas', or 'im2col')")
        return name
    return None


@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """HardwareTarget + precision policy + backend override + plan handle.

    Frozen and hashable so it can key jit static arguments and the serving
    engine's compiled-step cache, exactly as the old ``use_pallas`` bool did.
    """

    target: HardwareTarget = TPU_V5E
    backend: Optional[str] = None  # "xla" | "pallas" | None (resolve)
    interpret: Optional[bool] = None  # Pallas interpret override
    autotune: Any = None  # None/False | True | repro.plan.AutotunePolicy

    # -- backend resolution ---------------------------------------------------
    def resolved_backend(self) -> str:
        if self.backend is not None:
            return self.backend
        return env_backend() or ("pallas" if self.target.use_pallas else "xla")

    def resolved(self) -> "ExecutionContext":
        """Pin the backend choice (env var read once, here) so the context
        can key long-lived caches without depending on ambient state."""
        return dataclasses.replace(self, backend=self.resolved_backend())

    # -- builders -------------------------------------------------------------
    def with_backend(self, name: Optional[str]) -> "ExecutionContext":
        return dataclasses.replace(self, backend=name)

    @classmethod
    def from_target(cls, target: HardwareTarget,
                    backend: Optional[str] = None) -> "ExecutionContext":
        return cls(target=target, backend=backend)

    # -- plan-cache handle ----------------------------------------------------
    def plan(self, op, explicit=None):
        """Resolve the ExecutionPlan for ``op`` on this context's target via
        the shared resolution path (explicit > tuned > analytic), honoring
        this context's autotune policy."""
        return self.plan_with_source(op, explicit=explicit)[0]

    def plan_with_source(self, op, explicit=None) -> Tuple[Any, str]:
        """``(plan, source)`` with source one of ``"explicit"`` | ``"tuned"``
        | ``"analytic"`` — the same tuple ``DispatchDecision.plan_source``
        reports."""
        from repro.plan import resolve_plan

        return resolve_plan(op, self.target, explicit=explicit,
                            autotune=self.autotune)

    # -- precision policy -----------------------------------------------------
    @property
    def stream_dtype(self):
        """Input/filter stream dtype from the target's precision policy."""
        return dtype_for_words(self.target.precision.p_I)

    @property
    def acc_dtype(self):
        """Output/accumulator dtype from the target's precision policy (the
        default ``out_dtype`` of every dispatched op)."""
        return dtype_for_words(self.target.precision.p_O)


def default_context() -> ExecutionContext:
    """The context used when a consumer passes ``ctx=None``: plans against
    ``TPU_V5E`` (the pre-redesign kernel default) but executes on XLA unless
    ``REPRO_BACKEND`` asks for Pallas."""
    return ExecutionContext(target=TPU_V5E, backend=env_backend() or "xla")
