"""The dispatcher: resolve each op call to (ExecutionPlan, backend kernel).

``resolve(op, ctx, ...)`` walks the requested backend's fallback chain until
an entry's declared capabilities cover the call's required features, solves
the LP plan for entries that declare a ``spec_fn`` (through the context's
process-wide plan cache), and returns a :class:`DispatchDecision` — the
explain/trace record tests and tools assert against.

The public op wrappers (``matmul``/``conv2d``/``conv1d_causal``/``attention``)
derive the required features from the call itself (is ``q_offset`` a static
int, a traced scalar, or a per-row vector? is there a key mask?) so callers
never re-implement the capability logic. Dispatch happens at trace time:
inside ``jax.jit`` the decision is made once per compiled variant.

Observability:

  * ``explain(op, ctx, ...)``    - the decision, without executing anything;
  * ``record_dispatch()``        - context manager capturing every decision
                                   made while it is active (including those
                                   made while tracing a jit).

Runtime-failure fallback (``repro.resilience``): capability resolution only
proves an entry *claims* to serve the call. When the chosen entry actually
raises a ``TransientFault`` at execution, ``dispatch_call`` quarantines the
failing ``(op, backend, shape-key)``, re-resolves down the chain (an entry
may name an instrumented ``degrade_to`` backend so the demotion stays
priced), and retries — the resulting decision records ``degraded=True`` +
the fault name, with ``measured_words``/``bound_ratio`` re-priced for the
backend that actually served the call. A quarantined combination is probed
again after ``QUARANTINE_PROBE_AFTER`` dispatches. ``FatalFault`` always
propagates. The ``REPRO_FAULTS`` env knob installs a seeded
``resilience.FaultCampaign`` around every eager dispatch.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.resilience.errors import TransientFault

from .context import ExecutionContext, default_context
from .registry import OpEntry, get_backend

MAX_FALLBACK_DEPTH = 4  # registry misconfiguration guard, not a real limit
# runtime-fallback executor: per-call bound on demote/retry attempts
MAX_RUNTIME_ATTEMPTS = 4
# a quarantined (op, backend, shape-key) is probed again on the Nth dispatch
QUARANTINE_PROBE_AFTER = 8


@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    """Why one call ran where it did — and what it moved.

    ``requested`` is the backend the context resolved to; ``chosen`` the one
    that actually served the call; ``missing`` the capabilities whose absence
    forced each fallback hop (empty when ``chosen == requested``); ``plan``
    the ExecutionPlan the chosen entry consumed (None for closed-form ops and
    for XLA entries, which delegate tiling to the compiler);
    ``plan_source`` how that plan was obtained through the shared resolution
    path (``repro.plan.resolve_plan``): ``"explicit"`` (caller passed one),
    ``"tuned"`` (the measured autotuner's stored winner), or ``"analytic"``
    (the LP optimum — also the value when the entry consumes no plan);
    ``measured_words`` the words the chosen kernel's launch geometry moves
    for this call (None when the entry is not instrumented) — HBM words
    (32-bit) for single-device ops, *inter-device* words per device for the
    distributed ops — reported via ``bound_ratio`` against the matching
    bound: the plan's Thm 2.1 ``lower_bound``, or the plan's ``parallel``
    section's Thm 2.2/2.3 bound for ``*_dist`` ops.

    ``audited`` is the static auditor's independent word count (set only
    when dispatch ran with ``audit=True``): ``repro.verify`` abstractly
    interprets the entry's :class:`KernelAccessPlan` (grid walk over the
    BlockSpec index maps + manual-DMA halo windows) and raises
    ``verify.AuditError`` unless it reproduces ``measured_words`` exactly,
    fits VMEM, stays at/below the recorded bound ratio, and the DMA
    schedule is hazard-free — so when this field is set it *equals*
    ``measured_words``.

    ``degraded``/``fault`` record *runtime* demotion: a backend along the
    chain is quarantined after actually raising the named ``TransientFault``
    (``"KernelLaunchError"``, ``"NumericFault"``, ...), so the call was
    served further down the chain than capabilities alone required —
    ``measured_words``/``bound_ratio`` are re-priced for the backend that
    ran, making the communication cost of degradation visible in
    ``ops.explain``."""

    op: str
    requested: str
    chosen: str
    missing: Tuple[str, ...] = ()
    plan: Optional[Any] = None
    plan_source: str = "analytic"  # "explicit" | "tuned" | "analytic"
    measured_words: Optional[float] = None
    audited: Optional[float] = None
    degraded: bool = False
    fault: Optional[str] = None

    @property
    def fell_back(self) -> bool:
        return self.chosen != self.requested

    @property
    def lower_bound(self) -> Optional[float]:
        """The bound ``measured_words`` is compared against: Thm 2.2/2.3
        (per-processor) for distributed ops, Thm 2.1 otherwise."""
        if self.plan is None:
            return None
        if self.op.endswith("_dist"):
            if self.plan.parallel is None:
                return None  # planned for a single-device target
            return self.plan.parallel.lower_bound
        return self.plan.lower_bound

    @property
    def bound_ratio(self) -> Optional[float]:
        """measured words / the matching communication lower bound."""
        lb = self.lower_bound
        if self.measured_words is None or lb is None:
            return None
        return self.measured_words / max(lb, 1.0)

    def why(self) -> str:
        if self.degraded:
            msg = (f"{self.op}: runtime {self.fault} quarantined the "
                   f"primary backend; degraded from {self.requested!r} to "
                   f"{self.chosen!r} (words re-priced)")
        elif not self.fell_back:
            msg = f"{self.op}: ran on requested backend {self.chosen!r}"
        else:
            msg = (f"{self.op}: {self.requested!r} lacks "
                   f"{', '.join(self.missing)}; fell back to {self.chosen!r}")
        if self.plan is not None:
            msg += f"; {self.plan_source} plan"
            tuned = getattr(self.plan, "tuned", None)
            if tuned is not None:
                msg += (f" ({tuned.candidates_timed} candidates timed via "
                        f"{tuned.source}, winner {tuned.winner_seconds:.2e}s "
                        f"vs analytic)")
        if self.measured_words is not None:
            kind = ("inter-device" if self.op.endswith("_dist") else "HBM")
            msg += f"; measured {self.measured_words:.3e} {kind} words"
            if self.bound_ratio is not None:
                msg += (f" = {self.bound_ratio:.2f}x the "
                        f"{self.lower_bound:.3e}-word lower bound")
        if self.audited is not None:
            msg += " (statically audited)"
        return msg


_TRACE: List[List[DispatchDecision]] = []  # stack of active recorders


@contextlib.contextmanager
def record_dispatch():
    """Capture every DispatchDecision made while active (trace API)."""
    log: List[DispatchDecision] = []
    _TRACE.append(log)
    try:
        yield log
    finally:
        # remove by identity: nested recorders hold equal (e.g. empty) lists
        for i, entry in enumerate(_TRACE):
            if entry is log:
                del _TRACE[i]
                break


# ---------------------------------------------------------------------------
# Runtime-failure state: the quarantine table and the fault-injection hook.
# ---------------------------------------------------------------------------

# (op, backend, shape-key) -> {"fault": taxonomy class name, "probe_in": N}.
# Populated by dispatch_call when an entry raises a TransientFault; consulted
# by _resolve_entry so subsequent calls (including jit traces) demote past
# the failing backend. probe_in decrements only on executing dispatches; at
# zero the entry is removed and the primary backend is probed again.
_QUARANTINE: Dict[Tuple[str, str, Any], Dict[str, Any]] = {}

_FAULT_HOOK: Optional[Any] = None  # resilience.faults.DispatchFaultHook
_ENV_FAULTS_CHECKED = False


def set_fault_hook(hook) -> None:
    """Install/remove the campaign dispatch hook (``resilience.faults``)."""
    global _FAULT_HOOK
    _FAULT_HOOK = hook


def _ensure_env_faults() -> None:
    """First-eager-dispatch check of the ``REPRO_FAULTS`` env knob."""
    global _ENV_FAULTS_CHECKED
    if _ENV_FAULTS_CHECKED:
        return
    _ENV_FAULTS_CHECKED = True
    if os.environ.get("REPRO_FAULTS"):
        from repro.resilience.faults import install_env_campaign

        install_env_campaign()


def quarantined() -> Dict[Tuple[str, str, Any], Dict[str, Any]]:
    """A snapshot of the quarantine table (introspection/tests)."""
    return {k: dict(v) for k, v in _QUARANTINE.items()}


def clear_quarantine() -> None:
    """Forget every runtime quarantine (benchmarks reset between runs)."""
    _QUARANTINE.clear()


def _freeze_kw(v):
    """A hashable, deterministic stand-in for one spec kwarg value; None for
    ambient objects (meshes, blockings) that don't shape the quarantine."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, tuple):
        return tuple(_freeze_kw(x) for x in v)
    try:
        return jnp.dtype(v).name  # dtype-likes (jnp.bfloat16, "int8", ...)
    except TypeError:
        return None


def _shape_key(needs: Tuple[str, ...], spec_args: Optional[tuple],
               spec_kw: Optional[dict]):
    """The quarantine granularity: a kernel that faults on one launch
    geometry is demoted for that geometry only, not for the whole op."""
    if spec_args is None:
        return (needs,)
    arrs = tuple((tuple(getattr(a, "shape", ())), str(getattr(a, "dtype", "")))
                 for a in spec_args)
    kws = tuple((k, _freeze_kw(v))
                for k, v in sorted((spec_kw or {}).items()))
    return (needs, arrs, kws)


def _is_tracing(*trees) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree_util.tree_leaves(trees))


def _resolve_entry(op: str, ctx: ExecutionContext, dtype: Optional[str],
                   needs: Tuple[str, ...], shape_key: Any = None,
                   probe: bool = False) -> Tuple[OpEntry, DispatchDecision]:
    requested = ctx.resolved_backend()
    name: Optional[str] = requested
    missing: Tuple[str, ...] = ()
    degraded = False
    fault: Optional[str] = None
    for _ in range(MAX_FALLBACK_DEPTH):
        if name is None:
            break
        backend = get_backend(name)
        entry = backend.ops.get(op)
        lacks = (f"op:{op}",) if entry is None else entry.caps.missing(
            dtype=dtype, needs=needs)
        if not lacks:
            q = _QUARANTINE.get((op, name, shape_key))
            if q is not None and probe:
                q["probe_in"] -= 1
                if q["probe_in"] <= 0:  # probe the primary again
                    del _QUARANTINE[(op, name, shape_key)]
                    q = None
            if q is not None:
                missing = missing + (f"fault:{q['fault']}",)
                degraded, fault = True, q["fault"]
                name = entry.degrade_to or backend.fallback
                continue
            decision = DispatchDecision(op=op, requested=requested,
                                        chosen=name, missing=missing,
                                        degraded=degraded, fault=fault)
            return entry, decision
        missing = missing + lacks
        name = backend.fallback
    raise NotImplementedError(
        f"no registered backend can serve op {op!r} "
        f"(requested {requested!r}, dtype={dtype}, needs={needs}; "
        f"missing along the fallback chain: {missing})")


def _attach_plan_and_words(entry: OpEntry, decision: DispatchDecision,
                           ctx: ExecutionContext,
                           spec_args: Optional[tuple],
                           spec_kw: Optional[dict],
                           plan: Optional[Any] = None) -> DispatchDecision:
    """Resolve the entry's plan — explicit ``plan`` > stored tuned winner >
    analytic LP, via the shared ``ctx.plan_with_source`` path — and its
    measured-HBM-words counter (both need only shapes/dtypes, so tracers and
    ShapeDtypeStructs work)."""
    if spec_args is None:
        return decision
    kw = spec_kw or {}
    if plan is not None:
        decision = dataclasses.replace(decision, plan=plan,
                                       plan_source="explicit")
    elif entry.spec_fn is not None:
        resolved, source = ctx.plan_with_source(entry.spec_fn(*spec_args,
                                                              **kw))
        decision = dataclasses.replace(decision, plan=resolved,
                                       plan_source=source)
    if entry.words_fn is not None:
        decision = dataclasses.replace(
            decision,
            measured_words=entry.words_fn(ctx, decision.plan,
                                          *spec_args, **kw))
    return decision


def _maybe_audit(entry: OpEntry, decision: DispatchDecision,
                 ctx: ExecutionContext, spec_args: Optional[tuple],
                 spec_kw: Optional[dict], audit: bool) -> DispatchDecision:
    """Opt-in static audit: build the entry's KernelAccessPlan, abstractly
    interpret it, and stamp the audited word count on the decision. Raises
    ``repro.verify.AuditError`` on any mismatch/violation/hazard. Lazy
    import keeps the hot dispatch path free of the verify machinery."""
    if not audit or spec_args is None or entry.access_plan_fn is None:
        return decision
    from repro.verify import audit as _audit

    ap = entry.access_plan_fn(ctx, decision.plan, *spec_args,
                              **(spec_kw or {}))
    report = _audit.audit_decision(ap, decision, target=ctx.target)
    if not report.ok:
        raise _audit.AuditError(report)
    return dataclasses.replace(decision, audited=report.counted_words)


def resolve(op: str, ctx: Optional[ExecutionContext] = None,
            dtype: Optional[str] = None, needs: Tuple[str, ...] = (),
            spec_args: Optional[tuple] = None, spec_kw: Optional[dict] = None,
            audit: bool = False,
            plan: Optional[Any] = None) -> Tuple[OpEntry, DispatchDecision]:
    """Capability-resolve one call; resolve the entry's plan (explicit
    ``plan=`` > tuned > analytic, stamped as ``plan_source``) and measured
    HBM-word counter if it declares them. ``audit=True`` additionally runs
    the ``repro.verify`` static auditor against the chosen entry's access
    plan (raising on any mismatch or hazard). Quarantine-aware (a runtime-
    quarantined backend is skipped, the decision marked ``degraded``) but
    never consumes quarantine probes — only executing dispatches do."""
    ctx = default_context() if ctx is None else ctx
    needs = tuple(needs)
    entry, decision = _resolve_entry(
        op, ctx, dtype, needs, shape_key=_shape_key(needs, spec_args, spec_kw))
    decision = _attach_plan_and_words(entry, decision, ctx, spec_args,
                                      spec_kw, plan=plan)
    decision = _maybe_audit(entry, decision, ctx, spec_args, spec_kw, audit)
    for log in _TRACE:
        log.append(decision)
    return entry, decision


def explain(op: str, ctx: Optional[ExecutionContext] = None,
            dtype: Optional[str] = None, needs: Tuple[str, ...] = (),
            spec_args: Optional[tuple] = None,
            spec_kw: Optional[dict] = None,
            audit: bool = False,
            plan: Optional[Any] = None) -> DispatchDecision:
    """The decision ``resolve`` would make, without executing anything.
    ``spec_args``/``spec_kw`` mirror ``resolve`` so the reported plan and
    measured words are the ones the dispatched kernel would consume (e.g.
    conv2d needs stride=); ``jax.ShapeDtypeStruct`` spec_args work since
    only shapes/dtypes are consulted. The decision's ``plan_source`` tells
    tuned from analytic plans apart (and ``why()`` narrates the tuning
    provenance). ``audit=True`` runs the static communication auditor and
    stamps ``DispatchDecision.audited``."""
    ctx = default_context() if ctx is None else ctx
    needs = tuple(needs)
    entry, decision = _resolve_entry(
        op, ctx, dtype, needs, shape_key=_shape_key(needs, spec_args, spec_kw))
    decision = _attach_plan_and_words(entry, decision, ctx, spec_args,
                                      spec_kw, plan=plan)
    return _maybe_audit(entry, decision, ctx, spec_args, spec_kw, audit)


def dispatch_call(op: str, ctx: ExecutionContext, dtype: Optional[str],
                  needs: Tuple[str, ...], spec_args: tuple,
                  spec_kw: Optional[dict] = None,
                  call_args: Optional[tuple] = None,
                  call_kw: Optional[dict] = None,
                  plan: Optional[Any] = None):
    """Resolve AND execute one op call with runtime-failure fallback.
    ``plan=`` forces an explicit ExecutionPlan onto the chosen entry (the
    autotuner's candidate-timing path); omitted, the shared resolution path
    picks tuned-then-analytic.

    The public op wrappers funnel through here: resolve (quarantine-aware,
    consuming probes), price the plan/words, run the entry — through the
    fault-injection hook when a campaign is active — and on a
    ``TransientFault`` quarantine the failing ``(op, backend, shape-key)``
    and re-resolve. An entry with a ``degrade_to``/fallback chain demotes;
    a terminal entry retries in place. ``FatalFault`` (and anything not in
    the taxonomy) propagates. The decision lands in ``record_dispatch``
    logs only for the execution that actually served the call."""
    _ensure_env_faults()
    spec_kw = spec_kw or {}
    call_args = spec_args if call_args is None else call_args
    call_kw = dict(spec_kw) if call_kw is None else call_kw
    key = _shape_key(needs, spec_args, spec_kw)
    last_fault: Optional[TransientFault] = None
    for _ in range(MAX_RUNTIME_ATTEMPTS):
        entry, decision = _resolve_entry(op, ctx, dtype, needs,
                                         shape_key=key, probe=True)
        decision = _attach_plan_and_words(entry, decision, ctx,
                                          spec_args, spec_kw, plan=plan)

        def runner(entry=entry, decision=decision):
            return entry.fn(ctx, decision.plan, *call_args, **call_kw)

        hook = _FAULT_HOOK
        try:
            if hook is not None:
                out = hook.run(op, decision.chosen, runner,
                               tracing=_is_tracing(call_args, call_kw))
            else:
                out = runner()
        except TransientFault as e:
            last_fault = e
            nxt = entry.degrade_to or get_backend(decision.chosen).fallback
            inj = getattr(e, "injection", None)
            if inj is not None and inj.resolution is None:
                inj.resolution = "degraded" if nxt is not None else "retried"
            if nxt is not None:
                _QUARANTINE[(op, decision.chosen, key)] = {
                    "fault": type(e).__name__,
                    "probe_in": QUARANTINE_PROBE_AFTER}
            continue  # re-resolve: demote past the quarantine, or retry
        for log in _TRACE:
            log.append(decision)
        return out
    raise last_fault


# ---------------------------------------------------------------------------
# Public ops. Feature extraction happens here, once, for every caller.
# ---------------------------------------------------------------------------

def _is_static_int(v) -> bool:
    return isinstance(v, (int, np.integer))


def matmul(a, b, ctx: Optional[ExecutionContext] = None, out_dtype=None):
    """C[m,n] = A @ B through the dispatched backend; ``out_dtype`` defaults
    to the target precision policy's accumulator dtype."""
    ctx = default_context() if ctx is None else ctx
    out_dtype = out_dtype or ctx.acc_dtype
    # out_dtype rides in spec_kw so the measured-words counter charges the
    # store stream at the dtype the kernel actually writes
    return dispatch_call("matmul", ctx, str(a.dtype), (), (a, b),
                         spec_kw={"out_dtype": out_dtype})


def conv2d(x, w, stride=(1, 1), ctx: Optional[ExecutionContext] = None,
           out_dtype=None):
    """Direct 7NL convolution (VALID padding) through the dispatched backend."""
    ctx = default_context() if ctx is None else ctx
    out_dtype = out_dtype or ctx.acc_dtype
    return dispatch_call("conv2d", ctx, str(x.dtype), (), (x, w),
                         spec_kw={"stride": stride, "out_dtype": out_dtype})


def matmul_q(a, b, scale, ctx: Optional[ExecutionContext] = None,
             out_dtype=None):
    """Quantized GEMM: ``a``/``b`` int8 (from
    ``repro.quant.quantize_matmul_operands``), ``scale`` the folded (1, n)
    f32 per-column dequant scales. Streams stay int8 into VMEM; the f32
    accumulator is scaled once at the store. ``out_dtype`` defaults to bf16
    (``repro.quant.INT8_SPEC``), not the context accumulator — the narrower
    store is half of what moves the measured words."""
    ctx = default_context() if ctx is None else ctx
    out_dtype = out_dtype or jnp.bfloat16
    return dispatch_call("matmul_q", ctx, str(a.dtype), (), (a, b, scale),
                         spec_kw={"out_dtype": out_dtype})


def conv2d_q(x, w, scale, stride=(1, 1),
             ctx: Optional[ExecutionContext] = None, out_dtype=None):
    """Quantized direct conv2d (VALID padding): int8 ``x``/``w`` plus the
    folded (1, c_O) f32 scale from ``repro.quant.quantize_conv_operands``.
    ``out_dtype`` defaults to bf16 (see :func:`matmul_q`)."""
    ctx = default_context() if ctx is None else ctx
    out_dtype = out_dtype or jnp.bfloat16
    return dispatch_call("conv2d_q", ctx, str(x.dtype), (), (x, w, scale),
                         spec_kw={"stride": stride, "out_dtype": out_dtype})


def conv2d_dist(x, w, stride=(1, 1), blocking=None, mesh=None,
                ctx: Optional[ExecutionContext] = None, out_dtype=None):
    """Distributed halo-exchange conv2d over a device mesh (paper §4.2).

    ``blocking`` is the ``ParallelBlocking`` processor grid (LP-chosen over
    all available devices when omitted) and ``mesh`` the matching conv mesh
    (``launch.make_conv_mesh(blocking)`` when omitted). The backend picks the
    *shard-local* kernel (``pallas`` = the LP-tiled PR-4 kernel); the
    decision's ``measured_words`` are the per-device inter-device words
    (halo + psum), ratioed against the plan's Thm 2.2/2.3 parallel bound."""
    ctx = default_context() if ctx is None else ctx
    out_dtype = out_dtype or ctx.acc_dtype
    return dispatch_call(
        "conv2d_dist", ctx, str(x.dtype), (), (x, w),
        spec_kw={"stride": stride, "out_dtype": out_dtype,
                 "blocking": blocking, "mesh": mesh})


def conv1d_causal(x, w, ctx: Optional[ExecutionContext] = None):
    """Causal depthwise conv1d (the mamba/xLSTM short convolution)."""
    ctx = default_context() if ctx is None else ctx
    return dispatch_call("conv1d_causal", ctx, str(x.dtype), (), (x, w))


def attention_needs(q_offset=0, key_mask=None) -> Tuple[str, ...]:
    """Required capability flags of one attention call (shared with tests)."""
    needs = []
    if not _is_static_int(q_offset):
        needs.append("per_row_q_offset" if getattr(q_offset, "ndim", 0)
                     else "dynamic_q_offset")
    if key_mask is not None:
        needs.append("key_mask")
    return tuple(needs)


def attention(q, k, v, causal: bool = True, q_offset=0, key_mask=None,
              ctx: Optional[ExecutionContext] = None):
    """GQA attention, (B, H, L, Dh) layout; Hkv divides H.

    ``q_offset``: absolute position of the first query — a static python int
    (train/prefill), a traced scalar (lockstep decode), or a (B,) vector
    (continuous-batching decode, every slot at its own depth). ``key_mask``
    is an optional (B, Lk) validity mask over the keys (padded prefill).
    Backends that cannot serve the masked variant (the Pallas flash kernel)
    fall back by declared capability; traced and per-row offsets ride the
    flash kernel's scalar-prefetch path."""
    ctx = default_context() if ctx is None else ctx
    return dispatch_call("attention", ctx, str(q.dtype),
                         attention_needs(q_offset, key_mask), (q, k, v),
                         call_kw={"causal": causal, "q_offset": q_offset,
                                  "key_mask": key_mask})


def attention_decode(q, kp, vp, tables, lengths,
                     ctx: Optional[ExecutionContext] = None):
    """One paged decode step: ``q`` is (B, H, 1, hd), ``kp``/``vp`` the
    shared (num_blocks, KV, block_size, hd) pools, ``tables`` the (B, w)
    int32 physical-block ids backing each row's logical positions, and
    ``lengths`` the (B,) valid cache lengths (current token included).

    The pallas entry follows the tables inside the kernel's index_map (no
    gather copy); the xla entry gathers to a contiguous view first — the
    measured-words gap between them is the point of the paged subsystem."""
    ctx = default_context() if ctx is None else ctx
    return dispatch_call("attention_decode", ctx, str(q.dtype), (),
                         (q, kp, vp, tables, lengths))


def attention_decode_quant(q, kp, ks, vp, vs, tables, lengths,
                           ctx: Optional[ExecutionContext] = None):
    """One paged decode step against an int8-quantized KV pool.

    ``kp``/``vp`` are the (num_blocks, KV, block_size, hd) int8 pools and
    ``ks``/``vs`` their (num_blocks, KV, block_size) f32 per-(block, head,
    position) scales (written together by the engine's quantizing insert).
    Registered on the xla backend only — any requested backend reaches it
    through the fallback chain — since the interesting quantity here is the
    *pool's* halved stream width (the plan's p_F ~ 0.25 + 1/hd), not the
    gather kernel."""
    ctx = default_context() if ctx is None else ctx
    return dispatch_call("attention_decode_quant", ctx, str(q.dtype), (),
                         (q, kp, ks, vp, vs, tables, lengths))
