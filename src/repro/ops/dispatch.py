"""The dispatcher: resolve each op call to (ExecutionPlan, backend kernel).

``resolve(op, ctx, ...)`` walks the requested backend's fallback chain until
an entry's declared capabilities cover the call's required features, solves
the LP plan for entries that declare a ``spec_fn`` (through the context's
process-wide plan cache), and returns a :class:`DispatchDecision` — the
explain/trace record tests and tools assert against.

The public op wrappers (``matmul``/``conv2d``/``conv1d_causal``/``attention``)
derive the required features from the call itself (is ``q_offset`` a static
int, a traced scalar, or a per-row vector? is there a key mask?) so callers
never re-implement the capability logic. Dispatch happens at trace time:
inside ``jax.jit`` the decision is made once per compiled variant.

Observability:

  * ``explain(op, ctx, ...)``    - the decision, without executing anything;
  * ``record_dispatch()``        - context manager capturing every decision
                                   made while it is active (including those
                                   made while tracing a jit).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .context import ExecutionContext, default_context
from .registry import OpEntry, get_backend

MAX_FALLBACK_DEPTH = 4  # registry misconfiguration guard, not a real limit


@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    """Why one call ran where it did — and what it moved.

    ``requested`` is the backend the context resolved to; ``chosen`` the one
    that actually served the call; ``missing`` the capabilities whose absence
    forced each fallback hop (empty when ``chosen == requested``); ``plan``
    the ExecutionPlan the chosen entry consumed (None for closed-form ops and
    for XLA entries, which delegate tiling to the compiler);
    ``measured_words`` the words the chosen kernel's launch geometry moves
    for this call (None when the entry is not instrumented) — HBM words
    (32-bit) for single-device ops, *inter-device* words per device for the
    distributed ops — reported via ``bound_ratio`` against the matching
    bound: the plan's Thm 2.1 ``lower_bound``, or the plan's ``parallel``
    section's Thm 2.2/2.3 bound for ``*_dist`` ops.

    ``audited`` is the static auditor's independent word count (set only
    when dispatch ran with ``audit=True``): ``repro.verify`` abstractly
    interprets the entry's :class:`KernelAccessPlan` (grid walk over the
    BlockSpec index maps + manual-DMA halo windows) and raises
    ``verify.AuditError`` unless it reproduces ``measured_words`` exactly,
    fits VMEM, stays at/below the recorded bound ratio, and the DMA
    schedule is hazard-free — so when this field is set it *equals*
    ``measured_words``."""

    op: str
    requested: str
    chosen: str
    missing: Tuple[str, ...] = ()
    plan: Optional[Any] = None
    measured_words: Optional[float] = None
    audited: Optional[float] = None

    @property
    def fell_back(self) -> bool:
        return self.chosen != self.requested

    @property
    def lower_bound(self) -> Optional[float]:
        """The bound ``measured_words`` is compared against: Thm 2.2/2.3
        (per-processor) for distributed ops, Thm 2.1 otherwise."""
        if self.plan is None:
            return None
        if self.op.endswith("_dist"):
            if self.plan.parallel is None:
                return None  # planned for a single-device target
            return self.plan.parallel.lower_bound
        return self.plan.lower_bound

    @property
    def bound_ratio(self) -> Optional[float]:
        """measured words / the matching communication lower bound."""
        lb = self.lower_bound
        if self.measured_words is None or lb is None:
            return None
        return self.measured_words / max(lb, 1.0)

    def why(self) -> str:
        msg = (f"{self.op}: ran on requested backend {self.chosen!r}"
               if not self.fell_back else
               f"{self.op}: {self.requested!r} lacks "
               f"{', '.join(self.missing)}; fell back to {self.chosen!r}")
        if self.measured_words is not None:
            kind = ("inter-device" if self.op.endswith("_dist") else "HBM")
            msg += f"; measured {self.measured_words:.3e} {kind} words"
            if self.bound_ratio is not None:
                msg += (f" = {self.bound_ratio:.2f}x the "
                        f"{self.lower_bound:.3e}-word lower bound")
        if self.audited is not None:
            msg += " (statically audited)"
        return msg


_TRACE: List[List[DispatchDecision]] = []  # stack of active recorders


@contextlib.contextmanager
def record_dispatch():
    """Capture every DispatchDecision made while active (trace API)."""
    log: List[DispatchDecision] = []
    _TRACE.append(log)
    try:
        yield log
    finally:
        # remove by identity: nested recorders hold equal (e.g. empty) lists
        for i, entry in enumerate(_TRACE):
            if entry is log:
                del _TRACE[i]
                break


def _resolve_entry(op: str, ctx: ExecutionContext, dtype: Optional[str],
                   needs: Tuple[str, ...]
                   ) -> Tuple[OpEntry, DispatchDecision]:
    requested = ctx.resolved_backend()
    name: Optional[str] = requested
    missing: Tuple[str, ...] = ()
    for _ in range(MAX_FALLBACK_DEPTH):
        if name is None:
            break
        backend = get_backend(name)
        entry = backend.ops.get(op)
        lacks = (f"op:{op}",) if entry is None else entry.caps.missing(
            dtype=dtype, needs=needs)
        if not lacks:
            decision = DispatchDecision(op=op, requested=requested,
                                        chosen=name, missing=missing)
            return entry, decision
        missing = missing + lacks
        name = backend.fallback
    raise NotImplementedError(
        f"no registered backend can serve op {op!r} "
        f"(requested {requested!r}, dtype={dtype}, needs={needs}; "
        f"missing along the fallback chain: {missing})")


def _attach_plan_and_words(entry: OpEntry, decision: DispatchDecision,
                           ctx: ExecutionContext,
                           spec_args: Optional[tuple],
                           spec_kw: Optional[dict]) -> DispatchDecision:
    """Solve the entry's LP plan and measured-HBM-words counter (both need
    only shapes/dtypes, so tracers and ShapeDtypeStructs work)."""
    if spec_args is None:
        return decision
    kw = spec_kw or {}
    if entry.spec_fn is not None:
        decision = dataclasses.replace(
            decision, plan=ctx.plan(entry.spec_fn(*spec_args, **kw)))
    if entry.words_fn is not None:
        decision = dataclasses.replace(
            decision,
            measured_words=entry.words_fn(ctx, decision.plan,
                                          *spec_args, **kw))
    return decision


def _maybe_audit(entry: OpEntry, decision: DispatchDecision,
                 ctx: ExecutionContext, spec_args: Optional[tuple],
                 spec_kw: Optional[dict], audit: bool) -> DispatchDecision:
    """Opt-in static audit: build the entry's KernelAccessPlan, abstractly
    interpret it, and stamp the audited word count on the decision. Raises
    ``repro.verify.AuditError`` on any mismatch/violation/hazard. Lazy
    import keeps the hot dispatch path free of the verify machinery."""
    if not audit or spec_args is None or entry.access_plan_fn is None:
        return decision
    from repro.verify import audit as _audit

    ap = entry.access_plan_fn(ctx, decision.plan, *spec_args,
                              **(spec_kw or {}))
    report = _audit.audit_decision(ap, decision, target=ctx.target)
    if not report.ok:
        raise _audit.AuditError(report)
    return dataclasses.replace(decision, audited=report.counted_words)


def resolve(op: str, ctx: Optional[ExecutionContext] = None,
            dtype: Optional[str] = None, needs: Tuple[str, ...] = (),
            spec_args: Optional[tuple] = None, spec_kw: Optional[dict] = None,
            audit: bool = False) -> Tuple[OpEntry, DispatchDecision]:
    """Capability-resolve one call; solve the entry's LP plan and measured
    HBM-word counter if it declares them. ``audit=True`` additionally runs
    the ``repro.verify`` static auditor against the chosen entry's access
    plan (raising on any mismatch or hazard)."""
    ctx = default_context() if ctx is None else ctx
    entry, decision = _resolve_entry(op, ctx, dtype, tuple(needs))
    decision = _attach_plan_and_words(entry, decision, ctx, spec_args, spec_kw)
    decision = _maybe_audit(entry, decision, ctx, spec_args, spec_kw, audit)
    for log in _TRACE:
        log.append(decision)
    return entry, decision


def explain(op: str, ctx: Optional[ExecutionContext] = None,
            dtype: Optional[str] = None, needs: Tuple[str, ...] = (),
            spec_args: Optional[tuple] = None,
            spec_kw: Optional[dict] = None,
            audit: bool = False) -> DispatchDecision:
    """The decision ``resolve`` would make, without executing anything.
    ``spec_args``/``spec_kw`` mirror ``resolve`` so the reported plan and
    measured words are the ones the dispatched kernel would consume (e.g.
    conv2d needs stride=); ``jax.ShapeDtypeStruct`` spec_args work since
    only shapes/dtypes are consulted. ``audit=True`` runs the static
    communication auditor and stamps ``DispatchDecision.audited``."""
    ctx = default_context() if ctx is None else ctx
    entry, decision = _resolve_entry(op, ctx, dtype, tuple(needs))
    decision = _attach_plan_and_words(entry, decision, ctx, spec_args, spec_kw)
    return _maybe_audit(entry, decision, ctx, spec_args, spec_kw, audit)


# ---------------------------------------------------------------------------
# Public ops. Feature extraction happens here, once, for every caller.
# ---------------------------------------------------------------------------

def _is_static_int(v) -> bool:
    return isinstance(v, (int, np.integer))


def matmul(a, b, ctx: Optional[ExecutionContext] = None, out_dtype=None):
    """C[m,n] = A @ B through the dispatched backend; ``out_dtype`` defaults
    to the target precision policy's accumulator dtype."""
    ctx = default_context() if ctx is None else ctx
    out_dtype = out_dtype or ctx.acc_dtype
    # out_dtype rides in spec_kw so the measured-words counter charges the
    # store stream at the dtype the kernel actually writes
    entry, dec = resolve("matmul", ctx, dtype=str(a.dtype), spec_args=(a, b),
                         spec_kw={"out_dtype": out_dtype})
    return entry.fn(ctx, dec.plan, a, b, out_dtype=out_dtype)


def conv2d(x, w, stride=(1, 1), ctx: Optional[ExecutionContext] = None,
           out_dtype=None):
    """Direct 7NL convolution (VALID padding) through the dispatched backend."""
    ctx = default_context() if ctx is None else ctx
    out_dtype = out_dtype or ctx.acc_dtype
    entry, dec = resolve("conv2d", ctx, dtype=str(x.dtype),
                         spec_args=(x, w),
                         spec_kw={"stride": stride, "out_dtype": out_dtype})
    return entry.fn(ctx, dec.plan, x, w, stride=stride, out_dtype=out_dtype)


def matmul_q(a, b, scale, ctx: Optional[ExecutionContext] = None,
             out_dtype=None):
    """Quantized GEMM: ``a``/``b`` int8 (from
    ``repro.quant.quantize_matmul_operands``), ``scale`` the folded (1, n)
    f32 per-column dequant scales. Streams stay int8 into VMEM; the f32
    accumulator is scaled once at the store. ``out_dtype`` defaults to bf16
    (``repro.quant.INT8_SPEC``), not the context accumulator — the narrower
    store is half of what moves the measured words."""
    ctx = default_context() if ctx is None else ctx
    out_dtype = out_dtype or jnp.bfloat16
    entry, dec = resolve("matmul_q", ctx, dtype=str(a.dtype),
                         spec_args=(a, b, scale),
                         spec_kw={"out_dtype": out_dtype})
    return entry.fn(ctx, dec.plan, a, b, scale, out_dtype=out_dtype)


def conv2d_q(x, w, scale, stride=(1, 1),
             ctx: Optional[ExecutionContext] = None, out_dtype=None):
    """Quantized direct conv2d (VALID padding): int8 ``x``/``w`` plus the
    folded (1, c_O) f32 scale from ``repro.quant.quantize_conv_operands``.
    ``out_dtype`` defaults to bf16 (see :func:`matmul_q`)."""
    ctx = default_context() if ctx is None else ctx
    out_dtype = out_dtype or jnp.bfloat16
    entry, dec = resolve("conv2d_q", ctx, dtype=str(x.dtype),
                         spec_args=(x, w, scale),
                         spec_kw={"stride": stride, "out_dtype": out_dtype})
    return entry.fn(ctx, dec.plan, x, w, scale, stride=stride,
                    out_dtype=out_dtype)


def conv2d_dist(x, w, stride=(1, 1), blocking=None, mesh=None,
                ctx: Optional[ExecutionContext] = None, out_dtype=None):
    """Distributed halo-exchange conv2d over a device mesh (paper §4.2).

    ``blocking`` is the ``ParallelBlocking`` processor grid (LP-chosen over
    all available devices when omitted) and ``mesh`` the matching conv mesh
    (``launch.make_conv_mesh(blocking)`` when omitted). The backend picks the
    *shard-local* kernel (``pallas`` = the LP-tiled PR-4 kernel); the
    decision's ``measured_words`` are the per-device inter-device words
    (halo + psum), ratioed against the plan's Thm 2.2/2.3 parallel bound."""
    ctx = default_context() if ctx is None else ctx
    out_dtype = out_dtype or ctx.acc_dtype
    entry, dec = resolve(
        "conv2d_dist", ctx, dtype=str(x.dtype), spec_args=(x, w),
        spec_kw={"stride": stride, "out_dtype": out_dtype,
                 "blocking": blocking, "mesh": mesh})
    return entry.fn(ctx, dec.plan, x, w, stride=stride, out_dtype=out_dtype,
                    blocking=blocking, mesh=mesh)


def conv1d_causal(x, w, ctx: Optional[ExecutionContext] = None):
    """Causal depthwise conv1d (the mamba/xLSTM short convolution)."""
    ctx = default_context() if ctx is None else ctx
    entry, dec = resolve("conv1d_causal", ctx, dtype=str(x.dtype),
                         spec_args=(x, w))
    return entry.fn(ctx, dec.plan, x, w)


def attention_needs(q_offset=0, key_mask=None) -> Tuple[str, ...]:
    """Required capability flags of one attention call (shared with tests)."""
    needs = []
    if not _is_static_int(q_offset):
        needs.append("per_row_q_offset" if getattr(q_offset, "ndim", 0)
                     else "dynamic_q_offset")
    if key_mask is not None:
        needs.append("key_mask")
    return tuple(needs)


def attention(q, k, v, causal: bool = True, q_offset=0, key_mask=None,
              ctx: Optional[ExecutionContext] = None):
    """GQA attention, (B, H, L, Dh) layout; Hkv divides H.

    ``q_offset``: absolute position of the first query — a static python int
    (train/prefill), a traced scalar (lockstep decode), or a (B,) vector
    (continuous-batching decode, every slot at its own depth). ``key_mask``
    is an optional (B, Lk) validity mask over the keys (padded prefill).
    Backends that cannot serve the masked variant (the Pallas flash kernel)
    fall back by declared capability; traced and per-row offsets ride the
    flash kernel's scalar-prefetch path."""
    ctx = default_context() if ctx is None else ctx
    entry, dec = resolve("attention", ctx, dtype=str(q.dtype),
                         needs=attention_needs(q_offset, key_mask),
                         spec_args=(q, k, v))
    return entry.fn(ctx, dec.plan, q, k, v, causal=causal,
                    q_offset=q_offset, key_mask=key_mask)


def attention_decode(q, kp, vp, tables, lengths,
                     ctx: Optional[ExecutionContext] = None):
    """One paged decode step: ``q`` is (B, H, 1, hd), ``kp``/``vp`` the
    shared (num_blocks, KV, block_size, hd) pools, ``tables`` the (B, w)
    int32 physical-block ids backing each row's logical positions, and
    ``lengths`` the (B,) valid cache lengths (current token included).

    The pallas entry follows the tables inside the kernel's index_map (no
    gather copy); the xla entry gathers to a contiguous view first — the
    measured-words gap between them is the point of the paged subsystem."""
    ctx = default_context() if ctx is None else ctx
    entry, dec = resolve("attention_decode", ctx, dtype=str(q.dtype),
                         spec_args=(q, kp, vp, tables, lengths))
    return entry.fn(ctx, dec.plan, q, kp, vp, tables, lengths)


def attention_decode_quant(q, kp, ks, vp, vs, tables, lengths,
                           ctx: Optional[ExecutionContext] = None):
    """One paged decode step against an int8-quantized KV pool.

    ``kp``/``vp`` are the (num_blocks, KV, block_size, hd) int8 pools and
    ``ks``/``vs`` their (num_blocks, KV, block_size) f32 per-(block, head,
    position) scales (written together by the engine's quantizing insert).
    Registered on the xla backend only — any requested backend reaches it
    through the fallback chain — since the interesting quantity here is the
    *pool's* halved stream width (the plan's p_F ~ 0.25 + 1/hd), not the
    gather kernel."""
    ctx = default_context() if ctx is None else ctx
    entry, dec = resolve("attention_decode_quant", ctx, dtype=str(q.dtype),
                         spec_args=(q, kp, ks, vp, vs, tables, lengths))
    return entry.fn(ctx, dec.plan, q, kp, ks, vp, vs, tables, lengths)
