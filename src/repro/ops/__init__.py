"""``repro.ops`` — backend-registry op dispatch (ExecutionContext -> Backend
-> kernel).

The execution-layer counterpart of ``repro.plan``: where ``plan`` decides
*how* an op is tiled/sharded for a HardwareTarget, ``ops`` decides *which
implementation runs* — a per-(op, target) decision, matching the paper's
premise that mixed-precision word sizes change the Thm 2.1 bound and
therefore the optimal execution strategy.

    from repro import ops
    from repro.ops import ExecutionContext
    from repro.plan import TPU_V5E

    ctx = ExecutionContext(target=TPU_V5E)           # -> pallas by default
    y = ops.attention(q, k, v, ctx=ctx)              # flash kernel, LP blocks
    y = ops.attention(q, k, v, q_offset=idx, ctx=ctx)  # still pallas: traced
                                                       # offsets scalar-prefetch
    y = ops.attention_decode(q, kp, vp, tables, lens, ctx=ctx)  # paged decode
    ops.explain("attention", ctx, needs=("key_mask",)).chosen  # -> "xla"
                                                     # (fallback *by capability*)

Backends are registered in ``repro.ops.registry`` (``xla``, ``pallas``, and
the ``im2col`` conv baseline); each op entry declares capabilities (accepted
dtypes, per-row ``q_offset``, key masks) and the dispatcher walks the
fallback chain until one covers the call. ``ExecutionContext`` carries the
HardwareTarget (precision policy + plan cache handle), an optional backend
override, and the Pallas interpret flag — it supersedes the ``use_pallas``
booleans that used to thread through the model stack (the last shim,
``kernels/ops.py``, is gone). Backend selection from the environment:
``REPRO_BACKEND=xla|pallas|im2col`` — the only environment knob (the PR-3
``REPRO_USE_PALLAS`` variable is retired and now ignored).

Instrumented entries also declare a measured-words counter: every conv and
matmul ``DispatchDecision`` reports the words its launch geometry moves next
to the matching paper bound (``decision.measured_words``,
``decision.bound_ratio``, ``ops.explain(...).why()``) — HBM words vs. the
Thm 2.1 bound for single-device ops, per-device *inter-device* words vs. the
Thm 2.2/2.3 parallel bound for ``conv2d_dist`` (the distributed
halo-exchange conv of ``repro.distributed``, whose backend choice selects
the shard-local kernel).
"""

from .context import (  # noqa: F401
    BACKEND_ENV,
    ExecutionContext,
    default_context,
    dtype_for_words,
    env_backend,
)
from .dispatch import (  # noqa: F401
    QUARANTINE_PROBE_AFTER,
    DispatchDecision,
    attention,
    attention_decode,
    attention_decode_quant,
    attention_needs,
    clear_quarantine,
    conv1d_causal,
    conv2d,
    conv2d_dist,
    conv2d_q,
    dispatch_call,
    explain,
    matmul,
    matmul_q,
    quarantined,
    record_dispatch,
    resolve,
    set_fault_hook,
)
from .registry import (  # noqa: F401
    Backend,
    OpCapabilities,
    OpEntry,
    backends,
    get_backend,
    register_backend,
    registered_ops,
    xla_attention,
    xla_attention_decode,
    xla_attention_decode_quant,
)
