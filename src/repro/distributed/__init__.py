"""``repro.distributed`` — the paper's §4.2 parallel blocking, executed.

Takes a :class:`~repro.core.parallel_tiling.ParallelBlocking` (the integer
processor grid the parallel LP chose), snaps it onto a ``jax`` device mesh
(``repro.launch.make_conv_mesh``), and runs conv2d under ``shard_map``:
halo rows ``ppermute``-fetched from spatial neighbors, cI partials reduced
with ``psum``, and the shard-local conv dispatched through the ``repro.ops``
registry (op ``conv2d_dist``, backends ``xla``/``pallas``) so each shard
runs the PR-4 LP-tiled Pallas kernel.

    from repro import distributed, ops
    from repro.launch import fake_devices, make_conv_mesh

    fake_devices(8)                       # before jax initializes
    pb = distributed.default_blocking(x.shape, w.shape, stride=(1, 1))
    mesh = make_conv_mesh(pb)
    y = ops.conv2d_dist(x, w, blocking=pb, mesh=mesh)   # registry dispatch

``conv2d_dist_comm_words`` / ``allgather_comm_words`` report the measured
inter-device words per device from the identical launch geometry — the
numbers ``benchmarks/dist_bench.py`` compares against the Thm 2.2/2.3 bound.
"""

from .geometry import (  # noqa: F401
    DIST_AXES,
    DistConvGeometry,
    dist_grid,
)
from .halo import (  # noqa: F401
    allgather_comm_words,
    allgather_conv,
    conv2d_dist_comm_words,
    default_blocking,
    halo_conv,
)
