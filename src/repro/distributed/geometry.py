"""Launch geometry of the distributed halo-exchange conv (paper §4.2).

One value object — :class:`DistConvGeometry` — is the single source of truth
shared by the executable ``shard_map`` paths (``repro.distributed.halo``) and
the inter-device word counters (``conv2d_dist_comm_words`` /
``allgather_comm_words``), exactly as PR 4's ``_launch_geometry`` ties the
single-device kernel lowering to its HBM-word counter.

The scheme (Demmel & Dinh 2018, Li et al. 2021): snap the integer processor
grid of a :class:`~repro.core.parallel_tiling.ParallelBlocking` onto a device
mesh with axes ``("N", "cI", "hO", "wO")`` and give each device one block of
every array:

  * the input is partitioned into *disjoint* slabs of ``bh*sh`` rows x
    ``bw*sw`` cols — exactly the rows/cols "consumed" by the device's
    ``bh x bw`` output block;
  * each output block additionally needs the ``(bh-1)*sh + h_F`` row window,
    i.e. an ``h_F - sh`` row halo owned by the *next* device along ``hO``
    (and ``w_F - sw`` cols along ``wO``) — fetched with one ``ppermute``
    per spatial axis;
  * splitting ``cI`` leaves every device with a partial output block,
    combined by a ``psum`` over the ``cI`` mesh axis.

Padding discipline: ``h_O`` is padded up so that (a) every device gets an
equal block and (b) the *owned* input slabs cover the entire tight VALID
extent ``(h_O-1)*sh + h_F`` — the ring-wraparound halo a trailing device
receives then only ever feeds padded output rows, which are sliced away.
Without (b), the last device's real outputs would consume wrapped (wrong)
rows whenever ``h_F > sh`` and ``h_O`` divides evenly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Tuple

from repro.core.bounds import combined_parallel_bound
from repro.core.conv_model import ConvShape, ceil_div
from repro.core.parallel_tiling import PAR_AXES, ParallelBlocking

# Mesh axis order (canonical): the loop axes a distributed conv may split.
# cO / wF / hF splits are not lowered (cO sharding would need no comm but
# also exercises nothing; filter-tap sharding forces halo-heavy replication).
DIST_AXES = ("N", "cI", "hO", "wO")


def dist_grid(blocking_or_grid) -> Tuple[int, int, int, int]:
    """Normalize a ParallelBlocking / axis->procs mapping to (gN, gcI, ghO,
    gwO), rejecting splits on axes the distributed lowering cannot serve."""
    grid: Mapping[str, int]
    if isinstance(blocking_or_grid, ParallelBlocking):
        grid = blocking_or_grid.grid
    else:
        grid = dict(blocking_or_grid)
    for ax in grid:
        if ax not in PAR_AXES:
            raise ValueError(f"unknown loop axis {ax!r} (expected {PAR_AXES})")
        if ax not in DIST_AXES and grid[ax] > 1:
            raise ValueError(
                f"distributed conv cannot split axis {ax!r} (grid={dict(grid)}); "
                f"splittable axes: {DIST_AXES}")
    return tuple(int(grid.get(ax, 1)) for ax in DIST_AXES)


@dataclasses.dataclass(frozen=True)
class DistConvGeometry:
    """Everything the distributed conv lowers for one (shape, grid) pair."""

    N: int
    c_I: int
    c_O: int
    h_O: int
    w_O: int
    h_F: int
    w_F: int
    sh: int
    sw: int
    grid: Tuple[int, int, int, int]  # (gN, gcI, ghO, gwO), mesh axis sizes

    @classmethod
    def build(cls, N: int, c_I: int, c_O: int, h_O: int, w_O: int, h_F: int,
              w_F: int, sh: int, sw: int, grid) -> "DistConvGeometry":
        return cls(N=N, c_I=c_I, c_O=c_O, h_O=h_O, w_O=w_O, h_F=h_F, w_F=w_F,
                   sh=sh, sw=sw, grid=dist_grid(grid))

    @classmethod
    def from_shape(cls, shape: ConvShape, grid) -> "DistConvGeometry":
        return cls.build(shape.N, shape.c_I, shape.c_O, shape.h_O, shape.w_O,
                         shape.h_F, shape.w_F, shape.sh, shape.sw, grid)

    # -- processor counts -----------------------------------------------------
    @property
    def P(self) -> int:
        return math.prod(self.grid)

    # -- per-device blocks ----------------------------------------------------
    @property
    def bN(self) -> int:
        return ceil_div(self.N, self.grid[0])

    @property
    def b_cI(self) -> int:
        return ceil_div(self.c_I, self.grid[1])

    @property
    def bh(self) -> int:
        """Output rows per device. Padded beyond ceil(h_O/ghO) when needed so
        the owned input slabs (bh*sh rows each) cover the tight VALID input
        extent — see the module docstring's padding discipline."""
        ghO = self.grid[2]
        tight = (self.h_O - 1) * self.sh + self.h_F
        return max(ceil_div(self.h_O, ghO), ceil_div(tight, ghO * self.sh))

    @property
    def bw(self) -> int:
        gwO = self.grid[3]
        tight = (self.w_O - 1) * self.sw + self.w_F
        return max(ceil_div(self.w_O, gwO), ceil_div(tight, gwO * self.sw))

    # -- padded global dims (what the sharded arrays hold) --------------------
    @property
    def Np(self) -> int:
        return self.grid[0] * self.bN

    @property
    def cIp(self) -> int:
        return self.grid[1] * self.b_cI

    @property
    def hOp(self) -> int:
        return self.grid[2] * self.bh

    @property
    def wOp(self) -> int:
        return self.grid[3] * self.bw

    @property
    def Hp(self) -> int:
        """Sharded input rows: disjoint owned slabs of bh*sh rows."""
        return self.hOp * self.sh

    @property
    def Wp(self) -> int:
        return self.wOp * self.sw

    # -- halo extents ---------------------------------------------------------
    @property
    def halo_h(self) -> int:
        """Rows each device receives from its next ``hO`` neighbor (the
        overlap of consecutive halo windows)."""
        return max(self.h_F - self.sh, 0)

    @property
    def halo_w(self) -> int:
        return max(self.w_F - self.sw, 0)

    @property
    def h_ext(self) -> int:
        """Input rows of one device's haloed conv window."""
        return (self.bh - 1) * self.sh + self.h_F

    @property
    def w_ext(self) -> int:
        return (self.bw - 1) * self.sw + self.w_F

    def validate(self) -> "DistConvGeometry":
        gN, gcI, ghO, gwO = self.grid
        if self.halo_h > self.bh * self.sh and ghO > 1:
            raise ValueError(
                f"halo of {self.halo_h} rows exceeds the {self.bh * self.sh}"
                f"-row owned slab: grid hO={ghO} is too fine for filter "
                f"h_F={self.h_F} (halo must come from one neighbor)")
        if self.halo_w > self.bw * self.sw and gwO > 1:
            raise ValueError(
                f"halo of {self.halo_w} cols exceeds the {self.bw * self.sw}"
                f"-col owned slab: grid wO={gwO} is too fine for filter "
                f"w_F={self.w_F} (halo must come from one neighbor)")
        return self

    # -- inter-device word counters (32-bit words, per device) ----------------
    def halo_words(self, p_in: float = 1.0) -> float:
        """Words one device *receives* over the wire for its halos: the row
        halo over the owned column extent, then the column halo over the
        row-extended height (corners ride the second exchange)."""
        gN, gcI, ghO, gwO = self.grid
        words = 0.0
        if ghO > 1 and self.halo_h > 0:
            words += self.bN * self.b_cI * self.halo_h * (self.bw * self.sw)
        h_after = self.bh * self.sh + (self.halo_h if ghO > 1 else 0)
        if gwO > 1 and self.halo_w > 0:
            words += self.bN * self.b_cI * h_after * self.halo_w
        return p_in * words

    def psum_words(self, p_out: float = 1.0) -> float:
        """Ring all-reduce words per device combining the cI-partial output
        blocks: 2 (g-1)/g x the block size (reduce-scatter + all-gather)."""
        gcI = self.grid[1]
        if gcI <= 1:
            return 0.0
        block = self.bN * self.c_O * self.bh * self.bw
        return p_out * 2.0 * (gcI - 1) / gcI * block

    def comm_words(self, p_in: float = 1.0, p_out: float = 1.0) -> float:
        """Total measured inter-device words per device: halo + psum."""
        return self.halo_words(p_in) + self.psum_words(p_out)

    def allgather_words(self, p_in: float = 1.0, p_flt: float = 1.0) -> float:
        """Per-device words of the naive baseline: all-gather the full padded
        input over every mesh axis ((P-1)/P x |I_pad| received per device)
        plus the filter over the cI axis."""
        if self.P <= 1:
            return 0.0
        in_pad = self.Np * self.cIp * self.Hp * self.Wp
        words = p_in * in_pad * (self.P - 1) / self.P
        gcI = self.grid[1]
        if gcI > 1:
            flt_pad = self.c_O * self.cIp * self.h_F * self.w_F
            words += p_flt * flt_pad * (gcI - 1) / gcI
        return words

    # -- model hooks ----------------------------------------------------------
    def blocking(self, shape: ConvShape) -> ParallelBlocking:
        """The ParallelBlocking this geometry lowers (for model comparisons)."""
        grid = {ax: 1 for ax in PAR_AXES}
        grid.update(dict(zip(DIST_AXES, self.grid)))
        return ParallelBlocking(grid, shape)

    def lower_bound(self, shape: ConvShape, M: float) -> float:
        """Combined Thm 2.2/2.3 per-processor bound at local memory M."""
        return combined_parallel_bound(shape, self.P, M)

    def grid_dict(self) -> Dict[str, int]:
        return dict(zip(DIST_AXES, self.grid))
