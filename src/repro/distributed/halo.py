"""Executable distributed conv2d: halo exchange vs. all-gather, under
``shard_map`` on a real device mesh.

Two lowering strategies for the same 7NL conv, both driven by one
:class:`~repro.distributed.geometry.DistConvGeometry`:

  * :func:`halo_conv` — the paper-§4.2 blocking made runnable. Inputs are
    sharded over ``(N, cI, hO, wO)`` as disjoint owned slabs; each device
    ``ppermute``-fetches the ``h_F - sh`` overlap rows (and ``w_F - sw``
    cols) from its spatial neighbor, runs the shard-local conv through the
    ``repro.ops`` registry (so the PR-4 LP-tiled Pallas kernel serves each
    shard), and ``psum``s cI-partial outputs.
  * :func:`allgather_conv` — the naive baseline: every device all-gathers
    the full input (and the filter along cI), then computes only its own
    output block. Same sharded inputs, same outputs, (P-1)/P x |I| more
    wire traffic.

Both return the exact global VALID conv (bitwise vs. the single-device
reference when cI is not split; cI splits reassociate the reduction).
The shard-local conv dispatches at trace time, so the whole thing jits.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.parallel_tiling import ParallelBlocking, optimize_parallel_blocking

from .geometry import DIST_AXES, DistConvGeometry


def default_blocking(x_shape, w_shape, stride: Tuple[int, int],
                     P_devices: Optional[int] = None,
                     prec=None) -> ParallelBlocking:
    """The LP-chosen processor grid for this conv over ``P_devices`` devices,
    restricted to the axes the distributed lowering serves."""
    from repro.core.conv_model import ConvShape, Precision

    N, c_I, H, W = x_shape
    c_O, _, h_F, w_F = w_shape
    sh, sw = stride
    shape = ConvShape(N=N, c_I=c_I, c_O=c_O,
                      w_O=(W - w_F) // sw + 1, h_O=(H - h_F) // sh + 1,
                      w_F=w_F, h_F=h_F, sw=sw, sh=sh,
                      prec=prec or Precision())
    P_devices = P_devices or len(jax.devices())
    return optimize_parallel_blocking(shape, P_devices,
                                      restrict_axes=DIST_AXES)


def _geometry(x, w, stride, blocking) -> DistConvGeometry:
    N, c_I, H, W = x.shape
    c_O, _, h_F, w_F = w.shape
    sh, sw = stride
    return DistConvGeometry.build(
        N=N, c_I=c_I, c_O=c_O,
        h_O=(H - h_F) // sh + 1, w_O=(W - w_F) // sw + 1,
        h_F=h_F, w_F=w_F, sh=sh, sw=sw, grid=blocking).validate()


def _check_mesh(mesh: Mesh, geom: DistConvGeometry) -> Mesh:
    names = tuple(mesh.axis_names)
    if names != DIST_AXES:
        raise ValueError(f"distributed conv needs mesh axes {DIST_AXES}, "
                         f"got {names} (use launch.make_conv_mesh)")
    sizes = tuple(mesh.devices.shape)
    if sizes != geom.grid:
        raise ValueError(f"mesh sizes {sizes} do not match the blocking grid "
                         f"{geom.grid}")
    return mesh


def _resolve_mesh(mesh: Optional[Mesh], geom: DistConvGeometry) -> Mesh:
    if mesh is not None:
        return _check_mesh(mesh, geom)
    from repro.launch.mesh import make_conv_mesh

    return make_conv_mesh(geom.grid_dict())


def _local_ctx(ctx, backend: str):
    """The shard-local execution context: same target, mesh stripped (each
    shard is a single device), the requested local backend pinned."""
    target = dataclasses.replace(ctx.target, mesh_axes=())
    return dataclasses.replace(ctx, target=target, backend=backend)


def _pad_operands(x, w, geom: DistConvGeometry):
    """Pad to the sharded global dims. Input rows/cols beyond the tight
    VALID extent are never consumed by a real output; padded cI channels
    contribute zeros; padded N rows are sliced away."""
    N, c_I, H, W = x.shape
    c_O = w.shape[0]
    x = x[:, :, :min(H, geom.Hp), :min(W, geom.Wp)]
    x = jnp.pad(x, ((0, geom.Np - N), (0, geom.cIp - c_I),
                    (0, geom.Hp - x.shape[2]), (0, geom.Wp - x.shape[3])))
    w = jnp.pad(w, ((0, 0), (0, geom.cIp - c_I), (0, 0), (0, 0)))
    return x, w, c_O


def _shift_from_next(block, axis_name: str, size: int):
    """Each device receives ``block`` from its successor along ``axis_name``
    (ring: the last device receives the first's — wraparound data only ever
    feeds padded output rows, see geometry.py)."""
    return jax.lax.ppermute(
        block, axis_name, [(j, (j - 1) % size) for j in range(size)])


def halo_conv(x, w, stride=(1, 1), blocking=None, mesh: Optional[Mesh] = None,
              ctx=None, local_backend: str = "pallas", out_dtype=jnp.float32,
              full_output: bool = False):
    """Distributed halo-exchange conv2d (NCHW x OIHW, VALID padding).

    ``blocking`` is a :class:`ParallelBlocking` (or axis->procs dict) whose
    grid must match ``mesh`` (built via ``launch.make_conv_mesh`` when
    omitted). The shard-local conv dispatches through ``repro.ops`` with
    ``local_backend``, so pallas shards run the PR-4 LP-tiled kernel.

    ``full_output=True`` returns the padded, still-sharded global output
    ``(Np, c_O, hOp, wOp)``: slicing padded spatial dims back to
    ``(h_O, w_O)`` across even shards makes XLA insert small re-layout
    permutes, so pipelines that feed another sharded op should keep the
    padded form and slice once at the end. The measured-words counter
    charges only the algorithm's halo + psum traffic, never this fixup."""
    from repro import ops

    ctx = ops.default_context() if ctx is None else ctx
    if blocking is None:
        blocking = default_blocking(x.shape, w.shape, stride)
    geom = _geometry(x, w, stride, blocking)
    mesh = _resolve_mesh(mesh, geom)
    lctx = _local_ctx(ctx, local_backend)
    gN, gcI, ghO, gwO = geom.grid
    N, c_I = x.shape[0], x.shape[1]
    xp, wp, c_O = _pad_operands(x, w, geom)
    sh, sw = geom.sh, geom.sw

    def body(xl, wl):
        # xl: (bN, b_cI, bh*sh, bw*sw)  wl: (c_O, b_cI, h_F, w_F)
        # Rows first, then columns over the row-extended height, so corner
        # halos ride the second exchange. Single-shard axes skip the wire
        # entirely: their windows are completed with a *local* zero fill
        # below (those rows/cols only ever feed padded outputs), keeping the
        # ppermute traffic equal to geometry.halo_words for every grid.
        if geom.halo_h > 0 and ghO > 1:
            top = jax.lax.slice_in_dim(xl, 0, geom.halo_h, axis=2)
            xl = jnp.concatenate([xl, _shift_from_next(top, "hO", ghO)],
                                 axis=2)
        if geom.halo_w > 0 and gwO > 1:
            left = jax.lax.slice_in_dim(xl, 0, geom.halo_w, axis=3)
            xl = jnp.concatenate([xl, _shift_from_next(left, "wO", gwO)],
                                 axis=3)
        pad_h = max(geom.h_ext - xl.shape[2], 0)
        pad_w = max(geom.w_ext - xl.shape[3], 0)
        if pad_h or pad_w:
            xl = jnp.pad(xl, ((0, 0), (0, 0), (0, pad_h), (0, pad_w)))
        xl = xl[:, :, :geom.h_ext, :geom.w_ext]
        # every shard must now hold an exact halo window (kernel contract)
        from repro.kernels.conv2d import exact_window

        assert exact_window(geom.h_ext, geom.w_ext, geom.h_F, geom.w_F,
                            sh, sw), "mis-built halo window"
        # shard-local conv through the registry: f32 partials for the psum
        ol = ops.conv2d(xl, wl, stride=(sh, sw), ctx=lctx,
                        out_dtype=jnp.float32)
        if gcI > 1:
            ol = jax.lax.psum(ol, "cI")
        return ol.astype(out_dtype)

    f = shard_map(body, mesh=mesh,
                  in_specs=(P("N", "cI", "hO", "wO"), P(None, "cI", None, None)),
                  out_specs=P("N", None, "hO", "wO"), check_rep=False)
    out = f(xp, wp)
    if full_output:
        return out
    return out[:N, :c_O, :geom.h_O, :geom.w_O]


def allgather_conv(x, w, stride=(1, 1), blocking=None,
                   mesh: Optional[Mesh] = None, ctx=None,
                   local_backend: str = "pallas", out_dtype=jnp.float32,
                   full_output: bool = False):
    """The naive all-gather baseline: same sharded inputs as
    :func:`halo_conv`, but every device gathers the *full* input (and the
    filter along cI) before computing its own output block."""
    from repro import ops

    ctx = ops.default_context() if ctx is None else ctx
    if blocking is None:
        blocking = default_blocking(x.shape, w.shape, stride)
    geom = _geometry(x, w, stride, blocking)
    mesh = _resolve_mesh(mesh, geom)
    lctx = _local_ctx(ctx, local_backend)
    gN, gcI, ghO, gwO = geom.grid
    N, c_I = x.shape[0], x.shape[1]
    xp, wp, c_O = _pad_operands(x, w, geom)
    sh, sw = geom.sh, geom.sw

    def body(xl, wl):
        xg = xl
        for name, size, arr_axis in (("N", gN, 0), ("cI", gcI, 1),
                                     ("hO", ghO, 2), ("wO", gwO, 3)):
            if size > 1:
                xg = jax.lax.all_gather(xg, name, axis=arr_axis, tiled=True)
        wg = (jax.lax.all_gather(wl, "cI", axis=1, tiled=True)
              if gcI > 1 else wl)
        # tail windows read past the owned extent: zero-pad locally (free)
        xg = jnp.pad(xg, ((0, 0), (0, 0), (0, geom.halo_h),
                          (0, geom.halo_w)))
        i_n = jax.lax.axis_index("N")
        i_h = jax.lax.axis_index("hO")
        i_w = jax.lax.axis_index("wO")
        win = jax.lax.dynamic_slice(
            xg, (i_n * geom.bN, 0, i_h * geom.bh * sh, i_w * geom.bw * sw),
            (geom.bN, geom.cIp, geom.h_ext, geom.w_ext))
        ol = ops.conv2d(win, wg, stride=(sh, sw), ctx=lctx,
                        out_dtype=jnp.float32)
        return ol.astype(out_dtype)

    f = shard_map(body, mesh=mesh,
                  in_specs=(P("N", "cI", "hO", "wO"), P(None, "cI", None, None)),
                  out_specs=P("N", None, "hO", "wO"), check_rep=False)
    out = f(xp, wp)
    if full_output:
        return out
    return out[:N, :c_O, :geom.h_O, :geom.w_O]


# ---------------------------------------------------------------------------
# Measured inter-device word counters (shape-only; ShapeDtypeStruct works).
# ---------------------------------------------------------------------------

def _word_widths(x, w, out_dtype):
    p_in = jnp.dtype(x.dtype).itemsize / 4.0
    p_flt = jnp.dtype(w.dtype).itemsize / 4.0
    p_out = jnp.dtype(out_dtype).itemsize / 4.0
    return p_in, p_flt, p_out


def conv2d_dist_comm_words(x, w, stride=(1, 1), blocking=None,
                           out_dtype=jnp.float32, **_kw) -> float:
    """Measured inter-device words (32-bit, per device) one ``halo_conv``
    dispatch moves: halo ``ppermute`` volume + cI ``psum`` volume, computed
    from the same :class:`DistConvGeometry` the execution lowers.

    The psum leg always charges f32 words whatever ``out_dtype`` is: the
    shard-local conv emits f32 partials (the paper's accumulate-in-f32
    discipline) and the reduction runs *before* the ``astype``, so that is
    what the all-reduce puts on the wire."""
    if blocking is None:
        blocking = default_blocking(x.shape, w.shape, stride)
    geom = _geometry(x, w, stride, blocking)
    p_in, _, _ = _word_widths(x, w, out_dtype)
    return geom.comm_words(p_in=p_in, p_out=1.0)


def allgather_comm_words(x, w, stride=(1, 1), blocking=None,
                         out_dtype=jnp.float32, **_kw) -> float:
    """Per-device words the all-gather baseline moves for the same grid."""
    if blocking is None:
        blocking = default_blocking(x.shape, w.shape, stride)
    geom = _geometry(x, w, stride, blocking)
    p_in, p_flt, _ = _word_widths(x, w, out_dtype)
    return geom.allgather_words(p_in=p_in, p_flt=p_flt)
