"""Deterministic sharded token pipeline.

Sources:
  * SyntheticSource - structured pseudo-text (Zipf-distributed n-gram chains),
    deterministic in (seed, step, shard) so every host materializes exactly
    its own shard without coordination — the property that matters at 1000
    hosts (no data server in the loss path).
  * FileSource - memory-mapped token file (np.uint32), strided host shards.

The iterator yields host-local batches; under pjit the arrays are given the
batch NamedSharding via jax.make_array_from_process_local_data in multi-host
deployments (single-host here: device_put).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int  # host-local
    seq_len: int
    vocab_size: int
    seed: int = 0
    shard_index: int = 0
    shard_count: int = 1
    path: Optional[str] = None  # FileSource when set


class SyntheticSource:
    """Zipf-ish Markov chains: deterministic, compressible (loss can go well
    below ln(V)), and cheap to generate per host shard."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # a sparse deterministic transition table: each token prefers 8 successors
        self.successors = rng.integers(0, V, size=(V, 8), dtype=np.int64)
        self.zipf_p = 1.0 / np.arange(1, 9)
        self.zipf_p /= self.zipf_p.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * cfg.shard_count + cfg.shard_index)
        B, L = cfg.batch_size, cfg.seq_len
        toks = np.empty((B, L), dtype=np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=B)
        choices = rng.choice(8, size=(B, L), p=self.zipf_p)
        for t in range(1, L):
            toks[:, t] = self.successors[toks[:, t - 1], choices[:, t]]
        return {"tokens": toks}


class FileSource:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        self.tokens_per_batch = cfg.batch_size * cfg.seq_len
        usable = len(self.data) - self.tokens_per_batch * cfg.shard_count
        assert usable > 0, "token file smaller than one global batch"

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        stride = self.tokens_per_batch * cfg.shard_count
        start = (step * stride + cfg.shard_index * self.tokens_per_batch) % (
            len(self.data) - self.tokens_per_batch)
        flat = np.asarray(self.data[start:start + self.tokens_per_batch])
        return {"tokens": flat.reshape(cfg.batch_size, cfg.seq_len).astype(np.int32)}


def make_source(cfg: DataConfig):
    return FileSource(cfg) if cfg.path else SyntheticSource(cfg)


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    src = make_source(cfg)
    step = start_step
    while True:
        yield src.batch(step)
        step += 1
