from .pipeline import DataConfig, FileSource, SyntheticSource, iterate, make_source  # noqa: F401
