"""The paper's parallel blocking LP driving real jax shardings.

Builds an 8-fake-device mesh, asks the unified ``repro.plan`` planner (a
mesh-bearing HardwareTarget makes ``plan()`` attach a ShardingPlan) for the
comm-minimizing loop-axis -> mesh-axis binding of a convolution and of an LM
GEMM, then actually executes the conv under those NamedShardings and
cross-checks the result against the unsharded oracle.

    PYTHONPATH=src python examples/comm_optimal_sharding.py
"""

from repro.launch import fake_devices

fake_devices(8)  # before any jax device query

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.kernels.ref import conv2d_ref  # noqa: E402
from repro.plan import ConvSpec, MatmulSpec, TPU_V5E, plan as make_plan  # noqa: E402


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    target = TPU_V5E.with_mesh((("data", 4), ("model", 2)))
    ep = make_plan(ConvSpec(N=8, c_I=16, c_O=32, w_O=14, h_O=14, w_F=3, h_F=3),
                   target)
    plan = ep.sharding
    print(f"conv binding: {plan.binding} "
          f"(modeled {plan.comm_per_processor:.3e} words/chip)")
    print(f"  input  spec {plan.input_spec}")
    print(f"  filter spec {plan.filter_spec}")
    print(f"  output spec {plan.output_spec}")

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 16, 16, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16, 3, 3), jnp.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P(*plan.input_spec)))
    # filter layout is OIHW; plan.filter_spec is (cI, cO, ...) -> transpose
    fs = (plan.filter_spec[1], plan.filter_spec[0]) + plan.filter_spec[2:]
    ws = jax.device_put(w, NamedSharding(mesh, P(*fs)))

    with mesh:
        out = jax.jit(conv2d_ref)(xs, ws)
    ref = conv2d_ref(x, w)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"sharded conv vs oracle |err| = {err:.2e}")
    assert err < 1e-4

    gplan = make_plan(MatmulSpec(4096, 2048, 512), target).sharding
    print(f"\nGEMM (4096x2048x512) binding: {gplan.binding} "
          f"-> A rows on 'data', B cols on 'model' (Megatron-style)")
    print("OK")


if __name__ == "__main__":
    main()
