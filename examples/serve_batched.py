"""Batched serving demo: a reduced qwen2.5 decoder, a queue of requests with
ragged prompt lengths and heterogeneous output budgets, slot-based
continuous batching (freed slots are refilled mid-flight), greedy + sampled
decode with per-request sampling streams.

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import transformer as T
from repro.serving.engine import Engine, Request


def main():
    cfg = get_smoke("qwen2_5_3b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(1)
    requests = []
    for i in range(10):
        plen = int(rng.integers(2, 24))
        requests.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, size=plen, dtype=np.int64)
            .astype(np.int32),
            max_new_tokens=4 if i % 3 == 0 else 16,  # mixed output budgets
            temperature=0.0 if i % 2 == 0 else 0.8,
            rng_seed=i))  # fixed stream: same output in any batch mix

    eng = Engine(cfg, params, max_len=64, batch_size=4)
    t0 = time.time()
    eng.serve(requests)
    dt = time.time() - t0
    new_tokens = sum(len(r.out_tokens) for r in requests)  # real tokens only
    print(f"served {len(requests)} requests ({new_tokens} new tokens) "
          f"in {dt:.2f}s -> {new_tokens / dt:.1f} tok/s on CPU")
    for i, r in enumerate(requests):
        mode = "greedy" if i % 2 == 0 else "t=0.8 "
        print(f"  [{mode}] prompt({len(r.prompt)}) -> {r.out_tokens.tolist()} "
              f"({r.finish_reason})")


if __name__ == "__main__":
    main()
