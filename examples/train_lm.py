"""End-to-end training driver: train a small LM on the synthetic Markov
corpus for a few hundred steps with checkpointing and fault-tolerant
stepping.

Presets (CPU container -> default 'small'; on a real pod use 'm100'):
    small : ~6M params,  300 steps   (a few minutes on this CPU)
    m100  : ~100M params, 300 steps  (the deliverable config; needs real HW)

    PYTHONPATH=src python examples/train_lm.py [--preset small] [--steps N]
"""

import argparse
import logging
import tempfile

from repro.data.pipeline import DataConfig
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer

PRESETS = {
    "small": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                  d_ff=1024, vocab_size=2048, batch=8, seq=128),
    "m100": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab_size=32768, batch=32, seq=1024),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"train-lm-{args.preset}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], compute_dtype="float32")
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    dcfg = DataConfig(batch_size=p["batch"], seq_len=p["seq"],
                      vocab_size=cfg.vocab_size, seed=0)
    ocfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=ckpt_dir, ckpt_every=100,
                       log_every=20, remat=True)
    trainer = Trainer(cfg, ocfg, tcfg, dcfg)
    hist = trainer.run()
    import math
    print(f"\nloss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"(uniform = ln V = {math.log(cfg.vocab_size):.3f})")
    print(f"checkpoints in {ckpt_dir}")
    assert hist["loss"][-1] < hist["loss"][0], "training did not improve loss"


if __name__ == "__main__":
    main()
