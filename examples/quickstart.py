"""Quickstart: the paper's pipeline on one convolution, through the unified
``repro.plan`` API (HardwareTarget -> plan() -> kernel call).

1. Pose a conv layer (ResNet50 conv2_x, mixed precision) as a ``ConvSpec``.
2. Compute the Thm 2.1 / 2.2 / 2.3 communication lower bounds.
3. ``plan()`` it for the TPU_V5E target: the blocking LP (eq. 6 + the §5
   buffer model) solved against the target's memory hierarchy, with the
   modeled communication, bound, and efficiency carried on the returned
   ``ExecutionPlan``; compare blocking / im2col / Winograd / FFT volumes.
4. Run the Pallas conv2d kernel from that same plan (interpret mode) and
   check it against the jnp oracle.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (BF16_ACC32, FP32, ConvShape,
                        memory_independent_parallel_bound, parallel_bound,
                        single_processor_bound)
from repro.core.algorithms import single_processor_volumes
from repro.kernels.conv2d import conv2d
from repro.kernels.ref import conv2d_ref
from repro.plan import ConvSpec, Planner, TPU_V5E


def main():
    # ResNet50 conv2_x at batch 32, bf16 inputs + f32 accumulate
    shape = ConvShape(N=32, c_I=64, c_O=64, w_O=56, h_O=56, w_F=3, h_F=3,
                      prec=BF16_ACC32)
    print(f"conv: {shape}")
    print(f"G = {shape.G:.3e} updates, arrays = {shape.words():.3e} words\n")

    target = TPU_V5E
    M = target.memory_model().M_eff
    b = single_processor_bound(shape, M)
    print(f"Thm 2.1 (single chip, M={M:.0f} words):")
    for k, v in b.terms.items():
        print(f"  {k:20s} {v:.4e} words")
    print(f"  => X >= {b.value:.4e} ({b.dominant})\n")

    print("Thm 2.2/2.3 (P=256 chips):")
    print(f"  per-M bound        {parallel_bound(shape, 256, M).value:.4e}")
    print(f"  memory-independent "
          f"{memory_independent_parallel_bound(shape, 256).value:.4e}\n")

    ep = Planner(target).plan(ConvSpec.from_shape(shape))
    print(f"ExecutionPlan for {target.name}: tile={ep.conv_tile()}")
    print(f"  kernel tiles (bN, b_cI, b_cO, b_hO, b_wO) = {ep.tiles}, "
          f"grid = {ep.grid}")
    print(f"  modeled comm {ep.comm_volume:.4e} words "
          f"({ep.efficiency:.2f}x bound)\n")

    vols = single_processor_volumes(shape, M)
    lb = vols.pop("lower_bound")
    print("algorithm comparison (x bound):")
    for alg, v in sorted(vols.items(), key=lambda kv: kv[1]):
        print(f"  {alg:10s} {v / lb:8.2f}x")

    print("\nrunning the Pallas kernel from the same plan (interpret mode)...")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 16, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8, 3, 3), jnp.float32)
    small = Planner(target).plan(
        ConvSpec(N=2, c_I=8, c_O=16, w_O=14, h_O=14, w_F=3, h_F=3,
                 prec=FP32))  # matches the f32 arrays below
    got = conv2d(x, w, plan=small)
    want = conv2d_ref(x, w)
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"  kernel vs oracle max |err| = {err:.2e}")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
