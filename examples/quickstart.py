"""Quickstart: the paper's pipeline on one convolution.

1. Pose a conv layer (ResNet50 conv2_x, mixed precision).
2. Compute the Thm 2.1 / 2.2 / 2.3 communication lower bounds.
3. Solve the blocking LP (eq. 6) for a TPU-VMEM tiling and compare the
   modeled communication of blocking / im2col / Winograd / FFT to the bound.
4. Run the LP-tiled Pallas conv2d kernel (interpret mode) and check it
   against the jnp oracle.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BF16_ACC32, GEMMINI, TPU_VMEM, ConvShape,
                        memory_independent_parallel_bound, optimize_blocking,
                        parallel_bound, single_processor_bound)
from repro.core.algorithms import single_processor_volumes
from repro.kernels.conv2d import conv2d
from repro.kernels.ref import conv2d_ref


def main():
    # ResNet50 conv2_x at batch 32, bf16 inputs + f32 accumulate
    shape = ConvShape(N=32, c_I=64, c_O=64, w_O=56, h_O=56, w_F=3, h_F=3,
                      prec=BF16_ACC32)
    print(f"conv: {shape}")
    print(f"G = {shape.G:.3e} updates, arrays = {shape.words():.3e} words\n")

    M = TPU_VMEM.M_eff
    b = single_processor_bound(shape, M)
    print(f"Thm 2.1 (single chip, M={M:.0f} words):")
    for k, v in b.terms.items():
        print(f"  {k:20s} {v:.4e} words")
    print(f"  => X >= {b.value:.4e} ({b.dominant})\n")

    print("Thm 2.2/2.3 (P=256 chips):")
    print(f"  per-M bound        {parallel_bound(shape, 256, M).value:.4e}")
    print(f"  memory-independent "
          f"{memory_independent_parallel_bound(shape, 256).value:.4e}\n")

    blk = optimize_blocking(shape, TPU_VMEM)
    print(f"LP blocking (VMEM model): {blk.as_conv_tile()}")
    print(f"  modeled comm {blk.comm_volume():.4e} words "
          f"({blk.comm_volume() / b.value:.2f}x bound)\n")

    vols = single_processor_volumes(shape, M)
    lb = vols.pop("lower_bound")
    print("algorithm comparison (x bound):")
    for alg, v in sorted(vols.items(), key=lambda kv: kv[1]):
        print(f"  {alg:10s} {v / lb:8.2f}x")

    print("\nrunning the LP-tiled Pallas kernel (interpret mode)...")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 16, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8, 3, 3), jnp.float32)
    got = conv2d(x, w)
    want = conv2d_ref(x, w)
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"  kernel vs oracle max |err| = {err:.2e}")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
