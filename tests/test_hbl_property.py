"""Property tests of the HBL machinery itself: the inequality
|V| <= prod |phi_j(V)|^{s_j} must hold NUMERICALLY for the LP exponents on
random finite sets V — this checks the whole pipeline (kernels -> lattice ->
constraints -> LP) against the theorem it implements, not just against
hand-derived special cases."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bounds import single_processor_bound
from repro.core.conv_model import ConvShape
from repro.core.hbl import (Homomorphism, conv7nl_lifted_phis, conv7nl_phis,
                            matmul_phis, solve_exponents)
from repro.core.tiling import MemoryModel, optimize_blocking


def _check_hbl_on_random_sets(phis, s, rng, d, n_sets=20, n_pts=40):
    for _ in range(n_sets):
        V = rng.integers(-3, 4, size=(n_pts, d))
        V = np.unique(V, axis=0)
        lhs = len(V)
        rhs = 1.0
        for phi, sj in zip(phis, s):
            mat = np.array([[float(x) for x in row] for row in phi.mat])
            img = np.unique(np.round(V @ mat.T, 9), axis=0)
            rhs *= len(img) ** sj
        assert lhs <= rhs * (1 + 1e-9), (lhs, rhs)


def test_hbl_inequality_numerically_conv7nl():
    phis = conv7nl_phis(1, 1)
    s, _ = solve_exponents(phis)
    _check_hbl_on_random_sets(phis, s, np.random.default_rng(0), d=7)


def test_hbl_inequality_numerically_strided():
    phis = conv7nl_phis(2, 3)
    s, _ = solve_exponents(phis)
    _check_hbl_on_random_sets(phis, s, np.random.default_rng(1), d=7)


def test_hbl_inequality_numerically_lifted():
    phis = conv7nl_lifted_phis()
    s, _ = solve_exponents(phis)
    _check_hbl_on_random_sets(phis, s, np.random.default_rng(2), d=7)


def test_hbl_inequality_numerically_matmul():
    phis = matmul_phis()
    s, _ = solve_exponents(phis)
    _check_hbl_on_random_sets(phis, s, np.random.default_rng(3), d=3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_hbl_inequality_random_projections(seed):
    """Random coordinate-projection homomorphisms on Z^5: the LP exponents
    must satisfy the inequality on random finite sets."""
    rng = np.random.default_rng(seed)
    d = 5
    phis = []
    for j in range(3):
        # random subset of coordinates (nonempty)
        keep = rng.permutation(d)[: int(rng.integers(1, d + 1))]
        rows = [[1 if c == k else 0 for c in range(d)] for k in sorted(keep)]
        phis.append(Homomorphism(rows, name=f"p{j}"))
    # only solvable if the union of kept coordinates covers Z^d; else the
    # constraint rank(Z^d) <= sum s_j rank(phi_j) is infeasible with s <= 1
    covered = set()
    for phi in phis:
        for row in phi.mat:
            covered.add(tuple(row).index(1))
    if len(covered) < d:
        return
    s, _ = solve_exponents(phis)
    _check_hbl_on_random_sets(phis, s, rng, d=d, n_sets=8, n_pts=30)


# ---------------------------------------------------------------------------
# Attainability never beats the bound (the theorem's other face)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    N=st.integers(1, 16), cI=st.integers(1, 32), cO=st.integers(1, 32),
    wO=st.integers(3, 24), hO=st.integers(3, 24),
    wF=st.sampled_from([1, 3, 5]), hF=st.sampled_from([1, 3]),
    logM=st.floats(11, 17),
)
def test_blocking_never_beats_thm21(N, cI, cO, wO, hO, wF, hF, logM):
    """The LP blocking's modeled communication must respect the Thm 2.1
    lower bound (within boundary modeling slack): an 'algorithm' below the
    bound would falsify either the bound or the volume model."""
    shape = ConvShape(N=N, c_I=cI, c_O=cO, w_O=wO, h_O=hO, w_F=wF, h_F=hF)
    mem = MemoryModel(M=2.0 ** logM, mode="unified", double_buffer=False)
    blk = optimize_blocking(shape, mem)
    b = single_processor_bound(shape, mem.M_eff)
    # the compulsory-IO term is restated with *touched* input elements:
    # the paper's |I| convention (sw*wO + wF) includes a boundary margin of
    # sw/sh elements the convolution never reads, which the volume model
    # (correctly) does not charge.
    touched_io = (N * cI * (wO - 1 + wF) * (hO - 1 + hF)
                  + shape.filter_size + shape.output_size)
    lb = max(b.terms["per_M"], b.terms["small_filter"], touched_io)
    assert blk.comm_volume() >= 0.9 * lb
