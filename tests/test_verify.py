"""Tests for ``repro.verify``: the static communication auditor, the DMA
hazard simulator, the AST/registry lint, the seeded mutants, and the
``explain(audit=True)`` / plan-construction integrations."""

import dataclasses
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro import ops
from repro.plan import ConvSpec, MatmulSpec, Planner, TPU_V5E
from repro.verify import (audit_access_plan, audit_decision,
                          check_schedule, double_buffered_schedule,
                          validate_execution_plan)
from repro.verify.hazards import DmaEvent, DmaSchedule, READ, START, WAIT
from repro.verify.lint import lint_file, lint_registry, run_lint
from repro.verify.mutants import run_seeded_mutants

PALLAS = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
IM2COL = ops.ExecutionContext(target=TPU_V5E, backend="im2col")


def _assert_exact(decision):
    assert decision.audited is not None
    assert decision.measured_words is not None
    assert decision.audited == pytest.approx(decision.measured_words,
                                             rel=1e-6)


# ---------------------------------------------------------------------------
# audit exactness: the abstract walk reproduces words_fn per registered op
# ---------------------------------------------------------------------------

def test_conv2d_audit_matches_words_fn_both_backends():
    x = jax.ShapeDtypeStruct((8, 64, 58, 58), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((128, 64, 3, 3), jnp.bfloat16)
    for ctx in (PALLAS, IM2COL):
        d = ops.explain("conv2d", ctx, spec_args=(x, w),
                        spec_kw={"stride": (2, 2)}, audit=True)
        _assert_exact(d)


def test_matmul_audit_matches_words_fn_including_fit_shrunk_tiles():
    # the tall-skinny im2col GEMM whose lane-snapped bk the planner must
    # shrink back to feasibility (_fit_matmul_tiles) — audit stays exact
    for m, k, n in ((512, 384, 256), (23328, 576, 64)):
        a = jax.ShapeDtypeStruct((m, k), jnp.bfloat16)
        b = jax.ShapeDtypeStruct((k, n), jnp.bfloat16)
        d = ops.explain("matmul", PALLAS, spec_args=(a, b), audit=True)
        _assert_exact(d)
        ep = d.plan
        prec = ep.target.precision
        bm, bn, bk = ep.tiles
        fp = bm * bk * prec.p_I + bk * bn * prec.p_F + bm * bn * prec.p_O
        assert fp <= ep.target.memory_model().M_eff


def test_conv1d_audit_matches_words_fn():
    x = jax.ShapeDtypeStruct((2, 33, 130), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((4, 130), jnp.bfloat16)
    d = ops.explain("conv1d_causal", PALLAS, spec_args=(x, w), audit=True)
    _assert_exact(d)


def test_attention_audit_matches_words_fn_single_kv_block_corner():
    # n_k == 1 with n_q > 1: K/V are fetched once, not once per q block —
    # the words_fn corner the auditor originally flagged
    q = jax.ShapeDtypeStruct((2, 8, 512, 64), jnp.bfloat16)
    kv = jax.ShapeDtypeStruct((2, 8, 128, 64), jnp.bfloat16)
    d = ops.explain("attention", PALLAS, spec_args=(q, kv, kv), audit=True)
    _assert_exact(d)


def test_attention_decode_paged_audit():
    B, KV, BLOCK, hd, nb, w = 4, 2, 16, 128, 64, 4
    d = ops.explain(
        "attention_decode", PALLAS,
        spec_args=(jax.ShapeDtypeStruct((B, 16, 1, hd), jnp.bfloat16),
                   jax.ShapeDtypeStruct((nb, KV, BLOCK, hd), jnp.bfloat16),
                   jax.ShapeDtypeStruct((nb, KV, BLOCK, hd), jnp.bfloat16),
                   jax.ShapeDtypeStruct((B, w), jnp.int32),
                   jax.ShapeDtypeStruct((B,), jnp.int32)),
        audit=True)
    _assert_exact(d)


def test_audit_decision_flags_wrong_measured_words():
    x = jax.ShapeDtypeStruct((2, 8, 12, 12), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 8, 3, 3), jnp.float32)
    d = ops.explain("conv2d", PALLAS, spec_args=(x, w), audit=True)
    entry = ops.get_backend("pallas").ops["conv2d"]
    ap = entry.access_plan_fn(PALLAS, d.plan, x, w)
    bad = dataclasses.replace(d, measured_words=d.measured_words * 2)
    report = audit_decision(ap, bad)
    assert not report.ok
    assert any("!= words_fn" in p for p in report.problems)


# ---------------------------------------------------------------------------
# DMA hazard simulator
# ---------------------------------------------------------------------------

def test_double_buffered_schedule_is_hazard_free():
    for n in (1, 2, 7):
        assert check_schedule(double_buffered_schedule(n)) == []


def test_read_before_wait_is_h1():
    sched = DmaSchedule(n_slots=2, n_steps=1, name="t", events=(
        DmaEvent(START, 0, 0), DmaEvent(READ, 0, 0)))
    assert any(h.code in ("H1", "H4") for h in check_schedule(sched))


def test_double_start_and_overwrite_are_flagged():
    sched = DmaSchedule(n_slots=2, n_steps=2, name="t", events=(
        DmaEvent(START, 0, 0), DmaEvent(START, 0, 0),
        DmaEvent(WAIT, 0, 0), DmaEvent(READ, 0, 0)))
    assert any(h.code == "H2" for h in check_schedule(sched))


def test_dangling_start_is_h5():
    sched = DmaSchedule(n_slots=2, n_steps=1, name="t", events=(
        DmaEvent(START, 1, 0),))
    assert any(h.code == "H5" for h in check_schedule(sched))


# ---------------------------------------------------------------------------
# seeded mutants: the auditor's own regression harness
# ---------------------------------------------------------------------------

def test_all_seeded_mutants_are_caught():
    results = run_seeded_mutants()
    assert len(results) == 5
    escaped = [name for name, caught, _ in results if not caught]
    assert not escaped, f"mutants escaped the auditor: {escaped}"


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------

def test_lint_flags_kv_repeat_outside_kernels(tmp_path):
    f = tmp_path / "src" / "repro" / "serving" / "bad.py"
    f.parent.mkdir(parents=True)
    f.write_text(textwrap.dedent("""\
        import jax.numpy as jnp

        def grow(k, groups):
            return jnp.repeat(k, groups, axis=1)
    """))
    codes = [v.code for v in lint_file(f, tmp_path)]
    assert "VRF003" in codes


def test_lint_flags_pallas_call_outside_kernels(tmp_path):
    f = tmp_path / "src" / "repro" / "model" / "bad.py"
    f.parent.mkdir(parents=True)
    f.write_text("import jax.experimental.pallas as pl\n"
                 "y = pl.pallas_call(lambda: None, out_shape=None)\n")
    codes = [v.code for v in lint_file(f, tmp_path)]
    assert "VRF001" in codes


def test_lint_allows_kernels_dir(tmp_path):
    f = tmp_path / "src" / "repro" / "kernels" / "ok.py"
    f.parent.mkdir(parents=True)
    f.write_text("import jax.experimental.pallas as pl\n"
                 "y = pl.pallas_call(lambda: None, out_shape=None)\n")
    assert lint_file(f, tmp_path) == []


def test_registry_lint_and_tree_lint_are_clean():
    assert lint_registry() == []
    assert run_lint() == []


# ---------------------------------------------------------------------------
# plan-construction validation
# ---------------------------------------------------------------------------

def test_validate_execution_plan_accepts_real_plans():
    for spec in (ConvSpec(N=4, c_I=8, c_O=16, w_O=10, h_O=10, w_F=3, h_F=3),
                 MatmulSpec(512, 384, 256)):
        assert validate_execution_plan(Planner(TPU_V5E).plan(spec)) == []


def test_validate_execution_plan_rejects_uncovering_grid():
    ep = Planner(TPU_V5E).plan(MatmulSpec(512, 384, 256))
    bad = dataclasses.replace(ep, grid=(1, 1, 1), tiles=(8, 8, 8))
    problems = validate_execution_plan(bad)
    assert any("does not cover" in p for p in problems)


def test_access_plan_dma_schedules_simulate_clean():
    a = jax.ShapeDtypeStruct((512, 384), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((384, 256), jnp.bfloat16)
    d = ops.explain("matmul", PALLAS, spec_args=(a, b), audit=True)
    entry = ops.get_backend("pallas").ops["matmul"]
    ap = entry.access_plan_fn(PALLAS, d.plan, a, b)
    report = audit_access_plan(ap)
    assert report.ok and report.hazards == []
