"""Verify models/sharding.py's hand-written static rule tables against the
dynamic LP path (``gemm_sharding_plan``) — the ROADMAP open item.

Contract: for every weight GEMM the tables cover (``static_rule_gemms``),
either the per-GEMM LP reproduces the table's PartitionSpec exactly, or the
divergence is one of the two *documented* cases where the tables deliberately
encode cross-layer structure the per-GEMM communication model cannot see:

  * paired row-parallelism (``*.wo``, ``*.w_out``, ``*.w_down``): megatron
    pairs a column-parallel projection with a row-parallel one so the block
    needs a single all-reduce and no activation resharding between them; a
    GEMM scored in isolation never sees the pairing.
  * GQA-narrow projections (``attn.wk``/``attn.wv``): n = n_kv_heads*hd is
    small enough that the isolated LP prefers sharding the reduction axis.

Any divergence OUTSIDE these documented cases fails loudly: it means someone
edited a table (or the LP) and production would silently run a non-LP-backed
sharding. The stack-level justification is asserted separately: the LP's own
strategy ranking must still place megatron (the tables' strategy) first at
block level.
"""

from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sharding_opt import rank_lm_shardings
from repro.models import sharding as shd

# Per-data-shard token count and mesh of the reference regime the tables
# target (seq 2048 x batch 2 per data shard; 8x8 = one v5e-64 slice).
TOKENS = 4096
MESH_AXES = (("data", 8), ("model", 8))

# The documented divergence set (see module docstring). Matched by suffix.
KNOWN_DIVERGENT = ("wo", "w_out", "w_down", "wk", "wv")


def _mesh():
    shape = tuple(s for _, s in MESH_AXES)
    return SimpleNamespace(axis_names=tuple(n for n, _ in MESH_AXES),
                           devices=np.empty(shape))


@pytest.mark.parametrize("arch", ["qwen2_5_3b", "jamba_1_5_large",
                                  "xlstm_1_3b"])
def test_static_tables_match_lp_or_documented(arch):
    cfg = get_config(arch)
    mesh = _mesh()
    undocumented = []
    for name, (m, n, k), table_spec in shd.static_rule_gemms(cfg, TOKENS):
        _, _, lp_spec, _ = shd.gemm_sharding_plan(m, n, k, mesh)
        if tuple(lp_spec) == tuple(table_spec):
            continue
        if name.endswith(KNOWN_DIVERGENT):
            continue
        undocumented.append(
            f"  {name}: GEMM m={m} n={n} k={k} — static table says "
            f"{tuple(table_spec)}, LP (gemm_sharding_plan) says "
            f"{tuple(lp_spec)}")
    assert not undocumented, (
        f"{arch}: static sharding rule tables diverge from the LP outside "
        "the documented cases — models/sharding.py and the planner are out "
        "of sync:\n" + "\n".join(undocumented))


def test_documented_divergences_still_diverge():
    """If the LP starts agreeing on a documented case, the exemption list is
    stale — shrink it so the table check regains its teeth there."""
    cfg = get_config("qwen2_5_3b")
    mesh = _mesh()
    stale = []
    for name, (m, n, k), table_spec in shd.static_rule_gemms(cfg, TOKENS):
        if not name.endswith(KNOWN_DIVERGENT):
            continue
        _, _, lp_spec, _ = shd.gemm_sharding_plan(m, n, k, mesh)
        if tuple(lp_spec) == tuple(table_spec):
            stale.append(name)
    # w_down genuinely agrees (big-n row-parallel is LP-optimal in
    # isolation too); it is exempted only for its *.w_out suffix cousins.
    stale = [s for s in stale if not s.endswith("w_down")]
    assert not stale, (f"documented divergences now agree with the LP; "
                      f"remove from KNOWN_DIVERGENT: {stale}")


def test_megatron_ranks_first_at_stack_level():
    """The tables' strategy must stay the LP's block-level winner at the
    reference regime — the aggregate claim the static tables rest on."""
    cfg = get_config("qwen2_5_3b")
    ranking = rank_lm_shardings(TOKENS, cfg.d_model, cfg.d_ff, cfg.n_heads,
                                list(MESH_AXES))
    assert ranking[0][0] == "megatron", (
        f"the parallel LP no longer ranks megatron first at the reference "
        f"regime: {ranking}; the static tables need re-deriving")
