"""Blocking LP tests: feasibility (hypothesis), bound proximity, GEMMINI
regime, parallel grids, and the sharding planner."""

import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bounds import combined_parallel_bound, single_processor_bound
from repro.core.conv_model import (BF16_ACC32, INT8_ACC32, ConvShape,
                                   Precision, resnet50_layers)
from repro.core.parallel_tiling import (ParallelBlocking,
                                        optimize_parallel_blocking)
from repro.core.sharding_opt import plan_conv_sharding, plan_gemm_sharding
from repro.core.tiling import (GEMMINI, Blocking, MemoryModel, matmul_tiles,
                               optimize_blocking)

shape_strategy = st.builds(
    ConvShape,
    N=st.integers(1, 32),
    c_I=st.integers(1, 64),
    c_O=st.integers(1, 64),
    w_O=st.integers(2, 64),
    h_O=st.integers(2, 64),
    w_F=st.sampled_from([1, 3, 5, 7]),
    h_F=st.sampled_from([1, 3, 5]),
    sw=st.sampled_from([1, 2]),
    sh=st.sampled_from([1, 2]),
)


@settings(max_examples=40, deadline=None)
@given(shape=shape_strategy, logM=st.floats(10, 18))
def test_blocking_always_fits(shape, logM):
    """The integer refinement must return a memory-feasible blocking."""
    mem = MemoryModel(M=2.0 ** logM, mode="unified", double_buffer=True)
    blk = optimize_blocking(shape, mem)
    assert blk.fits(mem)
    d = Blocking.lifted_bounds(shape)
    for k, v in blk.b.items():
        assert 1 <= v <= max(d[k], 1)


@settings(max_examples=25, deadline=None)
@given(shape=shape_strategy)
def test_blocking_volume_at_least_compulsory_io(shape):
    """Comm volume can never undercut the output's compulsory traffic."""
    mem = MemoryModel(M=2 ** 14, mode="unified", double_buffer=True)
    blk = optimize_blocking(shape, mem)
    assert blk.comm_volume() >= shape.prec.p_O * shape.output_size - 1e-6


def test_resnet_blocking_near_bound():
    """Fig-2-style check: LP blocking within a small constant of Thm 2.1
    (paper observes 'a constant multiple of the communication bound')."""
    for name, s in resnet50_layers(1000).items():
        s = s.with_precision(INT8_ACC32)
        blk = optimize_blocking(s, GEMMINI)
        lb = single_processor_bound(s, GEMMINI.M_eff).value
        ratio = blk.comm_volume() / lb
        assert ratio < 8.0, f"{name}: ratio {ratio:.2f} too far from bound"


def test_gemmini_split_capacity_respected():
    s = resnet50_layers(1000)["conv2_x"].with_precision(INT8_ACC32)
    blk = optimize_blocking(s, GEMMINI)
    assert blk.in_block_words + blk.filt_block_words <= GEMMINI.M_eff
    assert blk.out_block_words <= GEMMINI.M_acc_eff


def test_blocking_beats_one_row_tiles():
    """The LP blocking must beat a naive degenerate blocking."""
    s = resnet50_layers(100)["conv3_x"]
    mem = MemoryModel(M=2 ** 15, mode="unified", double_buffer=True)
    blk = optimize_blocking(s, mem)
    naive = Blocking({k: 1 for k in blk.b}, s)
    assert blk.comm_volume() < naive.comm_volume()


def test_matmul_tiles_alignment():
    bm, bn, bk = matmul_tiles(4096, 4096, 4096)
    assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0
    # working set fits half of VMEM (double buffering), bf16 in / f32 acc
    from repro.core.tiling import TPU_VMEM_WORDS
    words = 0.5 * bm * bk + 0.5 * bk * bn + 1.0 * bm * bn
    assert words <= TPU_VMEM_WORDS / 2 + 1e-6


@settings(max_examples=20, deadline=None)
@given(shape=shape_strategy, P=st.sampled_from([4, 16, 64, 256]))
def test_parallel_grid_is_exact_factorization(shape, P):
    pb = optimize_parallel_blocking(shape, P)
    assert pb.P <= P
    assert math.prod(pb.grid.values()) == pb.P
    dims = dict(zip(("N", "cI", "cO", "wO", "hO", "wF", "hF"),
                    shape.loop_bounds()))
    for k, g in pb.grid.items():
        assert g <= max(dims[k], 1)


def test_parallel_blocking_decreases_with_P():
    """Per-processor communication must shrink as P grows (the regime where
    the paper's Fig 3 bound 'goes to 0 very quickly')."""
    s = resnet50_layers(1000)["conv2_x"]
    vols = [optimize_parallel_blocking(s, P).comm_per_processor()
            for P in (4, 16, 64, 256)]
    assert all(a >= b * 0.99 for a, b in zip(vols, vols[1:]))


def test_parallel_blocking_beats_im2col():
    """§4.2/Fig 3: 'blocking outperforms im2col considerably' — in the
    growing-P regime (im2col is modeled with an idealized COSMA GEMM, which
    edges out the integer grid at small P; the paper's blocking curves also
    only start at larger P due to its memory-model hypothesis)."""
    from repro.core.algorithms import (blocking_volume_parallel,
                                       im2col_volume_parallel)
    s = resnet50_layers(1000)["conv2_x"]
    for P in (64, 256, 1024):
        assert blocking_volume_parallel(s, P) < im2col_volume_parallel(s, P)


def test_conv_sharding_plan_sensible():
    s = resnet50_layers(1024)["conv2_x"]
    plan = plan_conv_sharding(s, [("data", 16), ("model", 16)])
    assert plan.binding.get("N") == "data"  # batch -> data axis
    assert plan.binding.get("cO") == "model" or plan.binding.get("cI") == "model"
    assert plan.output_spec[0] == "data"


def test_gemm_sharding_plan_megatron_like():
    plan = plan_gemm_sharding(65536, 11008, 2048, [("data", 16), ("model", 16)])
    assert plan.binding.get("N") == "data"
    assert plan.binding.get("cO") == "model"
