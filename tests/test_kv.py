"""Paged KV-cache block allocator (repro.serving.kv): alloc/free round
trips, refcounted prefix sharing, LRU eviction of retained blocks, OOM,
pool sizing from HBM, and randomized admit/finish schedules (hypothesis)
asserting no leak / no double free."""

import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as T
from repro.plan import TPU_V5E
from repro.serving import kv


def test_alloc_free_roundtrip():
    a = kv.BlockAllocator(8)  # block 0 reserved for garbage
    got = [a.alloc() for _ in range(7)]
    assert sorted(got) == list(range(1, 8))
    assert kv.GARBAGE_BLOCK not in got
    with pytest.raises(kv.BlockOOM):
        a.alloc()
    for b in got:
        a.free(b)
    assert a.available() == 7 and a.live_blocks() == 0
    a.check()
    # freed blocks are reusable
    assert sorted(a.alloc() for _ in range(7)) == list(range(1, 8))


def test_double_free_and_bad_ref_raise():
    a = kv.BlockAllocator(4)
    b = a.alloc()
    a.free(b)
    with pytest.raises(ValueError, match="double free"):
        a.free(b)
    with pytest.raises(ValueError, match="not live or evictable"):
        a.ref(b)
    a.check()


def test_pool_must_exceed_reserved():
    with pytest.raises(ValueError):
        kv.BlockAllocator(1)


def test_prefix_chain_full_blocks_only_and_chained_keys():
    toks = list(range(10))
    chain = kv.prefix_chain(toks, 4)
    assert len(chain) == 2  # 10 tokens -> 2 full blocks, partial tail private
    assert chain[0] == (None, (0, 1, 2, 3))
    assert chain[1] == (chain[0], (4, 5, 6, 7))
    # same tokens at a different prefix position hash differently
    other = kv.prefix_chain([9, 9, 9, 9, 4, 5, 6, 7], 4)
    assert other[1][1] == chain[1][1] and other[1] != chain[1]
    assert kv.prefix_chain([1, 2], 4) == []


def test_prefix_sharing_refcounts_and_used_words():
    a = kv.BlockAllocator(8)
    key = (None, (1, 2, 3, 4))
    b1 = a.alloc()
    a.register(b1, key)
    assert a.lookup(key) == b1
    # a second request with the same prefix shares the physical block
    assert a.ref(a.lookup(key)) == b1
    assert a.refcount(b1) == 2
    # shared block counted once in pool occupancy
    assert a.used_words(100.0) == 100.0
    a.free(b1)
    assert a.refcount(b1) == 1  # still held by the other request
    a.check()


def test_registered_block_is_retained_then_revived():
    a = kv.BlockAllocator(4)
    key = (None, (7, 7, 7, 7))
    b = a.alloc()
    a.register(b, key)
    a.free(b)  # rc 0: retained as evictable, not returned to the free list
    assert a.refcount(b) == 0 and a.lookup(key) == b
    assert a.available() == 3  # still allocatable if the pool runs dry
    revived = a.ref(a.lookup(key))
    assert revived == b and a.refcount(b) == 1
    a.check()


def test_eviction_is_lru_and_drops_the_key():
    a = kv.BlockAllocator(4)
    keys = [(None, (i,)) for i in range(3)]
    blocks = []
    for key in keys:
        b = a.alloc()
        a.register(b, key)
        blocks.append(b)
    for b in blocks:
        a.free(b)  # all three evictable, oldest-freed first
    got = [a.alloc() for _ in range(3)]  # forces eviction of all three
    assert got == blocks  # oldest first
    assert all(a.lookup(k) is None for k in keys)
    a.check()


def test_live_blocks_are_never_evicted():
    a = kv.BlockAllocator(4)
    held = a.alloc()
    key = (None, (0,))
    b = a.alloc()
    a.register(b, key)
    a.free(b)
    a.alloc()  # takes the last free block
    a.alloc()  # evicts the retained block...
    with pytest.raises(kv.BlockOOM):
        a.alloc()  # ...but never the held one
    assert a.refcount(held) == 1
    a.check()


def test_block_words_and_plan_pool_blocks():
    cfg = get_smoke("stablelm_1_6b")
    bw = kv.block_words(cfg, 16)
    n_attn = cfg.repeats * sum(1 for k in cfg.pattern if k == "attn")
    assert bw == n_attn * 2 * cfg.n_kv_heads * 16 * cfg.hd * 0.5
    # block-granular footprint: cache_footprint_words rounds max_len up
    assert T.cache_footprint_words(cfg, 24, block_size=16) == \
        T.cache_footprint_words(cfg, 32)
    # unclamped: one garbage block + batch * blocks-per-seq
    assert kv.plan_pool_blocks(cfg, max_len=64, batch_size=4) == 1 + 4 * 4
    # an HBM target caps the pool but never below one full sequence
    import dataclasses
    tiny = dataclasses.replace(TPU_V5E, hbm_words=float(8 * bw))
    assert kv.plan_pool_blocks(cfg, 64, 4, target=tiny) == 1 + 4
    big = dataclasses.replace(TPU_V5E, hbm_words=1e12)
    assert kv.plan_pool_blocks(cfg, 64, 4, target=big) == 1 + 4 * 4


def test_randomized_schedules_no_leak_no_double_free():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(st.data())
    def run(data):
        num_blocks = data.draw(st.integers(4, 24), label="num_blocks")
        a = kv.BlockAllocator(num_blocks)
        holdings = []  # [(blocks, keys_registered)] per admitted request
        for _ in range(data.draw(st.integers(1, 40), label="steps")):
            a.check()
            if holdings and data.draw(st.booleans(), label="finish"):
                blocks, _ = holdings.pop(
                    data.draw(st.integers(0, len(holdings) - 1), label="who"))
                for b in blocks:
                    a.free(b)
                continue
            # admit: a short token stream, shared-prefix-aware reservation
            toks = data.draw(st.lists(st.integers(0, 3), min_size=1,
                                      max_size=12), label="toks")
            need = max(1, -(-len(toks) // 2))
            chain = kv.prefix_chain(toks, 2)
            blocks, keys = [], []
            for key in chain:
                hit = a.lookup(key)
                if hit is None:
                    break
                blocks.append(hit)
            evictable_hits = sum(1 for b in blocks if a.refcount(b) == 0)
            if a.available() - evictable_hits < need - len(blocks):
                continue  # backpressure: engine re-queues the request
            blocks = [a.ref(b) for b in blocks]
            for key in chain[len(blocks):]:
                b = a.alloc()
                a.register(b, key)
                blocks.append(b)
                keys.append(key)
            while len(blocks) < need:
                blocks.append(a.alloc())
            holdings.append((blocks, keys))
        # drain everything: the pool must return to fully-available
        for blocks, _ in holdings:
            for b in blocks:
                a.free(b)
        a.check()
        assert a.live_blocks() == 0
        assert a.available() == num_blocks - 1
        assert a.used_words(1.0) == 0.0

    run()
