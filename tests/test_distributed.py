"""repro.distributed: geometry, counters, and shard-count invariance.

Geometry/counter tests are device-free. The invariance tests execute the
halo-exchange conv on real fake-device meshes: the CI ``distributed`` job
gives the whole pytest process 8 fake devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the tests skip
above the process's device count, so the tier-1 single-device run stays
green), and a subprocess smoke keeps the executed path covered on tier-1.
"""

import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import distributed, ops
from repro.core.conv_model import ConvShape
from repro.core.parallel_tiling import ParallelBlocking
from repro.distributed import DistConvGeometry, dist_grid
from repro.launch import fake_devices, make_conv_mesh
from repro.plan import ConvSpec, ExecutionPlan, Planner, TPU_V5E

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_DEV = len(jax.devices())

XLA = ops.ExecutionContext(target=TPU_V5E, backend="xla")


def _shape(N=4, c_I=8, c_O=6, H=18, W=18, h_F=3, w_F=3, s=1):
    x = jax.random.normal(jax.random.PRNGKey(0), (N, c_I, H, W), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (c_O, c_I, h_F, w_F),
                          jnp.float32)
    return x, w, (s, s)


def _ref(x, w, stride):
    return np.asarray(ops.conv2d(x, w, stride=stride, ctx=XLA))


def _blocking(x, w, stride, grid):
    sh, sw = stride
    N, c_I, H, W = x.shape
    c_O, _, h_F, w_F = w.shape
    shape = ConvShape(N=N, c_I=c_I, c_O=c_O, h_O=(H - h_F) // sh + 1,
                      w_O=(W - w_F) // sw + 1, h_F=h_F, w_F=w_F, sh=sh, sw=sw)
    return ParallelBlocking.from_grid(shape, grid)


# ---------------------------------------------------------------------------
# Geometry (device-free)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h_O,ghO,sh,h_F", [
    (16, 4, 1, 3), (13, 4, 1, 3), (11, 2, 2, 3), (7, 8, 1, 3),
    (56, 4, 1, 1), (112, 4, 2, 7), (9, 3, 3, 5),
])
def test_geometry_padding_invariants(h_O, ghO, sh, h_F):
    g = DistConvGeometry.build(N=2, c_I=4, c_O=4, h_O=h_O, w_O=8, h_F=h_F,
                               w_F=1, sh=sh, sw=1, grid={"hO": ghO})
    # every real output row is assigned to some device ...
    assert g.hOp >= h_O
    # ... and the disjoint owned slabs cover the tight VALID input extent,
    # so ring-wraparound halo rows only ever feed padded outputs
    assert g.Hp >= (h_O - 1) * sh + h_F
    assert g.halo_h == max(h_F - sh, 0)
    assert g.h_ext == (g.bh - 1) * sh + h_F
    # the halo plus owned slab exactly assembles the conv window
    assert g.h_ext <= g.bh * sh + g.halo_h


def test_dist_grid_rejects_unservable_axes():
    with pytest.raises(ValueError, match="cannot split"):
        dist_grid({"cO": 2})
    with pytest.raises(ValueError, match="unknown loop axis"):
        dist_grid({"zz": 2})
    assert dist_grid({"hO": 4, "cI": 2}) == (1, 2, 4, 1)


def test_geometry_validate_rejects_too_fine_spatial_grid():
    # 8 output rows over 8 devices -> 1-row slabs, but a 9-tap filter needs
    # an 8-row halo: more than one neighbor owns it
    g = DistConvGeometry.build(N=1, c_I=1, c_O=1, h_O=8, w_O=8, h_F=9, w_F=1,
                               sh=1, sw=1, grid={"hO": 8})
    with pytest.raises(ValueError, match="too fine"):
        g.validate()


def test_counters_pure_data_parallel_moves_nothing():
    x, w, stride = _shape()
    pb = _blocking(x, w, stride, {"N": 4})
    assert distributed.conv2d_dist_comm_words(x, w, stride, pb) == 0.0
    assert distributed.allgather_comm_words(x, w, stride, pb) > 0.0


def test_counters_halo_and_psum_components():
    x, w, stride = _shape()  # 18x18 input, 3x3 filter -> 16x16 out
    shape = _blocking(x, w, stride, {}).shape
    geom = DistConvGeometry.from_shape(shape, {"hO": 2, "wO": 2})
    # 16 output rows over 2 devices pad to 9-row blocks (the owned slabs
    # must cover the 18-row tight input extent, see geometry.py)
    assert (geom.bh, geom.bw) == (9, 9)
    # rows: 2-row halo over the owned 9-col width; cols: 2 cols over 9+2 rows
    assert geom.halo_words() == 4 * 8 * 2 * 9 + 4 * 8 * 11 * 2
    assert geom.psum_words() == 0.0
    g2 = DistConvGeometry.from_shape(shape, {"cI": 2})
    # ring all-reduce: 2 * (g-1)/g * the f32 output block (unsplit spatial
    # axes keep the whole 18-row padded extent in the slab)
    assert g2.psum_words() == 2 * 0.5 * 4 * 6 * g2.bh * g2.bw
    assert g2.halo_words() == 0.0


def test_counter_scales_with_dtype_words():
    x, w, stride = _shape()
    pb = _blocking(x, w, stride, {"hO": 2})
    full = distributed.conv2d_dist_comm_words(x, w, stride, pb)
    half = distributed.conv2d_dist_comm_words(
        jax.ShapeDtypeStruct(x.shape, jnp.bfloat16),
        jax.ShapeDtypeStruct(w.shape, jnp.bfloat16), stride, pb)
    assert half == full / 2  # halo volume is pure input-stream traffic


# ---------------------------------------------------------------------------
# fake_devices (the one process-wide knob)
# ---------------------------------------------------------------------------

def test_fake_devices_idempotent_and_fails_late():
    assert fake_devices(N_DEV) == N_DEV  # already initialized at this count
    with pytest.raises(RuntimeError, match="already initialized"):
        fake_devices(N_DEV + 1)
    with pytest.raises(ValueError):
        fake_devices(0)


# ---------------------------------------------------------------------------
# Plan format v3+: the parallel section (v4 added attention op specs)
# ---------------------------------------------------------------------------

def test_plan_parallel_section_roundtrip():
    from repro.plan import PLAN_FORMAT_VERSION

    tgt = TPU_V5E.with_mesh((("N", 2), ("cI", 2), ("hO", 2), ("wO", 1)))
    p = Planner(tgt).plan(
        ConvSpec(N=8, c_I=16, c_O=16, w_O=16, h_O=16, w_F=3, h_F=3))
    assert p.parallel is not None
    assert p.parallel.P == 8
    assert math.prod(dict(p.parallel.grid).values()) == 8
    assert p.parallel.comm_words >= 0.0
    d = p.to_dict()
    assert d["version"] == PLAN_FORMAT_VERSION >= 3
    assert ExecutionPlan.from_dict(d) == p


def test_plan_v2_dump_loads_with_parallel_none():
    p = Planner(TPU_V5E).plan(
             ConvSpec(N=4, c_I=8, c_O=8, w_O=8, h_O=8, w_F=3, h_F=3))
    d = p.to_dict()
    d.pop("parallel")
    d["version"] = 2
    restored = ExecutionPlan.from_dict(d)
    assert restored.parallel is None
    assert restored.tiles == p.tiles


def test_single_device_plan_has_no_parallel_section():
    p = Planner(TPU_V5E).plan(
             ConvSpec(N=4, c_I=8, c_O=8, w_O=8, h_O=8, w_F=3, h_F=3))
    assert p.parallel is None and p.sharding is None


# ---------------------------------------------------------------------------
# Dispatch: conv2d_dist through the registry
# ---------------------------------------------------------------------------

def test_conv2d_dist_explain_reports_interdevice_words_vs_parallel_bound():
    x = jax.ShapeDtypeStruct((4, 8, 18, 18), jnp.float32)
    w = jax.ShapeDtypeStruct((6, 8, 3, 3), jnp.float32)
    pb = _blocking(x, w, (1, 1), {"cI": 2, "hO": 2})
    ctx = ops.ExecutionContext(
        target=TPU_V5E.with_mesh((("N", 1), ("cI", 2), ("hO", 2), ("wO", 1))),
        backend="pallas")
    dec = ops.explain("conv2d_dist", ctx, dtype="float32", spec_args=(x, w),
                      spec_kw={"stride": (1, 1), "blocking": pb})
    assert dec.chosen == "pallas"
    assert dec.measured_words == distributed.conv2d_dist_comm_words(
        x, w, (1, 1), pb)
    assert dec.measured_words > 0
    # the ratio divides by the plan's Thm 2.2/2.3 parallel bound, not Thm 2.1
    assert dec.plan.parallel is not None
    assert dec.lower_bound == dec.plan.parallel.lower_bound
    assert "inter-device words" in dec.why()


def test_conv2d_shard_rejects_inexact_windows():
    from repro.kernels.conv2d import conv2d_shard, exact_window

    assert exact_window(18, 18, 3, 3, 1, 1)
    assert not exact_window(18, 18, 3, 3, 2, 2)
    x, w, _ = _shape()
    with pytest.raises(ValueError, match="not exact"):
        conv2d_shard(x, w, stride=(2, 2))


# ---------------------------------------------------------------------------
# Shard-count invariance (needs fake devices; CI distributed job has 8)
# ---------------------------------------------------------------------------

# (P, grid): bitwise grids never split cI — the psum would reassociate the
# reduction; cI grids assert allclose instead (below).
BITWISE_GRIDS = [(1, {}), (2, {"hO": 2}), (4, {"hO": 2, "wO": 2}),
                 (8, {"N": 2, "hO": 2, "wO": 2})]
PSUM_GRIDS = [(2, {"cI": 2}), (8, {"cI": 2, "hO": 2, "wO": 2})]


def _needs(P):
    return pytest.mark.skipif(
        N_DEV < P, reason=f"needs {P} devices (run under "
                          f"XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.mark.parametrize("P,grid", [pytest.param(P, g, marks=_needs(P))
                                    for P, g in BITWISE_GRIDS])
def test_dist_conv_bitwise_invariant_across_shard_counts(P, grid):
    """fp32 halo-exchange conv == the single-device conv bitwise when the
    reduction axis is unsplit, on 1/2/4/8 devices."""
    x, w, stride = _shape()
    pb = _blocking(x, w, stride, grid)
    got = np.asarray(ops.conv2d_dist(x, w, stride=stride, blocking=pb,
                                     ctx=XLA, out_dtype=jnp.float32))
    assert np.array_equal(got, _ref(x, w, stride))


@pytest.mark.parametrize("P,grid", [pytest.param(P, g, marks=_needs(P))
                                    for P, g in PSUM_GRIDS])
def test_dist_conv_psum_grids_allclose(P, grid):
    x, w, stride = _shape()
    pb = _blocking(x, w, stride, grid)
    got = np.asarray(ops.conv2d_dist(x, w, stride=stride, blocking=pb,
                                     ctx=XLA, out_dtype=jnp.float32))
    np.testing.assert_allclose(got, _ref(x, w, stride), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("P,grid,shape_kw", [
    pytest.param(2, {"hO": 2}, dict(H=23, W=19, s=2), marks=_needs(2)),
    pytest.param(4, {"hO": 2, "wO": 2}, dict(H=23, W=19, s=2),
                 marks=_needs(4)),
    pytest.param(8, {"hO": 8}, dict(H=15, W=15), marks=_needs(8)),  # ragged
    pytest.param(4, {"hO": 4}, dict(H=15, W=15), marks=_needs(4)),  # 13/4
])
def test_dist_conv_stride_and_ragged_h_O(P, grid, shape_kw):
    """stride > 1 and non-divisible h_O stay bitwise (no cI split)."""
    x, w, stride = _shape(**shape_kw)
    pb = _blocking(x, w, stride, grid)
    got = np.asarray(ops.conv2d_dist(x, w, stride=stride, blocking=pb,
                                     ctx=XLA, out_dtype=jnp.float32))
    assert np.array_equal(got, _ref(x, w, stride))


@pytest.mark.parametrize("P,grid", [
    pytest.param(4, {"cI": 2, "hO": 2}, marks=_needs(4))])
def test_dist_conv_pallas_local_shards(P, grid):
    """The shard-local conv dispatches to the PR-4 LP-tiled Pallas kernel."""
    x, w, stride = _shape()
    pb = _blocking(x, w, stride, grid)
    ctx = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
    with ops.record_dispatch() as log:
        got = np.asarray(ops.conv2d_dist(x, w, stride=stride, blocking=pb,
                                         ctx=ctx, out_dtype=jnp.float32))
    np.testing.assert_allclose(got, _ref(x, w, stride), rtol=2e-5, atol=2e-5)
    dist_decs = [d for d in log if d.op == "conv2d_dist"]
    local_decs = [d for d in log if d.op == "conv2d"]
    assert dist_decs and dist_decs[0].chosen == "pallas"
    # the shard-local conv went through the registry on the pallas backend
    assert local_decs and local_decs[0].chosen == "pallas"


@pytest.mark.parametrize("P,grid", [
    pytest.param(2, {"wO": 2}, marks=_needs(2)),
    pytest.param(8, {"N": 2, "cI": 2, "hO": 2}, marks=_needs(8))])
def test_allgather_baseline_matches_reference(P, grid):
    x, w, stride = _shape()
    pb = _blocking(x, w, stride, grid)
    got = np.asarray(distributed.allgather_conv(x, w, stride=stride,
                                                blocking=pb,
                                                local_backend="xla"))
    np.testing.assert_allclose(got, _ref(x, w, stride), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("P,grid", [
    pytest.param(4, {"hO": 2, "wO": 2}, marks=_needs(4))])
def test_measured_halo_words_match_lowered_collectives(P, grid):
    """The counter and the lowering share one geometry: the ppermute bytes in
    the compiled HLO equal the predicted halo words exactly."""
    from repro.analysis.roofline import collective_bytes

    x, w, stride = _shape()
    pb = _blocking(x, w, stride, grid)
    mesh = make_conv_mesh(pb)
    # full_output keeps the padded sharded result: the lowering then contains
    # exactly the algorithm's collectives (slicing ragged padding would add
    # small re-layout permutes the counter rightly never charges)
    f = jax.jit(lambda a, b: distributed.halo_conv(
        a, b, stride=stride, blocking=pb, mesh=mesh, local_backend="xla",
        full_output=True))
    hlo = f.lower(x, w).compile().as_text()
    cb = collective_bytes(hlo)
    geom = DistConvGeometry.from_shape(pb.shape, grid)
    assert cb["collective-permute"] == geom.halo_words(p_in=1.0) * 4
    assert cb["all-reduce"] == 0.0  # no cI split -> no psum

    pb2 = _blocking(x, w, stride, {"cI": 2, "hO": 2})
    f2 = jax.jit(lambda a, b: distributed.halo_conv(
        a, b, stride=stride, blocking=pb2, mesh=make_conv_mesh(pb2),
        local_backend="xla", full_output=True))
    cb2 = collective_bytes(f2.lower(x, w).compile().as_text())
    assert cb2["all-reduce"] > 0.0  # the psum is really on the wire

    # single-shard hO with a live row halo: the window's tail rows are a
    # *local* zero fill, never wire traffic — counter still exact
    pb3 = _blocking(x, w, stride, {"wO": 2})
    f3 = jax.jit(lambda a, b: distributed.halo_conv(
        a, b, stride=stride, blocking=pb3, mesh=make_conv_mesh(pb3),
        local_backend="xla", full_output=True))
    cb3 = collective_bytes(f3.lower(x, w).compile().as_text())
    geom3 = DistConvGeometry.from_shape(pb3.shape, {"wO": 2})
    assert cb3["collective-permute"] == geom3.halo_words(p_in=1.0) * 4


def test_psum_counter_is_out_dtype_invariant():
    """The reduction runs on f32 partials before the astype, so the counter
    must not scale psum words with out_dtype (device-free check)."""
    x, w, stride = _shape()
    pb = _blocking(x, w, stride, {"cI": 2})
    w32 = distributed.conv2d_dist_comm_words(x, w, stride, pb,
                                             out_dtype=jnp.float32)
    w16 = distributed.conv2d_dist_comm_words(x, w, stride, pb,
                                             out_dtype=jnp.bfloat16)
    assert w32 == w16 > 0.0


@pytest.mark.parametrize("P,grid", [
    pytest.param(4, {"hO": 2, "cI": 2}, marks=_needs(4))])
def test_dist_conv_differentiates(P, grid):
    x, w, stride = _shape()
    pb = _blocking(x, w, stride, grid)

    def loss(a, b):
        return ops.conv2d_dist(a, b, stride=stride, blocking=pb,
                               ctx=XLA).sum()

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert np.all(np.isfinite(np.asarray(gx)))
    assert np.all(np.isfinite(np.asarray(gw)))


@pytest.mark.slow
def test_dist_conv_subprocess_smoke():
    """Tier-1 coverage of the executed path on a single-device host: a fresh
    subprocess gets 4 fake devices via launch.fake_devices (the supported
    route) and checks halo-exchange == single-device bitwise."""
    code = textwrap.dedent("""
        from repro.launch import fake_devices, make_conv_mesh
        fake_devices(4)
        import jax, jax.numpy as jnp, numpy as np
        from repro import distributed, ops
        from repro.core.conv_model import ConvShape
        from repro.core.parallel_tiling import ParallelBlocking
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 12, 12),
                              jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 3, 3),
                              jnp.float32)
        shape = ConvShape(N=2, c_I=4, c_O=3, h_O=10, w_O=10, h_F=3, w_F=3)
        pb = ParallelBlocking.from_grid(shape, {"hO": 2, "wO": 2})
        from repro.plan import TPU_V5E
        ctx = ops.ExecutionContext(target=TPU_V5E, backend="xla")
        got = ops.conv2d_dist(x, w, blocking=pb, ctx=ctx,
                              out_dtype=jnp.float32)
        ref = ops.conv2d(x, w, ctx=ctx, out_dtype=jnp.float32)
        assert np.array_equal(np.asarray(got), np.asarray(ref))
        print("SUBPROCESS_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)  # prove fake_devices sets it, not the env
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560, env=env)
    assert "SUBPROCESS_OK" in out.stdout, out.stderr[-2000:]
