"""Tests for the unified ``repro.plan`` subsystem: cache identity, JSON
round-trips, kernel parity between ExecutionPlan and legacy tiles, and the
GEMMINI split-buffer footprint discipline."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv_model import INT8_ACC32, Precision, resnet50_layers
from repro.kernels.conv2d import conv2d
from repro.kernels.matmul import matmul
from repro.kernels.ref import conv2d_ref, matmul_ref
from repro.plan import (CPU_INTERPRET, GEMMINI, PLAN_FORMAT_VERSION, TPU_V5E,
                        AttentionSpec, ConvSpec, ExecutionPlan, HardwareTarget,
                        MatmulSpec, Planner, TunedSection, get_target,
                        load_plan_cache, plan, save_plan_cache)

KEY = jax.random.PRNGKey(0)
K2 = jax.random.PRNGKey(1)

CONV = ConvSpec(N=4, c_I=8, c_O=16, w_O=10, h_O=10, w_F=3, h_F=3)
GEMM = MatmulSpec(256, 512, 128, prec=Precision(0.5, 0.5, 1.0))


def _plan(op, target):
    """The post-redesign planning path (no deprecation warning)."""
    return Planner(target).plan(op)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_returns_identical_object():
    assert _plan(CONV, TPU_V5E) is _plan(CONV, TPU_V5E)
    assert _plan(GEMM, TPU_V5E) is _plan(GEMM, TPU_V5E)
    # equal-by-value keys hit the same entry even via fresh objects
    assert _plan(dataclasses.replace(CONV), TPU_V5E) is _plan(CONV, TPU_V5E)
    # a different target is a different plan
    assert _plan(CONV, CPU_INTERPRET) is not _plan(CONV, TPU_V5E)


def test_target_presets_and_registry():
    assert get_target("tpu_v5e") is TPU_V5E
    assert get_target("gemmini").memory == "split"
    with pytest.raises(KeyError):
        get_target("abacus")


# ---------------------------------------------------------------------------
# JSON round-trip + offline reuse
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op,target", [
    (CONV, TPU_V5E),
    (GEMM, TPU_V5E),
    (ConvSpec.from_shape(resnet50_layers(64)["conv3_x"]), GEMMINI),
    (MatmulSpec(4096, 2048, 512), TPU_V5E.with_mesh((("data", 4), ("model", 2)))),
])
def test_plan_json_roundtrip(op, target):
    ep = _plan(op, target)
    back = ExecutionPlan.from_json(ep.to_json())
    assert back == ep
    assert back.op == op and back.target == target
    assert back.tiles == ep.tiles and back.grid == ep.grid
    if target.mesh_axes:
        assert back.sharding == ep.sharding
        assert back.sharding.output_spec == ep.sharding.output_spec


def test_v1_conv_plan_json_upgrades():
    """Pre-spatial-tiling (format v1) conv dumps carried 3-tuple tiles and a
    3-axis grid; loading one must yield a working 5-tuple plan (spatial kept
    whole, the old kernel behavior) instead of crashing the new accessors."""
    ep = _plan(CONV, TPU_V5E)
    d = ep.to_dict()
    d["version"] = 1
    d["tiles"] = d["tiles"][:3]
    d["grid"] = [d["grid"][0], d["grid"][1], d["grid"][4]]
    back = ExecutionPlan.from_dict(d)
    assert back.tiles == tuple(d["tiles"]) + (CONV.h_O, CONV.w_O)
    assert len(back.grid) == 5
    assert back.kernel_footprints()["output"] > 0
    back.pallas_specs()


def test_plan_json_upgrade_chain_v1_to_v6():
    """Walk one conv dump through every historical format. v1 (3-tuple tiles,
    3-axis grid, no ``parallel``), v2 (spatial tiles, still no ``parallel``),
    v3 (``parallel`` present), v4 (no per-operand ``dtypes``), v5 (no
    ``tuned`` section), and current v6 fixtures must all load, and each
    upgraded plan must agree with the live plan on everything its era
    recorded — including the ``tuned`` autotune provenance, round-tripped
    when present and defaulted to None on every pre-v6 format."""
    meshed = TPU_V5E.with_mesh((("data", 4), ("model", 2)))
    ep = _plan(CONV, meshed)
    v6 = ep.to_dict()
    assert v6["version"] == PLAN_FORMAT_VERSION == 6
    assert v6["parallel"] is not None
    assert dict(v6["dtypes"])["accum"] == "float32"
    assert v6["tuned"] is None  # analytic plan: no autotune provenance

    # a tuned v6 dump round-trips its provenance section
    ts = TunedSection(source="roofline", candidates_timed=7,
                      winner_words=123.0, winner_seconds=4.5e-6)
    tuned_ep = dataclasses.replace(ep, tuned=ts)
    back_tuned = ExecutionPlan.from_dict(tuned_ep.to_dict())
    assert back_tuned == tuned_ep and back_tuned.tuned == ts

    # v5 predates the tuned section — the key is absent.
    v5 = {k: v for k, v in v6.items() if k != "tuned"}
    v5["version"] = 5
    # v4 predates the per-operand dtypes section — the key is absent.
    v4 = {k: v for k, v in v5.items() if k != "dtypes"}
    v4["version"] = 4
    # v3 conv dumps are layout-identical to v4 (v4 only added attention).
    v3 = dict(v4, version=3)
    # v2 predates the parallel section entirely — the key is absent.
    v2 = {k: v for k, v in v4.items() if k != "parallel"}
    v2["version"] = 2
    # v1 additionally predates spatial tiling: 3-tuple tiles, 3-axis grid.
    v1 = dict(v2, version=1, tiles=v4["tiles"][:3],
              grid=[v4["grid"][0], v4["grid"][1], v4["grid"][4]])

    no_dtypes = dataclasses.replace(ep, dtypes=())
    assert ExecutionPlan.from_dict(v6) == ep
    assert ExecutionPlan.from_dict(v5) == ep  # tuned defaults to None
    assert ExecutionPlan.from_dict(v4) == no_dtypes
    assert ExecutionPlan.from_dict(v3) == no_dtypes
    assert ExecutionPlan.from_dict(v2) == dataclasses.replace(
        no_dtypes, parallel=None)

    from_v1 = ExecutionPlan.from_dict(v1)
    assert from_v1.parallel is None
    assert from_v1.tiles == tuple(v6["tiles"][:3]) + (CONV.h_O, CONV.w_O)
    assert from_v1.grid == (v6["grid"][0], v6["grid"][1], 1, 1, v6["grid"][4])
    assert from_v1.sharding == ep.sharding

    for back in (from_v1, ExecutionPlan.from_dict(v2),
                 ExecutionPlan.from_dict(v3), ExecutionPlan.from_dict(v4),
                 ExecutionPlan.from_dict(v5)):
        assert back.op == ep.op and back.target == ep.target
        assert back.lower_bound == ep.lower_bound
        assert back.tuned is None
        assert back.kernel_footprints()["output"] > 0
        back.pallas_specs()


def test_attention_plan_v4_roundtrip_and_future_version_rejected():
    ep = _plan(AttentionSpec(B=2, H=8, KV=8, Lq=128, Lk=128, hd=64), TPU_V5E)
    back = ExecutionPlan.from_dict(ep.to_dict())
    assert back == ep and isinstance(back.op, AttentionSpec)
    bad = dict(ep.to_dict(), version=PLAN_FORMAT_VERSION + 1)
    with pytest.raises(ValueError, match="newer than"):
        ExecutionPlan.from_dict(bad)


def test_plan_cache_dump_load(tmp_path):
    ep = _plan(CONV, TPU_V5E)
    path = str(tmp_path / "plans.json")
    assert Planner.cache.save(path) >= 1
    n = Planner.cache.load(path)
    assert n >= 1
    # the loaded entries are equal-by-value to the live ones
    assert _plan(CONV, TPU_V5E) == ep


def test_legacy_planning_shims_warn_and_delegate(tmp_path):
    """The pre-redesign module-level surface still works but deprecates:
    every shim warns (message prefixed "legacy" so CI's -W error leg can
    target them) and delegates to the Planner front door."""
    ep = _plan(CONV, TPU_V5E)
    with pytest.deprecated_call(match="legacy planning API"):
        assert plan(CONV, TPU_V5E) is ep
    path = str(tmp_path / "plans.json")
    with pytest.deprecated_call(match="legacy planning API"):
        assert save_plan_cache(path) == Planner.cache.size() + len(
            __import__("repro.plan.autotune", fromlist=["records"]).records())
    with pytest.deprecated_call(match="legacy planning API"):
        assert load_plan_cache(path) >= 1
    from repro.plan import plan_cache_size, clear_plan_cache
    with pytest.deprecated_call(match="legacy planning API"):
        assert plan_cache_size() == Planner.cache.size()
    with pytest.deprecated_call(match="legacy planning API"):
        clear_plan_cache()
    assert Planner.cache.size() == 0


# ---------------------------------------------------------------------------
# kernel parity: ExecutionPlan vs legacy tiles argument
# ---------------------------------------------------------------------------

def test_conv2d_plan_matches_legacy_tiles():
    x = jax.random.normal(KEY, (2, 8, 12, 12), jnp.float32)
    w = jax.random.normal(K2, (16, 8, 3, 3), jnp.float32)
    spec = ConvSpec(N=2, c_I=8, c_O=16, w_O=10, h_O=10, w_F=3, h_F=3,
                    prec=Precision(1.0, 1.0, 1.0))
    ep = _plan(spec, TPU_V5E)
    got_plan = conv2d(x, w, plan=ep)  # explicit plan handoff: no warning
    with pytest.deprecated_call(match="legacy kernel kwargs"):
        got_tiles = conv2d(x, w, tiles=ep.conv_tiles())
    got_default = conv2d(x, w)  # plans internally through the same cache
    np.testing.assert_array_equal(np.asarray(got_plan), np.asarray(got_tiles))
    np.testing.assert_array_equal(np.asarray(got_plan), np.asarray(got_default))
    np.testing.assert_allclose(np.asarray(got_plan),
                               np.asarray(conv2d_ref(x, w)),
                               rtol=1e-4, atol=1e-4)


def test_matmul_plan_matches_legacy_tiles():
    a = jax.random.normal(KEY, (100, 77), jnp.float32)
    b = jax.random.normal(K2, (77, 130), jnp.float32)
    ep = _plan(MatmulSpec(100, 130, 77, prec=Precision(1.0, 1.0, 1.0)), TPU_V5E)
    got_plan = matmul(a, b, plan=ep)
    with pytest.deprecated_call(match="legacy kernel kwargs"):
        got_tiles = matmul(a, b, tiles=ep.matmul_tiles())
    np.testing.assert_array_equal(np.asarray(got_plan), np.asarray(got_tiles))
    np.testing.assert_allclose(np.asarray(got_plan),
                               np.asarray(matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


def test_kernel_rejects_mismatched_plan():
    x = jax.random.normal(KEY, (2, 8, 12, 12), jnp.float32)
    w = jax.random.normal(K2, (16, 8, 3, 3), jnp.float32)
    wrong = _plan(ConvSpec(N=4, c_I=8, c_O=16, w_O=10, h_O=10, w_F=3, h_F=3),
                  TPU_V5E)
    with pytest.raises(ValueError):
        conv2d(x, w, plan=wrong)
    a = jax.random.normal(KEY, (64, 32), jnp.float32)
    b = jax.random.normal(K2, (32, 48), jnp.float32)
    with pytest.raises(ValueError):
        matmul(a, b, plan=_plan(MatmulSpec(65, 48, 32), TPU_V5E))
    # a plan solved for narrower input streams than the data must be rejected
    bf16_plan = _plan(MatmulSpec(64, 48, 32, prec=Precision(0.5, 0.5, 1.0)),
                      TPU_V5E)
    with pytest.raises(ValueError, match="word input streams"):
        matmul(a, b, plan=bf16_plan)


def test_legacy_shims_retired():
    """The pre-redesign per-module planners are gone; ``repro.plan.plan`` is
    the single entry point (ROADMAP open item closed in PR 2)."""
    import repro.kernels as kernels
    import repro.kernels.conv2d as conv2d_mod
    import repro.kernels.matmul as matmul_mod
    for mod in (kernels, conv2d_mod, matmul_mod):
        assert not hasattr(mod, "plan_conv_tiles")
        assert not hasattr(mod, "plan_tiles")
    # the replacement path produces the same aligned tiles the shims did
    bm, bn, bk = _plan(MatmulSpec(512, 512, 512, prec=Precision(0.5, 0.5, 1.0)),
                       TPU_V5E).matmul_tiles()
    assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0


# ---------------------------------------------------------------------------
# GEMMINI split-buffer discipline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lname", ["conv2_x", "conv4_x"])
def test_gemmini_plans_respect_macc_footprint(lname):
    s = resnet50_layers(1000)[lname].with_precision(INT8_ACC32)
    ep = _plan(ConvSpec.from_shape(s), GEMMINI)
    mem = GEMMINI.memory_model()
    fp = ep.footprints()
    assert fp["input"] + fp["filter"] <= mem.M_eff
    assert fp["output"] <= mem.M_acc_eff
    assert ep.efficiency < 8.0  # stays near the Thm 2.1 bound (paper Fig 4)


# ---------------------------------------------------------------------------
# mesh targets -> sharding plans
# ---------------------------------------------------------------------------

def test_mesh_target_attaches_sharding_plan():
    target = TPU_V5E.with_mesh((("data", 16), ("model", 16)))
    ep = _plan(MatmulSpec(65536, 11008, 2048), target)
    assert ep.sharding is not None
    assert ep.sharding.binding.get("N") == "data"
    assert ep.sharding.binding.get("cO") == "model"
    # single-device plans carry no sharding
    assert _plan(GEMM, TPU_V5E).sharding is None


def test_hardware_target_from_dict_roundtrip():
    t = HardwareTarget.from_dict(GEMMINI.to_dict())
    assert t == GEMMINI


def test_plan_pallas_specs_shapes():
    from jax.experimental.pallas import tpu as pltpu

    ep = _plan(GEMM, TPU_V5E)
    grid, in_specs, out_spec = ep.pallas_specs()
    assert grid == ep.grid and len(in_specs) == 2
    bm, bn, bk = ep.tiles
    # inputs stay in ANY/HBM (the kernels stream double-buffered DMA windows
    # themselves); only the output block is lowered via a blocked BlockSpec
    assert all(s.memory_space == pltpu.ANY for s in in_specs)
    assert out_spec.block_shape == (bm, bn)
    cep = _plan(CONV, TPU_V5E)
    cgrid, _, cout = cep.pallas_specs()
    assert cgrid == cep.grid and len(cgrid) == 5
    bN, bcI, bcO, bh, bw = cep.conv_tiles()
    assert cout.block_shape == (bN, bcO, bh, bw)
