"""The measured autotune stage (``repro.plan.autotune``): frontier
enumeration stays auditable, the roofline-timed winner is deterministic,
records persist through the PlanCache, and the shared resolution path
reports where every plan came from."""

import dataclasses
import json

import pytest

import jax
import jax.numpy as jnp

from repro import ops
from repro.plan import (AutotunePolicy, ConvSpec, MatmulSpec, Planner,
                        TPU_V5E, TuningRecord, predicted_seconds,
                        resolve_plan, target_fingerprint)
from repro.plan import autotune as at
from repro.plan import planner as planner_mod

CONV = ConvSpec(N=4, c_I=8, c_O=16, w_O=14, h_O=14, w_F=3, h_F=3)
MM = MatmulSpec(256, 192, 128)
ROOFLINE = AutotunePolicy(timer="roofline")


@pytest.fixture(autouse=True)
def _fresh_cache():
    Planner.cache.clear()
    yield
    Planner.cache.clear()


# ---------------------------------------------------------------------------
# policy + record plumbing
# ---------------------------------------------------------------------------

def test_policy_coerce():
    assert AutotunePolicy.coerce(None) is None
    assert AutotunePolicy.coerce(False) is None
    assert AutotunePolicy.coerce(True) == AutotunePolicy()
    pol = AutotunePolicy(slack=1.1, timer="roofline")
    assert AutotunePolicy.coerce(pol) is pol
    with pytest.raises(TypeError):
        AutotunePolicy.coerce("yes please")


def test_tuning_record_roundtrip():
    ep = Planner(TPU_V5E).autotune(CONV, policy=ROOFLINE)
    (rec,) = at.records()
    back = TuningRecord.from_dict(rec.to_dict())
    assert back == rec
    assert back.fingerprint == target_fingerprint(TPU_V5E)
    assert ep.tiles == rec.tiles and ep.tuned == rec.tuned


def test_tuning_record_rejects_fingerprint_mismatch():
    Planner(TPU_V5E).autotune(CONV, policy=ROOFLINE)
    (rec,) = at.records()
    d = rec.to_dict()
    d["target_fingerprint"] = "0" * 12
    with pytest.raises(ValueError, match="fingerprint"):
        TuningRecord.from_dict(d)
    d2 = rec.to_dict()
    d2["version"] = at.TUNING_FORMAT_VERSION + 1
    with pytest.raises(ValueError, match="newer"):
        TuningRecord.from_dict(d2)


# ---------------------------------------------------------------------------
# frontier: every timed candidate is auditable and fits VMEM
# ---------------------------------------------------------------------------

def _frontier_survivors(spec):
    """Re-run the search's enumerate->slack/cap filter and return the
    candidate plans the audit gate would see."""
    from repro.ops import registry

    op = at._normalize(at.as_op_spec(spec), TPU_V5E)
    base = planner_mod.analytic_plan(op, TPU_V5E)
    op_name, spec_args, spec_kw = at._op_call(op, TPU_V5E)
    ctx = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
    entry = registry.get_backend("pallas").ops[op_name]
    tiles = (at._conv_candidates(op, TPU_V5E, base.tiles)
             if isinstance(op, ConvSpec)
             else at._matmul_candidates(op, TPU_V5E, base.tiles))
    base_words = float(entry.words_fn(ctx, base, *spec_args, **spec_kw))
    cap = max(ROOFLINE.bound_cap * base.lower_bound, base_words)
    out = []
    for t in tiles:
        cand = at._candidate_plan(base, op, t, 0.0)
        w = float(entry.words_fn(ctx, cand, *spec_args, **spec_kw))
        if w <= ROOFLINE.slack * base_words + 1e-9 and w <= cap + 1e-9:
            out.append((entry, ctx, op_name, spec_args, spec_kw,
                        at._candidate_plan(base, op, t, w), w))
    return out


@pytest.mark.parametrize("spec", [CONV, MM], ids=["conv", "matmul"])
def test_frontier_candidates_all_audit_exact(spec):
    from repro.ops.dispatch import DispatchDecision
    from repro.verify import audit

    survivors = _frontier_survivors(spec)
    assert len(survivors) >= 2  # the frontier is non-trivial
    mem = TPU_V5E.memory_model()
    for entry, ctx, op_name, spec_args, spec_kw, cand, w in survivors:
        ap = entry.access_plan_fn(ctx, cand, *spec_args, **spec_kw)
        decision = DispatchDecision(op=op_name, requested="pallas",
                                    chosen="pallas", plan=cand,
                                    measured_words=w, plan_source="explicit")
        res = audit.audit_decision(ap, decision, target=TPU_V5E)
        assert res.ok, (cand.tiles, res)
        assert ap.scratch_words() <= mem.M_eff  # VMEM feasibility


# ---------------------------------------------------------------------------
# the search: determinism, winner never loses to analytic, counter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [CONV, MM], ids=["conv", "matmul"])
def test_roofline_winner_deterministic(spec):
    first = Planner(TPU_V5E).autotune(spec, policy=ROOFLINE)
    (rec1,) = at.records()
    Planner.cache.clear()
    second = Planner(TPU_V5E).autotune(spec, policy=ROOFLINE)
    (rec2,) = at.records()
    assert rec1 == rec2
    assert first.tiles == second.tiles
    assert first.tuned.source == "roofline"
    assert at.search_count() >= 2  # both searches actually ran


def test_winner_never_slower_than_analytic_on_the_model():
    op = at._normalize(at.as_op_spec(CONV), TPU_V5E)
    base = planner_mod.analytic_plan(op, TPU_V5E)
    base_secs = predicted_seconds(base)
    tuned = Planner(TPU_V5E).autotune(CONV, policy=ROOFLINE)
    assert tuned.tuned.winner_seconds <= base_secs + 1e-12
    assert tuned.tuned.candidates_timed >= 1
    assert tuned.comm_volume == tuned.tuned.winner_words


def test_autotune_memoizes_and_counts_searches():
    n0 = at.search_count()
    p1 = Planner(TPU_V5E).autotune(CONV, policy=ROOFLINE)
    p2 = Planner(TPU_V5E).autotune(CONV, policy=ROOFLINE)
    assert p1 is p2  # record hit materializes the identical cached plan
    assert at.search_count() == n0 + 1


def test_attention_is_unsearchable():
    from repro.plan import AttentionSpec

    spec = AttentionSpec(B=1, H=2, KV=2, Lq=128, Lk=128, hd=64)
    assert not at.supports(spec)
    with pytest.raises(TypeError, match="closed-form"):
        Planner(TPU_V5E).autotune(spec)


# ---------------------------------------------------------------------------
# resolution path: explicit > tuned > analytic, everywhere the same
# ---------------------------------------------------------------------------

def test_resolve_plan_sources():
    p, src = resolve_plan(CONV, TPU_V5E)
    assert src == "analytic" and p.tuned is None
    explicit, src2 = resolve_plan(CONV, TPU_V5E, explicit=p)
    assert explicit is p and src2 == "explicit"
    tuned = Planner(TPU_V5E).autotune(CONV, policy=ROOFLINE)
    p3, src3 = resolve_plan(CONV, TPU_V5E)
    assert src3 == "tuned" and p3 is tuned


def test_resolve_plan_searches_under_policy():
    n0 = at.search_count()
    p, src = resolve_plan(CONV, TPU_V5E, autotune=ROOFLINE)
    assert src == "tuned" and p.tuned is not None
    assert at.search_count() == n0 + 1


def _conv_call():
    x = jax.ShapeDtypeStruct((CONV.N, CONV.c_I, 16, 16), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((CONV.c_O, CONV.c_I, 3, 3), jnp.bfloat16)
    return {"spec_args": (x, w), "spec_kw": {"stride": (1, 1)}}


def test_explain_reports_tuned_vs_analytic():
    ctx = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
    before = ops.explain("conv2d", ctx, **_conv_call())
    assert before.plan_source == "analytic"
    tuning = ops.ExecutionContext(target=TPU_V5E, backend="pallas",
                                  autotune=ROOFLINE)
    dec = ops.explain("conv2d", tuning, **_conv_call())
    assert dec.plan_source == "tuned"
    assert dec.plan.tuned is not None
    assert dec.measured_words == dec.plan.tuned.winner_words
    assert "tuned plan" in dec.why() and "candidates timed" in dec.why()
    # the record now serves every context for the pair, sans policy
    after = ops.explain("conv2d", ctx, **_conv_call())
    assert after.plan_source == "tuned"
    assert after.plan.tiles == dec.plan.tiles


def test_explain_explicit_plan_source():
    ctx = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
    base = ops.explain("conv2d", ctx, **_conv_call())
    again = ops.explain("conv2d", ctx, plan=base.plan, **_conv_call())
    assert again.plan_source == "explicit"
    assert "explicit plan" in again.why()


def test_dispatch_executes_tuned_plan():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 10, 10), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8, 3, 3), jnp.float32)
    ctx = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
    want = ops.conv2d(x, w, ctx=ctx)
    spec = ConvSpec(N=2, c_I=8, c_O=16, w_O=8, h_O=8, w_F=3, h_F=3,
                    prec=TPU_V5E.precision)
    Planner(TPU_V5E).autotune(spec, policy=ROOFLINE)
    tuning = ops.ExecutionContext(target=TPU_V5E, backend="pallas",
                                  autotune=ROOFLINE)
    got = ops.conv2d(x, w, ctx=tuning)
    import numpy as np
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# persistence: the zero-re-search serving contract
# ---------------------------------------------------------------------------

def test_cache_roundtrip_serves_without_research(tmp_path):
    tuned = Planner(TPU_V5E, autotune=ROOFLINE).plan(CONV)
    assert tuned.tuned is not None
    n0 = at.search_count()
    path = str(tmp_path / "cache.json")
    wrote = Planner.cache.save(path)
    assert wrote >= 2  # at least the tuned plan + its record
    Planner.cache.clear()
    assert Planner.cache.size() == 0 and not at.records()
    Planner.cache.load(path)
    served = Planner(TPU_V5E).plan(CONV)  # no policy: the record serves
    assert served.tuned == tuned.tuned and served.tiles == tuned.tiles
    assert at.search_count() == n0  # zero re-searches
    dump = json.loads(open(path).read())
    assert dump["format"] == planner_mod.PLAN_FORMAT_VERSION
    assert len(dump["tuning"]) == 1


def test_clear_records_keeps_analytic_entries():
    analytic = Planner(TPU_V5E).plan(MM)
    Planner(TPU_V5E).autotune(CONV, policy=ROOFLINE)
    at.clear_records()
    assert not at.records()
    # the matmul's analytic entry survived; the conv re-resolves analytic
    assert Planner(TPU_V5E).plan(MM) is analytic
    assert Planner(TPU_V5E).plan(CONV).tuned is None


# ---------------------------------------------------------------------------
# offline cost model + lint
# ---------------------------------------------------------------------------

def test_offline_model_prices_dma_setup():
    from repro.analysis.roofline import (DMA_SETUP_SECONDS,
                                         alpha_beta_seconds, hbm_seconds)
    assert alpha_beta_seconds(1e6, 0) == hbm_seconds(1e6)
    assert alpha_beta_seconds(1e6, 10) == pytest.approx(
        hbm_seconds(1e6) + 10 * DMA_SETUP_SECONDS)
    ep = Planner(TPU_V5E).plan(CONV)
    assert predicted_seconds(ep) > 0.0


def test_lint_vrf015_flags_legacy_kernel_kwargs(tmp_path):
    from repro.verify.lint import lint_file

    bad = tmp_path / "src" / "serving_thing.py"
    bad.parent.mkdir()
    bad.write_text(
        "from repro.kernels.conv2d import conv2d\n"
        "def f(x, w, tgt):\n"
        "    return conv2d(x, w, target=tgt, tiles=(1, 1, 1, 1, 1))\n")
    (viol,) = lint_file(bad, tmp_path)
    assert viol.code == "VRF015"
    assert "['target', 'tiles']" in viol.message
    ok = tmp_path / "src" / "good_thing.py"
    ok.write_text(
        "from repro import ops\n"
        "def f(x, w, ctx):\n"
        "    return ops.conv2d(x, w, ctx=ctx)\n")
    assert lint_file(ok, tmp_path) == []
    # kernels/ keeps its explicit-plan internals without tripping the rule
    kern = tmp_path / "kernels" / "wrap.py"
    kern.parent.mkdir()
    kern.write_text(
        "from .conv2d import conv2d\n"
        "def g(x, w, p):\n"
        "    return conv2d(x, w, plan=p)\n")
    assert lint_file(kern, tmp_path) == []
