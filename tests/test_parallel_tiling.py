"""core.parallel_tiling coverage (paper §4.2): integer grids factorize P
exactly, per-processor blocks cover the shape, and the LP volumes agree with
the fig3 sweep's ``parallel_volumes["blocking"]`` column."""

import math

import pytest

from repro.core.algorithms import parallel_volumes
from repro.core.conv_model import ConvShape, Precision, ceil_div, resnet50_layers
from repro.core.parallel_tiling import (PAR_AXES, ParallelBlocking,
                                        optimize_parallel_blocking)

FIG3_PREC = Precision(1.0, 1.0, 2.0)
FIG3_SHAPES = {k: v.with_precision(FIG3_PREC)
               for k, v in resnet50_layers(1000).items()
               if k in ("conv1", "conv2_x")}
FIG3_P = (4, 16, 64, 256, 1024)

SMALL = ConvShape(N=8, c_I=16, c_O=32, w_O=14, h_O=14, w_F=3, h_F=3)


@pytest.mark.parametrize("lname", sorted(FIG3_SHAPES))
@pytest.mark.parametrize("P", FIG3_P)
def test_grid_multiplies_to_exactly_P(lname, P):
    pb = optimize_parallel_blocking(FIG3_SHAPES[lname], P)
    assert math.prod(pb.grid.values()) == P
    assert pb.P == P


@pytest.mark.parametrize("lname", sorted(FIG3_SHAPES))
@pytest.mark.parametrize("P", (4, 64, 1024))
def test_blocks_cover_the_shape(lname, P):
    s = FIG3_SHAPES[lname]
    pb = optimize_parallel_blocking(s, P)
    dims = dict(zip(PAR_AXES, s.loop_bounds()))
    for ax in PAR_AXES:
        # grid never over-splits an axis ...
        assert 1 <= pb.grid[ax] <= dims[ax]
        # ... and ceil blocks tile it completely
        assert pb.block(ax) * pb.grid[ax] >= dims[ax]
        assert pb.block(ax) == ceil_div(dims[ax], pb.grid[ax])


@pytest.mark.parametrize("lname", sorted(FIG3_SHAPES))
@pytest.mark.parametrize("P", FIG3_P)
def test_lp_volume_matches_fig3_blocking_column(lname, P):
    s = FIG3_SHAPES[lname]
    M = float(2 ** 20)
    v = parallel_volumes(s, P, M)
    pb = optimize_parallel_blocking(s, P)
    assert pb.comm_per_processor() == pytest.approx(v["blocking"], rel=1e-12)


def test_restrict_axes_only_splits_allowed_axes():
    pb = optimize_parallel_blocking(SMALL, 8, restrict_axes=("N", "cI"))
    for ax in PAR_AXES:
        if ax not in ("N", "cI"):
            assert pb.grid[ax] == 1
    assert pb.P == 8


def test_from_grid_fills_ones_and_validates():
    pb = ParallelBlocking.from_grid(SMALL, {"hO": 2, "cI": 4})
    assert pb.grid["hO"] == 2 and pb.grid["cI"] == 4
    assert all(pb.grid[ax] == 1 for ax in PAR_AXES if ax not in ("hO", "cI"))
    assert pb.P == 8
    with pytest.raises(ValueError):
        ParallelBlocking.from_grid(SMALL, {"bogus": 2})


def test_comm_zero_only_without_real_traffic():
    # pure data parallelism on N: every processor still gathers the filter
    # and its input slab beyond what it owns -> nonneg, finite
    pb = ParallelBlocking.from_grid(SMALL, {"N": 8})
    assert pb.comm_per_processor() >= 0.0
    # splitting a reduction axis doubles the output traffic
    red = ParallelBlocking.from_grid(SMALL, {"cI": 2})
    unsplit = ParallelBlocking.from_grid(SMALL, {"cO": 2})
    assert red.comm_per_processor() > 0.0
    assert unsplit.out_block_words < red.out_block_words * 2 + 1


def test_imbalance_is_one_when_divisible():
    s = ConvShape(N=8, c_I=16, c_O=32, w_O=16, h_O=16, w_F=3, h_F=3)
    pb = ParallelBlocking.from_grid(s, {"N": 4, "cO": 2})
    assert pb.imbalance() == pytest.approx(1.0)
