"""End-to-end behaviour tests for the paper's system: the bound -> tiling ->
kernel -> model -> distribution chain working together."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BF16_ACC32, GEMMINI, INT8_ACC32, ConvShape,
                        MemoryModel, optimize_blocking, resnet50_layers,
                        single_processor_bound)
from repro.core.algorithms import parallel_volumes, single_processor_volumes


def test_volumes_respect_lower_bound_single_processor():
    """No modeled algorithm may beat the Thm 2.1 bound (within modeling
    slack at the boundary)."""
    for name, s in resnet50_layers(100).items():
        for M in (2 ** 14, 2 ** 18):
            v = single_processor_volumes(s, M)
            lb = v.pop("lower_bound")
            for alg, vol in v.items():
                assert vol >= 0.95 * lb, f"{name} {alg} below bound at M={M}"


def test_paper_fig2_ordering():
    """Fig 2 qualitative claims: blocking tracks the bound closest; naive is
    worst; FFT/Winograd scale worse than blocking/im2col for conv1."""
    s = resnet50_layers(1000)["conv1"]
    v = single_processor_volumes(s, 2 ** 18)
    assert v["blocking"] <= v["im2col"]
    assert v["im2col"] <= v["fft"]
    assert v["naive"] == max(x for k, x in v.items() if k != "lower_bound")


def test_paper_fig3_ordering():
    """Fig 3: 'blocking outperforms im2col considerably... im2col performs
    orders of magnitude better [than FFT/Winograd]'."""
    s = resnet50_layers(1000)["conv2_x"]
    v = parallel_volumes(s, 64, 2 ** 20)
    assert v["blocking"] < v["im2col"]
    assert v["im2col"] * 3 < v["fft"]
    assert v["im2col"] * 3 < v["winograd"]


def test_gemmini_regime_tiling_beats_vendor_proxy():
    """§5 analogue: the LP tiling must use less modeled communication than a
    'vendor-style' max-square heuristic tiling on the ResNet50 sizes."""
    from repro.core.tiling import Blocking

    wins = 0
    for name, s in resnet50_layers(1000).items():
        s = s.with_precision(INT8_ACC32)
        lp = optimize_blocking(s, GEMMINI)
        # vendor proxy: greedy channel-first tile (what GEMMINI's supplied
        # tiler roughly does: fill the array dims, then grow channels)
        d = Blocking.lifted_bounds(s)
        vendor = {k: 1 for k in d}
        for k in ("cO", "cI", "wO", "hO", "N"):
            while vendor[k] * 2 <= d[k]:
                vendor[k] *= 2
                if not Blocking(vendor, s).fits(GEMMINI):
                    vendor[k] //= 2
                    break
        vblk = Blocking(vendor, s)
        if lp.comm_volume() <= vblk.comm_volume():
            wins += 1
    assert wins >= 4, f"LP tiling won only {wins}/5 ResNet50 layers"


def test_mixed_precision_tightens_bound():
    """Lower precisions reduce the bound (the motivation for the paper's
    mixed-precision analysis and our int8 wire compression)."""
    s = resnet50_layers(100)["conv2_x"]
    M = 2 ** 16
    full = single_processor_bound(s, M).value
    bf16 = single_processor_bound(s.with_precision(BF16_ACC32), M).value
    int8 = single_processor_bound(s.with_precision(INT8_ACC32), M).value
    assert int8 < bf16 < full


def test_less_memory_never_less_communication():
    s = resnet50_layers(64)["conv3_x"].with_precision(BF16_ACC32)
    m1 = MemoryModel(M=2 ** 18, mode="unified", double_buffer=True)
    m2 = MemoryModel(M=2 ** 17, mode="unified", double_buffer=True)
    v1 = optimize_blocking(s, m1).comm_volume()
    v2 = optimize_blocking(s, m2).comm_volume()
    assert v2 >= v1 * 0.99


def test_end_to_end_conv_through_kernel():
    """ConvShape -> LP tiles -> Pallas kernel -> matches oracle."""
    from repro.kernels.conv2d import conv2d
    from repro.kernels.ref import conv2d_ref

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 8, 18, 18), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8, 3, 3), jnp.float32)
    got = conv2d(x, w, stride=(1, 1))
    want = conv2d_ref(x, w, stride=(1, 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
