"""Training-substrate tests: optimizer behavior, loss descent, gradient
accumulation equivalence, compression, fault tolerance, checkpoint resume."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data.pipeline import DataConfig, SyntheticSource, make_source
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train.optimizer import (AdamWConfig, AdamWState, apply_updates,
                                   global_norm, init_state, schedule)
from repro.train.trainer import (TrainConfig, Trainer, make_train_step,
                                 quantize_int8)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([4.0, -3.0])}
    state = init_state(params)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, clip_norm=100.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(schedule(cfg, jnp.asarray(110))) == pytest.approx(0.1, rel=1e-2)


def test_grad_clip_applied():
    params = {"w": jnp.zeros((3,))}
    state = init_state(params)
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    _, _, m = apply_updates(params, {"w": jnp.asarray([1e3, 0, 0])}, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(1e3)


def test_quantize_int8_bounded_error():
    g = {"a": jax.random.normal(KEY, (256,)) * 5.0}
    q = quantize_int8(g)
    scale = float(jnp.max(jnp.abs(g["a"]))) / 127.0
    assert float(jnp.max(jnp.abs(q["a"] - g["a"]))) <= scale / 2 + 1e-6


# ---------------------------------------------------------------------------
# training loop
# ---------------------------------------------------------------------------

def _mini_trainer(tmp, steps=24, **tkw):
    cfg = get_smoke("stablelm_1_6b")
    dcfg = DataConfig(batch_size=8, seq_len=32, vocab_size=cfg.vocab_size)
    tcfg = TrainConfig(steps=steps, log_every=0, ckpt_dir=tmp,
                       ckpt_every=8, **tkw)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    return Trainer(cfg, ocfg, tcfg, dcfg)


def test_loss_decreases(tmp_path):
    tr = _mini_trainer(str(tmp_path))
    hist = tr.run()
    assert hist["loss"][-1] < hist["loss"][0] - 0.2


def test_grad_accumulation_equivalent():
    """microbatches=2 must equal microbatches=1 on the same global batch."""
    cfg = dataclasses.replace(get_smoke("stablelm_1_6b"),
                              compute_dtype="float32")
    from repro.train.optimizer import init_state
    params = T.init_params(KEY, cfg)
    opt = init_state(params)
    batch = {"tokens": jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size)}
    ocfg = AdamWConfig(lr=1e-3)
    s1 = jax.jit(make_train_step(cfg, ocfg, TrainConfig(microbatches=1)))
    s2 = jax.jit(make_train_step(cfg, ocfg, TrainConfig(microbatches=2)))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(d)) < 1e-4


def test_compressed_grads_still_learn(tmp_path):
    tr = _mini_trainer(str(tmp_path), compress_grads=True)
    hist = tr.run()
    assert hist["loss"][-1] < hist["loss"][0] - 0.15


def test_resume_from_checkpoint(tmp_path):
    tmp = str(tmp_path)
    tr1 = _mini_trainer(tmp, steps=16)
    tr1.run()
    assert ckpt.latest_step(tmp) == 16
    # new trainer resumes at 16 and continues to 24
    tr2 = _mini_trainer(tmp, steps=24)
    tr2.resume_or_init()
    assert tr2.start_step == 16
    hist = tr2.run()
    assert len(hist["loss"]) == 8  # only the remaining steps ran


# ---------------------------------------------------------------------------
# checkpoint substrate
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 5, tree, extra={"note": "x"})
    restored, extra = ckpt.restore(str(tmp_path), tree)
    assert extra["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_rotation(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.committed_steps(str(tmp_path)) == [4, 5]


def test_checkpoint_ignores_uncommitted(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    # fake a crashed write: directory without commit marker
    os.makedirs(tmp_path / "step_00000002")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto a different 'mesh' (here: sharded layouts on 1 device —
    the API path real elastic restarts use)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    ckpt.save(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1,), ("model",))
    sh = {"w": NamedSharding(mesh, P("model", None))}
    restored, _ = ckpt.restore(str(tmp_path), tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_and_shard_disjoint():
    c0 = DataConfig(batch_size=4, seq_len=16, vocab_size=128, seed=7,
                    shard_index=0, shard_count=2)
    c1 = dataclasses.replace(c0, shard_index=1)
    a = SyntheticSource(c0).batch(3)["tokens"]
    b = SyntheticSource(c0).batch(3)["tokens"]
    c = SyntheticSource(c1).batch(3)["tokens"]
    np.testing.assert_array_equal(a, b)  # deterministic
    assert not np.array_equal(a, c)  # shards differ


def test_synthetic_is_learnable():
    """The Markov structure must make loss drop below ln(V) quickly — the
    property the train examples rely on."""
    src = SyntheticSource(DataConfig(batch_size=4, seq_len=64, vocab_size=64))
    toks = src.batch(0)["tokens"]
    assert toks.min() >= 0 and toks.max() < 64


def test_file_source_roundtrip(tmp_path):
    data = np.arange(10000, dtype=np.uint32) % 97
    path = str(tmp_path / "tokens.bin")
    data.tofile(path)
    cfg = DataConfig(batch_size=2, seq_len=8, vocab_size=97, path=path)
    src = make_source(cfg)
    b0 = src.batch(0)["tokens"]
    assert b0.shape == (2, 8)
    np.testing.assert_array_equal(b0.ravel(), data[:16].astype(np.int32))
