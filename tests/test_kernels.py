"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret=True) vs the
pure-jnp oracles in kernels/ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv_model import Precision
from repro.kernels import ref
from repro.kernels.conv1d import conv1d_causal
from repro.kernels.conv2d import conv2d
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.plan import ConvSpec, MatmulSpec, Planner, TPU_V5E

KEY = jax.random.PRNGKey(0)
K2 = jax.random.PRNGKey(1)


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [
    (8, 128, 128), (256, 512, 128), (100, 300, 77), (512, 512, 512),
    (1, 128, 64), (130, 257, 129),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, n, k, dtype):
    a = jax.random.normal(KEY, (m, k), dtype)
    b = jax.random.normal(K2, (k, n), dtype)
    got = matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


def test_matmul_tiles_divide_padded_problem():
    for (m, n, k) in [(4096, 4096, 4096), (512, 11008, 2048), (7, 13, 5)]:
        bm, bn, bk = Planner(TPU_V5E).plan(
            MatmulSpec(m, n, k, prec=Precision(0.5, 0.5, 1.0))).matmul_tiles()
        assert bm >= 1 and bn >= 1 and bk >= 1


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    (2, 8, 16, 12, 12, 3, 3, 1, 1),
    (4, 3, 32, 20, 20, 7, 7, 2, 2),
    (1, 16, 8, 9, 9, 1, 1, 1, 1),
    (3, 5, 7, 11, 13, 3, 5, 1, 2),
    (2, 4, 4, 8, 8, 2, 2, 2, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv2d_sweep(case, dtype):
    N, cI, cO, H, W, hF, wF, sh, sw = case
    x = jax.random.normal(KEY, (N, cI, H, W), dtype)
    w = jax.random.normal(K2, (cO, cI, hF, wF), dtype)
    got = conv2d(x, w, stride=(sh, sw))
    want = ref.conv2d_ref(x, w, stride=(sh, sw))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


def test_conv2d_tiles_from_lp_fit_vmem():
    """The kernel tiles (halo windows included) must fit inside half-VMEM."""
    from repro.core.tiling import TPU_VMEM_WORDS
    N, cI, cO, hO, wO, hF, wF = 64, 64, 256, 56, 56, 3, 3
    spec = ConvSpec(N=N, c_I=cI, c_O=cO, w_O=wO, h_O=hO, w_F=wF, h_F=hF,
                    prec=Precision(0.5, 0.5, 1.0))
    ep = Planner(TPU_V5E).plan(spec)
    bN, bcI, bcO, bh, bw = ep.conv_tiles()
    assert all(b >= 1 for b in ep.conv_tiles())
    fp = ep.kernel_footprints()
    words = (0.5 * bN * bcI * ((bh - 1) + hF) * ((bw - 1) + wF)
             + 0.5 * bcO * bcI * hF * wF + 1.0 * bN * bcO * bh * bw)
    assert words == pytest.approx(sum(fp.values()))
    assert words <= TPU_VMEM_WORDS / 2 * 1.01


@pytest.mark.parametrize("tiles", [
    (1, 4, 8, 5, 7),      # spatial blocks with halo overlap, ragged edges
    (2, 4, 8, 1, 23),     # single-row blocks (maximal halo reuse on h)
    (1, 4, 8, 23, 4),     # w-only spatial tiling
])
@pytest.mark.parametrize("stride", [(1, 1), (2, 2), (2, 1)])
def test_conv2d_spatial_tiling_agrees(tiles, stride):
    """Halo-aware spatial tiling vs the XLA oracle: stride > 1, block sizes
    that do not divide h_O/w_O, and windows sharing h_F - s row halos."""
    x = jax.random.normal(KEY, (2, 4, 25, 25), jnp.float32)
    w = jax.random.normal(K2, (8, 4, 3, 3), jnp.float32)
    with pytest.deprecated_call(match="legacy kernel kwargs"):
        got = conv2d(x, w, stride=stride, tiles=tiles)
    want = ref.conv2d_ref(x, w, stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_no_halo_when_unit_filter():
    """h_F == w_F == 1: windows are disjoint (halo = h_F - s <= 0), spatial
    tiling degenerates to plain blocking and must still agree."""
    x = jax.random.normal(KEY, (2, 6, 16, 16), jnp.float32)
    w = jax.random.normal(K2, (8, 6, 1, 1), jnp.float32)
    for stride in ((1, 1), (2, 2)):
        with pytest.deprecated_call(match="legacy kernel kwargs"):
            got = conv2d(x, w, stride=stride, tiles=(1, 6, 8, 3, 5))
        want = ref.conv2d_ref(x, w, stride=stride)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_conv2d_plan_tiles_spatial_when_footprint_demands():
    """A batch-1 megapixel conv cannot shrink N or c_O any further, so the
    LP has to block the spatial axes — the v1 full-extent kernel could not
    have run this shape inside VMEM at all."""
    spec = ConvSpec(N=1, c_I=8, c_O=8, w_O=512, h_O=512, w_F=3, h_F=3,
                    prec=Precision(0.5, 0.5, 1.0))
    ep = Planner(TPU_V5E).plan(spec)
    bN, bcI, bcO, bh, bw = ep.conv_tiles()
    assert bh < 512 or bw < 512
    from repro.core.tiling import TPU_VMEM_WORDS
    assert sum(ep.kernel_footprints().values()) <= TPU_VMEM_WORDS / 2 * 1.01


# ---------------------------------------------------------------------------
# conv1d
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,D,K", [(2, 16, 32, 4), (3, 100, 64, 3),
                                     (1, 7, 5, 2), (2, 33, 130, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_sweep(B, L, D, K, dtype):
    x = jax.random.normal(KEY, (B, L, D), dtype)
    w = jax.random.normal(K2, (K, D), dtype)
    got = conv1d_causal(x, w)
    want = ref.conv1d_causal_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,Lq,Lk,Dh,causal,off", [
    (1, 4, 4, 64, 64, 32, True, 0),
    (2, 8, 2, 33, 33, 64, True, 0),
    (1, 2, 2, 1, 100, 32, True, 99),   # decode: 1 query vs deep cache
    (1, 2, 1, 50, 70, 16, False, 0),   # encoder + ragged padding
    (1, 1, 1, 200, 200, 128, True, 0),
])
def test_flash_attention_sweep(B, H, Hkv, Lq, Lk, Dh, causal, off):
    q = jax.random.normal(KEY, (B, H, Lq, Dh), jnp.float32) * 0.3
    k = jax.random.normal(K2, (B, Hkv, Lk, Dh), jnp.float32) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, Lk, Dh), jnp.float32)
    want = ref.flash_attention_ref(q, k, v, causal=causal, q_offset=off)
    rep = H // Hkv
    kk = jnp.repeat(k, rep, axis=1).reshape(B * H, Lk, Dh)
    vv = jnp.repeat(v, rep, axis=1).reshape(B * H, Lk, Dh)
    got = flash_attention(q.reshape(B * H, Lq, Dh), kk, vv, causal=causal,
                          q_offset=off, block_q=32, block_k=32
                          ).reshape(B, H, Lq, Dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_gqa_group_folding():
    """q_seq_len folds GQA query groups onto the sequence axis: positions
    restart per group, so the grouped call matches per-head flash calls
    without ever repeating K/V (backend-agreement lives in test_ops)."""
    B, Hkv, g, Lq, Dh = 1, 2, 3, 40, 16
    q = jax.random.normal(KEY, (B, Hkv, g, Lq, Dh), jnp.float32) * 0.3
    k = jax.random.normal(K2, (B, Hkv, Lq, Dh), jnp.float32) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, Lq, Dh), jnp.float32)
    got = flash_attention(q.reshape(B * Hkv, g * Lq, Dh),
                          k.reshape(B * Hkv, Lq, Dh),
                          v.reshape(B * Hkv, Lq, Dh),
                          causal=True, q_seq_len=Lq, block_q=32, block_k=32)
    want = jnp.stack([
        flash_attention(q[:, :, j].reshape(B * Hkv, Lq, Dh),
                        k.reshape(B * Hkv, Lq, Dh),
                        v.reshape(B * Hkv, Lq, Dh),
                        causal=True, block_q=32, block_k=32)
        for j in range(g)], axis=1)  # (B*Hkv, g, Lq, Dh)
    np.testing.assert_allclose(
        np.asarray(got).reshape(B * Hkv, g, Lq, Dh), np.asarray(want),
        rtol=2e-3, atol=2e-3)
