"""HBL machinery tests: the lattice, the paper's constraint table, and the
optimal exponents for 7NL CNN / the lifted small-filter form / matmul."""

import numpy as np
import pytest

from repro.core.hbl import (Homomorphism, conv7nl_lifted_phis, conv7nl_phis,
                            hbl_constraints, matmul_phis, solve_exponents,
                            subgroup_lattice)


def test_conv7nl_kernel_ranks():
    phi_I, phi_F, phi_O = conv7nl_phis(1, 1)
    assert phi_I.kernel().rank == 3  # (i3, i4, i5, -i4, -i5) free in 3 dims
    assert phi_F.kernel().rank == 3  # (i1, i4, i5)
    assert phi_O.kernel().rank == 3  # (i2, i6, i7)


def test_paper_constraint_table():
    """§3.1: deduped constraints must include the paper's four:
    1<=sI+sF, 1<=sI+sO, 1<=sF+sO, 2<=sI+sF+sO (as normalized rank rows)."""
    cons = hbl_constraints(conv7nl_phis(1, 1))
    normalized = set()
    for rk, imgs in cons:
        normalized.add(tuple(r / rk for r in imgs))
    # 1 <= sI + sO  -> row (1, 0, 1)
    assert (1.0, 0.0, 1.0) in normalized
    assert (1.0, 1.0, 0.0) in normalized
    assert (0.0, 1.0, 1.0) in normalized
    # 2 <= sI + sF + sO -> normalized row (1/2, 1/2, 1/2)
    assert (0.5, 0.5, 0.5) in normalized


@pytest.mark.parametrize("sw,sh", [(1, 1), (2, 2), (2, 1), (3, 2)])
def test_conv7nl_exponent_sum_is_2(sw, sh):
    """The minimal HBL exponent sum is 2 regardless of stride -> the
    Omega(G/M) second bound of Thm 2.1."""
    _, total = solve_exponents(conv7nl_phis(sw, sh))
    assert abs(total - 2.0) < 1e-9


def test_lifted_exponents_are_half():
    """Lemma 3.4's lifted maps form a tensor contraction: s = (1/2,1/2,1/2)."""
    s, total = solve_exponents(conv7nl_lifted_phis())
    assert abs(total - 1.5) < 1e-9
    np.testing.assert_allclose(s, [0.5, 0.5, 0.5], atol=1e-9)


def test_matmul_loomis_whitney():
    s, total = solve_exponents(matmul_phis())
    assert abs(total - 1.5) < 1e-9
    np.testing.assert_allclose(s, [0.5, 0.5, 0.5], atol=1e-9)


def test_lattice_closure_contains_sums_and_intersections():
    phis = conv7nl_phis(1, 1)
    kernels = [p.kernel() for p in phis]
    lat = subgroup_lattice(kernels)
    ranks = sorted(s.rank for s in lat)
    # kernels rank 3; pairwise sums rank 5..6; triple sum rank 7
    assert 7 in ranks  # full space reached
    assert all(r >= 1 for r in ranks)
    for a in kernels:
        assert a in lat


def test_feasibility_of_paper_exponents():
    """s_j = 2 p_j / p_T satisfies every lattice constraint when the triangle
    condition holds (Lemma 3.2's choice)."""
    phis = conv7nl_phis(1, 1)
    cons = hbl_constraints(phis)
    for (pI, pF, pO) in [(1, 1, 1), (1, 1, 2), (0.5, 0.5, 1), (0.25, 0.25, 0.5)]:
        pT = pI + pF + pO
        s = (2 * pI / pT, 2 * pF / pT, 2 * pO / pT)
        if max(pI, pF, pO) > pT - max(pI, pF, pO):
            continue  # triangle fails; Lemma 3.3 regime
        for rk, imgs in cons:
            assert rk <= sum(si * ri for si, ri in zip(s, imgs)) + 1e-9


def test_identity_map_requires_s_1():
    ident = Homomorphism([[1, 0], [0, 1]], "id")
    s, total = solve_exponents([ident])
    assert abs(total - 1.0) < 1e-9
