"""Dry-run machinery tests.

The lowering pipeline itself is exercised on a small fake-device mesh in a
subprocess (jax locks the device count on first init, so the 8-device run
cannot share this process). The HLO collective parser and the roofline math
are tested in-process.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.roofline import (ICI_BW, PEAK_FLOPS, Roofline,
                                     collective_bytes, model_flops)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_collective_parser_counts_kinds():
    hlo = textwrap.dedent("""
      %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
      %ag = bf16[64,512]{1,0} all-gather(%y), dimensions={1}
      %rs = f32[32]{0} reduce-scatter(%z), dimensions={0}, to_apply=%add
      %a2a = bf16[16,16]{1,0} all-to-all(%w), dimensions={0}
      %cp = u32[8]{0} collective-permute(%v), source_target_pairs={{0,1}}
      %other = f32[4]{0} add(%a, %b)
    """)
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4 * 2  # 2x wire multiplier
    assert out["all-gather"] == 64 * 512 * 2
    assert out["reduce-scatter"] == 32 * 4
    assert out["all-to-all"] == 16 * 16 * 2
    assert out["collective-permute"] == 8 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_collective_parser_skips_async_done():
    hlo = ("%s = f32[1024]{0} all-reduce-start(%x), to_apply=%add\n"
           "%d = f32[1024]{0} all-reduce-done(%s)\n")
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 1024 * 4 * 2  # start counted once


def test_roofline_terms_and_dominance():
    r = Roofline(arch="a", shape="train_4k", mesh="16x16", chips=256,
                 hlo_flops=256 * PEAK_FLOPS,  # exactly 1s of compute
                 hlo_bytes=0.0, wire_bytes_per_chip=ICI_BW / 2,
                 collectives={}, model_flops=0.5 * 256 * PEAK_FLOPS,
                 bytes_per_device={})
    assert r.compute_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "compute"
    assert r.mfu == pytest.approx(0.5)
    assert r.useful_flops_frac == pytest.approx(0.5)


def test_model_flops_regimes():
    train = model_flops("qwen2_5_3b", "train_4k")
    prefill = model_flops("qwen2_5_3b", "prefill_32k")
    decode = model_flops("qwen2_5_3b", "decode_32k")
    assert train == pytest.approx(6 * 3.40e9 * 4096 * 256, rel=0.02)
    assert prefill == pytest.approx(2 * 3.40e9 * 32768 * 32, rel=0.02)
    assert decode == pytest.approx(2 * 3.40e9 * 128, rel=0.02)


def test_input_specs_shapes():
    from repro.launch import specs as sp

    tr = sp.input_specs("qwen2_5_3b", "train_4k")
    assert tr["batch"]["tokens"].shape == (256, 4096)
    assert tr["params"]["head"].shape[1] % 256 == 0  # padded vocab

    de = sp.input_specs("jamba_1_5_large", "long_500k")
    assert de["token"].shape == (1, 1)
    # attention cache depth = seq_len
    k = de["cache"]["b4"]["k"]
    assert k.shape == (9, 1, 8, 524288, 128)  # (repeats, B, kv, L, hd)

    enc = sp.input_specs("hubert_xlarge", "prefill_32k")
    assert enc["batch"]["embeds"].shape == (32, 32768, 1280)


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    """lower+compile one train cell and one decode cell on 16 fake devices."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        os.environ["REPRO_UNROLL_SCANS"] = "0"  # rolled: fast compile
        import jax
        from repro.launch.dryrun import run_cell
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        r1 = run_cell("internvl2_1b", "train_4k", mesh=mesh, save=False)
        assert r1["status"] == "ok", r1
        assert r1["hlo_flops"] > 0
        r2 = run_cell("qwen2_5_3b", "decode_32k", mesh=mesh, save=False)
        assert r2["status"] == "ok", r2
        assert r2["collectives"]["total"] > 0
        print("SUBPROCESS_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560, env=env)
    assert "SUBPROCESS_OK" in out.stdout, out.stderr[-2000:]
