"""Resilience tests: the fault taxonomy, deterministic seeded campaigns,
runtime-failure fallback in dispatch (quarantine + repriced degradation),
graceful degradation in the serving engine (retries, per-row failure,
backpressure, pool rebuild, deadlines), the distributed re-dispatch smoke,
the VRF014 lint rule, and the ``fault_swallowed`` mutant."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.configs import get_smoke
from repro.models import transformer as T
from repro.plan import CPU_INTERPRET
from repro.resilience import errors as flt
from repro.resilience import faults as fj
from repro.serving.engine import Engine, Request

KEY = jax.random.PRNGKey(0)
PALLAS = ops.ExecutionContext(target=CPU_INTERPRET, backend="pallas")
IM2COL = ops.ExecutionContext(target=CPU_INTERPRET, backend="im2col")
XLA = ops.ExecutionContext(target=CPU_INTERPRET, backend="xla")

P1 = np.array([3, 1, 4, 1, 5], np.int32)


@pytest.fixture(autouse=True)
def _clean_slate():
    """Quarantine and campaign state are process-global; isolate tests."""
    ops.clear_quarantine()
    fj.install(None)
    yield
    ops.clear_quarantine()
    fj.install(None)


@pytest.fixture(scope="module")
def engine_setup():
    cfg = dataclasses.replace(get_smoke("stablelm_1_6b"),
                              compute_dtype="float32")
    return cfg, T.init_params(KEY, cfg)


def _reqs(n=4, max_new=6, **kw):
    return [Request(prompt=P1.copy(), max_new_tokens=max_new, rng_seed=i,
                    **kw) for i in range(n)]


def _conv_args():
    x = jax.random.normal(KEY, (2, 8, 12, 12), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 3, 3), jnp.float32)
    return x, w


# ---------------------------------------------------------------------------
# Taxonomy
# ---------------------------------------------------------------------------

def test_taxonomy_transient_vs_fatal():
    assert issubclass(flt.Fault, RuntimeError)  # legacy except-clauses work
    for cls in (flt.KernelLaunchError, flt.NumericFault, flt.DmaTimeout,
                flt.PoolIntegrityFault):
        assert issubclass(cls, flt.TransientFault) and cls("x").transient
    for cls in (flt.DeviceLost, flt.AdmissionImpossible, flt.SchedulerStall,
                flt.FaultAccountingError):
        assert issubclass(cls, flt.FatalFault) and not cls("x").transient


def test_fault_str_carries_diagnostics():
    e = flt.KernelLaunchError("boom", op="conv2d", backend="pallas",
                              grid=(4, 4))
    s = str(e)
    assert "boom" in s and "op=conv2d" in s and "backend=pallas" in s
    assert "grid=(4, 4)" in s
    assert e.diagnostics["grid"] == (4, 4)


def test_blockoom_reclassified_transient():
    from repro.serving.kv import BlockOOM
    assert issubclass(BlockOOM, flt.TransientFault)
    assert issubclass(BlockOOM, RuntimeError)


def test_allocator_check_raises_typed_fault_with_occupancy():
    from repro.serving import kv
    alloc = kv.BlockAllocator(8)
    alloc.alloc()
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("pool",))
    inj = camp.draw("decode/pool")
    camp.corrupt_allocator(alloc, inj)
    with pytest.raises(flt.PoolIntegrityFault) as ei:
        alloc.check()
    assert ei.value.transient
    assert ei.value.diagnostics["num_blocks"] == 8
    assert "corruption" in inj.detail


# ---------------------------------------------------------------------------
# Campaign determinism + spec parsing
# ---------------------------------------------------------------------------

def test_campaign_is_deterministic_per_seed():
    def run(seed):
        c = fj.FaultCampaign(seed=seed, rate=0.3)
        return [(c.draw(f"site{i}") or None) and (c.injections[-1].site,
                                                  c.injections[-1].kind)
                for i in range(40)]
    assert run(7) == run(7)
    assert run(7) != run(8)


def test_campaign_max_faults_caps_injections():
    c = fj.FaultCampaign(seed=0, rate=1.0, max_faults=3)
    for i in range(10):
        c.draw(f"s{i}")
    assert len(c.injections) == 3 and c.draws == 10


def test_campaign_rejects_bad_config():
    with pytest.raises(ValueError, match="unknown fault kinds"):
        fj.FaultCampaign(kinds=("warp_drive",))
    with pytest.raises(ValueError, match="rate"):
        fj.FaultCampaign(rate=1.5)


def test_campaign_from_spec_round_trip():
    c = fj.campaign_from_spec(
        "rate=0.25,seed=9,kinds=launch+pool,ops=conv2d,max=5")
    assert (c.rate, c.seed, c.kinds, c.ops, c.max_faults) == \
        (0.25, 9, ("launch", "pool"), ("conv2d",), 5)
    with pytest.raises(ValueError, match="unknown REPRO_FAULTS field"):
        fj.campaign_from_spec("rate=0.1,typo=1")
    with pytest.raises(ValueError, match="expected key=value"):
        fj.campaign_from_spec("justarate")


def test_verify_accounted_flags_swallowed_fault():
    c = fj.FaultCampaign(seed=0, rate=1.0, max_faults=1)
    inj = c.draw("dispatch/conv2d")
    assert inj is not None
    with pytest.raises(flt.FaultAccountingError, match="swallowed"):
        c.verify_accounted()
    c.resolve(inj, "retried")
    c.verify_accounted()  # now clean


def test_fault_swallowed_mutant_is_caught():
    from repro.verify.mutants import run_seeded_mutants
    results = {name: caught for name, caught, _ in run_seeded_mutants()}
    assert results["fault_swallowed"]


# ---------------------------------------------------------------------------
# Dispatch: runtime-failure fallback
# ---------------------------------------------------------------------------

def test_launch_fault_degrades_conv2d_to_im2col_and_reprices():
    x, w = _conv_args()
    want = np.asarray(ops.conv2d(x, w, ctx=IM2COL))
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("launch",),
                            ops=("conv2d",), max_faults=1)
    spec = {"spec_args": (jax.ShapeDtypeStruct(x.shape, x.dtype),
                          jax.ShapeDtypeStruct(w.shape, w.dtype)),
            "spec_kw": {"stride": (1, 1), "out_dtype": jnp.float32}}
    clean = ops.explain("conv2d", PALLAS, **spec)
    with fj.activate(camp):
        got = np.asarray(ops.conv2d(x, w, ctx=PALLAS))
    camp.verify_accounted()
    assert camp.summary()["resolutions"] == {"degraded": 1}
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # the degradation is visible and re-priced in ops.explain
    dec = ops.explain("conv2d", PALLAS, **spec)
    assert dec.degraded and dec.fault == "KernelLaunchError"
    assert dec.chosen == "im2col" and dec.requested == "pallas"
    assert dec.measured_words > clean.measured_words
    assert dec.bound_ratio > clean.bound_ratio
    assert "degraded" in dec.why() and "re-priced" in dec.why()
    (key,) = ops.quarantined()
    assert key[0] == "conv2d" and key[1] == "pallas"


def test_quarantine_probes_primary_after_n_dispatches():
    x, w = _conv_args()
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("launch",),
                            ops=("conv2d",), max_faults=1)
    with fj.activate(camp):
        ops.conv2d(x, w, ctx=PALLAS)
    assert ops.quarantined()
    # the demoting dispatch consumed one probe on its own re-resolve; the
    # quarantine holds (serving im2col) for PROBE_AFTER-1 more dispatches...
    for _ in range(ops.QUARANTINE_PROBE_AFTER - 1):
        assert ops.quarantined()
        ops.conv2d(x, w, ctx=PALLAS)
    # ...then the primary is probed again and, healthy, fully restored
    assert not ops.quarantined()
    dec = ops.explain(
        "conv2d", PALLAS,
        spec_args=(jax.ShapeDtypeStruct(x.shape, x.dtype),
                   jax.ShapeDtypeStruct(w.shape, w.dtype)),
        spec_kw={"stride": (1, 1), "out_dtype": jnp.float32})
    assert not dec.degraded and dec.chosen == "pallas"


def test_quarantine_is_shape_keyed():
    x, w = _conv_args()
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("launch",),
                            ops=("conv2d",), max_faults=1)
    with fj.activate(camp):
        ops.conv2d(x, w, ctx=PALLAS)
    # a different launch geometry is untouched by the quarantine
    x2 = jnp.concatenate([x, x], axis=0)
    dec = ops.explain(
        "conv2d", PALLAS,
        spec_args=(jax.ShapeDtypeStruct(x2.shape, x2.dtype),
                   jax.ShapeDtypeStruct(w.shape, w.dtype)),
        spec_kw={"stride": (1, 1), "out_dtype": jnp.float32})
    assert not dec.degraded and dec.chosen == "pallas"


def test_terminal_backend_retries_in_place():
    x, w = _conv_args()
    want = np.asarray(ops.conv2d(x, w, ctx=XLA))
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("launch",),
                            ops=("conv2d",), max_faults=1)
    with fj.activate(camp):
        got = np.asarray(ops.conv2d(x, w, ctx=XLA))
    camp.verify_accounted()
    assert camp.summary()["resolutions"] == {"retried": 1}
    assert not ops.quarantined()  # nothing to demote to: no quarantine
    np.testing.assert_allclose(got, want)


def test_persistent_transient_fault_exhausts_attempts():
    x, w = _conv_args()
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("launch",),
                            ops=("conv2d",))  # unbounded: every attempt fails
    with fj.activate(camp), pytest.raises(flt.KernelLaunchError):
        ops.conv2d(x, w, ctx=XLA)


def test_device_lost_is_fatal_and_propagates():
    x, w = _conv_args()
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("device",),
                            ops=("conv2d",), max_faults=1)
    with fj.activate(camp), pytest.raises(flt.DeviceLost):
        ops.conv2d(x, w, ctx=PALLAS)
    camp.verify_accounted()  # stamped "fatal" at the raise site
    assert camp.injections[0].resolution == "fatal"
    assert not ops.quarantined()  # fatal faults never demote


def test_numeric_fault_corrupts_then_degrades():
    x, w = _conv_args()
    want = np.asarray(ops.conv2d(x, w, ctx=IM2COL))
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("numeric",),
                            ops=("conv2d",), max_faults=1)
    with fj.activate(camp):
        got = np.asarray(ops.conv2d(x, w, ctx=PALLAS))
    camp.verify_accounted()
    assert np.all(np.isfinite(got))  # the NaN output never escaped
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_faults_never_fire_under_tracing():
    a = jax.random.normal(KEY, (8, 8), jnp.float32)
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("launch", "numeric"))
    with fj.activate(camp):
        out = jax.jit(lambda p, q: ops.matmul(p, q, ctx=XLA))(a, a)
    assert camp.injections == []  # tracer args -> the hook stands down
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ a),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Serving engine: graceful degradation
# ---------------------------------------------------------------------------

def test_deadline_expires_with_partial_output(engine_setup):
    cfg, params = engine_setup
    eng = Engine(cfg, params, max_len=32, batch_size=1)
    out = eng.serve([Request(prompt=P1.copy(), max_new_tokens=5000,
                             deadline_s=1e-9)])
    assert out[0].finish_reason == "timeout"
    assert 0 < len(out[0].out_tokens) < 5000


def test_deadline_must_be_positive(engine_setup):
    cfg, params = engine_setup
    eng = Engine(cfg, params, max_len=32, batch_size=1)
    with pytest.raises(ValueError, match="deadline_s"):
        eng.serve([Request(prompt=P1.copy(), deadline_s=0.0)])


def test_admission_retry_exhaustion_fails_one_request(engine_setup):
    cfg, params = engine_setup
    # 4 launch faults = 1 admission (3 retries + terminal failure); the
    # remaining requests admit cleanly and complete
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("launch",),
                            max_faults=4)
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    with fj.activate(camp):
        out = eng.serve(_reqs(3, max_new=4))
    camp.verify_accounted()
    reasons = [r.finish_reason for r in out]
    assert reasons == ["error", "length", "length"]
    assert len(out[0].out_tokens) == 0
    assert camp.summary()["resolutions"] == {"retried": 3, "row_failed": 1}


def test_decode_nan_fails_only_bad_rows_with_clean_prefix(engine_setup):
    cfg, params = engine_setup
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    clean = eng.serve(_reqs(2))
    camp = fj.FaultCampaign(seed=3, rate=1.0, kinds=("numeric",),
                            ops=("decode",), max_faults=2, )
    eng = Engine(cfg, params, max_len=32, batch_size=2, numeric_retries=0)
    with fj.activate(camp):
        out = eng.serve(_reqs(2))
    camp.verify_accounted()
    assert camp.summary()["resolutions"] == {"row_failed": 2}
    for c, f in zip(clean, out):
        assert f.finish_reason == "error"
        # no tokens recorded from the faulted step; the prefix is the
        # clean run's tokens bit for bit
        assert len(f.out_tokens) < len(c.out_tokens)
        assert np.array_equal(f.out_tokens,
                              np.asarray(c.out_tokens)[:len(f.out_tokens)])


def test_decode_nan_retry_recovers_idempotently(engine_setup):
    cfg, params = engine_setup
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    clean = eng.serve(_reqs(2))
    camp = fj.FaultCampaign(seed=3, rate=1.0, kinds=("numeric",),
                            ops=("decode",), max_faults=1)
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    with fj.activate(camp):
        out = eng.serve(_reqs(2))
    camp.verify_accounted()
    assert camp.summary()["resolutions"] == {"retried": 1}
    for c, f in zip(clean, out):  # the retried step changed nothing
        assert f.finish_reason == c.finish_reason
        assert np.array_equal(f.out_tokens, c.out_tokens)


def test_injected_oom_rides_backpressure_to_completion(engine_setup):
    cfg, params = engine_setup
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    clean = eng.serve(_reqs(3))
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("oom",), max_faults=3)
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    with fj.activate(camp):
        out = eng.serve(_reqs(3))
    camp.verify_accounted()
    assert camp.summary()["resolutions"] == {"backpressure": 3}
    for c, f in zip(clean, out):
        assert f.finish_reason == c.finish_reason
        assert np.array_equal(f.out_tokens, c.out_tokens)


def test_pool_corruption_triggers_exact_rebuild(engine_setup):
    cfg, params = engine_setup
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    assert eng.paged  # the rebuild path is the paged engine's
    clean = eng.serve(_reqs(4))
    camp = fj.FaultCampaign(seed=2, rate=0.5, kinds=("pool",))
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    with fj.activate(camp):
        out = eng.serve(_reqs(4))
    camp.verify_accounted()
    assert camp.summary()["resolutions"].get("rebuilt", 0) >= 1
    for c, f in zip(clean, out):  # rebuilds reproduce the cache exactly
        assert f.finish_reason == c.finish_reason
        assert np.array_equal(f.out_tokens, c.out_tokens)


def test_admission_impossible_is_typed_with_diagnostics(engine_setup):
    cfg, params = engine_setup
    eng = Engine(cfg, params, max_len=32, batch_size=1, num_blocks=2)
    with pytest.raises(flt.AdmissionImpossible,
                       match="cannot ever admit") as ei:
        eng.serve([Request(prompt=np.arange(1, 30, dtype=np.int32),
                           max_new_tokens=4)])
    d = ei.value.diagnostics  # block 0 is the reserved garbage block
    assert d["num_blocks"] == 2 and d["blocks_needed"] > d["available_blocks"]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_schedule_no_deadlock_and_unaffected_identical(
        engine_setup, seed):
    """Seeded chaos over every engine site: the loop always terminates,
    every injection is accounted, completed requests are bit-identical to
    the fault-free run and failed ones a clean prefix of it."""
    cfg, params = engine_setup
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    clean = eng.serve(_reqs(5))
    camp = fj.FaultCampaign(
        seed=seed, rate=0.2,
        kinds=("launch", "dma", "numeric", "oom", "pool"))
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    with fj.activate(camp):
        out = eng.serve(_reqs(5))
    camp.verify_accounted()
    for c, f in zip(clean, out):
        assert f.finish_reason is not None  # nobody is left hanging
        c_toks = np.asarray(c.out_tokens)
        if f.finish_reason == "error":
            assert np.array_equal(f.out_tokens, c_toks[:len(f.out_tokens)])
        else:
            assert f.finish_reason == c.finish_reason
            assert np.array_equal(f.out_tokens, c_toks)


def test_chaos_schedules_hypothesis():
    """Property-based chaos: any (seed, rate, kinds) campaign terminates
    with full fault accounting and clean-prefix outputs."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    cfg = dataclasses.replace(get_smoke("stablelm_1_6b"),
                              compute_dtype="float32")
    params = T.init_params(KEY, cfg)
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    clean = eng.serve(_reqs(3, max_new=4))

    @hyp.settings(max_examples=10, deadline=None)
    @hyp.given(seed=st.integers(0, 2**31 - 1),
               rate=st.floats(0.0, 0.5),
               kinds=st.sets(st.sampled_from(
                   ("launch", "dma", "numeric", "oom", "pool")),
                   min_size=1))
    def run(seed, rate, kinds):
        camp = fj.FaultCampaign(seed=seed, rate=rate, kinds=tuple(kinds))
        e = Engine(cfg, params, max_len=32, batch_size=2)
        with fj.activate(camp):
            out = e.serve(_reqs(3, max_new=4))
        camp.verify_accounted()
        for c, f in zip(clean, out):
            c_toks = np.asarray(c.out_tokens)
            assert f.finish_reason is not None
            if f.finish_reason == "error":
                assert np.array_equal(f.out_tokens,
                                      c_toks[:len(f.out_tokens)])
            else:
                assert np.array_equal(f.out_tokens, c_toks)

    run()


# ---------------------------------------------------------------------------
# Distributed: shard fault re-dispatches through the xla leg
# ---------------------------------------------------------------------------

def test_dist_shard_fault_redispatches_through_xla():
    from repro.core.conv_model import ConvShape
    from repro.core.parallel_tiling import ParallelBlocking

    x = jax.random.normal(KEY, (2, 4, 18, 18), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 4, 3, 3), jnp.float32)
    shape = ConvShape(N=2, c_I=4, c_O=4, h_O=16, w_O=16, h_F=3, w_F=3,
                      sh=1, sw=1)
    pb = ParallelBlocking.from_grid(shape, {})  # 1-device smoke grid
    want = np.asarray(ops.conv2d_dist(x, w, stride=(1, 1), blocking=pb,
                                      ctx=XLA, out_dtype=jnp.float32))
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("launch",),
                            ops=("conv2d_dist",), max_faults=1)
    with fj.activate(camp):
        got = np.asarray(ops.conv2d_dist(x, w, stride=(1, 1), blocking=pb,
                                         ctx=PALLAS, out_dtype=jnp.float32))
    camp.verify_accounted()
    assert camp.summary()["resolutions"] == {"degraded": 1}
    (key,) = ops.quarantined()
    assert key[:2] == ("conv2d_dist", "pallas")  # xla leg served the call
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# VRF014: no bare RuntimeError in runtime layers
# ---------------------------------------------------------------------------

def _lint_snippet(tmp_path, rel_parts, src):
    from repro.verify.lint import lint_file
    p = tmp_path.joinpath(*rel_parts)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(src)
    return [v.code for v in lint_file(p, tmp_path)]


def test_vrf014_flags_bare_runtime_error(tmp_path):
    codes = _lint_snippet(
        tmp_path, ("src", "repro", "serving", "x.py"),
        "def f():\n    raise RuntimeError('boom')\n")
    assert codes == ["VRF014"]


def test_vrf014_allows_taxonomy_and_other_scopes(tmp_path):
    # taxonomy raises and re-raises are fine in runtime scope
    assert _lint_snippet(
        tmp_path, ("src", "repro", "serving", "x.py"),
        "from repro.resilience import errors as flt\n"
        "def f():\n"
        "    try:\n"
        "        raise flt.DeviceLost('gone')\n"
        "    except flt.Fault:\n"
        "        raise\n") == []
    # bare RuntimeError outside the runtime layers is not VRF014's business
    assert _lint_snippet(
        tmp_path, ("src", "repro", "models", "x.py"),
        "def f():\n    raise RuntimeError('boom')\n") == []


def test_runtime_tree_is_vrf014_clean():
    from pathlib import Path

    from repro.verify.lint import lint_sources
    root = Path(__file__).resolve().parents[1]
    found = [v for v in lint_sources([root / "src" / "repro"], root)
             if v.code == "VRF014"]
    assert found == []
