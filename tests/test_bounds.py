"""Closed-form bound tests (Thms 2.1/2.2/2.3) + hypothesis properties."""

import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bounds import (C_p, combined_parallel_bound, matmul_bound,
                               memory_independent_parallel_bound,
                               parallel_bound, single_processor_bound,
                               small_filter_regime)
from repro.core.conv_model import (BF16_ACC32, ConvShape, Precision,
                                   matmul_as_conv, resnet50_layers)


def test_Cp_standard_precision():
    assert C_p(Precision(1, 1, 1)) == pytest.approx(9 / 4)


def test_Cp_triangle_violated():
    # p_O > p_I + p_F -> C_p = p_O (p_I + p_F)
    assert C_p(Precision(1, 1, 3)) == pytest.approx(3 * 2)
    assert C_p(Precision(4, 1, 1)) == pytest.approx(4 * 2)


def test_single_processor_standard_form():
    """X >= max{|I|+|F|+|O|, 9G/4M - M, 2G(sw sh)^.5/(wF hF M)^.5 - 2M}."""
    s = ConvShape(N=8, c_I=16, c_O=32, w_O=10, h_O=10, w_F=3, h_F=3)
    M = 4096.0
    b = single_processor_bound(s, M)
    G = s.G
    assert b.terms["per_M"] == pytest.approx(9 * G / (4 * M) - M)
    assert b.terms["small_filter"] == pytest.approx(2 * G / math.sqrt(9 * M) - 2 * M)
    assert b.terms["memory_independent"] == pytest.approx(
        s.input_size + s.filter_size + s.output_size)


def test_small_filter_regime_boundary():
    """Third bound eclipses the second iff wF hF < 64 M sw sh / 81 (§3.1)."""
    s = ConvShape(N=4, c_I=8, c_O=8, w_O=64, h_O=64, w_F=3, h_F=3)
    M = 1e4
    assert small_filter_regime(s, M)
    b = single_processor_bound(s, M)
    assert b.terms["small_filter"] > b.terms["per_M"]


def test_matmul_bound_matches_classical():
    """7NL specialization must reproduce 2mnk/sqrt(M) - 2M for matmul."""
    m = n = k = 512
    M = 2048.0
    b = matmul_bound(m, n, k, M)
    classical = 2 * m * n * k / math.sqrt(M) - 2 * M
    assert b == pytest.approx(classical)


def test_parallel_bound_divides_by_P():
    s = resnet50_layers(100)["conv2_x"]
    M = 2 ** 16
    b1 = parallel_bound(s, 1, M).value
    b16 = parallel_bound(s, 16, M).value
    assert b16 < b1
    # leading term scales 1/P
    assert b16 + 2 * M == pytest.approx((b1 + 2 * M) / 16, rel=0.2)


def test_memory_independent_bound_regimes():
    """Thm 2.3 only binds once P is large enough that the owned share A_P/P
    is below the (G/P)^{1/2} replication term (paper §4.1: 'This becomes a
    concern if ... the number of processors is large relative to the size of
    the computation')."""
    s = resnet50_layers(1000)["conv3_x"]
    A_P = max(s.input_size, s.filter_size, s.output_size)
    P_crit = A_P ** 2 / s.G
    assert memory_independent_parallel_bound(s, 4).value < 0  # small P: trivial
    assert memory_independent_parallel_bound(s, int(4 * P_crit)).value > 0


shape_strategy = st.builds(
    ConvShape,
    N=st.integers(1, 8),
    c_I=st.integers(1, 16),
    c_O=st.integers(1, 16),
    w_O=st.integers(4, 32),
    h_O=st.integers(4, 32),
    w_F=st.integers(1, 4),
    h_F=st.integers(1, 4),
    sw=st.just(1),
    sh=st.just(1),
)


@settings(max_examples=50, deadline=None)
@given(shape=shape_strategy, logM=st.floats(8, 20))
def test_bound_monotone_decreasing_in_M(shape, logM):
    """More cache can never increase the M-dependent lower bound terms."""
    M = 2.0 ** logM
    b1 = single_processor_bound(shape, M)
    b2 = single_processor_bound(shape, 2 * M)
    assert b2.terms["per_M"] <= b1.terms["per_M"] + 1e-6
    assert b2.terms["small_filter"] <= b1.terms["small_filter"] + 1e-6


@settings(max_examples=50, deadline=None)
@given(shape=shape_strategy)
def test_bound_at_least_io(shape):
    """The max-bound never drops below compulsory IO."""
    b = single_processor_bound(shape, 2 ** 30)
    assert b.value >= shape.words() - 1e-6


@settings(max_examples=30, deadline=None)
@given(shape=shape_strategy, P=st.sampled_from([2, 4, 16, 64]))
def test_parallel_at_most_single(shape, P):
    """P processors can only reduce the per-processor M-decay bound."""
    M = 2 ** 12
    bp = parallel_bound(shape, P, M).terms["per_M"]
    bs = single_processor_bound(shape, M).terms["per_M"]
    assert bp <= bs + 1e-6


@settings(max_examples=30, deadline=None)
@given(
    pI=st.floats(0.25, 4), pF=st.floats(0.25, 4), pO=st.floats(0.25, 4))
def test_Cp_bounds(pI, pF, pO):
    """C_p is p_T^2/4 under triangle, else p_j(p_k+p_l); both <= p_T^2/4 + eps
    and positive."""
    c = C_p(Precision(pI, pF, pO))
    pT = pI + pF + pO
    assert 0 < c <= pT ** 2 / 4 + 1e-9
