"""Serving engine tests: wave batching, greedy consistency with full
forward, recurrent-arch decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as T
from repro.serving.engine import Engine, Request

KEY = jax.random.PRNGKey(0)


def _params_and_cfg(arch):
    cfg = dataclasses.replace(get_smoke(arch), compute_dtype="float32")
    return T.init_params(KEY, cfg), cfg


def test_greedy_matches_manual_decode():
    params, cfg = _params_and_cfg("stablelm_1_6b")
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    eng = Engine(cfg, params, max_len=32, batch_size=1)
    req = Request(prompt=prompt, max_new_tokens=6)
    eng.serve([req])

    # manual greedy via repeated full forwards (no cache)
    toks = list(prompt)
    for _ in range(6):
        lg, _, _ = T.forward(params, cfg,
                             tokens=jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(lg[0, -1])))
    np.testing.assert_array_equal(req.out_tokens, np.array(toks[len(prompt):]))


def test_wave_batching_processes_all_requests():
    params, cfg = _params_and_cfg("stablelm_1_6b")
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(2, 8)).astype(np.int32),
                    max_new_tokens=4) for _ in range(5)]
    eng = Engine(cfg, params, max_len=32, batch_size=2)  # 3 waves
    eng.serve(reqs)
    for r in reqs:
        assert r.out_tokens is not None and len(r.out_tokens) == 4
        assert r.out_tokens.min() >= 0


@pytest.mark.parametrize("arch", ["xlstm_1_3b", "jamba_1_5_large"])
def test_recurrent_arch_serving(arch):
    """SSM/hybrid archs decode through recurrent state, not a KV window."""
    params, cfg = _params_and_cfg(arch)
    eng = Engine(cfg, params, max_len=32, batch_size=2)
    reqs = [Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4),
            Request(prompt=np.array([9, 8], np.int32), max_new_tokens=4)]
    eng.serve(reqs)
    for r in reqs:
        assert len(r.out_tokens) == 4


def test_batched_left_padding_preserves_per_request_output():
    """A request's greedy output must not depend on its batch-mates."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    p1 = np.array([3, 1, 4, 1, 5], np.int32)
    p2 = np.array([7], np.int32)

    solo = Request(prompt=p1, max_new_tokens=4)
    Engine(cfg, params, max_len=32, batch_size=1).serve([solo])

    pair = [Request(prompt=p1, max_new_tokens=4),
            Request(prompt=p2, max_new_tokens=4)]
    Engine(cfg, params, max_len=32, batch_size=2).serve(pair)
    np.testing.assert_array_equal(solo.out_tokens, pair[0].out_tokens)


def test_greedy_unaffected_by_sampling_batchmate():
    """Per-request temperatures: a greedy request batched with a temperature>0
    request must still produce its deterministic greedy output."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    p1 = np.array([3, 1, 4, 1, 5], np.int32)
    p2 = np.array([2, 7, 1], np.int32)

    solo = Request(prompt=p1, max_new_tokens=5, temperature=0.0)
    Engine(cfg, params, max_len=32, batch_size=1).serve([solo])

    mixed = [Request(prompt=p1, max_new_tokens=5, temperature=0.0),
             Request(prompt=p2, max_new_tokens=5, temperature=1.0)]
    Engine(cfg, params, max_len=32, batch_size=2).serve(mixed)
    np.testing.assert_array_equal(solo.out_tokens, mixed[0].out_tokens)
