"""Serving engine tests: slot-based continuous batching, batch invariance
(greedy and sampled), EOS / cache-limit accounting, seeded reproducibility,
wave-baseline parity, recurrent-arch decode, plan-aware batch sizing, and
the paged KV-cache mode (block tables, prefix sharing, backpressure)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.configs import get_smoke
from repro.models import transformer as T
from repro.plan import CPU_INTERPRET
from repro.serving.engine import Engine, Request, WaveEngine, plan_batch_size

KEY = jax.random.PRNGKey(0)


def _params_and_cfg(arch):
    cfg = dataclasses.replace(get_smoke(arch), compute_dtype="float32")
    return T.init_params(KEY, cfg), cfg


P1 = np.array([3, 1, 4, 1, 5], np.int32)
P2 = np.array([7], np.int32)
P3 = np.array([2, 7, 1], np.int32)


def test_greedy_matches_manual_decode():
    params, cfg = _params_and_cfg("stablelm_1_6b")
    eng = Engine(cfg, params, max_len=32, batch_size=1)
    req = Request(prompt=P1, max_new_tokens=6)
    eng.serve([req])

    # manual greedy via repeated full forwards (no cache)
    toks = list(P1)
    for _ in range(6):
        lg, _, _ = T.forward(params, cfg,
                             tokens=jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(lg[0, -1])))
    np.testing.assert_array_equal(req.out_tokens, np.array(toks[len(P1):]))
    assert req.finish_reason == "length"


def test_queue_longer_than_pool_processes_all_requests():
    params, cfg = _params_and_cfg("stablelm_1_6b")
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(2, 8)).astype(np.int32),
                    max_new_tokens=4) for _ in range(5)]
    eng = Engine(cfg, params, max_len=32, batch_size=2)  # 5 requests, 2 slots
    eng.serve(reqs)
    for r in reqs:
        assert r.out_tokens is not None and len(r.out_tokens) == 4
        assert r.out_tokens.min() >= 0
        assert r.finish_reason == "length"


@pytest.mark.parametrize("arch", ["xlstm_1_3b", "jamba_1_5_large"])
def test_recurrent_arch_serving(arch):
    """SSM/hybrid archs decode through recurrent state, not a KV window;
    exact-length prefill-into-slot keeps them batch-invariant too."""
    params, cfg = _params_and_cfg(arch)
    solo = Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4)
    Engine(cfg, params, max_len=32, batch_size=1).serve([solo])
    reqs = [Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4),
            Request(prompt=np.array([9, 8], np.int32), max_new_tokens=4)]
    Engine(cfg, params, max_len=32, batch_size=2).serve(reqs)
    for r in reqs:
        assert len(r.out_tokens) == 4
    np.testing.assert_array_equal(solo.out_tokens, reqs[0].out_tokens)


def test_batch_invariance_greedy_mixed_lengths():
    """Regression for the left-pad wave bug: a short prompt decoded in a
    mixed-length batch must match the same prompt decoded alone."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    solo_long = Request(prompt=P1, max_new_tokens=4)
    solo_short = Request(prompt=P2, max_new_tokens=4)
    Engine(cfg, params, max_len=32, batch_size=1).serve([solo_long])
    Engine(cfg, params, max_len=32, batch_size=1).serve([solo_short])

    for order in ([P1, P2], [P2, P1]):
        pair = [Request(prompt=p, max_new_tokens=4) for p in order]
        Engine(cfg, params, max_len=32, batch_size=2).serve(pair)
        by_len = {len(r.prompt): r for r in pair}
        np.testing.assert_array_equal(solo_long.out_tokens,
                                      by_len[len(P1)].out_tokens)
        np.testing.assert_array_equal(solo_short.out_tokens,
                                      by_len[len(P2)].out_tokens)


def test_batch_invariance_sampled():
    """A sampled request with a pinned rng_seed produces identical tokens
    alone and in any batch composition (per-request sampling streams)."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    mk = lambda: Request(prompt=P1, max_new_tokens=5, temperature=0.9,
                         rng_seed=42)
    solo = mk()
    Engine(cfg, params, max_len=32, batch_size=1, seed=7).serve([solo])
    batched = [Request(prompt=P2, max_new_tokens=3),
               mk(),
               Request(prompt=P3, max_new_tokens=8, temperature=1.3)]
    Engine(cfg, params, max_len=32, batch_size=3, seed=7).serve(batched)
    np.testing.assert_array_equal(solo.out_tokens, batched[1].out_tokens)


def test_greedy_unaffected_by_sampling_batchmate():
    """A greedy request batched with a temperature>0 request must still
    produce its deterministic greedy output."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    solo = Request(prompt=P1, max_new_tokens=5, temperature=0.0)
    Engine(cfg, params, max_len=32, batch_size=1).serve([solo])

    mixed = [Request(prompt=P1, max_new_tokens=5, temperature=0.0),
             Request(prompt=P3, max_new_tokens=5, temperature=1.0)]
    Engine(cfg, params, max_len=32, batch_size=2).serve(mixed)
    np.testing.assert_array_equal(solo.out_tokens, mixed[0].out_tokens)


def test_seeded_runs_reproducible_across_batch_compositions():
    """Key consumption depends only on (engine seed, request rng_seed, step)
    — never on which requests share the pool — so a seeded run reproduces
    under a different batch size and queue order."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    prompts = [P1, P2, P3]

    def serve(batch_size, order):
        reqs = [Request(prompt=prompts[i], max_new_tokens=4, temperature=0.8,
                        rng_seed=i) for i in order]
        Engine(cfg, params, max_len=32, batch_size=batch_size, seed=3).serve(reqs)
        return {r.rng_seed: list(r.out_tokens) for r in reqs}

    a = serve(3, [0, 1, 2])
    b = serve(1, [2, 0, 1])
    assert a == b
    # a different engine seed shifts the sampled streams
    reqs = [Request(prompt=P1, max_new_tokens=4, temperature=0.8, rng_seed=0)]
    Engine(cfg, params, max_len=32, batch_size=1, seed=4).serve(reqs)
    assert list(reqs[0].out_tokens) != a[0]


def test_stop_token_ends_request():
    params, cfg = _params_and_cfg("stablelm_1_6b")
    ref = Request(prompt=P1, max_new_tokens=6)
    Engine(cfg, params, max_len=32, batch_size=1).serve([ref])
    eos = int(ref.out_tokens[2])  # force a stop on the 3rd greedy token

    req = Request(prompt=P1, max_new_tokens=6, stop_tokens=(eos,))
    Engine(cfg, params, max_len=32, batch_size=1).serve([req])
    assert req.finish_reason == "stop"
    np.testing.assert_array_equal(req.out_tokens, ref.out_tokens[:3])


def test_cache_limit_returns_only_real_tokens():
    """Regression for the wave-engine padding bug: when max_len truncates
    decode, out_tokens holds exactly the generated tokens (no zero-pad) and
    they match an untruncated run's prefix."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    full = Request(prompt=P1, max_new_tokens=12)
    Engine(cfg, params, max_len=32, batch_size=1).serve([full])

    trunc = Request(prompt=P1, max_new_tokens=12)
    Engine(cfg, params, max_len=8, batch_size=1).serve([trunc])
    cap = 8 - len(P1) + 1  # prefill token + writes up to max_len - 1
    assert len(trunc.out_tokens) == cap < 12
    assert trunc.finish_reason == "cache_limit"
    np.testing.assert_array_equal(trunc.out_tokens, full.out_tokens[:cap])


def test_wave_baseline_matches_continuous_outputs():
    """Scheduling must not change tokens: the wave baseline and the slot
    engine agree request-by-request (they differ only in admission timing)."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    rng = np.random.default_rng(2)
    specs = [(rng.integers(0, cfg.vocab_size, size=n).astype(np.int32), m)
             for n, m in ((5, 6), (2, 2), (3, 4), (6, 3), (1, 5))]
    a = [Request(prompt=p, max_new_tokens=m) for p, m in specs]
    b = [Request(prompt=p, max_new_tokens=m) for p, m in specs]
    Engine(cfg, params, max_len=32, batch_size=2).serve(a)
    WaveEngine(cfg, params, max_len=32, batch_size=2).serve(b)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.out_tokens, rb.out_tokens)
        assert ra.finish_reason == rb.finish_reason


def test_prefill_bucket_exactness_and_guard():
    """Masked bucketed prefill (attention archs) must equal exact-length
    prefill token-for-token; recurrent patterns must reject bucketing."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    exact = Request(prompt=P1, max_new_tokens=5)
    Engine(cfg, params, max_len=32, batch_size=1,
           prefill_bucket=1).serve([exact])
    bucketed = Request(prompt=P1, max_new_tokens=5)
    Engine(cfg, params, max_len=32, batch_size=1,
           prefill_bucket=8).serve([bucketed])
    np.testing.assert_array_equal(exact.out_tokens, bucketed.out_tokens)

    _, hybrid = _params_and_cfg("jamba_1_5_large")
    with pytest.raises(ValueError, match="pure-attention"):
        Engine(hybrid, params, max_len=32, batch_size=1, prefill_bucket=8)


def test_prompt_validation():
    params, cfg = _params_and_cfg("stablelm_1_6b")
    eng = Engine(cfg, params, max_len=8, batch_size=1)
    with pytest.raises(ValueError):
        eng.serve([Request(prompt=np.arange(9, dtype=np.int32))])
    with pytest.raises(ValueError):
        eng.serve([Request(prompt=P1, max_new_tokens=0)])
    with pytest.raises(ValueError):
        eng.serve([Request(prompt=P2, rng_seed=2**35)])


def test_plan_batch_size_from_target():
    _, cfg = _params_and_cfg("stablelm_1_6b")
    b = plan_batch_size(cfg, 512, CPU_INTERPRET)
    assert 1 <= b <= 64
    # tighter memory -> fewer slots, never below one
    tiny = dataclasses.replace(CPU_INTERPRET, hbm_words=1e4)
    assert plan_batch_size(cfg, 512, tiny) == 1
    # alignment: pools at/above the sublane multiple are rounded to it
    if b >= CPU_INTERPRET.align_sublane:
        assert b % CPU_INTERPRET.align_sublane == 0


# ---------------------------------------------------------------------------
# Paged KV-cache mode: block tables replace the per-slot contiguous cache.
# ---------------------------------------------------------------------------

def _spec_reqs(rng, n=7, shared_prefix=16):
    """A mixed workload: varied lengths plus two requests sharing a full-
    block prompt prefix, one of them sampled with a pinned seed."""
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(2, 20))
        reqs.append(Request(
            prompt=rng.integers(1, 64, size=plen).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 10))))
    shared = rng.integers(1, 64, size=shared_prefix).astype(np.int32)
    for tail, temp in ((3, 0.0), (5, 0.9)):
        p = np.concatenate(
            [shared, rng.integers(1, 64, size=tail).astype(np.int32)])
        reqs.append(Request(prompt=p, max_new_tokens=6, temperature=temp,
                            rng_seed=11))
    return reqs


def test_paged_matches_contiguous_outputs():
    """The tentpole invariant: switching the KV layout from per-slot
    contiguous to paged blocks changes no tokens — including across shared
    prompt prefixes and a sampled request."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    a = _spec_reqs(np.random.default_rng(5))
    b = _spec_reqs(np.random.default_rng(5))
    Engine(cfg, params, max_len=64, batch_size=3, paged=False).serve(a)
    eng = Engine(cfg, params, max_len=64, batch_size=3, paged=True)
    assert eng.paged and eng.num_blocks >= 1 + 64 // eng.block_size
    eng.serve(b)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.out_tokens, rb.out_tokens)
        assert ra.finish_reason == rb.finish_reason


def test_paged_is_default_only_for_pure_attention():
    params, cfg = _params_and_cfg("stablelm_1_6b")
    assert Engine(cfg, params, max_len=32, batch_size=1).paged
    hp, hybrid = _params_and_cfg("jamba_1_5_large")
    assert not Engine(hybrid, hp, max_len=32, batch_size=1).paged
    with pytest.raises(ValueError, match="pure-attention"):
        Engine(hybrid, hp, max_len=32, batch_size=1, paged=True)
    fused = dataclasses.replace(cfg, fused_kv_cache=True)
    with pytest.raises(ValueError, match="fused"):
        Engine(fused, params, max_len=32, batch_size=1, paged=True)


def test_paged_backpressure_completes_all_requests():
    """A pool too small for the full batch admits what fits, re-queues the
    rest, and still produces the exact contiguous-engine outputs."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    mk = lambda: [Request(prompt=np.full(20, i + 1, np.int32),
                          max_new_tokens=25) for i in range(5)]
    ref = mk()
    Engine(cfg, params, max_len=64, batch_size=4, paged=False).serve(ref)
    # each request needs ceil((20 + 25 - 1) / 16) = 3 blocks; 7 usable
    # blocks hold at most two concurrent requests of the four slots
    got = mk()
    Engine(cfg, params, max_len=64, batch_size=4, paged=True,
           num_blocks=1 + 7).serve(got)
    for ra, rb in zip(ref, got):
        np.testing.assert_array_equal(ra.out_tokens, rb.out_tokens)
        assert rb.finish_reason == "length"


def test_paged_pool_that_can_never_admit_raises():
    params, cfg = _params_and_cfg("stablelm_1_6b")
    eng = Engine(cfg, params, max_len=64, batch_size=2, paged=True,
                 num_blocks=1 + 2)
    with pytest.raises(RuntimeError, match="cannot ever admit"):
        eng.serve([Request(prompt=np.arange(1, 40, dtype=np.int32),
                           max_new_tokens=20)])


def test_paged_decode_dispatches_to_pallas_no_fallback():
    """Regression for the PR-6 acceptance criterion: pooled decode runs the
    pallas attention_decode entry with no capability fallback; an xla
    override serves the same op as requested."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    req = lambda: [Request(prompt=P1, max_new_tokens=4)]
    with ops.record_dispatch() as log:
        Engine(cfg, params, max_len=48, batch_size=1, paged=True,
               ctx=ops.ExecutionContext(backend="pallas")).serve(req())
    dec = [d for d in log if d.op == "attention_decode"]
    assert dec and all(d.chosen == "pallas" and not d.fell_back for d in dec)
    with ops.record_dispatch() as log:
        Engine(cfg, params, max_len=40, batch_size=1, paged=True,
               ctx=ops.ExecutionContext(backend="xla")).serve(req())
    dec = [d for d in log if d.op == "attention_decode"]
    assert dec and all(d.chosen == "xla" and not d.fell_back for d in dec)


def test_plan_batch_size_block_granularity():
    """Paged sizing rounds the per-request footprint up to whole blocks, so
    a block-size-misaligned max_len plans no more slots than contiguous."""
    _, cfg = _params_and_cfg("stablelm_1_6b")
    b = plan_batch_size(cfg, 24, CPU_INTERPRET, block_size=16)
    assert 1 <= b <= plan_batch_size(cfg, 24, CPU_INTERPRET)


def test_slot_cache_ops_roundtrip():
    """insert_cache_slot / reset_cache_slot splice batch-1 rows in and out
    of a pooled cache (every leaf stacked (repeats, B, ...))."""
    _, cfg = _params_and_cfg("jamba_1_5_large")  # attn + ssm leaves
    pool = T.init_cache(cfg, 3, 8, dtype=jnp.float32)
    row = jax.tree.map(lambda a: jnp.full_like(a[:, :1], 2.0),
                       T.init_cache(cfg, 1, 8, dtype=jnp.float32))
    pool = T.insert_cache_slot(pool, row, 1)
    for leaf in jax.tree.leaves(pool):
        np.testing.assert_array_equal(np.asarray(leaf[:, 1]), 2.0)
        np.testing.assert_array_equal(np.asarray(leaf[:, 0]), 0.0)
        np.testing.assert_array_equal(np.asarray(leaf[:, 2]), 0.0)
    pool = T.reset_cache_slot(pool, 1)
    for leaf in jax.tree.leaves(pool):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)
