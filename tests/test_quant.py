"""Tests for ``repro.quant`` and the quantized execution path: round-trip
numerics (property-based where hypothesis is available), pallas/xla kernel
agreement, static-audit exactness for the scale operand, mixed-precision
bounds, plan-v5 dtype carriage, the VRF013 lint rule, quantized KV pool
capacity, and int8-pool serving parity with the bf16 engine."""

import dataclasses
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.configs import get_smoke
from repro.core.bounds import (attention_bound, mixed_precision_attention_bound,
                               mixed_precision_bound,
                               mixed_precision_bound_ratio,
                               single_processor_bound)
from repro.core.conv_model import ConvShape, Precision
from repro.models import transformer as T
from repro.plan import TPU_V5E, HardwareTarget, get_target
from repro.plan.planner import PLAN_FORMAT_VERSION, ExecutionPlan, Planner
from repro.plan.ops import ConvSpec
from repro.quant import (INT8_SPEC, KV_INT8_SPEC, PrecisionSpec, dequantize,
                         dtype_words, fold_output_scales,
                         quantize_conv_operands, quantize_matmul_operands,
                         quantize_symmetric)
from repro.serving import kv
from repro.serving.engine import Engine, Request

KEY = jax.random.PRNGKey(0)
XLA = ops.ExecutionContext(target=TPU_V5E, backend="xla")
PALLAS = ops.ExecutionContext(target=TPU_V5E, backend="pallas")


# ---------------------------------------------------------------------------
# round-trip numerics
# ---------------------------------------------------------------------------

def _roundtrip_check(x, axis):
    q, s = quantize_symmetric(x, axis=axis)
    assert q.dtype == jnp.int8
    back = dequantize(q, s, axis=axis)
    assert back.shape == x.shape and back.dtype == jnp.float32
    # symmetric round-to-nearest: error is at most half a quantization step
    step = np.asarray(s, np.float32)
    if axis is not None:
        shp = [1] * x.ndim
        shp[axis % x.ndim] = x.shape[axis % x.ndim]
        step = step.reshape(shp)
    assert np.all(np.abs(np.asarray(back) - np.asarray(x, np.float32))
                  <= step / 2 + 1e-7)
    # exact zeros survive the trip exactly
    assert np.all(np.asarray(back)[np.asarray(x) == 0] == 0)


def test_roundtrip_deterministic():
    x = jax.random.normal(KEY, (16, 24), jnp.float32) * 3.0
    _roundtrip_check(x, axis=None)
    _roundtrip_check(x, axis=0)
    _roundtrip_check(x, axis=1)
    # all-zero input: scale falls back to 1.0, round-trip is exact
    q, s = quantize_symmetric(jnp.zeros((4, 4)), axis=0)
    assert np.all(np.asarray(s) == 1.0) and np.all(np.asarray(q) == 0)


def test_roundtrip_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp

    @settings(max_examples=50, deadline=None)
    @given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                                   min_side=1, max_side=8),
                      elements=st.floats(-1e4, 1e4, width=32)),
           st.sampled_from([None, 0, -1]))
    def check(x, axis):
        _roundtrip_check(jnp.asarray(x), axis)

    check()


def test_fold_output_scales_shape():
    s = fold_output_scales(jnp.float32(0.5), jnp.ones((8,), jnp.float32) * 2)
    assert s.shape == (1, 8) and np.all(np.asarray(s) == 1.0)


# ---------------------------------------------------------------------------
# kernels: backend agreement and closeness to the unquantized reference
# ---------------------------------------------------------------------------

def test_conv2d_q_backends_agree_and_match_f32():
    x = jax.random.normal(KEY, (2, 8, 12, 12), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8, 3, 3), jnp.float32)
    xq, wq, s = quantize_conv_operands(x, w)
    out_p = ops.conv2d_q(xq, wq, s, ctx=PALLAS, out_dtype=jnp.float32)
    out_x = ops.conv2d_q(xq, wq, s, ctx=XLA, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_x))
    ref = ops.conv2d(x, w, ctx=XLA, out_dtype=jnp.float32)
    err = np.abs(np.asarray(out_p) - np.asarray(ref))
    # int8 storage error budget: well under the activations' dynamic range
    assert err.max() <= 0.15 * np.abs(np.asarray(ref)).max()


def test_matmul_q_backends_agree():
    a = jax.random.normal(KEY, (64, 96), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (96, 128), jnp.float32)
    aq, bq, s = quantize_matmul_operands(a, b)
    out_p = ops.matmul_q(aq, bq, s, ctx=PALLAS, out_dtype=jnp.float32)
    out_x = ops.matmul_q(aq, bq, s, ctx=XLA, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_x))


# ---------------------------------------------------------------------------
# dispatch metadata: audit exactness and the moved bound
# ---------------------------------------------------------------------------

def _resnet_conv_structs(dtype):
    x = jax.ShapeDtypeStruct((8, 64, 56, 56), dtype)
    w = jax.ShapeDtypeStruct((128, 64, 3, 3), dtype)
    return x, w


def test_conv2d_q_audits_exactly_and_halves_words():
    x8, w8 = _resnet_conv_structs(jnp.int8)
    sc = jax.ShapeDtypeStruct((1, 128), jnp.float32)
    dq = ops.explain("conv2d_q", PALLAS, dtype="int8", spec_args=(x8, w8, sc),
                     spec_kw={"stride": (2, 2)}, audit=True)
    assert dq.chosen == "pallas" and dq.audited == dq.measured_words
    xb, wb = _resnet_conv_structs(jnp.bfloat16)
    db = ops.explain("conv2d", PALLAS, spec_args=(xb, wb),
                     spec_kw={"stride": (2, 2)}, audit=True)
    ratio = dq.measured_words / db.measured_words
    assert ratio <= 0.55, f"int8 conv words ratio {ratio:.3f} > 0.55"
    assert dq.bound_ratio <= 1.3


def test_matmul_q_audits_exactly():
    a = jax.ShapeDtypeStruct((512, 384), jnp.int8)
    b = jax.ShapeDtypeStruct((384, 256), jnp.int8)
    s = jax.ShapeDtypeStruct((1, 256), jnp.float32)
    d = ops.explain("matmul_q", PALLAS, dtype="int8", spec_args=(a, b, s),
                    audit=True)
    assert d.chosen == "pallas" and d.audited == d.measured_words


def test_scale_applied_twice_mutant_is_caught():
    from repro.verify.mutants import scale_applied_twice
    caught, detail = scale_applied_twice()
    assert caught, detail


# ---------------------------------------------------------------------------
# bounds: narrower storage moves the bound itself
# ---------------------------------------------------------------------------

def test_mixed_precision_bound_ratio_memfree_regime():
    shape = ConvShape(N=8, c_I=64, c_O=128, w_O=28, h_O=28, w_F=3, h_F=3,
                      prec=Precision(0.5, 0.5, 0.5))
    M = 1e9  # memory-free regime: bound is the operand footprints
    # int8 in/filter but bf16 out vs all-bf16: the output stream (the
    # biggest operand of this shape) keeps its width, so ~0.8 not ~0.5
    r = mixed_precision_bound_ratio(shape, M, INT8_SPEC)
    assert 0.7 < r < 0.85
    # quarter-width storage on every operand halves the memfree bound exactly
    all_q = PrecisionSpec(out_dtype="float8_e4m3fn")
    assert mixed_precision_bound_ratio(shape, M, all_q) == pytest.approx(0.5)
    assert mixed_precision_bound(shape, M, INT8_SPEC).value < \
        single_processor_bound(shape, M).value


def test_mixed_precision_attention_bound_decode_regime():
    base = attention_bound(4, 8, 8, 1, 256, 64, 1e9,
                           prec=Precision(0.5, 0.5, 0.5))
    quant = mixed_precision_attention_bound(4, 8, 8, 1, 256, 64, 1e9,
                                            KV_INT8_SPEC)
    # decode is KV-stream dominated: int8+per-row-scale KV ~ halves it
    assert quant.value < 0.65 * base.value


def test_precision_spec_validation_and_dict_roundtrip():
    assert INT8_SPEC.is_quantized and INT8_SPEC.precision.p_I == 0.25
    assert PrecisionSpec.from_dict(INT8_SPEC.to_dict()) == INT8_SPEC
    with pytest.raises(ValueError):
        PrecisionSpec(acc_dtype="bfloat16")  # accumulator below f32
    with pytest.raises(ValueError):
        dtype_words("complex128")


# ---------------------------------------------------------------------------
# plan v5 + target quant policy
# ---------------------------------------------------------------------------

def test_plan_v5_carries_operand_dtypes():
    spec = ConvSpec(N=4, c_I=8, c_O=16, w_O=10, h_O=10, w_F=3, h_F=3,
                    prec=INT8_SPEC.precision)
    ep = Planner(TPU_V5E).plan(spec)
    d = ep.to_dict()
    assert d["version"] == PLAN_FORMAT_VERSION == 6
    dmap = dict(d["dtypes"])
    assert dmap["input"] == "int8" and dmap["accum"] == "float32"
    assert ExecutionPlan.from_dict(d) == ep


def test_target_with_quant_roundtrip():
    tq = TPU_V5E.with_quant(INT8_SPEC)
    assert tq.quant == INT8_SPEC and TPU_V5E.quant is None
    back = HardwareTarget.from_dict(tq.to_dict())
    assert back.quant == INT8_SPEC
    assert get_target(TPU_V5E.name).quant is None


def test_roofline_words_to_bytes_per_operand():
    from repro.analysis.roofline import words_to_bytes
    assert words_to_bytes(10) == 40.0
    spec = ConvSpec(N=4, c_I=8, c_O=16, w_O=10, h_O=10, w_F=3, h_F=3,
                    prec=INT8_SPEC.precision)
    ep = Planner(TPU_V5E).plan(spec)
    per = words_to_bytes({"input": 1000, "output": 1000}, dtypes=ep.dtypes)
    assert per["input"] == 1000.0    # int8: one byte per element
    assert per["output"] == 2000.0   # bf16: two


# ---------------------------------------------------------------------------
# VRF013 lint
# ---------------------------------------------------------------------------

_BAD_KERNEL = """
import jax.numpy as jnp
def k(acc_ref, o_ref):
    bad = acc_ref[...].astype(jnp.bfloat16)
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)  # fine: dynamic dtype
"""


def test_vrf013_flags_narrow_accumulator_cast_in_kernels():
    from repro.verify.lint import lint_file
    with tempfile.TemporaryDirectory() as d:
        root = pathlib.Path(d)
        kfile = root / "kernels" / "bad.py"
        kfile.parent.mkdir()
        kfile.write_text(_BAD_KERNEL)
        found = [v for v in lint_file(kfile, root) if v.code == "VRF013"]
        assert len(found) == 1 and found[0].line == 4
        # same source outside kernels/ is out of scope for the rule
        other = root / "other.py"
        other.write_text(_BAD_KERNEL)
        assert not [v for v in lint_file(other, root) if v.code == "VRF013"]


def test_vrf013_registry_requires_accum_dtype():
    from repro.ops.registry import OpCapabilities
    from repro.verify import lint

    class _FakeEntry:
        def __init__(self, caps):
            self.caps = caps
            self.fn = lambda ctx, plan: None
            self.words_fn = object()
            self.access_plan_fn = object()

    class _FakeBackend:
        name = "fake"
        fallback = None

        def __init__(self, caps):
            self.ops = {"conv2d_q": _FakeEntry(caps)}

    def check(caps):
        import unittest.mock as mock
        backend = _FakeBackend(caps)
        with mock.patch.object(lint, "_FLAG_PARAMS", {}), \
                mock.patch("repro.ops.registry.backends",
                           lambda: ("fake",)), \
                mock.patch("repro.ops.registry.get_backend",
                           lambda name: backend):
            return [v for v in lint.lint_registry() if v.code == "VRF013"]

    assert check(OpCapabilities(dtypes=("int8",)))          # no accum: flags
    assert check(OpCapabilities(dtypes=("int8",),
                                accum_dtype="bfloat16"))    # narrow: flags
    assert not check(OpCapabilities(dtypes=("int8",),
                                    accum_dtype="float32"))  # fine
    assert not check(OpCapabilities(dtypes=("*",)))          # unquantized

    # and the real registry is clean
    assert not [v for v in lint.lint_registry() if v.code == "VRF013"]


# ---------------------------------------------------------------------------
# quantized paged KV pool
# ---------------------------------------------------------------------------

def test_quantized_block_words_capacity_gain():
    cfg = get_smoke("stablelm_1_6b")
    for hd in (64, 128):
        c = dataclasses.replace(cfg, head_dim=hd)
        assert c.hd == hd
        gain = kv.block_words(c, 16) / kv.block_words(c, 16, quantized=True)
        assert gain >= 1.8, f"hd={hd}: capacity gain {gain:.2f} < 1.8"


def test_plan_pool_blocks_quantized_packs_more():
    cfg = dataclasses.replace(get_smoke("stablelm_1_6b"), head_dim=64)
    # a budget small enough that HBM, not want, binds the pool size
    tiny = dataclasses.replace(
        TPU_V5E, hbm_words=64 * kv.block_words(cfg, 16))
    bf = kv.plan_pool_blocks(cfg, 256, 64, 16, target=tiny)
    q = kv.plan_pool_blocks(cfg, 256, 64, 16, target=tiny, quantized=True)
    assert (q - 1) >= 1.8 * (bf - 1)  # net of the reserved garbage block


def test_engine_kv_dtype_validation():
    params, cfg = _params_and_cfg("stablelm_1_6b")
    with pytest.raises(ValueError, match="kv_dtype"):
        Engine(cfg, params, max_len=32, batch_size=1, kv_dtype="fp4")
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, params, max_len=32, batch_size=1, paged=False,
               kv_dtype="int8")


def _params_and_cfg(arch):
    cfg = dataclasses.replace(get_smoke(arch), compute_dtype="float32")
    return T.init_params(KEY, cfg), cfg


def test_int8_pool_serving_matches_bf16_tokens():
    """The documented quality gate: greedy decode from the int8 pool must
    reproduce the bf16 pool's tokens on the smoke config (per-row scales
    keep the KV error below the greedy decision margin here)."""
    params, cfg = _params_and_cfg("stablelm_1_6b")
    prompts = [np.array([3, 1, 4, 1, 5], np.int32), np.array([7], np.int32),
               np.array([2, 7, 1], np.int32)]

    def run(kv_dtype):
        eng = Engine(cfg, params, max_len=64, batch_size=3, paged=True,
                     kv_dtype=kv_dtype)
        reqs = [Request(prompt=p, max_new_tokens=8) for p in prompts]
        eng.serve(reqs)
        return [list(r.out_tokens) for r in reqs]

    assert run("int8") == run("bf16")
