"""Per-arch smoke tests (reduced configs, one forward + train step on CPU,
shape and finiteness assertions) + block-level equivalence tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import ssm, xlstm
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.moe import moe_block, moe_block_dense_ref, init_moe

KEY = jax.random.PRNGKey(0)
B, L = 2, 32


def _batch(cfg):
    if cfg.inputs_are_embeddings:
        b = {"embeds": 0.1 * jax.random.normal(KEY, (B, L, cfg.d_model),
                                               jnp.float32)}
        if cfg.causal:
            b["tokens"] = jax.random.randint(KEY, (B, L), 0, cfg.vocab_size)
        else:
            b["labels"] = jax.random.randint(KEY, (B, L), 0, cfg.vocab_size)
        return b
    return {"tokens": jax.random.randint(KEY, (B, L), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward(arch):
    cfg = get_smoke(arch)
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    logits, cache, aux = T.forward(params, cfg, tokens=batch.get("tokens"),
                                   embeds=batch.get("embeds"), n_groups=2)
    assert logits.shape == (B, L, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert cache is None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    from repro.train.optimizer import AdamWConfig, init_state
    from repro.train.trainer import TrainConfig, make_train_step

    cfg = get_smoke(arch)
    params = T.init_params(KEY, cfg)
    opt = init_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3),
                                   TrainConfig(n_groups=2, remat=True)))
    p2, o2, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))), jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)),
            params, p2), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen2_5_3b", "xlstm_1_3b",
                                  "jamba_1_5_large", "olmoe_1b_7b"])
def test_decode_matches_full_forward(arch):
    """Teacher-forced decode through the cache must equal the full forward.
    capacity_factor is raised so MoE archs drop no tokens: capacity depends
    on the token count, so prefill-vs-full drop patterns would differ (a
    documented MoE property, not a cache bug)."""
    cfg = get_smoke(arch)
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              capacity_factor=8.0)
    params = T.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, 24), 0, cfg.vocab_size)

    full_logits, _, _ = T.forward(params, cfg, tokens=toks)

    cache = T.init_cache(cfg, B, 24, dtype=jnp.float32)
    pre = 16
    logits_p, cache, _ = T.forward(params, cfg, tokens=toks[:, :pre],
                                   cache=cache,
                                   cache_index=jnp.zeros((), jnp.int32))
    outs = [logits_p]
    for t in range(pre, 24):
        lg, cache, _ = T.forward(params, cfg, tokens=toks[:, t:t + 1],
                                 cache=cache,
                                 cache_index=jnp.asarray(t, jnp.int32),
                                 decode=True)
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_left_padded_forward_with_mask_matches_solo():
    """The left-pad fix at the source: with attn_mask + per-row positions a
    padded batch scores each row exactly as the row scored alone."""
    cfg = dataclasses.replace(get_smoke("stablelm_1_6b"),
                              compute_dtype="float32")
    params = T.init_params(KEY, cfg)
    p1 = np.array([3, 1, 4, 1, 5], np.int32)
    p2 = np.array([7], np.int32)
    L = len(p1)
    padded = np.zeros((2, L), np.int32)
    padded[0] = p1
    padded[1, L - len(p2):] = p2
    mask = np.zeros((2, L), bool)
    mask[0] = True
    mask[1, L - len(p2):] = True
    pads = np.array([0, L - len(p2)], np.int32)
    positions = np.arange(L, dtype=np.int32)[None, :] - pads[:, None]

    lg, _, _ = T.forward(params, cfg, tokens=jnp.asarray(padded),
                         attn_mask=jnp.asarray(mask),
                         positions=jnp.asarray(positions))
    for row, prompt in ((0, p1), (1, p2)):
        solo, _, _ = T.forward(params, cfg, tokens=jnp.asarray(prompt)[None])
        np.testing.assert_allclose(np.asarray(lg[row, -1]),
                                   np.asarray(solo[0, -1]),
                                   rtol=2e-4, atol=2e-4)


def test_masked_cached_prefill_ignores_pad_tail():
    """Right-padded prefill into a cache with attn_mask: pad keys in the
    written window are never attended, so real-token logits match solo."""
    cfg = dataclasses.replace(get_smoke("stablelm_1_6b"),
                              compute_dtype="float32")
    params = T.init_params(KEY, cfg)
    p1 = np.array([3, 1, 4, 1, 5], np.int32)
    solo, _, _ = T.forward(params, cfg, tokens=jnp.asarray(p1)[None])
    cache = T.init_cache(cfg, 1, 16, dtype=jnp.float32)
    padded = np.concatenate([p1, [0, 0, 0]])[None]
    mask = np.array([[True] * len(p1) + [False] * 3])
    lg, cache, _ = T.forward(params, cfg, tokens=jnp.asarray(padded),
                             cache=cache, cache_index=jnp.zeros((), jnp.int32),
                             attn_mask=jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(lg[0, len(p1) - 1]),
                               np.asarray(solo[0, -1]), rtol=2e-4, atol=2e-4)


def test_param_counts_match_published():
    expect = {
        "qwen2_5_3b": 3.4e9, "phi3_medium_14b": 14.7e9,
        "phi3_5_moe_42b": 42e9, "olmoe_1b_7b": 6.9e9,
        "jamba_1_5_large": 398e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.06, f"{arch}: {got / 1e9:.1f}B vs {n / 1e9}B"


def test_active_params_moe():
    assert get_config("phi3_5_moe_42b").active_param_count() == \
        pytest.approx(6.6e9, rel=0.05)
    assert get_config("jamba_1_5_large").active_param_count() == \
        pytest.approx(94e9, rel=0.05)


def test_chunked_loss_matches_dense():
    cfg = get_smoke("stablelm_1_6b")
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = T.init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (B, L), 0, cfg.vocab_size)}
    dense, _ = T.loss_fn(params, cfg, batch, aux_weight=0.0)
    chunked, _ = T.loss_fn(params, cfg, batch, aux_weight=0.0, loss_chunks=4)
    np.testing.assert_allclose(float(dense), float(chunked), rtol=1e-5)


def test_unroll_env_equivalence(monkeypatch):
    """REPRO_UNROLL_SCANS must not change numerics, only the lowering."""
    cfg = get_smoke("xlstm_1_3b")
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = T.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, L), 0, cfg.vocab_size)
    l1, _, _ = T.forward(params, cfg, tokens=toks)
    monkeypatch.setenv("REPRO_UNROLL_SCANS", "1")
    l2, _, _ = jax.jit(lambda p, t: T.forward(p, cfg, tokens=t))(params, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# block-level equivalences (chunked vs sequential oracles)
# ---------------------------------------------------------------------------

def _tiny_cfg(**kw):
    base = dict(name="t", family="ssm", n_layers=1, d_model=32, n_heads=2,
                n_kv_heads=2, d_ff=64, vocab_size=128, head_dim=16,
                ssm_state_dim=8, chunk_size=8, compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_mamba_chunked_equals_sequential():
    cfg = _tiny_cfg(pattern=("mamba",))
    p = ssm.init_mamba(KEY, cfg)
    x = 0.5 * jax.random.normal(KEY, (2, 21, 32), jnp.float32)
    y1, _ = ssm.mamba_block(p, x, cfg)
    y2 = ssm.mamba_block_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_chunked_equals_sequential():
    cfg = _tiny_cfg(pattern=("mlstm",), n_heads=4, n_kv_heads=4, d_ff=0)
    p = xlstm.init_mlstm(KEY, cfg)
    x = 0.5 * jax.random.normal(KEY, (2, 21, 32), jnp.float32)
    y1, _ = xlstm.mlstm_block(p, x, cfg)
    y2 = xlstm.mlstm_block_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_moe_matches_dense_at_high_capacity():
    cfg = _tiny_cfg(pattern=("attn",), d_model=16, d_ff=32, n_experts=4,
                    experts_per_token=2, capacity_factor=8.0, head_dim=None,
                    n_heads=2, n_kv_heads=2)
    p = init_moe(KEY, cfg)
    x = 0.5 * jax.random.normal(KEY, (2, 12, 16), jnp.float32)
    out, aux = moe_block(p, x, cfg, n_groups=2)
    want = moe_block_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0.9  # load-balance loss ~= 1 for near-uniform routing


def test_moe_drops_tokens_at_tight_capacity():
    cfg = _tiny_cfg(pattern=("attn",), d_model=16, d_ff=32, n_experts=4,
                    experts_per_token=2, capacity_factor=0.5, head_dim=None,
                    n_heads=2, n_kv_heads=2)
    p = init_moe(KEY, cfg)
    x = 0.5 * jax.random.normal(KEY, (2, 32, 16), jnp.float32)
    out, _ = moe_block(p, x, cfg, n_groups=1)
    want = moe_block_dense_ref(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    # with capacity 0.5 some tokens MUST have been dropped
    assert not np.allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_fused_kv_cache_decode_matches():
    """The fused (B,KV,L,2,hd) cache layout (§Perf decode variant) must be
    numerically identical to the split k/v layout."""
    cfg = dataclasses.replace(get_smoke("qwen2_5_3b"),
                              compute_dtype="float32", fused_kv_cache=True)
    params = T.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    full, _, _ = T.forward(params, cfg, tokens=toks)
    cache = T.init_cache(cfg, 2, 16, dtype=jnp.float32)
    lg, cache, _ = T.forward(params, cfg, tokens=toks[:, :8], cache=cache,
                             cache_index=jnp.zeros((), jnp.int32))
    outs = [lg]
    for t in range(8, 16):
        lg, cache, _ = T.forward(params, cfg, tokens=toks[:, t:t + 1],
                                 cache=cache,
                                 cache_index=jnp.asarray(t, jnp.int32),
                                 decode=True)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
