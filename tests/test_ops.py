"""The repro.ops dispatch subsystem: backend agreement for every registered
op, capability fallback (observable via explain/record_dispatch), environment
resolution, precision policy, and the deprecation shim."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.kernels import ref
from repro.plan import CPU_INTERPRET, GEMMINI, MatmulSpec, Planner, TPU_V5E
from repro.models import layers
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(0)
K2 = jax.random.PRNGKey(1)
K3 = jax.random.PRNGKey(2)

XLA = ops.ExecutionContext(target=TPU_V5E, backend="xla")
PALLAS = ops.ExecutionContext(target=TPU_V5E, backend="pallas")


# ---------------------------------------------------------------------------
# One parametrized sweep: every non-xla backend agrees with the xla oracle
# for EVERY registered op it implements (replaces the per-kernel agreement
# tests; partial backends like im2col are swept only on their own entries —
# the rest would just re-test xla through the fallback chain).
# ---------------------------------------------------------------------------

def _quantize_pool(pool):
    """Per-(block, head, position) int8 quantization of a KV pool leaf —
    the layout the engine's quantizing insert writes."""
    from repro.quant import quantize_symmetric

    flat = pool.reshape(-1, pool.shape[-1])
    q, s = quantize_symmetric(flat, axis=0)
    return (q.reshape(pool.shape), s.reshape(pool.shape[:-1]))


def _op_case(op: str):
    """Canonical inputs + call kwargs for one registered op."""
    if op == "matmul":
        return (jax.random.normal(KEY, (64, 96)),
                jax.random.normal(K2, (96, 128))), {}
    if op == "conv2d":
        return (jax.random.normal(KEY, (2, 8, 12, 12)),
                jax.random.normal(K2, (16, 8, 3, 3))), {"stride": (1, 1)}
    if op == "conv1d_causal":
        return (jax.random.normal(KEY, (2, 33, 130)),
                jax.random.normal(K2, (4, 130))), {}
    if op == "attention":  # GQA shape: exercises the repeat-free group fold
        return (jax.random.normal(KEY, (2, 8, 33, 16)) * 0.3,
                jax.random.normal(K2, (2, 2, 33, 16)) * 0.3,
                jax.random.normal(K3, (2, 2, 33, 16))), {"causal": True}
    if op == "attention_decode":  # paged decode: block-table gather + lengths
        return (jax.random.normal(KEY, (2, 4, 1, 16)) * 0.3,
                jax.random.normal(K2, (7, 2, 16, 16)) * 0.3,
                jax.random.normal(K3, (7, 2, 16, 16)),
                jnp.asarray([[1, 3, 0], [4, 2, 6]], jnp.int32),
                jnp.asarray([20, 45], jnp.int32)), {}
    if op == "matmul_q":  # int8 streams + folded per-column scale
        from repro.quant import quantize_matmul_operands

        a = jax.random.normal(KEY, (64, 96))
        b = jax.random.normal(K2, (96, 128))
        return quantize_matmul_operands(a, b), {}
    if op == "conv2d_q":
        from repro.quant import quantize_conv_operands

        x = jax.random.normal(KEY, (2, 8, 12, 12))
        w = jax.random.normal(K2, (16, 8, 3, 3))
        return quantize_conv_operands(x, w), {"stride": (1, 1)}
    if op == "attention_decode_quant":  # int8 pools + per-position scales
        kp, ks = _quantize_pool(jax.random.normal(K2, (7, 2, 16, 16)) * 0.3)
        vp, vs = _quantize_pool(jax.random.normal(K3, (7, 2, 16, 16)))
        return (jax.random.normal(KEY, (2, 4, 1, 16)) * 0.3, kp, ks, vp, vs,
                jnp.asarray([[1, 3, 0], [4, 2, 6]], jnp.int32),
                jnp.asarray([20, 45], jnp.int32)), {}
    if op == "conv2d_dist":  # P=1 grid: the mesh is one device, so the
        # sweep runs on any host; the real multi-device grids live in
        # tests/test_distributed.py under the CI distributed job
        from repro.core.conv_model import ConvShape
        from repro.core.parallel_tiling import ParallelBlocking

        shape = ConvShape(N=2, c_I=8, c_O=16, h_O=10, w_O=10, h_F=3, w_F=3)
        return (jax.random.normal(KEY, (2, 8, 12, 12)),
                jax.random.normal(K2, (16, 8, 3, 3))), {
                    "stride": (1, 1),
                    "blocking": ParallelBlocking.from_grid(shape, {})}
    raise NotImplementedError(
        f"op {op!r} is registered but has no agreement-sweep case; add one")


@pytest.mark.parametrize("backend", [b for b in ops.backends() if b != "xla"])
@pytest.mark.parametrize("op", ops.registered_ops())
def test_backends_agree(op, backend):
    if op not in ops.get_backend(backend).ops:
        pytest.skip(f"{backend} serves {op} through the fallback chain")
    args, kw = _op_case(op)
    fn = getattr(ops, op)
    ctx = ops.ExecutionContext(target=TPU_V5E, backend=backend)
    got_x = np.asarray(fn(*args, ctx=XLA, **kw))
    got_b = np.asarray(fn(*args, ctx=ctx, **kw))
    np.testing.assert_allclose(got_x, got_b, rtol=2e-3, atol=2e-3,
                               err_msg=f"xla and {backend} disagree on {op}")


def test_every_registered_op_is_swept():
    assert set(ops.backends()) == {"xla", "pallas", "im2col"}
    assert set(ops.registered_ops()) == {
        "matmul", "conv2d", "conv1d_causal", "attention", "attention_decode",
        "attention_decode_quant", "conv2d_q", "matmul_q", "conv2d_dist"}
    for op in ops.registered_ops():
        _op_case(op)  # raises if an op was registered without a sweep case


# ---------------------------------------------------------------------------
# GQA group folding (the jnp.repeat replacement)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("H,Hkv,Lq,Lk,causal", [
    (8, 2, 33, 33, True), (4, 1, 17, 17, True), (8, 8, 16, 16, True),
    (6, 3, 20, 20, False),
])
def test_pallas_gqa_grouping_matches_oracle(H, Hkv, Lq, Lk, causal):
    q = jax.random.normal(KEY, (2, H, Lq, 16)) * 0.3
    k = jax.random.normal(K2, (2, Hkv, Lk, 16)) * 0.3
    v = jax.random.normal(K3, (2, Hkv, Lk, 16))
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    got = ops.attention(q, k, v, causal=causal, ctx=PALLAS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Capability dispatch: decode offsets are pallas-native since the paged-KV
# PR (scalar-prefetched into the kernel); only key masks still fall back.
# ---------------------------------------------------------------------------

def test_explain_decode_offsets_stay_on_pallas():
    # static prefill call: pallas serves it
    assert ops.explain("attention", PALLAS).chosen == "pallas"
    # in-cache decode: the traced q_offset is scalar-prefetched -> no fallback
    needs = ops.attention_needs(q_offset=jnp.asarray(5, jnp.int32))
    dec = ops.explain("attention", PALLAS, needs=needs)
    assert dec.requested == "pallas" and dec.chosen == "pallas"
    assert not dec.missing and not dec.fell_back
    # continuous-batching decode: per-row offsets are served natively too
    needs = ops.attention_needs(q_offset=jnp.arange(4))
    assert ops.explain("attention", PALLAS, needs=needs).chosen == "pallas"
    # padded prefill: key mask still falls back to masked XLA by capability
    dec = ops.explain("attention", PALLAS, needs=("key_mask",))
    assert dec.chosen == "xla" and "key_mask" in dec.missing and dec.fell_back
    assert "xla" in dec.why()


def _tiny_cfg():
    return ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                       param_dtype="float32", compute_dtype="float32")


def test_in_cache_decode_stays_on_pallas_end_to_end():
    """The PR-6 acceptance check: the in-cache decode path dispatches to
    pallas with NO capability fallback (PR 3 sent it to masked XLA), and the
    two backends agree numerically; REPRO_BACKEND=xla still selects the old
    masked-XLA path as the *requested* backend, not a fallback."""
    cfg = _tiny_cfg()
    p = layers.init_attention(KEY, cfg)
    x = jax.random.normal(K2, (2, 1, cfg.d_model))
    kv = (jax.random.normal(K3, (2, 2, 16, cfg.hd)) * 0.3,
          jax.random.normal(KEY, (2, 2, 16, cfg.hd)))
    with ops.record_dispatch() as log:
        out_p, _ = layers.attention_block(p, x, cfg,
                                          positions=jnp.asarray([3]),
                                          cache=kv, cache_index=jnp.asarray(3),
                                          ctx=PALLAS)
    att = [d for d in log if d.op == "attention"]
    assert att and att[-1].requested == "pallas"
    assert att[-1].chosen == "pallas" and not att[-1].fell_back
    with ops.record_dispatch() as log:
        out_x, _ = layers.attention_block(p, x, cfg,
                                          positions=jnp.asarray([3]),
                                          cache=kv, cache_index=jnp.asarray(3),
                                          ctx=XLA)
    att = [d for d in log if d.op == "attention"]
    assert att and att[-1].chosen == "xla" and not att[-1].fell_back
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_x),
                               rtol=2e-3, atol=2e-3)
    # ...and the no-cache prefill path stays on pallas as before
    with ops.record_dispatch() as log:
        layers.attention_block(p, x, cfg, positions=jnp.asarray([0]),
                               ctx=PALLAS)
    att = [d for d in log if d.op == "attention"]
    assert att and att[-1].chosen == "pallas" and not att[-1].fell_back


def test_paged_decode_explain_no_fallback_and_bound(monkeypatch):
    """Pooled decode dispatch is shape-only explainable: pallas chosen with
    no fallback, measured decode words reported against the Lq=1 attention
    bound; forcing REPRO_BACKEND=xla picks xla as requested (no fallback)."""
    spec = (jax.ShapeDtypeStruct((2, 4, 1, 16), jnp.bfloat16),
            jax.ShapeDtypeStruct((7, 2, 16, 16), jnp.bfloat16),
            jax.ShapeDtypeStruct((7, 2, 16, 16), jnp.bfloat16),
            jax.ShapeDtypeStruct((2, 3), jnp.int32),
            jax.ShapeDtypeStruct((2,), jnp.int32))
    dec = ops.explain("attention_decode", PALLAS, spec_args=spec)
    assert dec.chosen == "pallas" and not dec.fell_back
    assert dec.measured_words is not None and dec.plan is not None
    assert dec.bound_ratio == pytest.approx(
        dec.measured_words / dec.plan.lower_bound, rel=1e-6)
    assert "HBM words" in dec.why()
    monkeypatch.setenv(ops.BACKEND_ENV, "xla")
    dec = ops.explain("attention_decode", ops.ExecutionContext(target=TPU_V5E),
                      spec_args=spec)
    assert dec.requested == "xla" and dec.chosen == "xla"
    assert not dec.fell_back


def test_pallas_backend_is_differentiable():
    """pallas_call has no JVP rule, so the pallas entries wrap the kernel in
    custom_vjp with an XLA-recompute backward: gradients match the pure-XLA
    path even through lax.scan (where call-time fallback could never work
    because scan differentiates its traced jaxpr, not the python)."""
    a = jax.random.normal(KEY, (16, 24))
    b = jax.random.normal(K2, (24, 8))

    def loss(ctx):
        def f(a_):
            out = ops.matmul(a_, b, ctx=ctx)
            s, _ = jax.lax.scan(lambda c, _: (c + ops.matmul(
                a_, b, ctx=ctx).sum(), None), 0.0, None, length=2)
            return out.sum() + s
        return jax.grad(f)(a)

    g_p = loss(PALLAS)
    g_x = loss(XLA)
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_x),
                               rtol=1e-4, atol=1e-4)

    q = jax.random.normal(KEY, (1, 4, 16, 8)) * 0.3
    k = jax.random.normal(K2, (1, 2, 16, 8)) * 0.3
    v = jax.random.normal(K3, (1, 2, 16, 8))
    ga = jax.grad(lambda q_: ops.attention(q_, k, v, ctx=PALLAS).sum())(q)
    gx = jax.grad(lambda q_: ops.attention(q_, k, v, ctx=XLA).sum())(q)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gx),
                               rtol=1e-3, atol=1e-3)


def test_dispatch_resolves_execution_plan():
    a = jax.random.normal(KEY, (128, 64))
    b = jax.random.normal(K2, (64, 256))
    dec = ops.explain("matmul", PALLAS, spec_args=(a, b))
    assert dec.plan is not None
    want = Planner(TPU_V5E).plan(MatmulSpec(128, 256, 64,
                                            prec=dec.plan.op.prec))
    assert dec.plan is want  # same memoized object: one process-wide cache
    # xla delegates tiling to the compiler: no LP plan resolved
    assert ops.explain("matmul", XLA, spec_args=(a, b)).plan is None


# ---------------------------------------------------------------------------
# Measured HBM-word counters: every instrumented dispatch reports words
# moved next to the paper's lower bound.
# ---------------------------------------------------------------------------

def test_explain_reports_measured_words_vs_bound():
    xs = jax.ShapeDtypeStruct((8, 64, 30, 30), jnp.bfloat16)
    ws = jax.ShapeDtypeStruct((64, 64, 3, 3), jnp.bfloat16)
    kw = {"spec_args": (xs, ws), "spec_kw": {"stride": (1, 1)}}
    tiled = ops.explain("conv2d", PALLAS, **kw)
    im2col = ops.explain("conv2d", ops.ExecutionContext(
        target=TPU_V5E, backend="im2col"), **kw)
    for dec in (tiled, im2col):
        assert dec.measured_words is not None and dec.plan is not None
        assert dec.bound_ratio == pytest.approx(
            dec.measured_words / dec.plan.lower_bound, rel=1e-6)
        assert "HBM words" in dec.why() and "lower bound" in dec.why()
    # both entries report against the identical conv plan/lower bound,
    # and the LP tiling moves fewer words than the im2col baseline
    assert tiled.plan is im2col.plan
    assert tiled.measured_words < im2col.measured_words
    # xla is not instrumented (the compiler owns its data movement)
    assert ops.explain("conv2d", XLA, **kw).measured_words is None


def test_record_dispatch_captures_measured_words():
    a = jax.random.normal(KEY, (64, 32))
    b = jax.random.normal(K2, (32, 48))
    with ops.record_dispatch() as log:
        ops.matmul(a, b, ctx=PALLAS)
    mm = [d for d in log if d.op == "matmul"]
    assert mm and mm[-1].measured_words is not None
    assert mm[-1].measured_words >= mm[-1].plan.lower_bound * 0.5


# ---------------------------------------------------------------------------
# ExecutionContext: resolution order, env vars, precision policy
# ---------------------------------------------------------------------------

def test_backend_resolution_order(monkeypatch):
    monkeypatch.delenv(ops.BACKEND_ENV, raising=False)
    # target default
    assert ops.ExecutionContext(target=TPU_V5E).resolved_backend() == "pallas"
    assert ops.ExecutionContext(target=CPU_INTERPRET).resolved_backend() == "xla"
    # env overrides target
    monkeypatch.setenv(ops.BACKEND_ENV, "xla")
    assert ops.ExecutionContext(target=TPU_V5E).resolved_backend() == "xla"
    # explicit override beats env
    assert ops.ExecutionContext(target=TPU_V5E,
                                backend="pallas").resolved_backend() == "pallas"
    assert ops.default_context().resolved_backend() == "xla"
    monkeypatch.setenv(ops.BACKEND_ENV, "nope")
    with pytest.raises(ValueError):
        ops.ExecutionContext().resolved_backend()


def test_legacy_env_var_retired(monkeypatch):
    # the PR-3 REPRO_USE_PALLAS shim is gone: the name is no longer exported
    # and setting the variable changes nothing
    monkeypatch.delenv(ops.BACKEND_ENV, raising=False)
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    assert not hasattr(ops, "LEGACY_BACKEND_ENV")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert ops.env_backend() is None


def test_resolved_pins_backend(monkeypatch):
    monkeypatch.setenv(ops.BACKEND_ENV, "pallas")
    pinned = ops.ExecutionContext(target=CPU_INTERPRET).resolved()
    monkeypatch.delenv(ops.BACKEND_ENV)
    assert pinned.backend == "pallas"  # env read once, cache-key safe


def test_precision_policy_dtypes():
    assert ops.ExecutionContext(target=TPU_V5E).stream_dtype == jnp.bfloat16
    assert ops.ExecutionContext(target=TPU_V5E).acc_dtype == jnp.float32
    assert ops.ExecutionContext(target=GEMMINI).stream_dtype == jnp.int8
    assert ops.ExecutionContext(target=CPU_INTERPRET).stream_dtype == jnp.float32
    # out dtype of a dispatched op defaults to the policy's accumulator
    a = jax.random.normal(KEY, (8, 8), jnp.bfloat16)
    assert ops.matmul(a, a, ctx=XLA).dtype == jnp.float32


# ---------------------------------------------------------------------------
# The use_pallas= shim (kernels/ops.py) is gone: ExecutionContext is the one
# way to pick a backend.
# ---------------------------------------------------------------------------

def test_use_pallas_shim_removed():
    import repro.kernels as kernels

    with pytest.raises(ImportError):
        from repro.kernels import ops as _legacy  # noqa: F401
    assert not hasattr(kernels, "ops")
