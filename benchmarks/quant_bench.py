"""Quantization gate: int8 storage must move the measured words AND the
bound, not just shrink arrays.

Three record groups, all deterministic (explicit contexts, no wall clock),
so the rows are identical on every CI leg:

1. ``conv_q/*`` — the five ResNet-50 shapes dispatched as int8 ``conv2d_q``
   (audited: the static auditor must reproduce the mixed-precision words_fn
   exactly, scale vector included) next to the bf16 ``conv2d`` baseline.
   Gates: ``words_vs_bf16_ratio <= 0.55`` and ``bound_ratio <= 1.3`` on
   every shape — the kernel must realize the re-priced Thm 2.1 bound, not
   merely store smaller tensors.
2. ``kv_pool`` — paged-pool blocks plannable from one binding HBM budget,
   bf16 vs the int8+per-row-scale layout. Gate: ``capacity_gain >= 1.8``
   (named without a ``_words``/``_ratio`` suffix on purpose: higher is
   better, so it is gated here, not by ``benchmarks.compare``'s
   lower-is-better rule).
3. ``kv_quality`` — greedy serving from the int8 pool vs the bf16 pool on
   the smoke config (explicit XLA context on every leg). Gate:
   ``token_match >= 0.95``; the committed baseline documents the measured
   value (1.0 — exact on this config, the quality tolerance README's
   mixed-precision section states).

CLI (the CI quant gate):

    PYTHONPATH=src python -m benchmarks.quant_bench --json BENCH_quant.json

exits 2 if any gate fails.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.configs import get_smoke
from repro.configs.resnet50_convs import RESNET50
from repro.plan import TPU_V5E
from repro.serving import kv

PALLAS = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
XLA = ops.ExecutionContext(target=TPU_V5E, backend="xla")

WORDS_GATE = 0.55   # int8 conv words vs bf16, every ResNet-50 shape
BOUND_GATE = 1.3    # audited words vs the mixed-precision Thm 2.1 bound
CAPACITY_GATE = 1.8  # int8 pool blocks vs bf16 from the same HBM budget
QUALITY_GATE = 0.95  # greedy token agreement, int8 pool vs bf16 pool


def sweep_conv_q():
    """ResNet-50 shapes: audited int8 conv2d_q vs the bf16 conv2d words."""
    records = []
    for lname, s in RESNET50.items():
        H = (s.h_O - 1) * s.sh + s.h_F
        W = (s.w_O - 1) * s.sw + s.w_F
        x8 = jax.ShapeDtypeStruct((s.N, s.c_I, H, W), jnp.int8)
        w8 = jax.ShapeDtypeStruct((s.c_O, s.c_I, s.h_F, s.w_F), jnp.int8)
        sc = jax.ShapeDtypeStruct((1, s.c_O), jnp.float32)
        dq = ops.explain("conv2d_q", PALLAS, dtype="int8",
                         spec_args=(x8, w8, sc),
                         spec_kw={"stride": (s.sh, s.sw)}, audit=True)
        xb = jax.ShapeDtypeStruct((s.N, s.c_I, H, W), jnp.bfloat16)
        wb = jax.ShapeDtypeStruct((s.c_O, s.c_I, s.h_F, s.w_F), jnp.bfloat16)
        db = ops.explain("conv2d", PALLAS, spec_args=(xb, wb),
                         spec_kw={"stride": (s.sh, s.sw)})
        records.append({
            "name": f"conv_q/{lname}",
            "int8_words": dq.measured_words,
            "bf16_words": db.measured_words,
            "words_vs_bf16_ratio": dq.measured_words / db.measured_words,
            "bound_ratio": dq.bound_ratio,
            "audited_exactly": dq.audited == dq.measured_words,
        })
    return records


def _pool_cfg():
    return dataclasses.replace(get_smoke("stablelm_1_6b"), head_dim=64,
                               compute_dtype="float32")


def sweep_kv_pool():
    """Blocks one binding HBM budget buys, bf16 layout vs int8+scales."""
    cfg = _pool_cfg()
    tiny = dataclasses.replace(TPU_V5E,
                               hbm_words=256 * kv.block_words(cfg, 16))
    bf = kv.plan_pool_blocks(cfg, 512, 256, 16, target=tiny)
    q = kv.plan_pool_blocks(cfg, 512, 256, 16, target=tiny, quantized=True)
    return [{
        "name": "kv_pool",
        "bf16_blocks": bf - 1,  # net of the reserved garbage block
        "int8_blocks": q - 1,
        "capacity_gain": (q - 1) / (bf - 1),
        "block_words_bf16": kv.block_words(cfg, 16),
        "block_words_int8": kv.block_words(cfg, 16, quantized=True),
    }]


def sweep_kv_quality():
    """Greedy tokens from the int8 pool vs the bf16 pool, same requests."""
    from repro.models import transformer as T
    from repro.serving.engine import Engine, Request

    cfg = dataclasses.replace(get_smoke("stablelm_1_6b"),
                              compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([7], np.int32),
               np.array([2, 7, 1], np.int32)]

    def run(kv_dtype):
        eng = Engine(cfg, params, max_len=64, batch_size=3, paged=True,
                     ctx=XLA, kv_dtype=kv_dtype)
        reqs = [Request(prompt=p, max_new_tokens=12) for p in prompts]
        eng.serve(reqs)
        return [np.asarray(r.out_tokens) for r in reqs]

    bf, q = run("bf16"), run("int8")
    match = float(np.mean([np.mean(a == b) for a, b in zip(bf, q)]))
    return [{"name": "kv_quality", "token_match": match,
             "requests": len(prompts), "new_tokens": 12}]


def gate(records) -> list:
    bad = []
    for r in records:
        name = r["name"]
        if name.startswith("conv_q/"):
            if r["words_vs_bf16_ratio"] > WORDS_GATE:
                bad.append(f"{name}: int8/bf16 words "
                           f"{r['words_vs_bf16_ratio']:.3f} > {WORDS_GATE}")
            if r["bound_ratio"] > BOUND_GATE:
                bad.append(f"{name}: bound ratio {r['bound_ratio']:.3f} > "
                           f"{BOUND_GATE}")
            if not r["audited_exactly"]:
                bad.append(f"{name}: audited words != words_fn")
        elif name == "kv_pool" and r["capacity_gain"] < CAPACITY_GATE:
            bad.append(f"kv_pool: capacity gain {r['capacity_gain']:.2f} < "
                       f"{CAPACITY_GATE}")
        elif name == "kv_quality" and r["token_match"] < QUALITY_GATE:
            bad.append(f"kv_quality: token match {r['token_match']:.3f} < "
                       f"{QUALITY_GATE}")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_quant.json", metavar="PATH",
                    help="write sweep records to PATH")
    args = ap.parse_args(argv)

    records = sweep_conv_q() + sweep_kv_pool() + sweep_kv_quality()
    with open(args.json, "w") as f:
        json.dump(records, f, indent=1)
    for r in records:
        if r["name"].startswith("conv_q/"):
            print(f"{r['name']:16s} int8={r['int8_words']:.3e}w "
                  f"bf16={r['bf16_words']:.3e}w "
                  f"ratio={r['words_vs_bf16_ratio']:.3f} "
                  f"bound={r['bound_ratio']:.2f}x")
        elif r["name"] == "kv_pool":
            print(f"kv_pool          bf16={r['bf16_blocks']} blocks "
                  f"int8={r['int8_blocks']} blocks "
                  f"gain={r['capacity_gain']:.2f}x")
        else:
            print(f"kv_quality       token_match={r['token_match']:.3f} "
                  f"({r['requests']} reqs x {r['new_tokens']} tokens)")
    print(f"wrote {len(records)} records to {args.json}")

    bad = gate(records)
    if bad:
        for b in bad:
            print(f"FAIL: {b}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
