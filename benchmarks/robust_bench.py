"""Robustness benchmark: graceful degradation under a seeded fault campaign.

Two kinds of rows, mirroring ``serving_bench``:

**Degradation-cost sweep (deterministic, gated).** Every runtime demotion
has a *priced* communication cost: when a transient fault quarantines the
pallas conv entry, ``dispatch_call`` re-resolves through its declared
``degrade_to`` chain (im2col), and the decision's ``measured_words`` /
``bound_ratio`` are re-priced for the degraded kernel. The sweep records
that cost for the ResNet-50 shapes (the paper's §5 set) straight from
``ops.explain`` on both backends — the 3.9-7.2x words gap a degraded
dispatch pays — plus one *live* row: a rate-1.0 launch campaign actually
faults an eager conv2d, and the row records the repriced decision the
quarantined dispatcher then reports. All fields are static word counts,
identical on every CI leg.

**Fault campaign (floor-gated).** The serving workload runs twice on the
same engine configuration — fault-free, then under a seeded transient-fault
campaign (default: 5% rate over launch/dma/numeric/oom/pool at every
scheduling site). The gate requires:

  * completion rate >= 0.99 (no aborts: any taxonomy escape fails the run),
  * zero unresolved injections (``FaultCampaign.verify_accounted``),
  * completed requests BIT-IDENTICAL to the fault-free run, failed ones a
    clean prefix of it (retries are idempotent, rebuilds exact),
  * faulted tok/s >= 0.4x the fault-free tok/s on the same leg.

CLI (the CI chaos gate):

    PYTHONPATH=src python -m benchmarks.robust_bench --campaign \\
        --json BENCH_robust.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.configs.resnet50_convs import RESNET50
from repro.plan import CPU_INTERPRET, TPU_V5E
from repro.resilience import faults as fj

PALLAS = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
IM2COL = ops.ExecutionContext(target=TPU_V5E, backend="im2col")

DEFAULT_SPEC = "rate=0.05,seed=0,kinds=launch+dma+numeric+oom+pool"
COMPLETION_FLOOR = 0.99
TOK_S_FLOOR = 0.4  # faulted >= 0.4x clean on the same leg

# serving workload: small enough for the pallas-interpret leg, mixed enough
# to exercise admission, lockstep decode, and finish at distinct depths
MAX_LEN = 64
BATCH = 4
N_REQUESTS = 12
PROMPT_LENS = (4, 9, 14)
MAX_NEWS = (6, 12, 16)


# ---------------------------------------------------------------------------
# Degradation cost: the words a demoted dispatch pays, per ResNet-50 shape
# ---------------------------------------------------------------------------

def degradation_rows(dtype=jnp.bfloat16) -> List[dict]:
    records = []
    for lname, s in RESNET50.items():
        H = (s.h_O - 1) * s.sh + s.h_F  # tight VALID input extent
        W = (s.w_O - 1) * s.sw + s.w_F
        xs = jax.ShapeDtypeStruct((s.N, s.c_I, H, W), dtype)
        ws = jax.ShapeDtypeStruct((s.c_O, s.c_I, s.h_F, s.w_F), dtype)
        kw = {"spec_args": (xs, ws), "spec_kw": {"stride": (s.sh, s.sw)}}
        primary = ops.explain("conv2d", PALLAS, **kw)
        degraded = ops.explain("conv2d", IM2COL, **kw)
        assert primary.chosen == "pallas" and degraded.chosen == "im2col"
        records.append({
            "name": f"degrade/{lname}",
            "primary_words": primary.measured_words,
            "degraded_words": degraded.measured_words,
            "primary_bound_ratio": primary.bound_ratio,
            "degraded_bound_ratio": degraded.bound_ratio,
            "degradation_cost_ratio":
                degraded.measured_words / primary.measured_words,
        })
    return records


def live_degradation_row() -> dict:
    """Actually fault a launch and record the repriced decision.

    A rate-1.0 launch campaign faults the eager pallas conv2d once;
    ``dispatch_call`` quarantines it and serves the call through im2col.
    The row captures what ``ops.explain`` then reports for the same shape:
    ``degraded=True``, the fault name, and measured words / bound ratio
    repriced at the degraded entry. Word counters are static, so the row is
    leg-independent despite executing for real."""
    ctx = ops.ExecutionContext(target=CPU_INTERPRET, backend="pallas")
    x = jnp.ones((2, 8, 12, 12), jnp.float32)
    w = jnp.ones((8, 8, 3, 3), jnp.float32)
    kw = {"spec_args": (jax.ShapeDtypeStruct(x.shape, x.dtype),
                        jax.ShapeDtypeStruct(w.shape, w.dtype)),
          "spec_kw": {"stride": (1, 1), "out_dtype": jnp.float32}}
    ops.clear_quarantine()
    before = ops.explain("conv2d", ctx, **kw)
    camp = fj.FaultCampaign(seed=0, rate=1.0, kinds=("launch",),
                            ops=("conv2d",), max_faults=1)
    with fj.activate(camp):
        y_faulted = ops.conv2d(x, w, ctx=ctx)
    camp.verify_accounted()
    after = ops.explain("conv2d", ctx, **kw)
    assert after.degraded and after.fault == "KernelLaunchError", after
    # the degraded path must still be numerically the same conv
    y_clean = ops.conv2d(x, w, ctx=ops.ExecutionContext(
        target=CPU_INTERPRET, backend="im2col"))
    np.testing.assert_allclose(np.asarray(y_faulted), np.asarray(y_clean),
                               rtol=1e-5, atol=1e-5)
    ops.clear_quarantine()
    return {
        "name": "degrade/live_conv2d",
        "fault": after.fault,
        "primary_words": before.measured_words,
        "degraded_words": after.measured_words,
        "primary_bound_ratio": before.bound_ratio,
        "degraded_bound_ratio": after.bound_ratio,
        "degradation_cost_ratio":
            after.measured_words / before.measured_words,
    }


# ---------------------------------------------------------------------------
# Fault campaign over the serving engine
# ---------------------------------------------------------------------------

def _smoke_cfg():
    from repro.configs import get_smoke
    return dataclasses.replace(get_smoke("qwen2_5_3b"),
                               compute_dtype="float32")


def _workload(cfg) -> List:
    from repro.serving.engine import Request
    rng = np.random.default_rng(0)
    return [Request(
        prompt=rng.integers(0, cfg.vocab_size, size=PROMPT_LENS[i % 3],
                            dtype=np.int64).astype(np.int32),
        max_new_tokens=MAX_NEWS[i % 3], temperature=0.0, rng_seed=i)
        for i in range(N_REQUESTS)]


def _serve(cfg, params, camp: Optional[fj.FaultCampaign]):
    from repro.serving.engine import Engine
    ops.clear_quarantine()  # each run prices its own degradations
    eng = Engine(cfg, params, max_len=MAX_LEN, batch_size=BATCH)
    reqs = _workload(cfg)
    if camp is None:
        t0 = time.perf_counter()
        eng.serve(reqs)
        dt = time.perf_counter() - t0
    else:
        with fj.activate(camp):
            t0 = time.perf_counter()
            eng.serve(reqs)
            dt = time.perf_counter() - t0
    return reqs, dt


def campaign_row(spec: str) -> tuple:
    """(record, problems) for the clean-vs-faulted serving comparison."""
    from repro.models import transformer as T

    cfg = _smoke_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    # warmup both paths: the faulted warmup (same seed -> same schedule)
    # traces the retry/rebuild-only shapes, so the timed runs compare
    # scheduling cost rather than one-off jit compilations
    _serve(cfg, params, None)
    _serve(cfg, params, fj.campaign_from_spec(spec))
    clean, dt_clean = _serve(cfg, params, None)
    camp = fj.campaign_from_spec(spec)
    faulted, dt_faulted = _serve(cfg, params, camp)
    camp.verify_accounted()  # raises if any handler swallowed a fault

    problems: List[str] = []
    done = [r for r in faulted
            if r.finish_reason not in ("error", "timeout")]
    completion = len(done) / len(faulted)
    if completion < COMPLETION_FLOOR:
        problems.append(f"completion rate {completion:.3f} below "
                        f"{COMPLETION_FLOOR} under {spec!r}")
    mismatched = 0
    for c, f in zip(clean, faulted):
        c_toks = np.asarray(c.out_tokens)
        if f.finish_reason in ("error", "timeout"):
            # a failed request keeps a clean prefix, never invented tokens
            if not np.array_equal(f.out_tokens,
                                  c_toks[:len(f.out_tokens)]):
                mismatched += 1
        elif (f.finish_reason != c.finish_reason
              or not np.array_equal(f.out_tokens, c_toks)):
            mismatched += 1
    if mismatched:
        problems.append(f"{mismatched} request(s) diverged from the "
                        "fault-free run (retries must be idempotent, "
                        "rebuilds exact)")
    toks = lambda rs: sum(len(r.out_tokens) for r in rs  # noqa: E731
                          if r.out_tokens is not None)
    tok_s_clean = toks(clean) / dt_clean
    tok_s_faulted = toks(faulted) / dt_faulted
    if tok_s_faulted < TOK_S_FLOOR * tok_s_clean:
        problems.append(f"faulted tok/s {tok_s_faulted:.1f} below "
                        f"{TOK_S_FLOOR}x clean {tok_s_clean:.1f}")
    if not camp.injections:
        problems.append(f"campaign {spec!r} injected nothing — the gate "
                        "is vacuous (raise rate or workload size)")
    # tok/s fields deliberately avoid _words/_ratio suffixes: compare.py
    # must never gate wall clock; the floors above run in-process instead
    record = {
        "name": "campaign/serving",
        "spec": spec,
        "requests": len(faulted),
        "completion_rate": completion,
        "faults_injected": len(camp.injections),
        "faults_unresolved": len(camp.unresolved()),
        "resolutions": camp.summary()["resolutions"],
        "unaffected_mismatches": mismatched,
        "tok_s_clean": tok_s_clean,
        "tok_s_faulted": tok_s_faulted,
    }
    return record, problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_robust.json", metavar="PATH",
                    help="write degradation + campaign records to PATH")
    ap.add_argument("--campaign", nargs="?", const=DEFAULT_SPEC, default=None,
                    metavar="SPEC",
                    help="run the serving fault campaign (REPRO_FAULTS-style "
                         f"spec; bare flag = {DEFAULT_SPEC!r})")
    args = ap.parse_args(argv)

    bad: List[str] = []
    records = degradation_rows()
    records.append(live_degradation_row())
    for r in records:
        print(f"{r['name']:22s} primary={r['primary_words']:.3e}w "
              f"degraded={r['degraded_words']:.3e}w "
              f"cost={r['degradation_cost_ratio']:.2f}x")
        if r["degradation_cost_ratio"] <= 1.0:
            bad.append(f"{r['name']}: degradation is free — the fallback "
                       "chain is mispriced or inverted")
    if args.campaign:
        rec, problems = campaign_row(args.campaign)
        bad.extend(problems)
        records.append(rec)
        print(f"{rec['name']:22s} completion={rec['completion_rate']:.3f} "
              f"injected={rec['faults_injected']} "
              f"unresolved={rec['faults_unresolved']} "
              f"tok/s={rec['tok_s_faulted']:.1f} "
              f"(clean {rec['tok_s_clean']:.1f}) "
              f"resolutions={rec['resolutions']}")
    with open(args.json, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {len(records)} records to {args.json}")
    if bad:
        print("FAIL:", file=sys.stderr)
        for b in bad:
            print(f"  {b}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
