"""Serving benchmark: paged KV-cache decode vs the PR-2 slot-pool engine,
with measured decode HBM words gated against the paper's attention bound.

Two kinds of rows, mirroring ``conv_bench``:

**Shape sweep (deterministic, gated).** Decode-state snapshots dispatched
through ``ops.explain`` with ``jax.ShapeDtypeStruct`` specs under an explicit
pallas context, so the records are identical on every CI leg regardless of
``REPRO_BACKEND``. Each snapshot reports the paged ``attention_decode``
kernel's measured HBM words (block-table gather over ``w`` live blocks) next
to the contiguous in-cache decode's words (full ``max_len`` stream) and the
Lq = 1 specialization of Thm 2.1 (``core.bounds.attention_bound``), whose
memory-independent KV-stream term dominates decode. A pool-occupancy row
charges a shared prompt prefix once (refcounted blocks) vs per-request.

**Throughput (informational + floor-gated).** The same mixed workload served
by the wave baseline, the slot-pool engine (``paged=False``), and the paged
engine; tok/s fields deliberately avoid the ``_words``/``_ratio`` suffixes
so ``compare.py`` never gates wall-clock noise, but ``main`` enforces a
paged >= 0.75x slot-pool floor.

CLI (the CI serving gate):

    PYTHONPATH=src python -m benchmarks.serving_bench --json BENCH_serving.json

exits nonzero if paged decode moves >= the contiguous words on any snapshot,
the measured/bound ratio drifts, prefix sharing stops saving pool words, or
paged tok/s falls below the slot-pool floor.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.plan import TPU_V5E
from repro.serving import kv

PALLAS = ops.ExecutionContext(target=TPU_V5E, backend="pallas")

# Two prompt-length buckets keep the prefill jit count small while still
# exercising mixed depths; heterogeneous output budgets make wave lockstep
# waste steps on drained rows; four requests share a full-block prefix so
# the paged engine exercises refcounted sharing in the timed run.
PROMPT_LENS = (4, 12)
MAX_NEWS = (8, 56)
N_REQUESTS = 12
N_SHARED = 4
SHARED_PREFIX = 16
BATCH = 4
# The serving window: paged decode reads w live blocks per step while the
# contiguous engine streams the whole max_len window, so the paged win grows
# with max_len - live_tokens. 512 is past the CPU-smoke crossover (~256)
# where block-gather graph overhead is repaid by the smaller KV stream.
MAX_LEN = 512
BLOCK = kv.DEFAULT_BLOCK_SIZE


# ---------------------------------------------------------------------------
# Shape sweep: measured decode words vs the attention bound
# ---------------------------------------------------------------------------

# (name, batch, live tokens per row) decode snapshots under MAX_LEN:
# early decode (1 live block), the bench workload's depth, a deep sequence.
SNAPSHOTS = (
    ("decode/B4_len12", 4, 12),
    ("decode/B4_len50", 4, 50),
    ("decode/B4_len200", 4, 200),
)


def _smoke_cfg():
    from repro.configs import get_smoke
    return dataclasses.replace(get_smoke("qwen2_5_3b"),
                               compute_dtype="float32")


def sweep(dtype=jnp.bfloat16):
    cfg = _smoke_cfg()
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    num_blocks = kv.plan_pool_blocks(cfg, MAX_LEN, BATCH, BLOCK)
    records = []
    for name, B, live in SNAPSHOTS:
        w = -(-live // BLOCK)
        q = jax.ShapeDtypeStruct((B, H, 1, hd), dtype)
        paged = ops.explain(
            "attention_decode", PALLAS,
            spec_args=(q,
                       jax.ShapeDtypeStruct((num_blocks, KV, BLOCK, hd), dtype),
                       jax.ShapeDtypeStruct((num_blocks, KV, BLOCK, hd), dtype),
                       jax.ShapeDtypeStruct((B, w), jnp.int32),
                       jax.ShapeDtypeStruct((B,), jnp.int32)))
        # the contiguous engine streams the whole max_len cache window each
        # step (per-row offsets, pallas-native since this PR)
        contig = ops.explain(
            "attention", PALLAS,
            needs=ops.attention_needs(q_offset=jnp.arange(B)),
            spec_args=(q,
                       jax.ShapeDtypeStruct((B, KV, MAX_LEN, hd), dtype),
                       jax.ShapeDtypeStruct((B, KV, MAX_LEN, hd), dtype)),
            spec_kw={"q_offset": jnp.full((B,), live, jnp.int32)})
        assert paged.chosen == "pallas" and not paged.fell_back
        assert contig.chosen == "pallas" and not contig.fell_back
        records.append({
            "name": name,
            "live_tokens": live,
            "table_width": w,
            "paged_words": paged.measured_words,
            "contig_words": contig.measured_words,
            "lower_bound": paged.plan.lower_bound,
            "paged_bound_ratio": paged.bound_ratio,
            "paged_over_contig_ratio":
                paged.measured_words / contig.measured_words,
        })
    # pool occupancy: N_SHARED requests sharing a SHARED_PREFIX-token system
    # prompt; refcounted blocks charge the prefix once
    bw = kv.block_words(cfg, BLOCK)
    alloc = kv.BlockAllocator(num_blocks)
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, size=SHARED_PREFIX)
    naive_blocks = 0
    for i in range(N_SHARED):
        toks = list(shared) + list(rng.integers(1, cfg.vocab_size, size=4 + i))
        need = -(-len(toks) // BLOCK)
        naive_blocks += need
        blocks = []
        for key in kv.prefix_chain(toks, BLOCK):
            hit = alloc.lookup(key)
            if hit is not None:
                blocks.append(alloc.ref(hit))
                continue
            b = alloc.alloc()
            alloc.register(b, key)
            blocks.append(b)
        while len(blocks) < need:
            blocks.append(alloc.alloc())
    records.append({
        "name": "pool/shared_prefix",
        "requests": N_SHARED,
        "prefix_tokens": SHARED_PREFIX,
        "shared_pool_words": alloc.used_words(bw),
        "naive_pool_words": naive_blocks * bw,
        "shared_over_naive_ratio":
            alloc.used_words(bw) / (naive_blocks * bw),
    })
    return records


# ---------------------------------------------------------------------------
# Throughput: wave vs slot-pool vs paged on one mixed workload
# ---------------------------------------------------------------------------

def _workload(cfg, seed: int = 0) -> List:
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(N_REQUESTS):
        plen = PROMPT_LENS[i % len(PROMPT_LENS)]
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, size=plen,
                                dtype=np.int64).astype(np.int32),
            max_new_tokens=MAX_NEWS[i % len(MAX_NEWS)],
            temperature=0.0))
    shared = rng.integers(0, cfg.vocab_size, size=SHARED_PREFIX,
                          dtype=np.int64).astype(np.int32)
    for i in range(N_SHARED):
        tail = rng.integers(0, cfg.vocab_size, size=2 + i,
                            dtype=np.int64).astype(np.int32)
        reqs.append(Request(prompt=np.concatenate([shared, tail]),
                            max_new_tokens=8, temperature=0.0))
    return reqs


def _run(mk_engine, cfg, params, seed: int):
    eng = mk_engine(cfg, params)
    reqs = _workload(cfg, seed=seed)
    t0 = time.perf_counter()
    eng.serve(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    return toks, dt


def throughput():
    from repro.models import transformer as T
    from repro.serving.engine import Engine, WaveEngine

    cfg = _smoke_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    mks = {
        "wave": lambda c, p: WaveEngine(c, p, max_len=MAX_LEN,
                                        batch_size=BATCH, paged=False),
        "slotpool": lambda c, p: Engine(c, p, max_len=MAX_LEN,
                                        batch_size=BATCH, paged=False),
        "paged": lambda c, p: Engine(c, p, max_len=MAX_LEN,
                                     batch_size=BATCH, paged=True),
    }
    out = {}
    for name, mk in mks.items():
        _run(mk, cfg, params, seed=1)  # warmup: jit ladder incl. table widths
        # best-of-3: the engines run identical tokens every repeat, so min
        # wall clock is the scheduling cost with the least OS noise
        toks, dt = min((_run(mk, cfg, params, seed=0) for _ in range(3)),
                       key=lambda td: td[1])
        out[name] = (toks, dt, toks / dt)
    return out


def run(csv_rows: list) -> None:
    for r in sweep():
        if r["name"].startswith("decode/"):
            csv_rows.append((
                f"serving/words/{r['name']}", "0",
                f"paged={r['paged_words']:.3e}w "
                f"({r['paged_bound_ratio']:.2f}x bound) "
                f"contig={r['contig_words']:.3e}w "
                f"paged/contig={r['paged_over_contig_ratio']:.2f}x"))
        else:
            csv_rows.append((
                f"serving/{r['name']}", "0",
                f"shared={r['shared_pool_words']:.3e}w "
                f"naive={r['naive_pool_words']:.3e}w "
                f"({r['shared_over_naive_ratio']:.2f}x)"))
    tp = throughput()
    for name, (toks, dt, tps) in tp.items():
        csv_rows.append((f"serving/{name}", f"{dt * 1e6:.0f}",
                         f"tok_s={tps:.1f} tokens={toks}"))
    csv_rows.append(("serving/speedup", "0",
                     f"paged_over_slotpool={tp['paged'][2] / tp['slotpool'][2]:.2f}x "
                     f"continuous_over_wave={tp['slotpool'][2] / tp['wave'][2]:.2f}x"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_serving.json", metavar="PATH",
                    help="write sweep + throughput records to PATH")
    ap.add_argument("--skip-throughput", action="store_true",
                    help="shape sweep only (no model execution)")
    args = ap.parse_args(argv)
    records = sweep()
    bad = []
    for r in records:
        if r["name"].startswith("decode/"):
            print(f"{r['name']:18s} paged={r['paged_words']:.3e}w "
                  f"({r['paged_bound_ratio']:.2f}x bound) "
                  f"contig={r['contig_words']:.3e}w "
                  f"gap={r['paged_over_contig_ratio']:.2f}x")
            if r["paged_words"] >= r["contig_words"]:
                bad.append(f"{r['name']}: paged moves >= contiguous words")
            if r["paged_bound_ratio"] > 1.2:
                bad.append(f"{r['name']}: measured decode words "
                           f"{r['paged_bound_ratio']:.2f}x off the "
                           f"attention bound")
        else:
            print(f"{r['name']:18s} shared={r['shared_pool_words']:.3e}w "
                  f"naive={r['naive_pool_words']:.3e}w")
            if r["shared_pool_words"] >= r["naive_pool_words"]:
                bad.append(f"{r['name']}: prefix sharing saves no pool words")
    if not args.skip_throughput:
        tp = throughput()
        rec = {"name": "throughput/mixed"}
        for name, (toks, dt, tps) in tp.items():
            print(f"throughput/{name:9s} tok_s={tps:.1f} tokens={toks}")
            rec[f"tok_s_{name}"] = tps
            rec[f"tokens_{name}"] = toks
        rec["paged_speedup"] = tp["paged"][2] / tp["slotpool"][2]
        records.append(rec)
        # a floor, not a compare.py metric: wall clock is noisy on shared CI
        if tp["paged"][2] < 0.75 * tp["slotpool"][2]:
            bad.append(f"throughput: paged tok/s {tp['paged'][2]:.1f} below "
                       f"0.75x slot-pool {tp['slotpool'][2]:.1f}")
    with open(args.json, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {len(records)} records to {args.json}")
    if bad:
        print("FAIL:", file=sys.stderr)
        for b in bad:
            print(f"  {b}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
