"""Serving throughput: slot-based continuous batching vs the wave-lockstep
baseline on a mixed workload (short + long prompts, heterogeneous
``max_new_tokens``) — the decode-axis analogue of the paper's
keep-every-processor-busy argument.

Both engines run the same corrected primitives and share compiled steps
(``serving.engine._make_steps`` caches per (cfg, max_len, ctx)), so
the measured difference is pure scheduling: the wave engine barriers a full
batch until its slowest request drains, continuous batching refills freed
slots mid-flight. A warmup pass populates the jit caches before timing.

Rows:
  serving/wave        - baseline tok/s (real generated tokens / wall clock)
  serving/continuous  - slot engine tok/s on the identical workload
  serving/speedup     - continuous over wave
"""

from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import numpy as np

# Two prompt-length buckets keep the prefill jit count at 2 while still
# exercising mixed depths; the output budgets are strongly heterogeneous so
# wave lockstep wastes steps on drained rows.
PROMPT_LENS = (4, 12)
MAX_NEWS = (4, 24)
N_REQUESTS = 12
BATCH = 4
MAX_LEN = 64


def _workload(cfg, seed: int = 0) -> List:
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(N_REQUESTS):
        plen = PROMPT_LENS[i % len(PROMPT_LENS)]
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, size=plen,
                                dtype=np.int64).astype(np.int32),
            max_new_tokens=MAX_NEWS[i % len(MAX_NEWS)],
            temperature=0.0))
    return reqs


def _run(engine_cls, cfg, params, seed: int):
    eng = engine_cls(cfg, params, max_len=MAX_LEN, batch_size=BATCH)
    reqs = _workload(cfg, seed=seed)
    t0 = time.perf_counter()
    eng.serve(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    return toks, dt


def run(csv_rows: list) -> None:
    from repro.configs import get_smoke
    from repro.models import transformer as T
    from repro.serving.engine import Engine, WaveEngine

    cfg = dataclasses.replace(get_smoke("qwen2_5_3b"),
                              compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    # warmup: populate the shared jit caches (both prompt buckets + decode)
    for cls in (WaveEngine, Engine):
        _run(cls, cfg, params, seed=1)

    toks_w, dt_w = _run(WaveEngine, cfg, params, seed=0)
    toks_c, dt_c = _run(Engine, cfg, params, seed=0)
    tps_w, tps_c = toks_w / dt_w, toks_c / dt_c
    csv_rows.append(("serving/wave", f"{dt_w * 1e6:.0f}",
                     f"tok_s={tps_w:.1f} tokens={toks_w}"))
    csv_rows.append(("serving/continuous", f"{dt_c * 1e6:.0f}",
                     f"tok_s={tps_c:.1f} tokens={toks_c}"))
    csv_rows.append(("serving/speedup", "0",
                     f"continuous_over_wave={tps_c / tps_w:.2f}x"))
