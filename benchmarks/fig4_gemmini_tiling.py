"""Paper Figure 4 analogue: LP-optimized tiling vs vendor-style tiling on the
five standard ResNet50 convolution sizes, under the GEMMINI buffer model
(256 KiB scratchpad / 64 KiB accumulator, double-buffered, int8 inputs with
32-bit accumulation) and under the TPU VMEM model.

The paper measures scratchpad-row traffic on FireSim; with no accelerator in
this container we report the same *estimated communication* the paper uses as
its energy proxy ("our system consistently uses between 45% and 85% as much
estimated communication compared to the vendor tiling"). The vendor proxy is
a greedy channel-first power-of-two tiler (the shape GEMMINI's supplied
tiler produces when it cannot reason about reuse).
"""

from __future__ import annotations

import time

from repro.core.conv_model import INT8_ACC32, BF16_ACC32, resnet50_layers
from repro.core.tiling import Blocking
from repro.plan import GEMMINI, TPU_V5E, ConvSpec, Planner


def vendor_tiling(shape, mem) -> Blocking:
    d = Blocking.lifted_bounds(shape)
    b = {k: 1 for k in d}
    for k in ("cO", "cI", "wO", "hO", "N"):
        while b[k] * 2 <= d[k]:
            b[k] *= 2
            if not Blocking(b, shape).fits(mem):
                b[k] //= 2
                break
    return Blocking(b, shape)


def run(csv_rows: list) -> None:
    for target, prec in ((GEMMINI, INT8_ACC32), (TPU_V5E, BF16_ACC32)):
        mem = target.memory_model()
        for lname, s in resnet50_layers(1000).items():
            s = s.with_precision(prec)
            t0 = time.perf_counter()
            ours = Planner(target).plan(ConvSpec.from_shape(s))
            dt_us = (time.perf_counter() - t0) * 1e6
            vend = vendor_tiling(s, mem)
            ours_v, vend_v = ours.comm_volume, vend.comm_volume()
            csv_rows.append((
                f"fig4/{target.name}/{lname}", f"{dt_us:.0f}",
                f"ours={ours_v:.3e}w vendor={vend_v:.3e}w "
                f"ratio={ours_v / vend_v:.2f} eff={ours.efficiency:.2f} "
                f"tile={ours.conv_tile()}"))


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
