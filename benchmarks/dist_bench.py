"""Paper §4.2 in *measured* inter-device words: the executed halo-exchange
conv (``repro.distributed``) vs. the naive all-gather baseline on the fig3
shapes (ResNet-50 conv1 / conv2_x, batch 1000), against the combined
Thm 2.2/2.3 per-processor bound, on an 8-fake-device host mesh.

This is the measured companion of ``benchmarks/fig3_parallel.py``: where
fig3 prints the *symbolic* per-processor volumes of five algorithms, every
row here comes from a launch geometry the ``shard_map`` paths actually lower
(halo ``ppermute`` volume + cI ``psum`` volume per device — the counter
``ops.explain("conv2d_dist", ...)`` reports), so no 1000-image arrays are
materialized for the sweep. A scaled-down shape also runs end-to-end on the
8-device mesh (halo vs. all-gather vs. the single-device reference) for
wall-clock rows and a live correctness check.

CLI (the CI ``distributed`` job's gate):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.dist_bench --json BENCH_dist.json

exits nonzero unless, on every swept shape, the halo-exchange conv moves
strictly fewer measured inter-device words than the all-gather baseline AND
stays within 2.0x of the Thm 2.2/2.3 bound (when the bound is non-trivial).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

N_DEVICES = 8  # the CI mesh; sweep rows are geometry-only and device-free

BOUND_SLACK = 2.0  # acceptance: measured halo words <= 2.0x the bound

# Per-processor local memory for the bound column: fig3's setting (2^20
# words). At the TPU target's own M_eff the combined bound is negative
# (trivial) for every fig3 shape at P=8 — the plan's ``parallel`` section
# reports that faithfully — so the bench gates against the paper's figure
# configuration, where the bound is live.
BOUND_M = float(2 ** 20)

# informational probe grids (exercise the halo/psum legs even when the LP
# prefers pure data parallelism for a shape)
PROBE_GRIDS = ({"hO": 4, "wO": 2}, {"cI": 2, "hO": 2, "wO": 2})


def _records(dtype_words: float = 0.5):
    """Measured-words records for the fig3 shapes at P=8, bf16 streams."""
    import jax
    import jax.numpy as jnp

    from repro import ops
    from repro.configs.resnet50_convs import RESNET50
    from repro.core.bounds import combined_parallel_bound
    from repro.core.conv_model import BF16_ACC32
    from repro.core.parallel_tiling import (ParallelBlocking,
                                            optimize_parallel_blocking)
    from repro.distributed import (DIST_AXES, allgather_comm_words,
                                   conv2d_dist_comm_words)
    from repro.plan import TPU_V5E

    dtype = jnp.bfloat16 if dtype_words == 0.5 else jnp.float32
    records = []
    for lname in ("conv1", "conv2_x"):  # the fig3 sweep
        s = RESNET50[lname].with_precision(BF16_ACC32)
        H = (s.h_O - 1) * s.sh + s.h_F  # tight VALID input extent
        W = (s.w_O - 1) * s.sw + s.w_F
        xs = jax.ShapeDtypeStruct((s.N, s.c_I, H, W), dtype)
        ws = jax.ShapeDtypeStruct((s.c_O, s.c_I, s.h_F, s.w_F), dtype)
        lp = optimize_parallel_blocking(s, N_DEVICES, restrict_axes=DIST_AXES)
        grids = [("lp", lp)] + [
            (f"probe{i}", ParallelBlocking.from_grid(s, g))
            for i, g in enumerate(PROBE_GRIDS)]
        for tag, pb in grids:
            grid = {k: v for k, v in pb.grid.items() if v > 1}
            ctx = ops.ExecutionContext(
                target=TPU_V5E.with_mesh(
                    tuple((ax, pb.grid.get(ax, 1)) for ax in DIST_AXES)),
                backend="pallas")
            kw = {"spec_args": (xs, ws),
                  "spec_kw": {"stride": (s.sh, s.sw), "blocking": pb}}
            dec = ops.explain("conv2d_dist", ctx, dtype=jnp.dtype(dtype).name,
                              **kw)
            halo = dec.measured_words
            ag = allgather_comm_words(xs, ws, stride=(s.sh, s.sw),
                                      blocking=pb)
            lb = combined_parallel_bound(s, N_DEVICES, BOUND_M)
            assert halo == conv2d_dist_comm_words(
                xs, ws, stride=(s.sh, s.sw), blocking=pb)
            records.append({
                "name": f"{lname}/{tag}",
                "layer": lname,
                "gate": tag == "lp",  # acceptance applies to the LP grid
                "grid": grid,
                "shape": f"N{s.N} {s.c_I}->{s.c_O} {s.h_O}x{s.w_O} "
                         f"f{s.h_F}x{s.w_F} s{s.sh}",
                "halo_words": halo,
                "allgather_words": ag,
                "model_words": pb.comm_per_processor(),
                "lower_bound": lb,
                "halo_ratio": (halo / lb) if lb and lb > 0 else None,
                "halo_over_allgather": halo / ag if ag else None,
            })
    return records


def sweep():
    return _records()


def _live_rows(csv_rows: list) -> None:
    """Execute halo vs. all-gather on the real 8-device mesh (small shape)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import distributed, ops
    from repro.core.parallel_tiling import ParallelBlocking
    from repro.launch.mesh import make_conv_mesh
    from repro.plan import TPU_V5E

    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 26, 26), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 3, 3), jnp.float32)
    ref = np.asarray(ops.conv2d(
        x, w, ctx=ops.ExecutionContext(target=TPU_V5E, backend="xla")))
    pb = distributed.default_blocking(x.shape, w.shape, (1, 1),
                                      P_devices=len(jax.devices()))
    forced = ParallelBlocking.from_grid(pb.shape, {"cI": 2, "hO": 2, "wO": 2})
    for tag, blocking in (("lp", pb), ("spatial", forced)):
        mesh = make_conv_mesh(blocking)
        f_h = jax.jit(lambda a, b, bl=blocking, m=mesh: distributed.halo_conv(
            a, b, blocking=bl, mesh=m, local_backend="xla"))
        f_a = jax.jit(lambda a, b, bl=blocking, m=mesh:
                      distributed.allgather_conv(a, b, blocking=bl, mesh=m,
                                                 local_backend="xla"))
        for name, fn in (("halo", f_h), ("allgather", f_a)):
            got = np.asarray(jax.block_until_ready(fn(x, w)))
            np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)
            t0 = time.perf_counter()
            for _ in range(5):
                jax.block_until_ready(fn(x, w))
            us = (time.perf_counter() - t0) / 5 * 1e6
            grid = {k: v for k, v in blocking.grid.items() if v > 1}
            csv_rows.append((f"dist/exec_{name}/{tag}", f"{us:.0f}",
                             f"grid={grid} 8-device host mesh, xla shards"))


def run(csv_rows: list) -> None:
    """Geometry rows for the benchmark harness (device-count independent);
    the live execution rows join only when the process has the 8 devices
    the ``distributed`` CI job provides."""
    import jax

    for r in sweep():
        lbtxt = (f"{r['halo_ratio']:.2f}x bound"
                 if r["halo_ratio"] is not None else "bound trivial")
        csv_rows.append((
            f"dist/measured/{r['name']}", "0",
            f"halo={r['halo_words']:.3e}w ({lbtxt}) "
            f"allgather={r['allgather_words']:.3e}w "
            f"grid={r['grid']}"))
    if len(jax.devices()) >= N_DEVICES:
        _live_rows(csv_rows)


def main(argv=None) -> int:
    from repro.launch import fake_devices

    try:
        fake_devices(N_DEVICES)
    except RuntimeError as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 2

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_dist.json", metavar="PATH",
                    help="write sweep records to PATH")
    args = ap.parse_args(argv)
    records = sweep()
    with open(args.json, "w") as f:
        json.dump(records, f, indent=1)
    bad = []
    for r in records:
        ratio = (f"{r['halo_ratio']:.2f}x bound"
                 if r["halo_ratio"] is not None else "bound trivial")
        print(f"{r['name']:16s} grid={r['grid']} "
              f"halo={r['halo_words']:.3e}w ({ratio}) "
              f"allgather={r['allgather_words']:.3e}w")
        if not r["gate"]:
            continue
        if r["halo_words"] >= r["allgather_words"]:
            bad.append((r["name"], "halo >= allgather"))
        if r["halo_ratio"] is not None and r["halo_ratio"] > BOUND_SLACK:
            bad.append((r["name"], f"halo > {BOUND_SLACK}x Thm 2.2/2.3"))
    rows: list = []
    _live_rows(rows)  # correctness assert + wall rows on the live mesh
    for row in rows:
        print(",".join(row))
    print(f"wrote {len(records)} records to {args.json}")
    if bad:
        print(f"FAIL: {bad}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
