"""The §Roofline deliverable: read every dry-run artifact under
results/dryrun/ and emit the per-(arch x shape x mesh) three-term roofline
rows (also consumed by EXPERIMENTS.md)."""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_all() -> list:
    recs = []
    for fn in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def run(csv_rows: list) -> None:
    recs = load_all()
    if not recs:
        csv_rows.append(("roofline/none", "0",
                         "no dry-run artifacts: run python -m repro.launch.dryrun"))
        return
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        derived = (f"compute={r['compute_s'] * 1e3:.2f}ms "
                   f"memory={r['memory_s'] * 1e3:.2f}ms "
                   f"collective={r['collective_s'] * 1e3:.2f}ms "
                   f"dominant={r['dominant']} mfu={r['mfu']:.4f} "
                   f"useful={r['useful_flops_frac']:.3f}")
        csv_rows.append((name, f"{r.get('compile_s', 0) * 1e6:.0f}", derived))


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
