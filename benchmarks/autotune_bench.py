"""Autotuner gate: the measured frontier search must never lose to the
analytic LP plan, and production serving must never re-search.

For each of the five standard ResNet-50 shapes (batch 1000) the sweep
records the analytic plan (exact ``measured_words`` from ``ops.explain``,
priced on the offline alpha-beta model) next to the frontier winner the
autotuner picks under the deterministic roofline timer, then asserts the
paper-facing contract:

  * tuned wall time <= analytic wall time on *every* shape (the analytic
    tiles are always in the timed set, so a loss is a ranking bug), and
    strictly faster on at least two of the five;
  * tuned words <= 1.3x the Thm 2.1 lower bound (``AutotunePolicy.bound_cap``
    — tuning never leaves the audited near-bound regime); conv5_x's analytic
    optimum itself measures 1.35x the bound (irreducible halo + store
    overhead at 7x7 spatial), so there and only there the gate is "no worse
    than analytic";
  * a ``Planner.cache.save()`` / ``clear()`` / ``load()`` round trip followed
    by re-planning every shape runs **zero** new searches
    (``autotune.search_count()`` is the witness) and still serves the tuned
    tiles.

CLI (the CI bench-smoke gate; exit 2 on any violated contract):

    PYTHONPATH=src python -m benchmarks.autotune_bench \\
        --json BENCH_autotune.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

from repro import ops
from repro.configs.resnet50_convs import RESNET50
from repro.plan import (AutotunePolicy, ConvSpec, Planner, TPU_V5E,
                        predicted_seconds)
from repro.plan import autotune as plan_autotune

# the deterministic offline harness: same winner on every machine / CI leg
POLICY = AutotunePolicy(timer="roofline")

PALLAS = ops.ExecutionContext(target=TPU_V5E, backend="pallas")


def _explain(spec: ConvSpec, ctx):
    s = spec
    H = (s.h_O - 1) * s.sh + s.h_F  # tight VALID input extent
    W = (s.w_O - 1) * s.sw + s.w_F
    import jax
    import jax.numpy as jnp

    xs = jax.ShapeDtypeStruct((s.N, s.c_I, H, W), jnp.float32)
    ws = jax.ShapeDtypeStruct((s.c_O, s.c_I, s.h_F, s.w_F), jnp.float32)
    return ops.explain("conv2d", ctx, spec_args=(xs, ws),
                       spec_kw={"stride": (s.sh, s.sw)})


def sweep():
    """Analytic-vs-tuned records for every ResNet-50 shape. Starts from a
    cleared cache so the analytic rows are genuinely analytic."""
    Planner.cache.clear()
    records = []
    tuner = Planner(TPU_V5E, autotune=POLICY)
    for lname, s in RESNET50.items():
        spec = ConvSpec.from_shape(s)
        base = _explain(spec, PALLAS)
        assert base.plan_source == "analytic", base.plan_source
        base_secs = predicted_seconds(base.plan, base.measured_words)
        ep = tuner.plan(spec)
        assert ep.tuned is not None and ep.tuned.source == "roofline"
        after = _explain(spec, PALLAS)  # serving resolves the tuned winner
        assert after.plan_source == "tuned", after.plan_source
        assert after.measured_words == ep.tuned.winner_words
        records.append({
            "layer": lname,
            "shape": f"N{s.N} {s.c_I}->{s.c_O} {s.h_O}x{s.w_O} "
                     f"f{s.h_F}x{s.w_F} s{s.sh}",
            "analytic_words": base.measured_words,
            "tuned_words": ep.tuned.winner_words,
            "analytic_seconds": base_secs,
            "tuned_seconds": ep.tuned.winner_seconds,
            # higher-is-better speedup: named to dodge the compare.py
            # lower-is-better *_ratio/_seconds gates
            "time_gain": base_secs / max(ep.tuned.winner_seconds, 1e-30),
            "analytic_bound_ratio": base.measured_words / ep.lower_bound,
            "tuned_bound_ratio": ep.tuned.winner_words / ep.lower_bound,
            "candidates_timed": ep.tuned.candidates_timed,
            "analytic_tiles": list(base.plan.tiles),
            "tuned_tiles": list(ep.tiles),
        })
    return records


def check(records) -> list:
    """The gate: (layer, problem) pairs; empty means every contract holds."""
    bad = []
    strict = 0
    for r in records:
        if r["tuned_seconds"] > r["analytic_seconds"]:
            bad.append((r["layer"],
                        f"tuned {r['tuned_seconds']:.3e}s slower than "
                        f"analytic {r['analytic_seconds']:.3e}s"))
        elif r["tuned_seconds"] < r["analytic_seconds"]:
            strict += 1
        cap = max(POLICY.bound_cap, r["analytic_bound_ratio"])
        if r["tuned_bound_ratio"] > cap + 1e-9:
            bad.append((r["layer"],
                        f"tuned words {r['tuned_bound_ratio']:.3f}x bound "
                        f"exceed the {cap:.3f}x cap"))
    if strict < 2:
        bad.append(("sweep", f"tuned plan strictly faster on only {strict} "
                             "shape(s); need >= 2"))
    return bad


def check_zero_research(records) -> list:
    """save -> clear -> load -> re-plan every shape: zero new frontier
    searches, identical tuned tiles."""
    bad = []
    before = plan_autotune.search_count()
    fd, path = tempfile.mkstemp(suffix=".json", prefix="plan_cache_")
    os.close(fd)
    try:
        Planner.cache.save(path)
        Planner.cache.clear()
        Planner.cache.load(path)
        serving = Planner(TPU_V5E)  # no autotune policy: records must serve
        for r, (lname, s) in zip(records, RESNET50.items()):
            ep = serving.plan(ConvSpec.from_shape(s))
            if ep.tuned is None or list(ep.tiles) != r["tuned_tiles"]:
                bad.append((lname, "reloaded cache does not serve the tuned "
                                   f"winner (got tiles {list(ep.tiles)})"))
        delta = plan_autotune.search_count() - before
        if delta:
            bad.append(("sweep", f"{delta} re-search(es) after a save/clear/"
                                 "load round trip; serving must run zero"))
    finally:
        os.unlink(path)
    return bad


def run(csv_rows: list) -> None:
    for r in sweep():
        csv_rows.append((
            f"autotune/{r['layer']}", "0",
            f"analytic={r['analytic_seconds']:.3e}s "
            f"tuned={r['tuned_seconds']:.3e}s ({r['time_gain']:.2f}x) "
            f"words={r['tuned_bound_ratio']:.2f}x bound "
            f"cands={r['candidates_timed']} "
            f"tiles={tuple(r['tuned_tiles'])}"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_autotune.json", metavar="PATH",
                    help="write sweep records to PATH")
    args = ap.parse_args(argv)
    records = sweep()
    with open(args.json, "w") as f:
        json.dump(records, f, indent=1)
    for r in records:
        print(f"{r['layer']:9s} analytic={r['analytic_seconds']:.3e}s "
              f"tuned={r['tuned_seconds']:.3e}s ({r['time_gain']:.2f}x) "
              f"words={r['tuned_bound_ratio']:.2f}x bound "
              f"cands={r['candidates_timed']}")
    problems = check(records) + check_zero_research(records)
    print(f"wrote {len(records)} records to {args.json}; "
          f"{plan_autotune.search_count()} frontier search(es) total")
    if problems:
        print(f"FAIL: {len(problems)} autotune contract violation(s):",
              file=sys.stderr)
        for layer, desc in problems:
            print(f"  {layer}: {desc}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
