"""Paper Figure 3: parallel (distributed-memory) communication volumes for
ResNet50 conv1 / conv2_x as a multiple of the combined Thm 2.2/2.3 bound,
swept over processor count P.

Paper setting: p_I = p_F = 1, p_O = 2, batch 1000.

These are the *symbolic* per-processor volumes; ``benchmarks/dist_bench.py``
is the measured companion — the same shapes executed as a halo-exchange conv
under ``shard_map`` on an 8-fake-device mesh (``repro.distributed``), with
inter-device words counted from the launch geometry.
"""

from __future__ import annotations

import time

from repro.core.algorithms import parallel_volumes
from repro.core.conv_model import Precision, resnet50_layers

ALGS = ("naive", "im2col", "blocking", "winograd", "fft")


def run(csv_rows: list) -> None:
    prec = Precision(1.0, 1.0, 2.0)
    layers = resnet50_layers(1000)
    M = float(2 ** 20)
    for lname in ("conv1", "conv2_x"):
        s = layers[lname].with_precision(prec)
        for P in (4, 16, 64, 256, 1024):
            t0 = time.perf_counter()
            v = parallel_volumes(s, P, M)
            dt_us = (time.perf_counter() - t0) * 1e6
            lb = v["lower_bound"]
            if lb > 0:  # multiples of the bound, as in the paper's figure
                derived = ";".join(f"{a}={v[a] / lb:.2f}x" for a in ALGS)
            else:  # bound trivial at this P (paper: 'goes to 0 very quickly')
                derived = ";".join(f"{a}={v[a]:.2e}w" for a in ALGS)
            csv_rows.append((f"fig3/{lname}/P={P}", f"{dt_us:.0f}",
                             f"lb={lb:.3e}w {derived}"))


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
