"""Paper Figure 2: theoretically computed single-processor communication
volumes for mixed-precision ResNet50 conv1 / conv2_x relative to the Thm 2.1
lower bound, swept over cache size M.

Paper setting: p_I = p_F = 1, p_O = 2, batch 1000.
"""

from __future__ import annotations

import time

from repro.core.algorithms import single_processor_volumes
from repro.core.conv_model import Precision, resnet50_layers

ALGS = ("naive", "im2col", "blocking", "winograd", "fft")


def run(csv_rows: list) -> None:
    prec = Precision(1.0, 1.0, 2.0)
    layers = resnet50_layers(1000)
    for lname in ("conv1", "conv2_x"):
        s = layers[lname].with_precision(prec)
        for logM in range(14, 25, 2):
            M = float(2 ** logM)
            t0 = time.perf_counter()
            v = single_processor_volumes(s, M)
            dt_us = (time.perf_counter() - t0) * 1e6
            lb = v["lower_bound"]
            derived = ";".join(f"{a}={v[a] / lb:.2f}x" for a in ALGS)
            csv_rows.append((f"fig2/{lname}/M=2^{logM}", f"{dt_us:.0f}",
                             f"lb={lb:.3e}w {derived}"))


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
