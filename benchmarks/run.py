"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--json out.json] [--only fig4]

Prints ``name,us_per_call,derived`` CSV:
  fig2/*     - paper Fig 2 (single-processor volumes vs bound, mixed precision)
  fig3/*     - paper Fig 3 (parallel volumes vs bound)
  fig4/*     - paper Fig 4 / §5 (LP tiling vs vendor tiling, GEMMINI + TPU)
  plan/*     - unified-planner solve times (repro.plan)
  kernel/*   - Pallas/XLA kernel micro-timings
  conv/*     - measured HBM words: LP-tiled conv vs Im2Col vs Thm 2.1 bound
  autotune/* - measured frontier search: tuned vs analytic plan wall time
  dist/*     - measured inter-device words: halo-exchange conv vs all-gather
               vs the Thm 2.2/2.3 bound (live rows need the 8-device mesh)
  serving/*  - continuous-batching vs wave-lockstep serving throughput
  roofline/* - §Roofline rows from the dry-run artifacts

``--json`` additionally writes the rows as a machine-readable list of
``{"name", "us_per_call", "derived"}`` objects so successive PRs can diff the
perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump rows as JSON to PATH")
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filter on module names "
                         "(e.g. 'fig4' or 'fig4,serving')")
    args = ap.parse_args(argv)

    from . import (autotune_bench, conv_bench, dist_bench,
                   fig2_single_processor, fig3_parallel, fig4_gemmini_tiling,
                   kernel_bench, roofline_table, serving_bench)

    only = [s for s in (args.only or "").split(",") if s]
    rows = [("name", "us_per_call", "derived")]
    for mod in (fig2_single_processor, fig3_parallel, fig4_gemmini_tiling,
                kernel_bench, conv_bench, autotune_bench, dist_bench,
                serving_bench, roofline_table):
        if only and not any(s in mod.__name__ for s in only):
            continue
        try:
            mod.run(rows)
        except Exception:
            traceback.print_exc()
            rows.append((f"{mod.__name__}/ERROR", "0", "see stderr"))
    for r in rows:
        print(",".join(str(x) for x in r))
    if args.json:
        header, body = rows[0], rows[1:]
        with open(args.json, "w") as f:
            json.dump([dict(zip(header, (str(x) for x in r))) for r in body],
                      f, indent=1)
        print(f"wrote {len(body)} rows to {args.json}")


if __name__ == "__main__":
    main()
