"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV:
  fig2/*     - paper Fig 2 (single-processor volumes vs bound, mixed precision)
  fig3/*     - paper Fig 3 (parallel volumes vs bound)
  fig4/*     - paper Fig 4 / §5 (LP tiling vs vendor tiling, GEMMINI + TPU)
  kernel/*   - Pallas/XLA kernel micro-timings
  roofline/* - §Roofline rows from the dry-run artifacts
"""

from __future__ import annotations

import traceback


def main() -> None:
    from . import (fig2_single_processor, fig3_parallel, fig4_gemmini_tiling,
                   kernel_bench, roofline_table)

    rows = [("name", "us_per_call", "derived")]
    for mod in (fig2_single_processor, fig3_parallel, fig4_gemmini_tiling,
                kernel_bench, roofline_table):
        try:
            mod.run(rows)
        except Exception:
            traceback.print_exc()
            rows.append((f"{mod.__name__}/ERROR", "0", "see stderr"))
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
