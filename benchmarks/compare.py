"""Benchmark-regression gate over the ``BENCH_*.json`` trajectory.

Compares a freshly produced benchmark JSON (a list of record dicts, as
emitted by ``conv_bench``/``dist_bench``) against the committed baseline in
``benchmarks/baselines/``. Every numeric metric whose name ends in
``_words`` or ``_ratio`` is a communication quantity where *lower is
better*; the gate fails (exit 2) if any such metric grew more than the
tolerance (default 10%) over its baseline value, or if a baseline row
disappeared. New rows (new coverage) pass. Metrics ending in ``_seconds``
are wall-time quantities (the autotuner benchmark emits them): lower is
still better, but they get a looser 15% tolerance since even the modeled
alpha-beta times shift when the cost model is legitimately refined.

CLI (wired after each CI bench step):

    PYTHONPATH=src python -m benchmarks.compare BENCH_conv.json \\
        benchmarks/baselines/BENCH_conv.json
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

TOLERANCE = 0.10
# wall-time metrics drift more than audited word counts; see module docstring
WALL_TOLERANCE = 0.15

# metrics where lower is better and a >tolerance increase is a regression
_METRIC_SUFFIXES = ("_words", "_ratio")
# lower-is-better wall-time metrics gated at WALL_TOLERANCE
_WALL_SUFFIXES = ("_seconds",)


def _key(rec: dict) -> str:
    """Stable row identity: dist records carry ``name``, conv ones ``layer``."""
    return str(rec.get("name") or rec.get("layer") or rec.get("shape"))


def _metrics(rec: dict) -> Dict[str, float]:
    out = {}
    for k, v in rec.items():
        if isinstance(v, (int, float)) and not isinstance(v, bool) \
                and k.endswith(_METRIC_SUFFIXES + _WALL_SUFFIXES):
            out[k] = float(v)
    return out


def compare(current: List[dict], baseline: List[dict],
            tolerance: float = TOLERANCE,
            exact: bool = False,
            wall_tolerance: float = WALL_TOLERANCE) -> List[Tuple[str, str]]:
    """Regressions as (row key, description) pairs; empty = gate passes.

    With ``exact=True`` every metric must match the baseline bit-for-bit in
    *both* directions — the static-verification gate, where the audited word
    counts are deterministic and any drift (even an "improvement") means a
    word model silently changed."""
    cur = {_key(r): r for r in current}
    problems: List[Tuple[str, str]] = []
    for base_rec in baseline:
        key = _key(base_rec)
        if key not in cur:
            problems.append((key, "row missing from current results"))
            continue
        cur_m = _metrics(cur[key])
        for name, base_v in _metrics(base_rec).items():
            if name not in cur_m:
                problems.append((key, f"metric {name} missing"))
                continue
            cur_v = cur_m[name]
            if exact:
                if cur_v != base_v:
                    problems.append(
                        (key, f"{name} drifted from the baseline: "
                              f"{base_v!r} -> {cur_v!r}"))
                continue
            tol = wall_tolerance if name.endswith(_WALL_SUFFIXES) \
                else tolerance
            # guard the degenerate baseline (0 words: nothing may appear)
            limit = base_v * (1.0 + tol) if base_v > 0 else 1e-9
            if cur_v > limit:
                pct = ((cur_v / base_v - 1.0) * 100) if base_v > 0 \
                    else float("inf")
                problems.append(
                    (key, f"{name} regressed {pct:.1f}%: "
                          f"{base_v:.4g} -> {cur_v:.4g}"))
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="freshly produced BENCH_*.json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE,
                    help="allowed fractional growth per metric "
                         f"(default {TOLERANCE})")
    ap.add_argument("--wall-tolerance", type=float, default=WALL_TOLERANCE,
                    help="allowed fractional growth for *_seconds metrics "
                         f"(default {WALL_TOLERANCE})")
    ap.add_argument("--exact", action="store_true",
                    help="require bit-identical metrics in both directions "
                         "(the deterministic static-verification gate)")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    problems = compare(current, baseline, args.tolerance, exact=args.exact,
                       wall_tolerance=args.wall_tolerance)
    n_metrics = sum(len(_metrics(r)) for r in baseline)
    if problems:
        print(f"FAIL: {len(problems)} regression(s) vs {args.baseline}:",
              file=sys.stderr)
        for key, desc in problems:
            print(f"  {key}: {desc}", file=sys.stderr)
        return 2
    bound = "bit-identical to" if args.exact else \
        f"within {args.tolerance:.0%} of"
    print(f"OK: {len(baseline)} rows / {n_metrics} metrics {bound} "
          f"{args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
