"""Wall-clock microbench of the LP-tiled Pallas kernels (interpret mode on
CPU -> relative numbers only; the tiling decisions are the deliverable) and
of the XLA paths used by the model stack."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.conv_model import Precision
from repro.kernels import ops
from repro.kernels.matmul import matmul as matmul_pallas
from repro.plan import MatmulSpec, TPU_V5E, clear_plan_cache, plan


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(csv_rows: list) -> None:
    key = jax.random.PRNGKey(0)
    # GEMM shapes from the LM stack (qwen QKV / olmoe expert / head slice)
    for (m, n, k) in ((512, 2048, 2048), (1024, 1024, 1024)):
        a = jax.random.normal(key, (m, k), jnp.bfloat16)
        b = jax.random.normal(key, (k, n), jnp.bfloat16)
        us_x = _time(lambda x, y: ops.matmul(x, y, use_pallas=False), a, b)
        flops = 2 * m * n * k
        csv_rows.append((f"kernel/matmul_xla/{m}x{n}x{k}", f"{us_x:.0f}",
                         f"gflops={flops / us_x / 1e3:.1f}"))
        # the unified planner: cold solve time + the plan the kernel consumes
        spec = MatmulSpec(m, n, k, prec=Precision(0.5, 0.5, 1.0))
        clear_plan_cache()
        t0 = time.perf_counter()
        ep = plan(spec, TPU_V5E)
        plan_us = (time.perf_counter() - t0) * 1e6
        csv_rows.append((f"plan/matmul/{m}x{n}x{k}", f"{plan_us:.0f}",
                         f"tiles={ep.tiles} eff={ep.efficiency:.2f}"))
        us_p = _time(lambda x, y: matmul_pallas(x, y, plan=ep), a, b)
        csv_rows.append((f"kernel/matmul_pallas_interp/{m}x{n}x{k}",
                         f"{us_p:.0f}",
                         "interpret=True (correctness mode, not perf)"))
    # conv2d: ResNet conv3_x-like block at batch 8
    x = jax.random.normal(key, (8, 64, 30, 30), jnp.float32)
    w = jax.random.normal(key, (64, 64, 3, 3), jnp.float32)
    us = _time(lambda a_, b_: ops.conv2d(a_, b_, use_pallas=False), x, w)
    csv_rows.append(("kernel/conv2d_xla/8x64x30", f"{us:.0f}", "oracle-path"))
    us = _time(lambda a_, b_: ops.conv2d(a_, b_, use_pallas=True), x, w)
    csv_rows.append(("kernel/conv2d_pallas_interp/8x64x30", f"{us:.0f}",
                     "interpret=True (correctness mode, not perf)"))
    # conv1d causal (mamba short conv)
    x1 = jax.random.normal(key, (4, 512, 256), jnp.bfloat16)
    w1 = jax.random.normal(key, (4, 256), jnp.bfloat16)
    us = _time(lambda a_, b_: ops.conv1d_causal(a_, b_, use_pallas=False), x1, w1)
    csv_rows.append(("kernel/conv1d_xla/4x512x256", f"{us:.0f}", ""))


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
