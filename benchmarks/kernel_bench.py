"""Wall-clock microbench of the LP-tiled Pallas kernels (interpret mode on
CPU -> relative numbers only; the tiling decisions are the deliverable) and
of the XLA paths used by the model stack. Kernel calls route through the
``repro.ops`` dispatch subsystem (ExecutionContext -> Backend -> kernel).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import ops
from repro.core.conv_model import Precision
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul as matmul_pallas
from repro.plan import MatmulSpec, Planner, TPU_V5E

XLA = ops.ExecutionContext(target=TPU_V5E, backend="xla")
PALLAS = ops.ExecutionContext(target=TPU_V5E, backend="pallas")


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _gqa_rows(csv_rows: list, key) -> None:
    """The dispatch layer's repeat-free GQA vs the old jnp.repeat wrapper.

    The win is KV HBM traffic: repeat materializes H/Hkv copies of K and V
    before the kernel streams them; group-folding streams the original
    (B*Hkv, Lk, Dh) arrays. Wall time is interpret-mode (correctness path);
    the modeled KV words are the communication-volume deliverable."""
    B, H, Hkv, L, Dh = 1, 8, 2, 256, 64
    q = jax.random.normal(key, (B, H, L, Dh), jnp.bfloat16) * 0.3
    k = jax.random.normal(key, (B, Hkv, L, Dh), jnp.bfloat16) * 0.3
    v = jax.random.normal(key, (B, Hkv, L, Dh), jnp.bfloat16)
    kv_word = jnp.dtype(jnp.bfloat16).itemsize / 4.0

    def repeat_path(q, k, v):  # the pre-dispatch wrapper, for comparison
        rep = H // Hkv
        kk = jnp.repeat(k, rep, axis=1).reshape(B * H, L, Dh)
        vv = jnp.repeat(v, rep, axis=1).reshape(B * H, L, Dh)
        return flash_attention(q.reshape(B * H, L, Dh), kk, vv,
                               target=TPU_V5E).reshape(B, H, L, Dh)

    def grouped_path(q, k, v):  # what ops.attention(ctx=pallas) dispatches
        return ops.attention(q, k, v, ctx=PALLAS)

    us_rep = _time(jax.jit(repeat_path), q, k, v)
    us_grp = _time(jax.jit(grouped_path), q, k, v)
    words_rep = 2 * B * H * L * Dh * kv_word  # K and V, repeated to H heads
    words_grp = 2 * B * Hkv * L * Dh * kv_word
    case = f"{B}x{H}h{Hkv}kv{L}x{Dh}"
    csv_rows.append((f"kernel/attn_gqa_repeat/{case}", f"{us_rep:.0f}",
                     f"kv_hbm_words={words_rep:.0f}"))
    csv_rows.append((f"kernel/attn_gqa_grouped/{case}", f"{us_grp:.0f}",
                     f"kv_hbm_words={words_grp:.0f} "
                     f"({words_rep / words_grp:.0f}x less KV traffic, "
                     f"{us_rep / us_grp:.2f}x wall)"))


def run(csv_rows: list) -> None:
    key = jax.random.PRNGKey(0)
    # GEMM shapes from the LM stack (qwen QKV / olmoe expert / head slice)
    for (m, n, k) in ((512, 2048, 2048), (1024, 1024, 1024)):
        a = jax.random.normal(key, (m, k), jnp.bfloat16)
        b = jax.random.normal(key, (k, n), jnp.bfloat16)
        us_x = _time(jax.jit(lambda x, y: ops.matmul(x, y, ctx=XLA)), a, b)
        flops = 2 * m * n * k
        csv_rows.append((f"kernel/matmul_xla/{m}x{n}x{k}", f"{us_x:.0f}",
                         f"gflops={flops / us_x / 1e3:.1f}"))
        # the unified planner: cold solve time + the plan the kernel consumes
        spec = MatmulSpec(m, n, k, prec=Precision(0.5, 0.5, 1.0))
        Planner.cache.clear()
        t0 = time.perf_counter()
        ep = Planner(TPU_V5E).plan(spec)
        plan_us = (time.perf_counter() - t0) * 1e6
        csv_rows.append((f"plan/matmul/{m}x{n}x{k}", f"{plan_us:.0f}",
                         f"tiles={ep.tiles} eff={ep.efficiency:.2f}"))
        us_p = _time(lambda x, y: matmul_pallas(x, y, plan=ep), a, b)
        csv_rows.append((f"kernel/matmul_pallas_interp/{m}x{n}x{k}",
                         f"{us_p:.0f}",
                         "interpret=True (correctness mode, not perf)"))
    # conv2d: ResNet conv3_x-like block at batch 8
    x = jax.random.normal(key, (8, 64, 30, 30), jnp.float32)
    w = jax.random.normal(key, (64, 64, 3, 3), jnp.float32)
    us = _time(jax.jit(lambda a_, b_: ops.conv2d(a_, b_, ctx=XLA)), x, w)
    csv_rows.append(("kernel/conv2d_xla/8x64x30", f"{us:.0f}", "oracle-path"))
    us = _time(jax.jit(lambda a_, b_: ops.conv2d(a_, b_, ctx=PALLAS)), x, w)
    csv_rows.append(("kernel/conv2d_pallas_interp/8x64x30", f"{us:.0f}",
                     "interpret=True (correctness mode, not perf)"))
    # conv1d causal (mamba short conv)
    x1 = jax.random.normal(key, (4, 512, 256), jnp.bfloat16)
    w1 = jax.random.normal(key, (4, 256), jnp.bfloat16)
    us = _time(jax.jit(lambda a_, b_: ops.conv1d_causal(a_, b_, ctx=XLA)),
               x1, w1)
    csv_rows.append(("kernel/conv1d_xla/4x512x256", f"{us:.0f}", ""))
    # GQA dispatch: repeat-free group folding vs the old KV repeat
    _gqa_rows(csv_rows, key)


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
