"""Paper §5 reproduction in *measured* HBM words: the LP-tiled direct conv
vs the runnable Im2Col baseline on the five standard ResNet-50 shapes
(``configs/resnet50_convs.py``, batch 1000, bf16 streams).

Each shape is dispatched through ``ops.explain`` for both conv backends; the
``measured_words`` counters come from the exact launch geometry the kernels
lower (grid x DMA window sizes + output stores), so no 1000-image arrays are
materialized. Every row reports measured words next to the paper's Thm 2.1
lower bound (the measured/bound ratio) and the Im2Col-over-tiled gap — the
paper's headline 13-150% win. A scaled-down shape also runs end-to-end
(interpret mode) for wall-clock rows and a live correctness check.

CLI (the CI bench-smoke gate):

    PYTHONPATH=src python -m benchmarks.conv_bench --json BENCH_conv.json

exits nonzero if the tiled kernel moves more measured HBM words than Im2Col
on any swept shape.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.configs.resnet50_convs import RESNET50
from repro.plan import TPU_V5E

PALLAS = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
IM2COL = ops.ExecutionContext(target=TPU_V5E, backend="im2col")


def sweep(dtype=jnp.bfloat16):
    """Measured-words records for every ResNet-50 shape, tiled vs Im2Col."""
    records = []
    for lname, s in RESNET50.items():
        H = (s.h_O - 1) * s.sh + s.h_F  # tight VALID input extent
        W = (s.w_O - 1) * s.sw + s.w_F
        xs = jax.ShapeDtypeStruct((s.N, s.c_I, H, W), dtype)
        ws = jax.ShapeDtypeStruct((s.c_O, s.c_I, s.h_F, s.w_F), dtype)
        kw = {"spec_args": (xs, ws), "spec_kw": {"stride": (s.sh, s.sw)}}
        tiled = ops.explain("conv2d", PALLAS, **kw)
        im2 = ops.explain("conv2d", IM2COL, **kw)
        records.append({
            "layer": lname,
            "shape": f"N{s.N} {s.c_I}->{s.c_O} {s.h_O}x{s.w_O} "
                     f"f{s.h_F}x{s.w_F} s{s.sh}",
            "tiled_words": tiled.measured_words,
            "im2col_words": im2.measured_words,
            "lower_bound": tiled.plan.lower_bound,
            "tiled_ratio": tiled.bound_ratio,
            "im2col_ratio": im2.bound_ratio,
            "im2col_over_tiled": im2.measured_words / tiled.measured_words,
            "tiles": list(tiled.plan.conv_tiles()),
        })
    return records


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(csv_rows: list) -> None:
    for r in sweep():
        csv_rows.append((
            f"conv/measured/{r['layer']}", "0",
            f"tiled={r['tiled_words']:.3e}w ({r['tiled_ratio']:.2f}x bound) "
            f"im2col={r['im2col_words']:.3e}w ({r['im2col_ratio']:.2f}x) "
            f"gap={r['im2col_over_tiled']:.2f}x tiles={tuple(r['tiles'])}"))
    # one live execution (scaled-down conv3_x) for wall rows + correctness
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 32, 16, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 32, 3, 3), jnp.float32)
    got_t = ops.conv2d(x, w, ctx=PALLAS)
    got_i = ops.conv2d(x, w, ctx=IM2COL)
    got_x = ops.conv2d(x, w, ctx=ops.ExecutionContext(target=TPU_V5E,
                                                      backend="xla"))
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(got_x),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got_i), np.asarray(got_x),
                               rtol=2e-3, atol=2e-3)
    us_t = _time(lambda a, b: ops.conv2d(a, b, ctx=PALLAS), x, w)
    us_i = _time(lambda a, b: ops.conv2d(a, b, ctx=IM2COL), x, w)
    csv_rows.append(("conv/exec_tiled_interp/2x32x16", f"{us_t:.0f}",
                     "interpret=True (correctness mode, not perf)"))
    csv_rows.append(("conv/exec_im2col_interp/2x32x16", f"{us_i:.0f}",
                     "interpret=True (correctness mode, not perf)"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_conv.json", metavar="PATH",
                    help="write sweep records to PATH")
    args = ap.parse_args(argv)
    records = sweep()
    with open(args.json, "w") as f:
        json.dump(records, f, indent=1)
    bad = []
    for r in records:
        print(f"{r['layer']:9s} tiled={r['tiled_words']:.3e}w "
              f"({r['tiled_ratio']:.2f}x bound) "
              f"im2col={r['im2col_words']:.3e}w "
              f"gap={r['im2col_over_tiled']:.2f}x")
        if r["tiled_words"] >= r["im2col_words"]:
            bad.append(r["layer"])
    print(f"wrote {len(records)} records to {args.json}")
    if bad:
        print(f"FAIL: tiled conv moves >= im2col words on {bad}",
              file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
