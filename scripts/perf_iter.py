"""Perf-iteration driver: run a dry-run cell under named override variants
and print the before/after roofline deltas.

    PYTHONPATH=src python scripts/perf_iter.py --arch olmoe_1b_7b \
        --shape train_4k --variant moe4096 --set moe_groups=4096

Variants land in results/dryrun/<arch>__<shape>__<mesh>__<variant>.json and
are compared against the base record.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("REPRO_UNROLL_SCANS", "1")

import argparse  # noqa: E402
import json  # noqa: E402

from repro.launch.dryrun import ensure_dryrun_devices  # noqa: E402

ensure_dryrun_devices()


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v in ("true", "false"):
        return k, v == "true"
    try:
        return k, int(v)
    except ValueError:
        return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="override as key=value (repeatable)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import RESULTS_DIR, run_cell

    overrides = dict(parse_override(kv) for kv in args.set)
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   variant=args.variant, overrides=overrides)

    mesh = rec["mesh"]
    base_fn = os.path.join(RESULTS_DIR, f"{args.arch}__{args.shape}__{mesh}.json")
    if os.path.exists(base_fn):
        with open(base_fn) as f:
            base = json.load(f)
        print(f"\n=== {args.variant} vs base ({args.arch} x {args.shape} x {mesh}) ===")
        for term in ("compute_s", "memory_s", "collective_s", "step_time_s", "mfu"):
            b, v = base[term], rec[term]
            delta = (v - b) / b * 100 if b else float("nan")
            print(f"  {term:13s} {b:.6g} -> {v:.6g}  ({delta:+.1f}%)")
        print(f"  dominant      {base['dominant']} -> {rec['dominant']}")


if __name__ == "__main__":
    main()
