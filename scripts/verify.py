"""Static verification sweep: every registered instrumented op, no device.

For each op x shape in the sweep (the five ResNet-50 conv shapes from
``configs/resnet50_convs.py`` on both conv backends — plus their int8
``conv2d_q``/``matmul_q`` quantized forms, whose folded scale vector is an
audited operand of its own — the GEMM / conv1d / attention shapes the
tier-1 suite exercises, and the serving decode snapshots from
``benchmarks/serving_bench``), dispatch through
``ops.explain(audit=True)``: the ``repro.verify`` auditor abstractly
interprets the kernel's access plan and the dispatch fails unless the
audited words reproduce ``words_fn`` exactly, fit VMEM, and the DMA
schedule is hazard-free. The run itself is therefore the assertion; rows
are also emitted for the cross-leg byte-identity gate in CI.

    PYTHONPATH=src python scripts/verify.py --json VERIFY.json
    PYTHONPATH=src python scripts/verify.py --mutants   # auditor self-test

Exit codes: 0 clean; 1 audit/lint violations; 3 a seeded mutant escaped.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_REPO / "src"))
sys.path.insert(0, str(_REPO))  # benchmarks.* (serving snapshot geometry)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import ops  # noqa: E402
from repro.configs.resnet50_convs import RESNET50  # noqa: E402
from repro.plan import TPU_V5E  # noqa: E402
from repro.verify import install_plan_audit  # noqa: E402
from repro.verify.lint import run_lint  # noqa: E402

PALLAS = ops.ExecutionContext(target=TPU_V5E, backend="pallas")
IM2COL = ops.ExecutionContext(target=TPU_V5E, backend="im2col")


def _row(name: str, decision) -> dict:
    assert decision.audited is not None, f"{name}: dispatch was not audited"
    return {
        "name": name,
        "chosen": decision.chosen,
        "measured_words": decision.measured_words,
        "audited_words": decision.audited,
        "bound_ratio": decision.bound_ratio,
    }


def sweep_convs(dtype=jnp.bfloat16):
    """The conv_bench shape sweep, audited, on both conv backends."""
    rows = []
    for lname, s in RESNET50.items():
        H = (s.h_O - 1) * s.sh + s.h_F
        W = (s.w_O - 1) * s.sw + s.w_F
        xs = jax.ShapeDtypeStruct((s.N, s.c_I, H, W), dtype)
        ws = jax.ShapeDtypeStruct((s.c_O, s.c_I, s.h_F, s.w_F), dtype)
        kw = {"spec_args": (xs, ws), "spec_kw": {"stride": (s.sh, s.sw)},
              "audit": True}
        rows.append(_row(f"conv2d/{lname}/pallas",
                         ops.explain("conv2d", PALLAS, **kw)))
        rows.append(_row(f"conv2d/{lname}/im2col",
                         ops.explain("conv2d", IM2COL, **kw)))
    return rows


def sweep_gemm_conv1d(dtype=jnp.bfloat16):
    rows = []
    for m, k, n in ((512, 384, 256), (2048, 2048, 2048), (23328, 576, 64)):
        a = jax.ShapeDtypeStruct((m, k), dtype)
        b = jax.ShapeDtypeStruct((k, n), dtype)
        rows.append(_row(
            f"matmul/{m}x{k}x{n}",
            ops.explain("matmul", PALLAS, spec_args=(a, b), audit=True)))
    for B, L, D, K in ((2, 33, 130, 4), (4, 256, 512, 4)):
        x = jax.ShapeDtypeStruct((B, L, D), dtype)
        w = jax.ShapeDtypeStruct((K, D), dtype)
        rows.append(_row(
            f"conv1d_causal/B{B}_L{L}_D{D}_K{K}",
            ops.explain("conv1d_causal", PALLAS, spec_args=(x, w),
                        audit=True)))
    return rows


def sweep_quant():
    """Quantized int8 conv2d/matmul dispatches, audited like the bf16 sweep.
    The scale vector is a separately-audited operand here, so these rows
    also pin the one-shot scale-fetch accounting the ``scale_applied_twice``
    mutant perturbs."""
    rows = []
    for lname, s in RESNET50.items():
        H = (s.h_O - 1) * s.sh + s.h_F
        W = (s.w_O - 1) * s.sw + s.w_F
        xs = jax.ShapeDtypeStruct((s.N, s.c_I, H, W), jnp.int8)
        ws = jax.ShapeDtypeStruct((s.c_O, s.c_I, s.h_F, s.w_F), jnp.int8)
        sc = jax.ShapeDtypeStruct((1, s.c_O), jnp.float32)
        rows.append(_row(f"conv2d_q/{lname}/pallas", ops.explain(
            "conv2d_q", PALLAS, dtype="int8", spec_args=(xs, ws, sc),
            spec_kw={"stride": (s.sh, s.sw)}, audit=True)))
    for m, k, n in ((512, 384, 256), (2048, 2048, 2048)):
        a = jax.ShapeDtypeStruct((m, k), jnp.int8)
        b = jax.ShapeDtypeStruct((k, n), jnp.int8)
        sc = jax.ShapeDtypeStruct((1, n), jnp.float32)
        rows.append(_row(f"matmul_q/{m}x{k}x{n}", ops.explain(
            "matmul_q", PALLAS, dtype="int8", spec_args=(a, b, sc),
            audit=True)))
    return rows


def sweep_attention(dtype=jnp.bfloat16):
    """Prefill + contiguous decode + paged decode, mirroring serving_bench."""
    import dataclasses

    from benchmarks.serving_bench import BATCH, BLOCK, MAX_LEN, SNAPSHOTS
    from repro.configs import get_smoke
    from repro.serving import kv

    rows = []
    # prefill-style static attention
    q = jax.ShapeDtypeStruct((2, 8, 128, 64), dtype)
    kvs = jax.ShapeDtypeStruct((2, 8, 128, 64), dtype)
    rows.append(_row("attention/prefill_B2_H8_L128",
                     ops.explain("attention", PALLAS, spec_args=(q, kvs, kvs),
                                 audit=True)))
    cfg = dataclasses.replace(get_smoke("qwen2_5_3b"),
                              compute_dtype="float32")
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    num_blocks = kv.plan_pool_blocks(cfg, MAX_LEN, BATCH, BLOCK)
    for name, B, live in SNAPSHOTS:
        w = -(-live // BLOCK)
        qd = jax.ShapeDtypeStruct((B, H, 1, hd), dtype)
        rows.append(_row(f"attention_decode/{name}", ops.explain(
            "attention_decode", PALLAS,
            spec_args=(qd,
                       jax.ShapeDtypeStruct((num_blocks, KV, BLOCK, hd), dtype),
                       jax.ShapeDtypeStruct((num_blocks, KV, BLOCK, hd), dtype),
                       jax.ShapeDtypeStruct((B, w), jnp.int32),
                       jax.ShapeDtypeStruct((B,), jnp.int32)),
            audit=True)))
        rows.append(_row(f"attention_contig/{name}", ops.explain(
            "attention", PALLAS,
            needs=ops.attention_needs(q_offset=jnp.arange(B)),
            spec_args=(qd,
                       jax.ShapeDtypeStruct((B, KV, MAX_LEN, hd), dtype),
                       jax.ShapeDtypeStruct((B, KV, MAX_LEN, hd), dtype)),
            spec_kw={"q_offset": jnp.full((B,), live, jnp.int32)},
            audit=True)))
    return rows


def run_mutants() -> int:
    from repro.verify.mutants import run_seeded_mutants

    escaped = 0
    for name, caught, detail in run_seeded_mutants():
        tag = "caught" if caught else "ESCAPED"
        print(f"mutant {name:20s} {tag}: {detail[:100]}")
        escaped += 0 if caught else 1
    return escaped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", help="write audited rows to this path")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--mutants", action="store_true",
                    help="run the seeded-mutant self-test and exit")
    args = ap.parse_args(argv)

    if args.mutants:
        escaped = run_mutants()
        if escaped:
            print(f"verify: {escaped} seeded mutant(s) escaped the auditor")
            return 3
        print("verify: all seeded mutants caught")
        return 0

    rc = 0
    if not args.skip_lint:
        found = run_lint()
        for viol in found:
            print(viol)
        if found:
            print(f"verify: {len(found)} lint violation(s)")
            rc = 1

    # every plan built below also passes construction-time validation
    install_plan_audit()

    rows = []
    try:
        rows += sweep_convs()
        rows += sweep_gemm_conv1d()
        rows += sweep_quant()
        rows += sweep_attention()
    except Exception as e:
        print(f"verify: FAILED — {e}")
        return 1

    mismatched = [r for r in rows
                  if abs(r["audited_words"] - r["measured_words"])
                  > 1e-6 * max(r["measured_words"], 1.0)]
    from repro.analysis.roofline import hbm_seconds

    for r in rows:
        print(f"{r['name']:40s} [{r['chosen']:6s}] "
              f"words={r['measured_words']:.6e} "
              f"(~{hbm_seconds(r['measured_words']) * 1e6:.1f}us HBM) "
              f"audited exactly")
    print(f"verify: {len(rows)} dispatches audited, "
          f"{len(mismatched)} mismatched")
    if mismatched:
        rc = 1

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.json} ({len(rows)} rows)")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
