"""Regenerate EXPERIMENTS.md §Dry-run / §Roofline tables and the §Perf log
from results/dryrun/*.json. Idempotent; run after any dry-run/perf pass.

    PYTHONPATH=src python scripts/finalize_experiments.py
"""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import report  # noqa: E402

REPO = os.path.join(os.path.dirname(__file__), "..")
RESULTS = os.path.join(REPO, "results", "dryrun")

PERF_ENTRIES = [
    # (cell-title, variant, hypothesis, outcome)
    ("olmoe-1b-7b × train_4k (most collective-bound: 16.29s collective term, "
     "814 GB/chip wire)",
     "moe_g4096",
     "The GShard combine/dispatch one-hots are (G,Tg,E,C) with C ∝ Tg, so "
     "total size ∝ T·Tg·k·f. Shrinking groups 256→4096 (Tg 4096→256) cuts "
     "the tensors 16× and with them the partial-sum all-reduces GSPMD emits "
     "on the G↔E reshard. Predicted ≥5× collective reduction.",
     "CONFIRMED: collective −78.8% (16.29s → 3.46s), compute −52% (smaller "
     "one-hot einsums), memory −40%. step-time −78.8%, roofline-MFU "
     "0.0098 → 0.0462 (4.7×)."),
    ("olmoe-1b-7b × train_4k",
     "moe_hints",
     "The all-reduce volume is GSPMD *replicating* the big one-hots when "
     "resolving the G-sharded → E-sharded einsum chain. Pinning the dispatch "
     "path with with_sharding_constraint (G on (data,model), E on model) "
     "should force all-to-alls instead. Predicted ~2× collective cut alone.",
     "CONFIRMED: collective −56.5% (16.29s → 7.09s) with no other term "
     "changing (+1% compute, +5% memory)."),
    ("olmoe-1b-7b × train_4k",
     "moe_g4096_hints",
     "The two mechanisms are independent (size × routing) and should "
     "compose.",
     "CONFIRMED: collective −84.4% (16.29s → 2.54s); **dominant term flipped "
     "collective → memory** — the hillclimb on the collective term is "
     "converged; step-time 16.29s → 3.46s (now memory-bound)."),
    ("qwen2.5-3b × train_4k (memory-dominant dense train)",
     "noseqshard",
     "Ablation of our default sequence-parallel activation sharding "
     "(P(data, model, None)): without it, every norm/residual is replicated "
     "16× across `model`. Predict ≥3× memory-term regression (this is the "
     "baseline-vs-paper-faithful comparison: the paper's §4.2 blocking has "
     "no SP notion).",
     "CONFIRMED: memory +375% (3.53s → 16.76s), collective +269%, MFU "
     "0.120 → 0.025. Our SP default is a 4.75× step-time optimization over "
     "the non-SP layout."),
    ("qwen2.5-3b × train_4k",
     "bf16params",
     "bf16 parameter storage (f32 optimizer moments kept) halves parameter "
     "HBM traffic; params are ~1% of train bytes at 1M tokens/step, so "
     "predict ≤2% gain — run to measure, expect ~neutral.",
     "REFUTED (as suspected): +0.6% memory — parameter bytes are noise "
     "next to activations at this batch; kept f32 params as default."),
    ("stablelm-1.6b × decode_32k (worst roofline-MFU: 5.2e-5)",
     "fusedkv",
     "One fused (B,KV,L,2,hd) cache halves the dynamic-update-slice count "
     "per step (2 DUS → 1). Predicted ~2× cut of the DUS-dominated bytes.",
     "REFUTED: memory +187%. The fused layout forces a stack(k,v) copy on "
     "write and — decisive — strided reads ckv[...,0,:] / ckv[...,1,:] that "
     "materialize full-cache slices on every layer. Split caches read "
     "in-place; fused caches pay two extra full-cache copies. Reverted "
     "(flag kept for the record)."),
    ("stablelm-1.6b × decode_32k",
     "batchonly",
     "Control experiment: unshard the cache length axis (batch-only "
     "sharding). Cache/chip grows 16×; predicted large memory regression.",
     "CONFIRMED (as a negative control): memory +1087%, and GSPMD now "
     "emits 2.06s of collectives (cache gathers). Sequence-sharded KV with "
     "GSPMD's flash-decode all-reduce pattern is the right production "
     "layout."),
    ("qwen2.5-3b × decode_32k",
     "f32compute",
     "Decode hlo_bytes are dominated by bf16→f32 `convert`s of cache/weight "
     "tensors (75.8 GB of convert results found by opcode profiling). If "
     "those converts come from the *compute* dtype, f32 compute should "
     "remove them.",
     "REFUTED: byte-identical terms (−0.0%). The converts are the CPU "
     "backend's bf16 *storage* legalization, independent of compute dtype — "
     "quantifying them as a measurement artifact that a real TPU (native "
     "bf16) does not pay. Recorded as a §Roofline caveat, not a real "
     "bottleneck."),
]


def _summary_table() -> str:
    cells = [
        ("olmoe_1b_7b", "train_4k", "moe_g4096_hints", "most collective-bound"),
        ("stablelm_1_6b", "decode_32k", None, "worst roofline fraction"),
        ("jamba_1_5_large", "train_4k", "moe_g4096_hints", "paper-representative"),
    ]
    rows = ["| cell (criterion) | baseline step / MFU | optimized step / MFU | Δ |",
            "|---|---|---|---|"]
    for arch, shape, var, why in cells:
        bfn = os.path.join(RESULTS, f"{arch}__{shape}__16x16.json")
        if not os.path.exists(bfn):
            continue
        b = json.load(open(bfn))
        if var and os.path.exists(bfn.replace(".json", f"__{var}.json")):
            v = json.load(open(bfn.replace(".json", f"__{var}.json")))
            rows.append(
                f"| {arch} × {shape} ({why}) "
                f"| {b['step_time_s']:.2f}s / {b['mfu']:.4f} "
                f"| {v['step_time_s']:.2f}s / {v['mfu']:.4f} "
                f"| **{(1 - v['step_time_s'] / b['step_time_s']) * 100:.0f}% "
                f"step time** |")
        else:
            rows.append(
                f"| {arch} × {shape} ({why}) "
                f"| {b['step_time_s'] * 1e3:.1f}ms / {b['mfu']:.4f} "
                f"| (all tried variants regressed — baseline layout is the "
                f"optimum found; see log) | — |")
    return "\n".join(rows)


def perf_block() -> str:
    out = []
    out.append(
        "**Paper-faithful baseline vs beyond-paper optimized, per cell:**\n")
    out.append(_summary_table())
    out.append(
        "\nThe *baseline* is the paper-faithful configuration: LP-derived "
        "tiling + LP-ranked sharding (batch→data, features/experts→model), "
        "remat, chunked CE — i.e. the paper's machinery applied as-is. The "
        "*optimized* columns add beyond-paper changes (MoE dispatch-group "
        "sizing + pinned dispatch shardings) the paper does not discuss.\n")
    out.append(
        "Methodology: hypothesis → napkin math → change → re-lower → "
        "compare (scripts/perf_iter.py). Variant artifacts live next to the "
        "baselines as `*__<variant>.json`. Three cells were picked per the "
        "assignment (worst roofline fraction, most collective-bound, most "
        "paper-representative); negative results are kept — a refuted "
        "hypothesis pins down the measurement model.\n")
    cur = None
    for cell, variant, hyp, res in PERF_ENTRIES:
        if cell != cur:
            out.append(f"\n### {cell}\n")
            cur = cell
        out.append(f"**[{variant}]**")
        out.append(f"- *Hypothesis:* {hyp}")
        out.append(f"- *Result:* {res}\n")
    # jamba entries are appended programmatically when present
    jn = os.path.join(RESULTS, "jamba_1_5_large__train_4k__16x16.json")
    out.append(_jamba_block(jn))
    return "\n".join(out)


def _jamba_block(base_fn: str) -> str:
    if not os.path.exists(base_fn):
        return ("\n### jamba-1.5-large × train_4k (paper-representative: "
                "mamba conv1d + MoE + attention)\n\nBaseline cell pending "
                "(longest compile of the sweep).")
    with open(base_fn) as f:
        b = json.load(f)
    lines = [
        "\n### jamba-1.5-large × train_4k (paper-representative: mamba "
        "conv1d + MoE + attention)\n",
        f"Baseline: compute {b['compute_s']*1e3:.0f}ms / memory "
        f"{b['memory_s']*1e3:.0f}ms / collective {b['collective_s']*1e3:.0f}ms "
        f"→ dominant **{b['dominant']}**, roofline-MFU {b['mfu']:.4f}, "
        f"useful-FLOP fraction {b['useful_flops_frac']:.3f} "
        f"(SSD chunk {b.get('chunk_size', '?')}).",
    ]
    lines.append(
        "\n*Hypotheses:* (1) **[moe_g4096_hints]** jamba's MoE layers share "
        "olmoe's pathology — G=256 groups make (G,Tg,E,C) one-hots huge and "
        "GSPMD replicates them across `model`; smaller groups + pinned "
        "dispatch shardings should collapse the 95s collective term. "
        "*Result:* CONFIRMED — collective −36.3% (95.2s → 60.7s), MFU "
        "0.122 → 0.191 (+57%). Smaller relative win than olmoe: jamba's "
        "collective also carries 398B-param gradient reduction and mamba "
        "activation reshards that the MoE fix does not touch. "
        "(2) **[chunk1024]** halving the SSD chunk (2048→1024) should cut "
        "the (B,c,c,H) decay traffic ~2× on the mamba share. *Result:* "
        "REFUTED — step +1.7%: the per-chunk decay tensor shrinks 4× but "
        "there are 2× more chunks and the inter-chunk state/carry terms "
        "double; net memory +2.1%. The SSD chunk sweet spot is flat near "
        "c≈2k for these shapes, so the LP-style capacity reasoning (bigger "
        "tiles amortize) wins again.\n\n"
        "*Residual attribution* (op_name profiling of the optimized R=1 "
        "program): the remaining collective volume is ~60% backward-pass "
        "all-gathers (`transpose(jvp)` — re-gathering sequence-sharded "
        "activations for weight-gradient dots) and ~40% forward dot "
        "all-gathers at the SP↔TP boundary. Both are the textbook "
        "sequence-parallel gather/scatter pairs that XLA's latency-hiding "
        "scheduler overlaps with the surrounding GEMMs on real TPUs — the "
        "roofline's no-overlap assumption (step = max of terms) makes them "
        "look like a hard wall here. Next lever on hardware: "
        "reduce-scatter'ed weight-grad accumulation (ZeRO-2) to halve the "
        "backward gather volume.\n")
    for variant in ("moe_g4096_hints", "chunk1024"):
        fn = base_fn.replace(".json", f"__{variant}.json")
        if os.path.exists(fn):
            with open(fn) as f:
                v = json.load(f)
            lines.append(
                f"- **[{variant}]** step {b['step_time_s']:.2f}s → "
                f"{v['step_time_s']:.2f}s ({(v['step_time_s']/b['step_time_s']-1)*100:+.1f}%), "
                f"memory {(v['memory_s']/b['memory_s']-1)*100:+.1f}%, "
                f"collective {(v['collective_s']/b['collective_s']-1)*100:+.1f}%, "
                f"MFU {b['mfu']:.4f} → {v['mfu']:.4f}.")
    return "\n".join(lines)


def main():
    recs = report.load("base")
    md = open(os.path.join(REPO, "EXPERIMENTS.md")).read()

    n_single = sum(1 for r in recs if r["mesh"] == "16x16")
    n_multi = sum(1 for r in recs if r["mesh"] == "2x16x16")
    dr = (f"**{n_single} cells on 16×16 (256 chips) and {n_multi} on "
          f"2×16×16 (512 chips) lowered + compiled green.**\n\n"
          + report.dryrun_table(recs))
    md = md.split("<!-- DRYRUN_TABLE -->")[0] + "<!-- DRYRUN_TABLE -->\n" + dr \
        + "\n\n## §Roofline — single-pod (16×16 = 256 chips)" \
        + md.split("## §Roofline — single-pod (16×16 = 256 chips)", 1)[1]

    rt = report.roofline_table(recs, "16x16")
    picks = report.pick_hillclimb_cells(recs)
    picks_txt = "\n".join(
        f"- **{k}** → {v['arch']} × {v['shape']} (MFU {v['mfu']:.4f}, "
        f"dominant {v['dominant']})" for k, v in picks.items())
    rl_block = rt + "\n\n**Hillclimb cell selection:**\n" + picks_txt
    md = md.split("<!-- ROOFLINE_TABLE -->")[0] + "<!-- ROOFLINE_TABLE -->\n" \
        + rl_block + "\n\n## §Perf — hillclimb log (3 cells)\n\n" \
        + "<!-- PERF_SECTION -->\n" + perf_block() + "\n"

    with open(os.path.join(REPO, "EXPERIMENTS.md"), "w") as f:
        f.write(md)
    print(f"EXPERIMENTS.md regenerated: {len(recs)} base records, "
          f"{len(glob.glob(os.path.join(RESULTS, '*__*__*__*.json')))} variants")


if __name__ == "__main__":
    main()
